"""Quickstart: the MSFP quantization core in 60 seconds.

Demonstrates the paper's Observation 1 + mixup-sign selection on raw
tensors, then packs a weight to deployment W4 and matmuls through the
kernel path.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pack_weight, w4_dense_xla
from repro.kernels import ops
from repro.quant import (search_activation_params, search_signed_fp,
                         search_unsigned_fp, search_weight_params)

rng = np.random.default_rng(0)

# --- 1. Signed FP4 is fine for symmetric data -----------------------------
sym = rng.normal(size=50_000).astype(np.float32)
r = search_signed_fp(sym, 4)
print(f"symmetric  : best={r.params.fmt.name} maxval={float(r.params.maxval):.3f} "
      f"mse={r.mse:.5f}")

# --- 2. ...but fails on SiLU outputs (the paper's AALs) --------------------
silu = sym / (1 + np.exp(-sym))
rs = search_signed_fp(silu, 4)
ru = search_unsigned_fp(silu, 4)  # unsigned + zero-point (Eq. 8)
print(f"SiLU signed  : {rs.params.fmt.name:6s} mse={rs.mse:.5f}")
print(f"SiLU unsigned: {ru.params.fmt.name:6s} mse={ru.mse:.5f} "
      f"zp={float(ru.params.zero_point):.3f}  "
      f"({rs.mse / ru.mse:.1f}x better)")

# --- 3. Mixup-sign selection (Alg. 1) picks the right one per site ---------
for name, data in [("attn.q (NAL)", sym), ("mlp.down (AAL)", silu)]:
    best = search_activation_params(data, 4, allow_unsigned=True)
    kind = "unsigned+zp" if best.params.kind == 1 else "signed"
    print(f"mixup-sign @ {name:14s} -> {kind:12s} ({best.params.fmt.name})")

# --- 4. Deployment: pack a weight to 4-bit codes, matmul through W4 path ---
w = jnp.asarray(rng.normal(size=(256, 128)).astype(np.float32))
qp = search_weight_params(w, 4).params
pw = pack_weight(w, qp)
x = jnp.asarray(rng.normal(size=(8, 256)).astype(np.float32)).astype(jnp.bfloat16)
y_q = ops.w4_matmul(x, pw)
y_fp = x @ w.astype(jnp.bfloat16)
rel = float(jnp.linalg.norm((y_q - y_fp).astype(jnp.float32))
            / jnp.linalg.norm(y_fp.astype(jnp.float32)))
print(f"packed W4: {w.size * 4 // 8} bytes (vs {w.size * 2} bf16), "
      f"matmul rel err {rel:.3f}")
