"""Walkthrough: one multi-pod dry-run cell + its roofline terms.

Spawns the dry-run (it must own jax initialization for the 512 host
devices) for a single (arch, shape) on the 2x16x16 mesh, then prints the
derived roofline terms — the minimal version of what
``python -m repro.launch.dryrun --arch all --shape all --mesh both`` does
for the full matrix.

    PYTHONPATH=src python examples/multipod_dryrun.py --arch gemma3-4b --shape decode_32k
"""
import argparse
import json
import os
import subprocess
import sys
import tempfile


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--quant", default="w4")
    ap.add_argument("--kv", default="fp4")
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as d:
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", args.arch, "--shape", args.shape, "--mesh", "multi",
               "--quant", args.quant, "--kv", args.kv, "--out", d]
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
        print("+", " ".join(cmd))
        subprocess.run(cmd, check=True, env=env)
        rec = json.load(open(os.path.join(d, os.listdir(d)[0])))

    from benchmarks.roofline import analyze, fmt_s
    r = analyze(rec)
    print(f"\ncell {r['cell']} on {rec['chips']} chips "
          f"(quant={args.quant}, kv={args.kv})")
    print(f"  compute    {fmt_s(r['compute_s'])}")
    print(f"  memory     {fmt_s(r['memory_s'])}")
    print(f"  collective {fmt_s(r['collective_s'])}")
    print(f"  dominant   {r['dominant']}   roofline-frac {r['roofline_frac']:.3f}")
    print(f"  HBM/dev    {r['hbm_gb_per_dev']:.2f} GB")


if __name__ == "__main__":
    main()
