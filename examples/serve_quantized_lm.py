"""Quantized LM serving: bf16 vs W4 weights vs W4 + FP4 KV cache.

Runs the same prompts through three serving configurations of a reduced
LM and reports memory footprints + agreement of generations — the
deployment story of the paper applied to the assigned LM architectures.

    PYTHONPATH=src python examples/serve_quantized_lm.py --arch qwen1.5-0.5b
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.tree import tree_bytes
from repro.configs.registry import get_config
from repro.launch.steps import make_decode_fn, quantize_lm_for_serving
from repro.models.lm import init_caches, lm_init


def generate(cfg, params, prompts, gen_len: int):
    s_max = prompts.shape[1] + gen_len
    caches = init_caches(cfg, prompts.shape[0], s_max)
    dec = jax.jit(make_decode_fn(cfg))
    logits = None
    for i in range(prompts.shape[1]):
        logits, caches = dec(params, caches, prompts[:, i:i + 1], jnp.int32(i))
    toks = []
    tok = jnp.argmax(logits[:, -1:], axis=-1)
    for i in range(gen_len):
        toks.append(np.asarray(tok)[:, 0])
        logits, caches = dec(params, caches, tok,
                             jnp.int32(prompts.shape[1] + i))
        tok = jnp.argmax(logits[:, -1:], axis=-1)
    return np.stack(toks, 1), caches


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--gen-len", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    key = jax.random.PRNGKey(0)
    params = lm_init(key, cfg)
    prompts = jax.random.randint(key, (2, 8), 0, cfg.vocab)

    ref, caches_bf = generate(cfg, params, prompts, args.gen_len)
    print(f"bf16    params={tree_bytes(params) / 1e6:6.2f}MB "
          f"kv={tree_bytes(caches_bf) / 1e6:6.2f}MB  gen[0]={ref[0][:10]}")

    w4 = quantize_lm_for_serving(params, searched=False)
    out_w4, _ = generate(cfg, w4, prompts, args.gen_len)
    agree = float((out_w4 == ref).mean())
    print(f"W4      params={tree_bytes(w4) / 1e6:6.2f}MB "
          f"(agree {agree:.0%})            gen[0]={out_w4[0][:10]}")

    cfg4 = dataclasses.replace(cfg, kv_dtype="fp4")
    out_kv4, caches_kv4 = generate(cfg4, w4, prompts, args.gen_len)
    agree4 = float((out_kv4 == ref).mean())
    print(f"W4+KV4  params={tree_bytes(w4) / 1e6:6.2f}MB "
          f"kv={tree_bytes(caches_kv4) / 1e6:6.2f}MB (agree {agree4:.0%}) "
          f"gen[0]={out_kv4[0][:10]}")


if __name__ == "__main__":
    main()
