"""End-to-end driver: the paper's full recipe on a CPU-trainable DDIM.

  1. Train a small DDIM eps-predictor on the synthetic image distribution
     (a few hundred steps — the 'train ~100M-class model' e2e driver).
  2. Build the Q-Diffusion calibration set from FP trajectories.
  3. MSFP search -> W4A4 fake-quantized model.
  4. Attach TALoRA (h=2, rank 8), fine-tune with the DFA loss.
  5. Report the denoising-gap metrics before/after + router allocation.

    PYTHONPATH=src python examples/finetune_ddim_w4a4.py [--steps 400]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import get_tiny_ddim
from repro.core import allocation_histogram
from repro.core.talora import TALoRAConfig
from repro.diffusion.pipeline import (build_calibration_set,
                                      quantize_diffusion, sample_quantized)
from repro.train.finetune import FinetuneConfig, eval_denoising_gap, finetune


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--epochs", type=int, default=6)
    ap.add_argument("--retrain", action="store_true")
    args = ap.parse_args()

    print("== stage 1: FP teacher (trained tiny DDIM) ==")
    params, cfg, sched = get_tiny_ddim(retrain=args.retrain, steps=args.steps)

    print("== stage 2/3: calibrate + MSFP W4A4 ==")
    key = jax.random.PRNGKey(0)
    calib = build_calibration_set(params, cfg, sched, key, n_samples=8,
                                  steps=10, batch=4)
    bundle = quantize_diffusion(
        params, cfg, sched, key, bits_w=4, bits_a=4, mode="msfp", calib=calib,
        talora_cfg=TALoRAConfig(hub_size=2, rank=8, t_emb_dim=128,
                                router_hidden=64))
    print("   plan:", bundle.plan.summary())

    ft = FinetuneConfig(steps_per_epoch=10, epochs=args.epochs, batch=8,
                        loss_mode="dfa", router_mode="learned")
    before = eval_denoising_gap(bundle, ft, jax.random.PRNGKey(9), steps=10)
    print(f"   PTQ-only: final_image_mse={before['final_image_mse']:.5f}")

    print("== stage 4: TALoRA + DFA fine-tune ==")
    bundle, logs = finetune(bundle, ft, log=print)
    after = eval_denoising_gap(bundle, ft, jax.random.PRNGKey(9), steps=10)
    print(f"   after FT: final_image_mse={after['final_image_mse']:.5f} "
          f"({before['final_image_mse'] / max(after['final_image_mse'], 1e-12):.1f}x better)")

    print("== stage 5: router allocation over timesteps (paper Fig. 7) ==")
    names = sorted(bundle.hubs)
    hist = allocation_histogram(bundle.router, jnp.linspace(0, sched.T - 1, 10),
                                names, bundle.talora_cfg)
    for i, t in enumerate(np.linspace(0, sched.T - 1, 10).astype(int)):
        bars = "".join("#" if v > 0.5 else "." for v in np.asarray(hist[i]))
        print(f"   t={t:4d}  hub usage {np.asarray(hist[i]).round(2)} {bars}")

    x = sample_quantized(bundle, jax.random.PRNGKey(3), n=4, steps=10)
    np.save("experiments/w4a4_samples.npy", np.asarray(x))
    print("samples -> experiments/w4a4_samples.npy", x.shape)


if __name__ == "__main__":
    main()
