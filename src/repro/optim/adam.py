"""Adam/AdamW in pure JAX: dtype-configurable moments, clipping, schedules.

No optax on-box; this is the real optimizer used by the trainer and the
TALoRA fine-tune loop. Moments dtype matters at scale: kimi-k2 (1T params)
only fits a v5e pod-pair with bf16 moments (see EXPERIMENTS §Roofline), so
``moment_dtype`` is a first-class config.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 1e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: float | None = 1.0
    moment_dtype: Any = jnp.float32
    schedule: str = "constant"     # constant | cosine | linear_warmup_cosine
    warmup_steps: int = 0
    total_steps: int = 10_000


def lr_at(cfg: AdamConfig, step: jnp.ndarray) -> jnp.ndarray:
    s = step.astype(jnp.float32)
    lr = jnp.float32(cfg.lr)
    if cfg.schedule == "constant":
        return lr
    warm = jnp.minimum(1.0, s / jnp.maximum(cfg.warmup_steps, 1))
    if cfg.schedule == "linear_warmup_cosine" or cfg.schedule == "cosine":
        frac = jnp.clip((s - cfg.warmup_steps)
                        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                        0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return lr * warm * cos
    raise ValueError(cfg.schedule)


def adam_init(params: Any, cfg: AdamConfig) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32)))
              for l in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adam_update(grads: Any, state: dict, params: Any,
                cfg: AdamConfig) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    lr = lr_at(cfg, step)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = lr * mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + lr * cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - delta).astype(p.dtype),
                m_new.astype(cfg.moment_dtype), v_new.astype(cfg.moment_dtype))

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    # unzip the 3-tuples
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}


@dataclasses.dataclass
class EMA:
    """Exponential moving average of params (diffusion training standard)."""
    decay: float = 0.999

    def init(self, params):
        return jax.tree.map(lambda p: p.astype(jnp.float32), params)

    def update(self, ema, params):
        d = self.decay
        return jax.tree.map(
            lambda e, p: d * e + (1 - d) * p.astype(jnp.float32), ema, params)
