"""optim substrate."""
