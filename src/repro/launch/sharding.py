"""Parameter/input sharding rules (logical -> mesh PartitionSpec).

Strategy (GSPMD, Megatron-style TP x FSDP):
  * ``model`` axis: tensor parallel — attention heads, MLP hidden, MoE
    experts, vocab (embed rows / lm_head cols).
  * ``data`` axis: batch + FSDP (ZeRO-3): every >=2D weight additionally
    shards a non-TP dim over ``data``; with scan-over-layers GSPMD
    all-gathers one layer's params per scan step (the standard FSDP
    prefetch pattern).
  * ``pod`` axis (multi-pod): batch DP; optionally joins FSDP
    (``fsdp_over_pod``) for models that cannot fit a single pod's HBM
    (kimi-k2 training).

Rules are path-pattern based so they cover every architecture family with
one table; divisibility is checked per-dim and axes that don't divide are
dropped (e.g. batch 1 in long_500k stays unsharded).
"""
from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.common.tree import flatten_paths, unflatten_paths
from repro.core.qmodule import PackedW4


def _fits(dim: int, axes: tuple[str, ...], sizes: dict) -> tuple | None:
    kept, prod = [], 1
    for a in axes:
        if a in sizes and dim % (prod * sizes[a]) == 0:
            kept.append(a)
            prod *= sizes[a]
    if not kept:
        return None
    return kept[0] if len(kept) == 1 else tuple(kept)


# (regex on path, per-dim logical axes counted from the LAST dims).
# 'F' = fsdp axes, 'M' = model axis. Entries align to the trailing dims so
# the same rule covers scanned (G, ...) stacks (leading dims replicate).
_RULES: list[tuple[str, tuple]] = [
    # embed: vocab-sharded only — sharding D as well makes the token gather
    # unpartitionable (SPMD falls back to full rematerialization)
    (r"embed$", ("M", None)),                      # (V, D)
    (r"lm_head/w$", ("F", "M")),                   # (D, V)
    (r"vision_proj/w$", (None, None)),
    (r"(wq|wk|wv)/w$", ("F", "M")),                # (D, H*hd)
    (r"wo/w$", ("M", "F")),                        # (H*hd, D)
    (r"(gate|up)/w$", ("F", "M")),                 # (D, ff)
    (r"down/w$", ("M", "F")),                      # (ff, D)
    (r"router/w$", (None, None)),
    (r"w_gate$", ("M", "F", None)),                # (E, D, f)
    (r"w_up$", ("M", "F", None)),
    (r"w_down$", ("M", None, "F")),                # (E, f, D)
    (r"in_proj/w$", ("F", "M")),                   # (D, d_in_proj)
    (r"out_proj/w$", ("M", "F")),                  # (d_inner, D)
    (r"conv_w$", (None, "M")),                     # (K, conv_dim)
    (r"(wq|wk|wv)/b$", ("M",)),
    (r"(gate|up)/b$", ("M",)),
]


_HEAD_RULES = (r"(wq|wo)/(w|b)$", r"(wk|wv)/(w|b)$")


def _head_ok(path: str, cfg, model_size: int) -> bool:
    """TP on attention projections only when the head count divides the

    model axis — sharding the flat (H*hd) dim across head boundaries makes
    every (B,S,H,hd) reshape a reshard (the gemma3-4b/smollm collective
    storm in the baseline §Roofline table)."""
    if cfg is None:
        return True
    if re.search(_HEAD_RULES[0], path):
        return cfg.n_heads % model_size == 0
    if re.search(_HEAD_RULES[1], path):
        return cfg.n_kv % model_size == 0
    return True


def param_spec(path: str, shape: tuple, mesh, *,
               fsdp: bool = True, fsdp_over_pod: bool = False,
               cfg=None, tp: bool = True) -> P:
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    if not tp or not _head_ok(path, cfg, sizes.get("model", 1)):
        sizes = {k: v for k, v in sizes.items() if k != "model"}
    fsdp_axes: tuple = ()
    if fsdp:
        fsdp_axes = ("pod", "data") if fsdp_over_pod else ("data",)
    for pat, logical in _RULES:
        if re.search(pat, path):
            n_extra = len(shape) - len(logical)
            entries: list = [None] * n_extra
            for i, ent in enumerate(logical):
                dim = shape[n_extra + i]
                if ent == "M":
                    entries.append(_fits(dim, ("model",), sizes))
                elif ent == "F":
                    entries.append(_fits(dim, fsdp_axes, sizes))
                else:
                    entries.append(None)
            return P(*entries)
    # default: replicate (norms, scalars, biases, conv kernels of the UNet)
    return P()


def _key_str(k) -> str:
    from jax.tree_util import DictKey, GetAttrKey, SequenceKey
    if isinstance(k, DictKey):
        return str(k.key)
    if isinstance(k, SequenceKey):
        return f"#{k.idx}"
    if isinstance(k, GetAttrKey):
        return k.name
    return str(k)


def path_str(path) -> str:
    return "/".join(_key_str(k) for k in path)


def param_shardings(abstract_params: Any, mesh, *, fsdp: bool = True,
                    fsdp_over_pod: bool = False, cfg=None,
                    tp: bool = True) -> Any:
    """NamedSharding tree matching params (descends PackedW4 dataclasses:

    '.../w/packed' inherits the dense weight's rule — dims already halved
    pass the same divisibility check; scales/zero-points replicate).
    ``cfg`` (an LMConfig) enables the head-divisibility constraint."""
    leaves, treedef = jax.tree_util.tree_flatten_with_path(abstract_params)
    out = []
    for path, leaf in leaves:
        p = path_str(path)
        if p.endswith("/packed"):
            p = p[: -len("/packed")]
        elif p.endswith("/scale") or p.endswith("/zero_point"):
            out.append(NamedSharding(mesh, P()))
            continue
        spec = param_spec(p, tuple(leaf.shape), mesh, fsdp=fsdp,
                          fsdp_over_pod=fsdp_over_pod, cfg=cfg, tp=tp)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def like_tree(shardings: Any, tree: Any) -> Any:
    """Optimizer-state shardings mirror the param shardings."""
    return jax.tree.map(lambda _: shardings, tree)


# ---------------------------------------------------------------------------
# inputs / caches
# ---------------------------------------------------------------------------

DP_AXES = ("pod", "data")


def data_spec(shape: tuple, mesh, *, batch_dim: int = 0,
              axes: tuple = DP_AXES) -> P:
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    entries: list = [None] * len(shape)
    entries[batch_dim] = _fits(shape[batch_dim], axes, sizes)
    return P(*entries)


def cache_spec(path: str, shape: tuple, mesh) -> P:
    """KV caches (G, B, S, K, hd) / packed variants / SSM states.

    Batch shards over DP; the kv-head (or SSM-head) dim over model.
    """
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    entries: list = [None] * len(shape)
    if len(shape) >= 2:
        # find batch dim: stacked caches lead with groups
        bdim = 1 if len(shape) >= 4 else 0
        entries[bdim] = _fits(shape[bdim], DP_AXES, sizes)
    if re.search(r"(^|/)(k|v|k_scale|v_scale)$", path) and len(shape) >= 4:
        kdim = len(shape) - (1 if path.endswith("_scale") else 2)
        entries[kdim] = _fits(shape[kdim], ("model",), sizes)
    elif path.endswith("state") and len(shape) >= 3:
        entries[-3] = _fits(shape[-3], ("model",), sizes)  # SSM heads
    elif path.endswith("conv"):
        entries[-1] = _fits(shape[-1], ("model",), sizes)
    return P(*entries)


def cache_shardings(cache_tree: Any, mesh) -> Any:
    flat = flatten_paths(cache_tree)
    return unflatten_paths({
        p: NamedSharding(mesh, cache_spec(p, tuple(l.shape), mesh))
        for p, l in flat.items()})
