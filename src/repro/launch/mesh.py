"""Production meshes + version-compat shims for the mesh API.

Single pod:  (16, 16)      ("data", "model")   = 256 chips (v5e pod)
Multi-pod:   (2, 16, 16)   ("pod", "data", "model") = 512 chips

The ``pod`` axis carries only data parallelism (gradient all-reduce and
optional ZeRO sharding of optimizer state) — never per-layer tensor
collectives, so cross-pod traffic stays on the DCN-friendly path.

Defined as functions (not module constants) so importing this module never
touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first init.

Compat: newer JAX exposes ``jax.sharding.AxisType`` + ``jax.set_mesh``;
older releases (e.g. 0.4.x in this container) have neither, but ``Mesh``
itself is a context manager that sets the ambient mesh. ``compat_make_mesh``
and ``mesh_scope`` paper over the difference so launchers and tests run on
either API.
"""
from __future__ import annotations

import jax


def _axis_type_kwargs(n_axes: int) -> dict:
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def compat_make_mesh(shape: tuple, axes: tuple):
    """jax.make_mesh with Auto axis types where the installed JAX has them."""
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def mesh_scope(mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    ``jax.set_mesh`` on new JAX; on older releases the ``Mesh`` object's own
    context manager provides the same scoping for shard_map /
    with_sharding_constraint.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (CPU tests / examples)."""
    n = len(jax.devices())
    model = min(model, n)
    data = n // model
    return compat_make_mesh((data, model), ("data", "model"))


def mesh_chip_count(mesh) -> int:
    n = 1
    for s in mesh.axis_sizes:
        n *= s
    return n
