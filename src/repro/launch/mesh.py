"""Production meshes.

Single pod:  (16, 16)      ("data", "model")   = 256 chips (v5e pod)
Multi-pod:   (2, 16, 16)   ("pod", "data", "model") = 512 chips

The ``pod`` axis carries only data parallelism (gradient all-reduce and
optional ZeRO sharding of optimizer state) — never per-layer tensor
collectives, so cross-pod traffic stays on the DCN-friendly path.

Defined as functions (not module constants) so importing this module never
touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (CPU tests / examples)."""
    n = len(jax.devices())
    model = min(model, n)
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def mesh_chip_count(mesh) -> int:
    n = 1
    for s in mesh.axis_sizes:
        n *= s
    return n
