"""Diffusion serving launcher: trace replay / scenario runs on the engine.

Quantizes a UNet preset to real packed FP4 (TALoRA-merged per routing
segment via the weight bank), then feeds the continuous-batching engine
one of:

  * ``--trace file.jsonl``  — replay a recorded/generated trace file,
  * ``--scenario name``     — a named workload from the traffic registry
    (``steady`` | ``burst`` | ``diurnal`` | ``heavy_tail`` |
    ``closed_loop`` | ``deadline_mix`` | ``tight_deadlines`` |
    ``golden``; default steady),

and reports sliding-window + whole-run SLO metrics (throughput, latency
percentiles from arrival, goodput vs per-request deadlines, queue depth,
segment-cache and prefetch behavior), plus a deterministic outcome
digest — two replays of the same trace under ``--replay-clock virtual``
must print the same digest.

    PYTHONPATH=src python -m repro.launch.serve_diffusion --smoke \
        --scenario golden --kernels interpret --replay-clock virtual

``--policy slo`` swaps the largest-group-wins scheduler for the
slack-aware one (EDF pressure vs segment-switch cost, preemptive group
splits — see ``serving/scheduler.py``); both policies stay benchable
against the same scenario. ``--save-trace out.jsonl`` captures whatever
workload actually ran (including closed-loop realized arrivals) back
into a replayable trace.
``--plan absmax`` (default) builds the calibration-free abs-max FP4 plan;
``--plan search`` runs the paper's calibrate + MSE-search pipeline first
(slow — minutes on CPU).

Observability (``serving/obs``) switches on when any of ``--trace-out``
(Perfetto-loadable span trace), ``--metrics-out`` (text exposition of
the metrics registry) or ``--report-json`` (machine-readable run report
— summary, SLO verdicts, engine stats, kernel route counts, outcome
digest; what CI asserts on) is given; otherwise the engine runs with the
no-op ``NULL_OBS``. Tracing follows the engine clock, so a virtual-clock
replay's trace — and its digest — is deterministic.
"""
from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.clock import wall_clock
from repro.configs.diffusion_presets import DIFFUSION_PRESETS, tiny_ddim
from repro.core import talora
from repro.diffusion.schedule import make_schedule
from repro.kernels import ops
from repro.nn.unet import io_sites, unet_init
from repro.quant.fakequant import KIND_FP_SIGNED, QuantizerParams
from repro.serving import (DiffusionServingEngine, VirtualClock, WeightBank,
                           absmax_talora_setup, act_qps_from_plan)
from repro.serving.obs import NULL_OBS, Observability
from repro.serving.traffic import (MetricsCollector, Scenario, TraceWriter,
                                   get_scenario, list_scenarios, load_trace,
                                   run_scenario)


def build_quantized(cfg, sched, key, *, plan_mode: str, talora_cfg):
    """(q_params, plan, hubs, router) for the weight bank."""
    params = unet_init(key, cfg)
    if plan_mode == "search":
        from repro.diffusion.pipeline import quantize_diffusion
        bundle = quantize_diffusion(params, cfg, sched, key,
                                    talora_cfg=talora_cfg)
        return bundle.q_params, bundle.plan, bundle.hubs, bundle.router
    plan, hubs, router = absmax_talora_setup(params, talora_cfg, key,
                                             io_sites=io_sites(params))
    return params, plan, hubs, router


def outcome_digest(results) -> str:
    """Deterministic digest of per-request outcomes (step counts, final
    latents, expiry) — the replay-determinism check compares this line
    across runs of the same trace."""
    h = hashlib.sha256()
    for rid in sorted(results):
        rs = results[rid]
        h.update(f"{rid}:{rs.n_evals}:{int(rs.expired)}".encode())
        if rs.x0 is not None:
            h.update(np.asarray(rs.x0, np.float32).tobytes())
    return h.hexdigest()[:16]


def _warn_ignored_shaping(args) -> None:
    ignored = [f for f, v in (("--steps", args.steps),
                              ("--steps-jitter", args.steps_jitter),
                              ("--eta", args.eta),
                              ("--samplers", args.samplers),
                              ("--requests", args.requests),
                              ("--rate", args.rate)) if v is not None]
    if ignored:
        print(f"note: {', '.join(ignored)} ignored — a trace replays its "
              "recorded requests verbatim")


def _scenario_from_args(args) -> Scenario:
    if args.trace:
        _warn_ignored_shaping(args)
        return Scenario(name=f"trace:{args.trace}", kind="trace",
                        desc="ad-hoc trace replay", trace_path=args.trace)
    scn = get_scenario(args.scenario)
    if scn.kind == "trace":        # e.g. the golden fixture scenario
        _warn_ignored_shaping(args)
        return scn
    mix = scn.mix
    if args.steps is not None:
        mix = dataclasses.replace(mix, steps=args.steps)
    if args.steps_jitter is not None:
        mix = dataclasses.replace(mix, steps_jitter=args.steps_jitter)
    if args.eta is not None:
        mix = dataclasses.replace(mix, eta=args.eta)
    if args.samplers is not None:
        mix = dataclasses.replace(mix, samplers=tuple(
            args.samplers.split(",")))
    scn = dataclasses.replace(scn, mix=mix)
    if args.requests is not None:
        scn = dataclasses.replace(scn, n_requests=args.requests)
    if args.rate is not None and scn.kind == "open":
        kw = dict(scn.gen_kw)
        if "rate" in kw:
            kw["rate"] = args.rate
            scn = dataclasses.replace(scn, gen_kw=tuple(kw.items()))
        else:
            print(f"note: --rate ignored for generator {scn.gen!r} "
                  f"(tune {sorted(kw)} via the registry)")
    if args.smoke and scn.kind != "trace":
        scn = dataclasses.replace(
            scn, n_requests=min(scn.n_requests, 2), n_users=2,
            requests_per_user=1,
            mix=dataclasses.replace(scn.mix, steps=min(scn.mix.steps, 3),
                                    steps_jitter=min(scn.mix.steps_jitter,
                                                     1)))
    return scn


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny-ddim",
                    choices=sorted(DIFFUSION_PRESETS))
    ap.add_argument("--image-size", type=int, default=16,
                    help="tiny-ddim only; other presets fix their size")
    ap.add_argument("--T", type=int, default=100, help="schedule length")
    src = ap.add_mutually_exclusive_group()
    src.add_argument("--trace", default=None,
                     help="replay a recorded JSONL trace file")
    src.add_argument("--scenario", default="steady",
                     choices=list_scenarios(),
                     help="named workload from the traffic registry")
    ap.add_argument("--save-trace", default=None,
                    help="capture the run's submissions to a trace file")
    ap.add_argument("--replay-clock", default="wall",
                    choices=["wall", "virtual"],
                    help="virtual: deterministic admission/batching "
                         "(replay checks); wall: real SLO timing")
    ap.add_argument("--policy", default="fifo", choices=["fifo", "slo"],
                    help="group selection: fifo = largest-group-wins "
                         "baseline; slo = slack-aware EDF vs segment-"
                         "switch cost with preemptive group splits")
    ap.add_argument("--sync-prefetch", action="store_true",
                    help="build prefetched segments inline instead of on "
                         "the bank's background thread (virtual-clock "
                         "replay is always synchronous)")
    ap.add_argument("--requests", type=int, default=None,
                    help="override the scenario's open-loop request count")
    ap.add_argument("--rate", type=float, default=None,
                    help="override the scenario's arrival rate (req/s), "
                         "generators with a 'rate' knob only")
    ap.add_argument("--steps", type=int, default=None,
                    help="override base sampler steps per request")
    ap.add_argument("--steps-jitter", type=int, default=None)
    ap.add_argument("--eta", type=float, default=None)
    ap.add_argument("--samplers", default=None,
                    help="comma list cycled across requests "
                         "(ddim,plms,dpm_solver2)")
    ap.add_argument("--max-batch", type=int, default=None,
                    help="in-flight slots (default: the scenario's "
                         "max_batch hint)")
    ap.add_argument("--max-idle-sleep", type=float, default=0.25,
                    help="cap (s) on one idle sleep while waiting for the "
                         "next arrival")
    ap.add_argument("--metrics-window", type=float, default=1.0,
                    help="sliding-window width (s) for the metrics report")
    ap.add_argument("--bank-cap", type=int, default=4,
                    help="LRU cap on cached segment weight-sets")
    ap.add_argument("--no-prefetch", action="store_true",
                    help="disable eager next-segment weight-bank builds")
    ap.add_argument("--plan", default="absmax", choices=["absmax", "search"])
    ap.add_argument("--act-quant", default="fp4", choices=["off", "fp4"],
                    help="fp4 = fuse E2M1 act quant into packed matmuls")
    ap.add_argument("--act-maxval", type=float, default=6.0)
    ap.add_argument("--kernels", default="auto",
                    choices=["auto", "xla", "interpret", "pallas"])
    ap.add_argument("--conv-route", default="auto",
                    choices=["auto", "implicit", "im2col"],
                    help="Pallas conv route: implicit GEMM vs im2col "
                         "(auto: implicit on compiled TPU when it fits "
                         "VMEM; im2col in interpret mode — the golden "
                         "trace digest is pinned to its numerics)")
    ap.add_argument("--trace-out", default=None,
                    help="write the run's span trace here: .json = Chrome "
                         "trace-event format (open in Perfetto / "
                         "chrome://tracing), .jsonl = one event per line")
    ap.add_argument("--metrics-out", default=None,
                    help="write the metrics registry's text exposition "
                         "(Prometheus-style) here at run end")
    ap.add_argument("--report-json", default=None,
                    help="write a machine-readable run report (summary, "
                         "SLO verdicts, engine stats, obs counters, "
                         "outcome digest) here — what CI asserts on")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny everything (CI: 2 concurrent requests)")
    args = ap.parse_args(argv)

    if args.kernels != "auto":
        ops.FORCE = args.kernels
    if args.conv_route != "auto":
        ops.CONV_ROUTE = args.conv_route
    if args.smoke:
        args.image_size = min(args.image_size, 8)
        args.T = min(args.T, 50)

    scn = _scenario_from_args(args)
    max_batch = (args.max_batch if args.max_batch is not None
                 else scn.max_batch)
    if args.smoke:
        max_batch = min(max_batch, 2)

    if args.preset == "tiny-ddim":
        cfg = tiny_ddim(args.image_size)
    else:
        cfg = DIFFUSION_PRESETS[args.preset]()
    sched = make_schedule("linear", args.T)
    key = jax.random.PRNGKey(args.seed)
    tcfg = talora.TALoRAConfig(hub_size=2, rank=4, t_emb_dim=32,
                               router_hidden=16)

    t0 = wall_clock()
    q_params, plan, hubs, router = build_quantized(
        cfg, sched, key, plan_mode=args.plan, talora_cfg=tcfg)
    bank = WeightBank(q_params, plan, hubs, router, tcfg, args.T,
                      max_cached=args.bank_cap)
    act_qps = act_qps_from_plan(plan) if args.plan == "search" else {}
    if args.act_quant == "fp4":
        act_qps.setdefault("*", QuantizerParams(
            KIND_FP_SIGNED, 2, 1, 4, jnp.float32(args.act_maxval)))
    elif args.act_quant == "off":
        act_qps = {}
    clock = VirtualClock() if args.replay_clock == "virtual" else None
    obs = (Observability() if (args.trace_out or args.metrics_out
                               or args.report_json) else NULL_OBS)
    obs.install_kernels()
    engine = DiffusionServingEngine(cfg, sched, bank, act_qps=act_qps,
                                    max_batch=max_batch, clock=clock,
                                    policy=args.policy,
                                    max_idle_sleep=args.max_idle_sleep,
                                    prefetch=not args.no_prefetch,
                                    async_prefetch=not args.sync_prefetch,
                                    obs=obs)
    print(f"bank ready: {bank.n_segments} routing segments, plan={args.plan}, "
          f"kernels={args.kernels} ({wall_clock() - t0:.1f}s)")
    print(f"workload: {scn.name} — {scn.desc} "
          f"[clock={args.replay_clock}, policy={args.policy}]")

    writer = None
    if args.save_trace:
        writer = TraceWriter(args.save_trace,
                             meta={"scenario": scn.name,
                                   "seed": args.seed}).attach(engine)

    collector = MetricsCollector(window_s=args.metrics_window)
    summary = run_scenario(scn, engine, seed=args.seed, collector=collector)
    if writer is not None:
        writer.close()
        print(f"captured {writer.n} requests -> {args.save_trace}")
    results = engine.results
    for rs in results.values():
        if not rs.expired:
            assert bool(jnp.isfinite(rs.x0).all()), \
                f"non-finite x0 rid={rs.req.rid}"

    s = engine.stats()
    evals = sum(rs.n_evals for rs in results.values())
    wall = summary["wall_s"]
    print(f"served {summary['requests']} requests "
          f"({summary['expired']} expired) in {wall:.2f}s "
          f"({summary['requests'] / max(wall, 1e-9):.2f} req/s, "
          f"{evals / max(wall, 1e-9):.1f} denoise evals/s)")
    print(f"latency p50={summary['p50_s']:.2f}s p95={summary['p95_s']:.2f}s "
          f"p99={summary['p99_s']:.2f}s  goodput={summary['goodput_frac']:.2f} "
          f"({summary['deadline_misses']} deadline misses)")
    print(f"batching: mean batch {s['mean_batch']:.2f} "
          f"({s['forwards']} forwards / {s['ticks']} ticks), "
          f"peak queue depth {summary['peak_queue_depth']}")
    print(f"scheduler: policy={s['policy']}, {s['preemptions']} preemptions, "
          f"{s['deadline_saves']} deadline saves")
    for row in collector.windows()[:8]:
        hr = row.get("cache_hit_rate")
        print(f"  window t={row['t']:5.1f}s: {row['throughput_rps']:6.2f} "
              f"req/s, p95 {row['p95_s']:6.2f}s, goodput "
              f"{row['goodput_rps']:6.2f}/s, queue {row['queue_depth']:4.1f}"
              + (f", cache hit {hr:.2f}" if hr is not None else ""))
    slo = summary["slo"]
    if slo["checks"]:
        verdict = "PASS" if slo["passed"] else "FAIL"
        detail = ", ".join(f"{k}={c['actual']:.3g} (limit {c['limit']:.3g})"
                           for k, c in slo["checks"].items())
        print(f"SLO {verdict}: {detail}")
    print(f"weight bank: hit rate {s['bank_hit_rate']:.2f} "
          f"({s['bank_hits']} hits / {s['bank_misses']} misses, "
          f"{s['bank_evictions']} evictions, cap {args.bank_cap}), "
          f"{s['prefetch_hits']} prefetch hits / {s['bank_prefetches']} "
          f"prefetches, {s['bank_builds']} builds "
          f"({s['bank_build_joins']} joined in-progress), "
          f"{s['bank_packed_sites']} packed / "
          f"{s['bank_fallback_sites']} bf16-fallback sites")
    print(f"jit cache: {s['compiled_forwards']} compiled forwards "
          f"(buckets {s['buckets']}), {s['padded_samples']} padded samples, "
          f"{s['idle_sleeps']} idle sleeps")

    # conv parity: every even-width non-io conv weight must serve packed
    # (the packed W4A4 conv routes), never from the bf16 fallback bucket.
    from repro.common.tree import flatten_paths
    flat_q = dict(flatten_paths(q_params))
    conv_w = [k for k, v in flat_q.items()
              if k.endswith("/w") and getattr(v, "ndim", 0) == 4]
    packed_sites = set(bank.pack_stats["packed"])
    n_conv_packed = sum(k in packed_sites for k in conv_w)
    print(f"conv sites: {n_conv_packed}/{len(conv_w)} packed (W4A4 conv route)")
    if args.plan == "absmax":
        missing = [k for k in conv_w
                   if k not in io_sites(q_params)
                   and flat_q[k].shape[-1] % 2 == 0
                   and k not in packed_sites]
        assert not missing, f"conv sites fell back to bf16: {missing}"
    digest = outcome_digest(results)
    print(f"outcome digest: {digest} "
          f"({len(results)} requests, {summary['expired']} expired)")

    obs.finalize(engine, collector)
    obs.uninstall_kernels()
    if args.trace_out:
        n = obs.tracer.export(args.trace_out)
        dropped = (f" ({obs.tracer.dropped} dropped)"
                   if obs.tracer.dropped else "")
        print(f"trace: {n} events -> {args.trace_out}{dropped}")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            f.write(obs.metrics.to_text())
        print(f"metrics: -> {args.metrics_out}")
    if args.report_json:
        report = {
            "scenario": scn.name,
            "policy": args.policy,
            "replay_clock": args.replay_clock,
            "kernels": args.kernels,
            "seed": args.seed,
            "outcome_digest": digest,
            "n_requests": len(results),
            "summary": {k: v for k, v in summary.items() if k != "slo"},
            "slo": summary["slo"],
            "engine": s,
            "kernel_routes": (obs.kernel_profiler.route_counts()
                              if obs.kernel_profiler is not None else {}),
            "obs": obs.metrics.snapshot(),
        }
        with open(args.report_json, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True, default=float)
        print(f"report: -> {args.report_json}")


if __name__ == "__main__":
    main()
