"""Diffusion serving launcher: Poisson-trace replay through the engine.

Quantizes a UNet preset to real packed FP4 (TALoRA-merged per routing
segment via the weight bank), then replays a synthetic Poisson arrival
trace of generation requests through the continuous-batching engine and
reports throughput, latency percentiles, and segment-cache behavior.

    PYTHONPATH=src python -m repro.launch.serve_diffusion --smoke \
        --requests 2 --max-batch 2 --kernels interpret

``--plan absmax`` (default) builds the calibration-free abs-max FP4 plan;
``--plan search`` runs the paper's calibrate + MSE-search pipeline first
(slow — minutes on CPU).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.diffusion_presets import DIFFUSION_PRESETS, tiny_ddim
from repro.core import talora
from repro.diffusion.schedule import make_schedule
from repro.kernels import ops
from repro.nn.unet import io_sites, unet_init
from repro.quant.fakequant import KIND_FP_SIGNED, QuantizerParams
from repro.serving import (DiffusionServingEngine, WeightBank,
                           absmax_talora_setup, act_qps_from_plan)


def build_quantized(cfg, sched, key, *, plan_mode: str, talora_cfg):
    """(q_params, plan, hubs, router) for the weight bank."""
    params = unet_init(key, cfg)
    if plan_mode == "search":
        from repro.diffusion.pipeline import quantize_diffusion
        bundle = quantize_diffusion(params, cfg, sched, key,
                                    talora_cfg=talora_cfg)
        return bundle.q_params, bundle.plan, bundle.hubs, bundle.router
    plan, hubs, router = absmax_talora_setup(params, talora_cfg, key,
                                             io_sites=io_sites(params))
    return params, plan, hubs, router


def poisson_trace(n: int, rate: float, seed: int) -> np.ndarray:
    """Cumulative arrival times (seconds) for n requests at `rate` req/s."""
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / max(rate, 1e-9), size=n))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny-ddim",
                    choices=sorted(DIFFUSION_PRESETS))
    ap.add_argument("--image-size", type=int, default=16,
                    help="tiny-ddim only; other presets fix their size")
    ap.add_argument("--T", type=int, default=100, help="schedule length")
    ap.add_argument("--steps", type=int, default=10,
                    help="base sampler steps per request")
    ap.add_argument("--steps-jitter", type=int, default=2,
                    help="request i runs steps + (i %% (jitter+1)) steps")
    ap.add_argument("--eta", type=float, default=0.0)
    ap.add_argument("--samplers", default="ddim",
                    help="comma list cycled across requests "
                         "(ddim,plms,dpm_solver2)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--rate", type=float, default=20.0,
                    help="Poisson arrival rate, requests/s")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--bank-cap", type=int, default=4,
                    help="LRU cap on cached segment weight-sets")
    ap.add_argument("--plan", default="absmax", choices=["absmax", "search"])
    ap.add_argument("--act-quant", default="fp4", choices=["off", "fp4"],
                    help="fp4 = fuse E2M1 act quant into packed matmuls")
    ap.add_argument("--act-maxval", type=float, default=6.0)
    ap.add_argument("--kernels", default="auto",
                    choices=["auto", "xla", "interpret", "pallas"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny everything (CI: 2 concurrent requests)")
    args = ap.parse_args(argv)

    if args.kernels != "auto":
        ops.FORCE = args.kernels
    if args.smoke:
        args.image_size = min(args.image_size, 8)
        args.T = min(args.T, 50)
        args.steps = min(args.steps, 3)
        args.requests = min(args.requests, 2)
        args.max_batch = min(args.max_batch, 2)

    if args.preset == "tiny-ddim":
        cfg = tiny_ddim(args.image_size)
    else:
        cfg = DIFFUSION_PRESETS[args.preset]()
    sched = make_schedule("linear", args.T)
    key = jax.random.PRNGKey(args.seed)
    tcfg = talora.TALoRAConfig(hub_size=2, rank=4, t_emb_dim=32,
                               router_hidden=16)

    t0 = time.time()
    q_params, plan, hubs, router = build_quantized(
        cfg, sched, key, plan_mode=args.plan, talora_cfg=tcfg)
    bank = WeightBank(q_params, plan, hubs, router, tcfg, args.T,
                      max_cached=args.bank_cap)
    act_qps = act_qps_from_plan(plan) if args.plan == "search" else {}
    if args.act_quant == "fp4":
        act_qps.setdefault("*", QuantizerParams(
            KIND_FP_SIGNED, 2, 1, 4, jnp.float32(args.act_maxval)))
    elif args.act_quant == "off":
        act_qps = {}
    engine = DiffusionServingEngine(cfg, sched, bank, act_qps=act_qps,
                                    max_batch=args.max_batch)
    print(f"bank ready: {bank.n_segments} routing segments, plan={args.plan}, "
          f"kernels={args.kernels} ({time.time() - t0:.1f}s)")

    samplers = args.samplers.split(",")
    arrivals = poisson_trace(args.requests, args.rate, args.seed)
    for i in range(args.requests):
        engine.submit(steps=args.steps + i % (args.steps_jitter + 1),
                      eta=args.eta, seed=args.seed + i,
                      sampler=samplers[i % len(samplers)],
                      arrival=float(arrivals[i]))

    t0 = time.time()
    results = engine.run()
    wall = time.time() - t0
    for rs in results.values():
        assert bool(jnp.isfinite(rs.x0).all()), f"non-finite x0 rid={rs.req.rid}"
    s = engine.stats()
    evals = sum(rs.n_evals for rs in results.values())
    print(f"served {s['requests']} requests in {wall:.2f}s "
          f"({s['requests'] / max(wall, 1e-9):.2f} req/s, "
          f"{evals / max(wall, 1e-9):.1f} denoise evals/s)")
    print(f"latency p50={s['p50_s']:.2f}s p95={s['p95_s']:.2f}s "
          f"p99={s['p99_s']:.2f}s  mean batch={s['mean_batch']:.2f} "
          f"({s['forwards']} forwards / {s['ticks']} ticks)")
    print(f"weight bank: hit rate {s['bank_hit_rate']:.2f} "
          f"({s['bank_hits']} hits / {s['bank_misses']} misses, "
          f"{s['bank_evictions']} evictions, cap {args.bank_cap}), "
          f"{s['bank_packed_sites']} packed / {s['bank_fallback_sites']} "
          f"bf16-fallback sites")
    print(f"jit cache: {s['compiled_forwards']} compiled forwards "
          f"(buckets {s['buckets']}), {s['padded_samples']} padded samples, "
          f"{s['idle_sleeps']} idle sleeps")

    # conv parity: every even-width non-io conv weight must serve packed
    # (the im2col W4A4 route), never from the bf16 fallback bucket.
    from repro.common.tree import flatten_paths
    flat_q = dict(flatten_paths(q_params))
    conv_w = [k for k, v in flat_q.items()
              if k.endswith("/w") and getattr(v, "ndim", 0) == 4]
    packed_sites = set(bank.pack_stats["packed"])
    n_conv_packed = sum(k in packed_sites for k in conv_w)
    print(f"conv sites: {n_conv_packed}/{len(conv_w)} packed (im2col W4A4)")
    if args.plan == "absmax":
        missing = [k for k in conv_w
                   if k not in io_sites(q_params)
                   and flat_q[k].shape[-1] % 2 == 0
                   and k not in packed_sites]
        assert not missing, f"conv sites fell back to bf16: {missing}"


if __name__ == "__main__":
    main()
