"""Training launcher: --arch <id> [--smoke] with the fault-tolerant trainer.

On this CPU container it runs the reduced configs end-to-end (the
``examples/train_lm.py`` driver trains a ~100M-class model for a few
hundred steps); on a real fleet the same entry point runs the full config
on the production mesh — the mesh/sharding path is identical, only the
device count differs.
"""
from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.checkpoint.ckpt import CheckpointManager
from repro.configs.registry import get_config
from repro.data.synthetic import zipf_tokens
from repro.launch.mesh import (make_host_mesh, make_production_mesh,
                               mesh_scope)
from repro.launch.sharding import data_spec, param_shardings
from repro.launch.steps import make_train_step
from repro.models.lm import lm_init
from repro.optim.adam import AdamConfig, adam_init
from repro.train.trainer import Trainer, TrainerConfig


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = (make_production_mesh(multi_pod=args.multi_pod)
            if args.production_mesh else make_host_mesh(args.model_parallel))
    acfg = AdamConfig(lr=args.lr, schedule="linear_warmup_cosine",
                      warmup_steps=max(10, args.steps // 20),
                      total_steps=args.steps)
    ckpt = CheckpointManager(os.path.join(args.ckpt_dir, cfg.name), keep=3)

    with mesh_scope(mesh):
        params = lm_init(jax.random.PRNGKey(0), cfg)
        ps = param_shardings(params, mesh)
        params = jax.tree.map(jax.device_put, params, ps)
        opt = adam_init(params, acfg)
        step = make_train_step(cfg, acfg)

        @jax.jit
        def step_fn(state, batch):
            params, opt = state
            params, opt, metrics = step(params, opt, batch)
            return (params, opt), metrics

        def data():
            key = jax.random.PRNGKey(1)
            bspec = NamedSharding(mesh, data_spec((args.batch, args.seq), mesh))
            while True:
                key, k = jax.random.split(key)
                toks = zipf_tokens(k, args.batch, args.seq, cfg.vocab)
                batch = {"tokens": jax.device_put(toks, bspec)}
                if cfg.family == "vlm":
                    batch["extra"] = jnp.zeros(
                        (args.batch, cfg.n_img_tokens, cfg.d_vision),
                        jnp.bfloat16)
                yield batch

        tcfg = TrainerConfig(max_steps=args.steps, ckpt_every=args.ckpt_every,
                             log_every=20)
        trainer = Trainer(tcfg, ckpt, step_fn)
        state, history = trainer.run((params, opt), data())
        losses = [r.metrics.get("loss", float("nan")) for r in history]
        print(f"arch={cfg.name} steps={len(history)} "
              f"loss[0]={losses[0]:.4f} loss[-1]={losses[-1]:.4f} "
              f"stragglers={trainer.straggler_steps()}")


if __name__ == "__main__":
    main()
