"""Multi-model gateway launcher: mixed-model traffic over per-model banks.

Registers the requested models (``--models tiny-ddim,smollm-135m``) from
the gateway registry's curated entries, builds one engine + weight bank
per model — the diffusion preset through the same quantize/pack path as
``serve_diffusion``, the LM through ``quantize_lm_for_serving`` via the
bank's ``build_fn`` seam — and drives a named traffic scenario through
one ``ServingGateway``:

    PYTHONPATH=src python -m repro.launch.serve_gateway --smoke \
        --models tiny-ddim,smollm-135m --scenario mixed_model \
        --kernels interpret --clock virtual

Clocks: ``--clock virtual`` replays deterministically (two runs of the
same scenario print the same outcome digest — the CI check); ``--clock
sim`` scores SLOs under simulated service time shared across every
engine (machine-independent goodput, the bench rows); ``--clock wall``
is real timing on a shared origin.

Identity check: with a single diffusion model the gateway adds zero
behavior — ``--models tiny-ddim --scenario golden --smoke --kernels
interpret --clock virtual`` reproduces ``serve_diffusion``'s golden
outcome digest bit-for-bit (CI asserts the literal digest).

The report (``--report-json``) carries per-model goodput/SLO verdicts,
per-bank counters with their reconciliation check (``builds +
build_failures == misses + prefetches`` *per bank*), the aggregate
outcome digest over gateway-wide request ids, and per-model digests.
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from repro.common.clock import wall_clock
from repro.configs.diffusion_presets import DIFFUSION_PRESETS, tiny_ddim
from repro.configs.registry import ARCHS
from repro.core import talora
from repro.diffusion.schedule import make_schedule
from repro.kernels import ops
from repro.launch.serve_diffusion import (_scenario_from_args,
                                          build_quantized, outcome_digest)
from repro.launch.steps import quantize_lm_for_serving
from repro.models.lm import lm_init
from repro.quant.fakequant import KIND_FP_SIGNED, QuantizerParams
from repro.serving import DiffusionServingEngine, VirtualClock, WeightBank
from repro.serving.gateway import (LMServingEngine, ModelRegistry,
                                   ServingGateway, default_entries)
from repro.serving.obs import NULL_OBS, Observability
from repro.serving.traffic import MetricsCollector, TraceWriter, run_scenario
from repro.serving.traffic.scenarios import list_scenarios
from repro.serving.traffic.sim import SimClock


def build_diffusion_engine(entry, args, eng_kw, obs, max_batch):
    """The exact quantize -> bank -> engine path ``serve_diffusion``
    takes with ``--plan absmax --act-quant fp4`` — same seed, same
    TALoRA shaping — so a single-model gateway run is digest-identical
    to the standalone launcher."""
    if entry.config == "tiny-ddim":
        cfg = tiny_ddim(args.image_size)
    else:
        cfg = DIFFUSION_PRESETS[entry.config]()
    sched = make_schedule("linear", args.T)
    key = jax.random.PRNGKey(args.seed)
    tcfg = talora.TALoRAConfig(hub_size=2, rank=4, t_emb_dim=32,
                               router_hidden=16)
    q_params, plan, hubs, router = build_quantized(
        cfg, sched, key, plan_mode="absmax", talora_cfg=tcfg)
    bank = WeightBank(q_params, plan, hubs, router, tcfg, args.T,
                      max_cached=args.bank_cap or entry.bank_cap)
    act_qps = {"*": QuantizerParams(KIND_FP_SIGNED, 2, 1, 4,
                                    jnp.float32(6.0))}
    return DiffusionServingEngine(cfg, sched, bank, act_qps=act_qps,
                                  max_batch=max_batch, policy=args.policy,
                                  obs=obs, model=entry.name, **eng_kw)


def build_lm_engine(entry, args, eng_kw, obs, max_batch):
    """LM adapter path: init -> quantize_lm_for_serving (calibration-free
    abs-max W4) through the bank's build_fn seam; one weight segment."""
    arch = ARCHS[entry.config]
    cfg = arch.smoke() if entry.smoke else arch.full()
    params = lm_init(jax.random.PRNGKey(args.seed), cfg)
    bank = WeightBank(params, None, {}, None, None, 1,
                      max_cached=args.bank_cap or entry.bank_cap,
                      build_fn=lambda p: quantize_lm_for_serving(
                          p, searched=False))
    return LMServingEngine(cfg, bank, max_batch=max_batch,
                           policy=args.policy, obs=obs, model=entry.name,
                           **eng_kw)


BUILDERS = {"diffusion": build_diffusion_engine, "lm": build_lm_engine}


def build_gateway(model_names, args, obs=NULL_OBS):
    """(gateway, sim_clock | None): registry-resolved engines behind one
    routing surface, all on one shared clock."""
    registry = ModelRegistry(default_entries())
    entries = [registry.resolve(n) for n in model_names]
    sim = None
    if args.clock == "virtual":
        clock = VirtualClock()
        gw = ServingGateway(clock=clock)
        eng_kw = {"clock": clock}
    elif args.clock == "sim":
        sim = SimClock()
        gw = ServingGateway(now_fn=sim.now, max_idle_sleep=0.0)
        eng_kw = {"now_fn": sim.now, "max_idle_sleep": 0.0}
    else:
        t0 = wall_clock()
        now_fn = lambda: wall_clock() - t0   # noqa: E731 — shared origin
        gw = ServingGateway(now_fn=now_fn)
        eng_kw = {"now_fn": now_fn}
    for entry in entries:
        mb = min(args.gateway_max_batch, entry.max_batch)
        engine = BUILDERS[entry.family](entry, args, eng_kw, obs, mb)
        if sim is not None:
            sim.attach(engine)
        gw.add_model(entry, engine)
    return gw, sim


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", default="tiny-ddim,smollm-135m",
                    help="comma list of registered model names "
                         f"(registry: {[e.name for e in default_entries()]})")
    src = ap.add_mutually_exclusive_group()
    src.add_argument("--trace", default=None,
                     help="replay a recorded JSONL trace file (v1 files "
                          "route every request to the default model)")
    src.add_argument("--scenario", default="mixed_model",
                     choices=list_scenarios())
    ap.add_argument("--save-trace", default=None,
                    help="capture the run (gateway-wide rids + model "
                         "routing) to a v2 trace file")
    ap.add_argument("--clock", default="wall",
                    choices=["wall", "virtual", "sim"],
                    help="virtual: deterministic replay; sim: simulated "
                         "service time shared across models (machine-"
                         "independent SLOs); wall: real timing")
    ap.add_argument("--policy", default="fifo", choices=["fifo", "slo"])
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--rate", type=float, default=None)
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--steps-jitter", type=int, default=None)
    ap.add_argument("--eta", type=float, default=None)
    ap.add_argument("--samplers", default=None)
    ap.add_argument("--max-batch", type=int, default=None,
                    help="cap on any model engine's in-flight slots "
                         "(default: scenario hint; each entry's own "
                         "max_batch still applies)")
    ap.add_argument("--bank-cap", type=int, default=None,
                    help="override every bank's LRU cap (default: each "
                         "registry entry's)")
    ap.add_argument("--image-size", type=int, default=16)
    ap.add_argument("--T", type=int, default=100)
    ap.add_argument("--kernels", default="auto",
                    choices=["auto", "xla", "interpret", "pallas"])
    ap.add_argument("--trace-out", default=None,
                    help="span trace (per-model tracks) — .json/.jsonl")
    ap.add_argument("--metrics-out", default=None,
                    help="metrics registry text exposition (per-model "
                         "labeled series)")
    ap.add_argument("--report-json", default=None,
                    help="machine-readable run report — what CI asserts on")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny everything (CI shaping)")
    args = ap.parse_args(argv)

    if args.kernels != "auto":
        ops.FORCE = args.kernels
    if args.smoke:
        args.image_size = min(args.image_size, 8)
        args.T = min(args.T, 50)

    scn = _scenario_from_args(args)
    mb = args.max_batch if args.max_batch is not None else scn.max_batch
    if args.smoke:
        mb = min(mb, 2)
    args.gateway_max_batch = mb

    model_names = [s.strip() for s in args.models.split(",") if s.strip()]
    if not model_names:
        raise SystemExit("--models needs at least one registered name")

    obs = (Observability() if (args.trace_out or args.metrics_out
                               or args.report_json) else NULL_OBS)
    obs.install_kernels()
    t0 = wall_clock()
    gw, _sim = build_gateway(model_names, args, obs=obs)
    for name in gw.list_models():
        e = gw.engine(name)
        print(f"model {name}: {e.bank.n_segments} segments, "
              f"cap {e.bank.max_cached}, max_batch {e.batcher.max_batch}")
    print(f"gateway ready: {len(model_names)} models "
          f"({wall_clock() - t0:.1f}s) [clock={args.clock}, "
          f"policy={args.policy}]")
    print(f"workload: {scn.name} — {scn.desc}")

    writer = None
    if args.save_trace:
        writer = TraceWriter(args.save_trace,
                             meta={"scenario": scn.name, "seed": args.seed,
                                   "models": model_names}).attach(gw)

    collector = MetricsCollector()
    summary = run_scenario(scn, gw, seed=args.seed, collector=collector)
    if writer is not None:
        writer.close()
        print(f"captured {writer.n} requests -> {args.save_trace}")

    for gid, rs in gw.results.items():
        if not rs.expired:
            assert bool(jnp.isfinite(rs.x0).all()), f"non-finite x0 gid={gid}"

    gs = gw.stats()
    agg = gs["aggregate"]
    digest = outcome_digest(gw.results)
    wall = summary["wall_s"]
    print(f"served {agg['requests']} requests ({agg['expired']} expired) "
          f"across {len(model_names)} models in {wall:.2f}s")
    per_model_digest = {}
    reconciled = {}
    for name in gw.list_models():
        p = gs["per_model"][name]
        e = gw.engine(name)
        bank = e.bank
        ok = (bank.builds + bank.build_failures
              == bank.misses + bank.prefetches)
        reconciled[name] = ok
        per_model_digest[name] = outcome_digest(e.results)
        slo = p["slo"]
        verdict = ("PASS" if slo["passed"] else "FAIL") if slo["checks"] \
            else "n/a"
        print(f"  {name} [{p['family']}]: "
              f"{p['engine']['requests']} done / "
              f"{p['engine']['expired']} expired, "
              f"goodput {p['summary']['goodput_frac']:.2f}, "
              f"p95 {p['summary']['p95_s']:.2f}s, SLO {verdict}; "
              f"bank {bank.builds} builds = {bank.misses} misses + "
              f"{bank.prefetches} prefetches "
              f"[{'reconciled' if ok else 'MISMATCH'}]")
        assert ok, f"bank counters do not reconcile for {name}"
    print(f"outcome digest: {digest} ({len(gw.results)} requests)")

    for name in gw.list_models():
        obs.finalize(gw.engine(name),
                     gw._models[name].collector)
    obs.uninstall_kernels()
    if args.trace_out:
        n = obs.tracer.export(args.trace_out)
        print(f"trace: {n} events -> {args.trace_out}")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            f.write(obs.metrics.to_text())
        print(f"metrics: -> {args.metrics_out}")
    if args.report_json:
        report = {
            "scenario": scn.name,
            "models": model_names,
            "clock": args.clock,
            "policy": args.policy,
            "kernels": args.kernels,
            "seed": args.seed,
            "outcome_digest": digest,
            "n_requests": len(gw.results),
            "summary": {k: v for k, v in summary.items() if k != "slo"},
            "slo": summary["slo"],
            "aggregate": agg,
            "per_model": {
                name: {
                    "digest": per_model_digest[name],
                    "family": gs["per_model"][name]["family"],
                    "goodput_frac":
                        gs["per_model"][name]["summary"]["goodput_frac"],
                    "summary": gs["per_model"][name]["summary"],
                    "slo": gs["per_model"][name]["slo"],
                    "engine": gs["per_model"][name]["engine"],
                    "bank_reconciled": reconciled[name],
                } for name in gw.list_models()},
            "obs": obs.metrics.snapshot() if obs.enabled else {},
        }
        with open(args.report_json, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True, default=float)
        print(f"report: -> {args.report_json}")


if __name__ == "__main__":
    main()
