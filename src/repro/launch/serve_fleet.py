"""Multi-host fleet launcher: N replica engines behind one router.

Builds the ``serve_diffusion`` quantize -> bank -> engine path **once**
(one merge/pack plan shared read-only across the fleet), instantiates N
replicas each with its *own* ``WeightBank`` LRU, and drives a traffic
scenario through a ``FleetRouter`` under a placement policy:

    PYTHONPATH=src python -m repro.launch.serve_fleet --smoke \
        --replicas 2 --placement affinity --scenario deadline_mix \
        --kernels interpret --clock sim

Placements: ``rr`` (round-robin), ``least_loaded`` (queue depth +
in-flight padded rows), ``affinity`` (segment-affinity against each
replica's bank contents; the policy the fleet exists for).

Clocks: ``--clock virtual`` replays deterministically on one shared
clock; ``--clock sim`` gives each replica its *own* simulated service
axis (parallel hosts — replica sweeps show real scaling) with
``--build-s`` charging every cold segment build, which is what makes
placement quality visible in goodput; ``--clock wall`` is real timing
on a shared origin.

Identity check: ``--replicas 1 --placement rr --scenario golden --smoke
--kernels interpret --clock virtual`` must reproduce
``serve_diffusion``'s golden outcome digest bit-for-bit — the fleet
layer adds zero behavior at N=1 (CI asserts the literal digest).

The report (``--report-json``) carries the placement-decision
histogram, pooled + per-replica bank counters with reconciliation,
per-replica goodput, and the aggregate outcome digest over fleet gids.
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from repro.common.clock import wall_clock
from repro.configs.diffusion_presets import tiny_ddim
from repro.core import talora
from repro.diffusion.schedule import make_schedule
from repro.kernels import ops
from repro.launch.serve_diffusion import (_scenario_from_args,
                                          build_quantized, outcome_digest)
from repro.quant.fakequant import KIND_FP_SIGNED, QuantizerParams
from repro.serving import DiffusionServingEngine, VirtualClock, WeightBank
from repro.serving.fleet import PLACEMENTS, FleetRouter
from repro.serving.obs import NULL_OBS, Observability
from repro.serving.traffic import MetricsCollector, TraceWriter, run_scenario
from repro.serving.traffic.scenarios import list_scenarios
from repro.serving.traffic.sim import SimClock

PLACEMENT_ALIASES = {"rr": "round_robin", "affinity": "segment_affinity",
                     **{p: p for p in PLACEMENTS}}


def build_fleet(args, obs=NULL_OBS):
    """(router, [sim_clocks]): one quantize pass, N banks/engines."""
    cfg = tiny_ddim(args.image_size)
    sched = make_schedule("linear", args.T)
    key = jax.random.PRNGKey(args.seed)
    tcfg = talora.TALoRAConfig(hub_size=2, rank=4, t_emb_dim=32,
                               router_hidden=16)
    q_params, plan, hubs, router = build_quantized(
        cfg, sched, key, plan_mode="absmax", talora_cfg=tcfg)
    act_qps = {"*": QuantizerParams(KIND_FP_SIGNED, 2, 1, 4,
                                    jnp.float32(6.0))}

    placement = PLACEMENT_ALIASES[args.placement]
    sims: list[SimClock] = []
    if args.clock == "virtual":
        clock = VirtualClock()
        fleet = FleetRouter(placement=placement, clock=clock, obs=obs)
        eng_kw_for = lambda i: {"clock": clock}             # noqa: E731
    elif args.clock == "sim":
        # per-replica clocks: each host charges compute on its own
        # parallel axis; the router's fleet clock is their minimum
        fleet = FleetRouter(placement=placement, max_idle_sleep=0.0,
                            obs=obs)
        sims = [SimClock(build_s=args.build_s)
                for _ in range(args.replicas)]
        eng_kw_for = lambda i: {"now_fn": sims[i].now,       # noqa: E731
                                "max_idle_sleep": 0.0}
    else:
        t0 = wall_clock()
        now_fn = lambda: wall_clock() - t0   # noqa: E731 — shared origin
        fleet = FleetRouter(placement=placement, now_fn=now_fn, obs=obs)
        eng_kw_for = lambda i: {"now_fn": now_fn}           # noqa: E731

    for i in range(args.replicas):
        bank = WeightBank(q_params, plan, hubs, router, tcfg, args.T,
                          max_cached=args.bank_cap)
        engine = DiffusionServingEngine(
            cfg, sched, bank, act_qps=act_qps,
            max_batch=args.fleet_max_batch, policy=args.policy, obs=obs,
            **eng_kw_for(i))
        if sims:
            sims[i].attach(engine)
        fleet.add_replica(engine)
    return fleet, sims


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--placement", default="affinity",
                    choices=sorted(PLACEMENT_ALIASES),
                    help="rr=round_robin, affinity=segment_affinity")
    src = ap.add_mutually_exclusive_group()
    src.add_argument("--trace", default=None,
                     help="replay a recorded JSONL trace file")
    src.add_argument("--scenario", default="deadline_mix",
                     choices=list_scenarios())
    ap.add_argument("--save-trace", default=None,
                    help="capture the run (fleet gids) to a trace file")
    ap.add_argument("--clock", default="sim",
                    choices=["wall", "virtual", "sim"],
                    help="virtual: deterministic replay on one shared "
                         "clock; sim: one simulated service axis per "
                         "replica (parallel hosts, machine-independent "
                         "SLOs); wall: real timing")
    ap.add_argument("--build-s", type=float, default=0.3,
                    help="simulated seconds charged per cold bank build "
                         "(sim clock only) — the cost affinity routing "
                         "avoids paying once per replica")
    ap.add_argument("--policy", default="fifo", choices=["fifo", "slo"])
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--rate", type=float, default=None)
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--steps-jitter", type=int, default=None)
    ap.add_argument("--eta", type=float, default=None)
    ap.add_argument("--samplers", default=None)
    ap.add_argument("--max-batch", type=int, default=None,
                    help="per-replica in-flight slots "
                         "(default: scenario hint)")
    ap.add_argument("--bank-cap", type=int, default=2,
                    help="per-replica bank LRU cap; below the segment "
                         "count so placement decides what stays warm")
    ap.add_argument("--image-size", type=int, default=16)
    ap.add_argument("--T", type=int, default=100)
    ap.add_argument("--kernels", default="auto",
                    choices=["auto", "xla", "interpret", "pallas"])
    ap.add_argument("--trace-out", default=None,
                    help="span trace (per-replica tracks + router "
                         "route instants) — .json/.jsonl")
    ap.add_argument("--metrics-out", default=None,
                    help="metrics registry text exposition "
                         "({replica=...} labeled series)")
    ap.add_argument("--report-json", default=None,
                    help="machine-readable run report — what CI asserts on")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny everything (CI shaping)")
    args = ap.parse_args(argv)

    if args.replicas < 1:
        raise SystemExit("--replicas must be >= 1")
    if args.kernels != "auto":
        ops.FORCE = args.kernels
    if args.smoke:
        args.image_size = min(args.image_size, 8)
        args.T = min(args.T, 50)

    scn = _scenario_from_args(args)
    mb = args.max_batch if args.max_batch is not None else scn.max_batch
    if args.smoke:
        mb = min(mb, 2)
    args.fleet_max_batch = mb

    obs = (Observability() if (args.trace_out or args.metrics_out
                               or args.report_json) else NULL_OBS)
    obs.install_kernels()
    t0 = wall_clock()
    fleet, _sims = build_fleet(args, obs=obs)
    bank0 = fleet.replicas[0].bank
    print(f"fleet ready: {args.replicas} replicas "
          f"({wall_clock() - t0:.1f}s) [placement={fleet.placement}, "
          f"clock={args.clock}, policy={args.policy}; "
          f"{bank0.n_segments} segments/bank, cap {bank0.max_cached}, "
          f"max_batch {mb}]")
    print(f"workload: {scn.name} — {scn.desc}")

    writer = None
    if args.save_trace:
        writer = TraceWriter(args.save_trace,
                             meta={"scenario": scn.name, "seed": args.seed,
                                   "replicas": args.replicas,
                                   "placement": fleet.placement}
                             ).attach(fleet)

    collector = MetricsCollector()
    summary = run_scenario(scn, fleet, seed=args.seed, collector=collector)
    if writer is not None:
        writer.close()
        print(f"captured {writer.n} requests -> {args.save_trace}")

    for gid, rs in fleet.results.items():
        if not rs.expired:
            assert bool(jnp.isfinite(rs.x0).all()), f"non-finite x0 gid={gid}"

    fs = fleet.stats()
    agg = fs["aggregate"]
    digest = outcome_digest(fleet.results)
    print(f"served {agg['requests']} requests ({agg['expired']} expired) "
          f"across {args.replicas} replicas in {summary['wall_s']:.2f}s; "
          f"pooled bank hit rate {agg['bank_hit_rate']:.2f}, "
          f"placements {agg['placement_reasons']}")
    reconciled = {}
    for rep in fleet.replicas:
        p = fs["per_replica"][rep.name]
        bank = rep.bank
        ok = (bank.builds + bank.build_failures
              == bank.misses + bank.prefetches)
        reconciled[rep.name] = ok
        print(f"  {rep.name}: {p['engine']['requests']} done / "
              f"{p['engine']['expired']} expired, "
              f"{p['placed']} placed, "
              f"goodput {p['summary']['goodput_frac']:.2f}, "
              f"bank {bank.builds} builds = {bank.misses} misses + "
              f"{bank.prefetches} prefetches "
              f"[{'reconciled' if ok else 'MISMATCH'}]")
        assert ok, f"bank counters do not reconcile for {rep.name}"
    print(f"outcome digest: {digest} ({len(fleet.results)} requests)")

    for rep in fleet.replicas:
        obs.finalize(rep.engine, rep.collector)
    obs.uninstall_kernels()
    if args.trace_out:
        n = obs.tracer.export(args.trace_out)
        print(f"trace: {n} events -> {args.trace_out}")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            f.write(obs.metrics.to_text())
        print(f"metrics: -> {args.metrics_out}")
    if args.report_json:
        report = {
            "scenario": scn.name,
            "replicas": args.replicas,
            "placement": fleet.placement,
            "clock": args.clock,
            "build_s": args.build_s if args.clock == "sim" else None,
            "policy": args.policy,
            "kernels": args.kernels,
            "seed": args.seed,
            "outcome_digest": digest,
            "n_requests": len(fleet.results),
            "summary": {k: v for k, v in summary.items() if k != "slo"},
            "slo": summary["slo"],
            "aggregate": agg,
            "per_replica": {
                rep.name: {
                    "goodput_frac":
                        fs["per_replica"][rep.name]["summary"]
                          ["goodput_frac"],
                    "summary": fs["per_replica"][rep.name]["summary"],
                    "engine": fs["per_replica"][rep.name]["engine"],
                    "placed": fs["per_replica"][rep.name]["placed"],
                    "bank_reconciled": reconciled[rep.name],
                } for rep in fleet.replicas},
            "obs": obs.metrics.snapshot() if obs.enabled else {},
        }
        with open(args.report_json, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True, default=float)
        print(f"report: -> {args.report_json}")


if __name__ == "__main__":
    main()
