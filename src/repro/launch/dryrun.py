import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# (required before ANY jax import — jax locks device count on first init.
#  REPRO_DRYRUN_DEVICES overrides for quick local runs, e.g. 64.)
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves the distribution config is coherent (shardings
compose, collectives legal, memory fits) and extracts the roofline inputs:
  * compiled.memory_analysis()  -> per-device bytes (args/temps/outputs)
  * compiled.cost_analysis()    -> per-device HLO FLOPs + bytes accessed
  * optimized HLO text          -> per-device collective bytes by op type

Results land in ``experiments/dryrun/<cell>.json``; benchmarks/roofline.py
turns them into the EXPERIMENTS.md tables.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all --mesh both
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-27b --shape decode_32k \
      --quant w4 --kv fp4          # the paper-technique serving variant
"""
import argparse
import dataclasses
import json
import re
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.common.clock import wall_clock
from repro.configs.registry import ARCH_IDS, all_cells, get_config
from repro.configs.shapes import SHAPES
from repro.launch.mesh import (make_production_mesh, mesh_chip_count,
                               mesh_scope)
from repro.launch.sharding import (cache_shardings, data_spec,
                                   param_shardings)
from repro.launch.steps import (abstract_caches, abstract_opt,
                                abstract_params, input_specs,
                                make_decode_fn, make_prefill_step,
                                make_train_step, quantize_abstract)
from repro.optim.adam import AdamConfig

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s32": 4, "u32": 4,
                "s64": 8, "u64": 8, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1}

_COLL_RE = re.compile(
    r"=\s+((?:\([^)]*\)|\S+))\s+(all-gather|all-reduce|reduce-scatter|"
    r"all-to-all|collective-permute)(?:-start)?\(")
_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")


def parse_collectives(hlo: str) -> dict:
    """Sum per-device operand bytes of every collective in optimized HLO."""
    totals: dict[str, float] = {}
    counts: dict[str, int] = {}
    largest: list[tuple[float, str, str]] = []
    for m in _COLL_RE.finditer(hlo):
        ty, op = m.group(1), m.group(2)
        nbytes = 0
        for sm in _SHAPE_RE.finditer(ty):
            dt, dims = sm.group(1), sm.group(2)
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        # all-reduce moves ~2x payload (reduce-scatter + all-gather phases)
        moved = nbytes * (2 if op == "all-reduce" else 1)
        totals[op] = totals.get(op, 0) + moved
        counts[op] = counts.get(op, 0) + 1
        largest.append((moved, op, ty[:120]))
    largest.sort(reverse=True)
    return {"bytes_by_op": totals, "count_by_op": counts,
            "total_bytes": sum(totals.values()),
            "top5": [dict(bytes=b, op=o, type=t) for b, o, t in largest[:5]]}


def _mem_dict(ma) -> dict:
    if ma is None:
        return {}
    return {k: getattr(ma, k) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "alias_size_in_bytes",
             "generated_code_size_in_bytes") if hasattr(ma, k)}


def with_depth(cfg, n_groups: int):
    """Reduced-depth clone (same per-group body) for cost extrapolation."""
    return dataclasses.replace(
        cfg, n_layers=cfg.first_k_dense + cfg.period * n_groups)


def _compile_cell(cfg, shape, mesh, *, quant: str, kv: str, big: bool,
                  multi_pod: bool, opts: frozenset = frozenset(),
                  save_hlo: str | None = None) -> dict:
    """Lower + compile one configuration; return raw analysis record.

    ``opts`` are hillclimb variants: 'headfix' (head-divisibility-aware
    attention sharding), 'accumN' (N-way gradient accumulation)."""
    acfg = AdamConfig(lr=3e-4,
                      moment_dtype=jnp.bfloat16 if big else jnp.float32)
    rule_cfg = cfg if "headfix" in opts else None
    grad_accum = 1
    for o in opts:
        if o.startswith("accum"):
            grad_accum = int(o[5:])
    if "moeep" in opts:
        cfg = dataclasses.replace(cfg, moe_impl="ep")
    if "noremat" in opts:
        cfg = dataclasses.replace(cfg, remat=False)
    # serving weights are read every step: FSDP sharding would all-gather
    # them per token — 'nofsdp' keeps them TP-resident (§Perf iteration 1)
    use_fsdp = not ("nofsdp" in opts and shape.kind != "train")
    # 'dpall': small-model config — pure DP, batch over every mesh axis,
    # params replicated (no TP, no FSDP)
    dpall = "dpall" in opts
    use_tp = not dpall
    if dpall:
        use_fsdp = False
    batch_axes = (("pod", "data", "model") if dpall else ("pod", "data"))
    from repro.common.sharding import set_dp_axes
    set_dp_axes(batch_axes)  # activation hints must match input shardings
    rec: dict = {}
    t0 = wall_clock()
    with mesh_scope(mesh):
        aparams = abstract_params(cfg)
        if quant == "w4" and shape.kind != "train":
            aparams = quantize_abstract(aparams)
        ps = param_shardings(aparams, mesh, fsdp=use_fsdp,
                             fsdp_over_pod=(big and multi_pod), cfg=rule_cfg,
                             tp=use_tp)
        specs = input_specs(cfg, shape)
        if shape.kind == "train":
            aopt = abstract_opt(aparams, acfg)
            os_ = param_shardings(aopt, mesh, fsdp=not dpall,
                                  fsdp_over_pod=(big and multi_pod),
                                  cfg=rule_cfg, tp=use_tp)
            bs = {k: NamedSharding(mesh, data_spec(v.shape, mesh,
                                                   axes=batch_axes))
                  for k, v in specs["batch"].items()}
            step = make_train_step(cfg, acfg, grad_accum=grad_accum)
            jitted = jax.jit(step, in_shardings=(ps, os_, bs),
                             out_shardings=(ps, os_, None))
            lowered = jitted.lower(aparams, aopt, specs["batch"])
        elif shape.kind == "prefill":
            bs = {k: NamedSharding(mesh, data_spec(v.shape, mesh,
                                                   axes=batch_axes))
                  for k, v in specs["batch"].items()}
            step = make_prefill_step(cfg)
            jitted = jax.jit(step, in_shardings=(ps, bs))
            lowered = jitted.lower(aparams, specs["batch"])
        else:  # decode
            acaches = specs["caches"]
            cs = cache_shardings(acaches, mesh)
            ts = NamedSharding(mesh, data_spec(specs["token"].shape, mesh))
            step = make_decode_fn(cfg)
            jitted = jax.jit(step, in_shardings=(ps, cs, ts, NamedSharding(mesh, P())),
                             out_shardings=(None, cs))
            lowered = jitted.lower(aparams, acaches, specs["token"],
                                   specs["pos"])
        rec["lower_s"] = round(wall_clock() - t0, 1)
        t1 = wall_clock()
        compiled = lowered.compile()
        rec["compile_s"] = round(wall_clock() - t1, 1)
        try:
            rec["memory"] = _mem_dict(compiled.memory_analysis())
        except Exception as e:  # CPU backend quirks
            rec["memory"] = {"error": str(e)}
        ca = compiled.cost_analysis() or {}
        rec["cost"] = {k: float(v) for k, v in ca.items()
                       if isinstance(v, (int, float)) and "{" not in k}
        hlo = compiled.as_text()
        rec["collectives"] = parse_collectives(hlo)
        rec["hlo_bytes"] = len(hlo)
        if save_hlo:
            with open(save_hlo, "w") as f:
                f.write(hlo)
    return rec


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             quant: str = "bf16", kv: str = "bf16", opts: frozenset = frozenset(),
             save_hlo: str | None = None, extrapolate: bool = True) -> dict:
    """Full-depth compile (the deliverable: shardings + memory are exact)

    plus, because XLA's cost_analysis counts a scan body ONCE regardless of
    trip count, a two-point depth extrapolation (1-group and 2-group
    clones) that recovers true per-step FLOPs/bytes/collective-bytes:
        total(L) = shallow(1) + (L - 1) * [shallow(2) - shallow(1)].
    """
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    if shape.kind == "decode":
        cfg = dataclasses.replace(cfg, kv_dtype=kv)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh_chip_count(mesh)
    big = cfg.param_count() > 3e11  # kimi-class: bf16 moments + pod-FSDP
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "multi" if multi_pod else "single", "chips": chips,
           "quant": quant, "kv": kv, "kind": shape.kind,
           "params": cfg.param_count(),
           "active_params": cfg.active_param_count(),
           "n_groups": cfg.n_groups, "opts": sorted(opts)}
    kw = dict(quant=quant, kv=kv, big=big, multi_pod=multi_pod, opts=opts)
    rec.update(_compile_cell(cfg, shape, mesh, save_hlo=save_hlo, **kw))
    if extrapolate and cfg.n_groups > 1:
        # fully-unrolled shallow clones: every scan/map becomes straightline
        # HLO so cost_analysis counts true per-depth work
        ucfg = dataclasses.replace(cfg, unroll=True)
        r1 = _compile_cell(with_depth(ucfg, 1), shape, mesh, **kw)
        r2 = _compile_cell(with_depth(ucfg, 2), shape, mesh, **kw)
        g = cfg.n_groups

        def lin(a, b):
            return a + (g - 1) * (b - a)

        cost = {}
        for k in ("flops", "bytes accessed", "transcendentals"):
            if k in r1["cost"] and k in r2["cost"]:
                cost[k] = lin(r1["cost"][k], r2["cost"][k])
        coll_by_op = {}
        ops1 = r1["collectives"]["bytes_by_op"]
        ops2 = r2["collectives"]["bytes_by_op"]
        for op in set(ops1) | set(ops2):
            coll_by_op[op] = lin(ops1.get(op, 0), ops2.get(op, 0))
        rec["extrap"] = {
            "cost": cost,
            "collective_bytes_by_op": coll_by_op,
            "collective_bytes": sum(coll_by_op.values()),
            "shallow": [{"cost": r1["cost"],
                         "coll": ops1},
                        {"cost": r2["cost"], "coll": ops2}],
        }
    else:
        rec["extrap"] = {
            "cost": {k: rec["cost"].get(k, 0.0)
                     for k in ("flops", "bytes accessed", "transcendentals")},
            "collective_bytes_by_op": rec["collectives"]["bytes_by_op"],
            "collective_bytes": rec["collectives"]["total_bytes"],
        }
    return rec


def cell_id(rec_or_args) -> str:
    r = rec_or_args
    extra = ""
    if r.get("quant", "bf16") != "bf16":
        extra += f"_{r['quant']}"
    if r.get("kv", "bf16") != "bf16":
        extra += f"_kv{r['kv']}"
    for o in r.get("opts", []) or []:
        extra += f"_{o}"
    return f"{r['arch']}_{r['shape']}_{r['mesh']}{extra}"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--quant", default="bf16", choices=["bf16", "w4"])
    ap.add_argument("--kv", default="bf16", choices=["bf16", "fp8", "fp4"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--save-hlo", default=None)
    ap.add_argument("--opts", default="",
                    help="comma list of hillclimb variants, e.g. headfix,accum4")
    ap.add_argument("--no-extrapolate", action="store_true",
                    help="skip the shallow cost-extrapolation compiles "
                         "(pass/fail + memory only — multi-pod sweep)")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    cells = all_cells()
    if args.arch != "all":
        cells = [(a, s) for a, s in cells if a == args.arch]
    if args.shape != "all":
        cells = [(a, s) for a, s in cells if s == args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    opts = frozenset(o for o in args.opts.split(",") if o)

    n_fail = 0
    for arch, shape in cells:
        for mp in meshes:
            rec_key = cell_id({"arch": arch, "shape": shape,
                               "mesh": "multi" if mp else "single",
                               "quant": args.quant, "kv": args.kv,
                               "opts": sorted(opts)})
            path = os.path.join(args.out, rec_key + ".json")
            if args.skip_existing and os.path.exists(path):
                print(f"[skip] {rec_key}")
                continue
            try:
                rec = run_cell(arch, shape, multi_pod=mp, quant=args.quant,
                               kv=args.kv, opts=opts, save_hlo=args.save_hlo,
                               extrapolate=not args.no_extrapolate)
                rec["ok"] = True
                coll = rec["collectives"]["total_bytes"] / 1e6
                mem = rec.get("memory", {}).get("temp_size_in_bytes", 0) / 1e9
                print(f"[ok]   {rec_key}: compile={rec['compile_s']}s "
                      f"flops/dev={rec['cost'].get('flops', 0):.3e} "
                      f"coll={coll:.1f}MB/dev temp={mem:.2f}GB/dev")
            except Exception as e:
                n_fail += 1
                rec = {"arch": arch, "shape": shape,
                       "mesh": "multi" if mp else "single", "ok": False,
                       "quant": args.quant, "kv": args.kv,
                       "opts": sorted(opts),
                       "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-2000:]}
                print(f"[FAIL] {rec_key}: {type(e).__name__}: {e}")
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
    print(f"done: {len(cells) * len(meshes)} cells, {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
