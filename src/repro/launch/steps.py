"""Step builders + abstract (no-allocation) param/state trees for dry-runs.

``abstract_*`` functions produce ShapeDtypeStruct trees via ``eval_shape``
so the 1T-param configs lower/compile without a byte of device memory —
the dry-run contract.
"""
from __future__ import annotations

import dataclasses
import re
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.common.tree import flatten_paths, unflatten_paths
from repro.configs.shapes import ShapeSpec
from repro.core.qmodule import PackedW4, pack_weight
from repro.models.lm import (LMConfig, cache_specs, decode_step, forward,
                             init_caches, lm_init, loss_fn)
from repro.optim.adam import AdamConfig, adam_init, adam_update
from repro.quant.fakequant import KIND_FP_SIGNED, QuantizerParams
from repro.quant.search import search_weight_params

# Weights quantized for W4 serving (embed/lm_head stay high precision —
# the paper's io-layer convention).
QUANT_WEIGHT_RE = re.compile(
    r"((wq|wk|wv|wo|gate|up|down|in_proj|out_proj)/w|w_gate|w_up|w_down)$")


# ---------------------------------------------------------------------------
# steps
# ---------------------------------------------------------------------------


def make_train_step(cfg: LMConfig, acfg: AdamConfig, *, grad_accum: int = 1):
    """Standard train step; ``grad_accum > 1`` scans over microbatches

    (activation memory drops ~k-fold; grads accumulate in f32)."""

    def train_step(params, opt, batch):
        if grad_accum == 1:
            def loss(p):
                return loss_fn(p, cfg, batch["tokens"], batch.get("extra"))

            l, g = jax.value_and_grad(loss)(params)
        else:
            def split(t):
                return t.reshape(grad_accum, t.shape[0] // grad_accum,
                                 *t.shape[1:])

            micro = {k: split(v) for k, v in batch.items()}

            def body(acc, mb):
                def loss(p):
                    return loss_fn(p, cfg, mb["tokens"], mb.get("extra"))

                li, gi = jax.value_and_grad(loss)(params)
                acc_l, acc_g = acc
                acc_g = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), acc_g, gi)
                return (acc_l + li, acc_g), None

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            if cfg.unroll:  # dry-run cost mode: count every microbatch
                acc = (jnp.float32(0.0), zero_g)
                for i in range(grad_accum):
                    acc, _ = body(acc, {k: v[i] for k, v in micro.items()})
                l, g = acc
            else:
                (l, g), _ = jax.lax.scan(body, (jnp.float32(0.0), zero_g),
                                         micro)
            l = l / grad_accum
            g = jax.tree.map(lambda x: x / grad_accum, g)
        params, opt, m = adam_update(g, opt, params, acfg)
        return params, opt, {"loss": l, **m}

    return train_step


def make_prefill_step(cfg: LMConfig):
    def prefill_step(params, batch):
        return forward(params, cfg, batch["tokens"], batch.get("extra"))

    return prefill_step


def make_decode_fn(cfg: LMConfig, ctx=None):
    """``ctx``: optional QuantContext; a serve-mode context routes packed
    dense layers through the fused W4A4 kernel (activation quant in-VMEM)."""

    def serve_step(params, caches, token, pos):
        return decode_step(params, cfg, caches, token, pos, ctx=ctx)

    return serve_step


# ---------------------------------------------------------------------------
# abstract trees
# ---------------------------------------------------------------------------


def abstract_params(cfg: LMConfig):
    return jax.eval_shape(lambda: lm_init(jax.random.PRNGKey(0), cfg))


def abstract_opt(aparams, acfg: AdamConfig):
    return jax.eval_shape(partial(adam_init, cfg=acfg), aparams)


def abstract_caches(cfg: LMConfig, batch: int, s_max: int):
    return jax.eval_shape(lambda: init_caches(cfg, batch, s_max))


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def quantize_abstract(aparams) -> Any:
    """Replace quantizable weights with abstract PackedW4 (W4 serving form).

    Scanned stacks (G, ..., N) get per-layer scales (G, 1, ..., 1)."""
    flat = flatten_paths(aparams)
    out = {}
    for path, leaf in flat.items():
        if (QUANT_WEIGHT_RE.search(path) and leaf.ndim >= 2
                and leaf.shape[-1] % 2 == 0):
            lead = leaf.shape[:-2]
            scale_shape = tuple([*lead, 1, 1]) if lead else ()
            out[path] = PackedW4(
                packed=_sds((*leaf.shape[:-1], leaf.shape[-1] // 2), jnp.uint8),
                scale=_sds(scale_shape, jnp.float32),
                zero_point=_sds(scale_shape, jnp.float32),
                exp_bits=2, man_bits=1, signed=True, shape=tuple(leaf.shape))
        else:
            out[path] = leaf
    return unflatten_paths(out)


# ---------------------------------------------------------------------------
# concrete serving quantization (examples / benchmarks scale)
# ---------------------------------------------------------------------------


def quantize_lm_for_serving(params, bits: int = 4, *, searched: bool = True,
                            per_channel: bool = False):
    """Pack quantizable LM weights to W4.

    ``searched=True`` runs the paper's MSE search per weight (Table 6
    spaces); False uses absmax scales (the cheap deployment default).
    ``per_channel=True`` emits one scale per output channel: the searched
    (or default E2M1) format is kept, but the grid maximum is refit per
    column — ``maxval_c = absmax_c * (searched_maxval / absmax)`` — so
    every column uses its full code range. The Pallas serving kernel
    consumes the vector scale directly.
    """
    flat = flatten_paths(params)
    out = {}
    for path, leaf in flat.items():
        if not (QUANT_WEIGHT_RE.search(path) and hasattr(leaf, "ndim")
                and leaf.ndim >= 2 and leaf.shape[-1] % 2 == 0):
            out[path] = leaf
            continue
        if leaf.ndim == 2:
            if searched:
                qp = search_weight_params(leaf, bits).params
            else:
                qp = QuantizerParams(KIND_FP_SIGNED, 2, 1, bits,
                                     jnp.max(jnp.abs(leaf)).astype(jnp.float32))
            if per_channel:
                absmax = jnp.maximum(jnp.max(jnp.abs(leaf)), 1e-8)
                col = jnp.maximum(jnp.max(jnp.abs(leaf), axis=0), 1e-8)
                mv = (col * (qp.maxval / absmax)).astype(jnp.float32)
                qp = dataclasses.replace(qp, maxval=mv)
            out[path] = pack_weight(leaf, qp)
        else:
            # stacked (G, ..., N): per-slice absmax scale, one packed array;
            # per_channel additionally keeps the output-channel axis, giving
            # per-(slice, channel) scales of shape (G, 1, ..., N).
            red = tuple(range(1, leaf.ndim - (1 if per_channel else 0)))
            mv = jnp.maximum(
                jnp.max(jnp.abs(leaf), axis=red, keepdims=True), 1e-8
            ).astype(jnp.float32)
            qp = QuantizerParams(KIND_FP_SIGNED, 2, 1, bits, mv)
            out[path] = pack_weight(leaf, qp)
    return unflatten_paths(out)


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins for every model input)
# ---------------------------------------------------------------------------


def input_specs(cfg: LMConfig, shape: ShapeSpec) -> dict:
    """Abstract inputs for one dry-run cell."""
    gb, s = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        batch = {"tokens": _sds((gb, s), jnp.int32)}
        if cfg.family == "vlm":
            batch["extra"] = _sds((gb, cfg.n_img_tokens, cfg.d_vision),
                                  jnp.bfloat16)
        return {"batch": batch}
    # decode: one new token against an s-long cache
    spec_tree = cache_specs(cfg, gb, s)
    caches = jax.tree.map(
        lambda d: _sds(d["shape"], d["dtype"]),
        spec_tree, is_leaf=lambda d: isinstance(d, dict) and "shape" in d)
    return {"caches": caches, "token": _sds((gb, 1), jnp.int32),
            "pos": _sds((), jnp.int32)}
