"""Serving launcher: batched decode with optional W4 weights + FP4/8 KV.

Demonstrates the paper's deployment path end-to-end at reduced scale:
quantize a trained (or randomly initialized) LM to packed W4, prefill a
prompt batch, then decode tokens against the (optionally quantized) KV
cache. The same step functions are what the dry-run lowers at production
scale.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.clock import wall_clock
from repro.configs.registry import get_config
from repro.launch.mesh import make_host_mesh, mesh_scope
from repro.launch.steps import make_decode_fn, quantize_lm_for_serving
from repro.models.lm import forward, init_caches, lm_init
from repro.quant.calibrate import QuantContext
from repro.quant.fakequant import KIND_FP_SIGNED, QuantizerParams


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--quant", default="bf16",
                    choices=["bf16", "w4", "w4pc"],
                    help="w4 = per-tensor scales; w4pc = per-output-channel")
    ap.add_argument("--kv", default="bf16", choices=["bf16", "fp8", "fp4"])
    ap.add_argument("--act-quant", default="off", choices=["off", "fp4"],
                    help="fp4 = fuse E2M1 activation quant into the W4 "
                         "matmul kernel (W4A4 serving)")
    ap.add_argument("--act-maxval", type=float, default=6.0,
                    help="per-tensor activation grid max for --act-quant "
                         "(deployment default; calibration would refine it)")
    ap.add_argument("--greedy", action="store_true", default=True)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    cfg = dataclasses.replace(cfg, kv_dtype=args.kv)
    mesh = make_host_mesh()
    s_max = args.prompt_len + args.gen_len

    with mesh_scope(mesh):
        key = jax.random.PRNGKey(0)
        params = lm_init(key, cfg)
        if args.quant in ("w4", "w4pc"):
            t0 = wall_clock()
            params = quantize_lm_for_serving(
                params, searched=False, per_channel=(args.quant == "w4pc"))
            print(f"quantized to W4 ({args.quant}) in {wall_clock() - t0:.1f}s")
        ctx = None
        if args.act_quant == "fp4" and args.quant == "bf16":
            print("note: --act-quant fp4 with --quant bf16 quantizes "
                  "activations in a standalone msfp pass (A4 only; no "
                  "packed weights to fuse into)")
        if args.act_quant == "fp4":
            # Fused W4A4: every packed dense site quantizes its input to
            # signed E2M1 inside the matmul kernel (no separate qdq pass);
            # bf16-fallback sites quantize in a standalone pass so serving
            # numerics track the fake-quant model at every act site.
            qp = QuantizerParams(KIND_FP_SIGNED, 2, 1, 4,
                                 jnp.float32(args.act_maxval))
            ctx = QuantContext("serve", act_qps={"*": qp})
        prompts = jax.random.randint(key, (args.batch, args.prompt_len),
                                     0, cfg.vocab)
        extra = (jnp.zeros((args.batch, cfg.n_img_tokens, cfg.d_vision),
                           cfg.dtype) if cfg.family == "vlm" else None)
        caches = init_caches(cfg, args.batch, s_max)
        dec = jax.jit(make_decode_fn(cfg, ctx=ctx))

        # prefill by stepping the prompt (teacher-forced decode fills caches)
        t0 = wall_clock()
        logits = None
        for i in range(args.prompt_len):
            logits, caches = dec(params, caches, prompts[:, i:i + 1],
                                 jnp.int32(i))
        prefill_s = wall_clock() - t0

        out_tokens = []
        t0 = wall_clock()
        tok = jnp.argmax(logits[:, -1:], axis=-1)
        for i in range(args.gen_len):
            out_tokens.append(np.asarray(tok)[:, 0])
            logits, caches = dec(params, caches, tok,
                                 jnp.int32(args.prompt_len + i))
            tok = jnp.argmax(logits[:, -1:], axis=-1)
        jax.block_until_ready(logits)
        decode_s = wall_clock() - t0
        gen = np.stack(out_tokens, axis=1)
        print(f"arch={cfg.name} quant={args.quant} act={args.act_quant} "
              f"kv={args.kv}")
        print(f"prefill: {prefill_s:.2f}s  decode: {decode_s:.2f}s "
              f"({args.gen_len * args.batch / max(decode_s, 1e-9):.1f} tok/s)")
        print("sample ids:", gen[0][:16].tolist())


if __name__ == "__main__":
    main()
