"""Launch: production meshes, sharding rules, dry-run, train/serve drivers."""
