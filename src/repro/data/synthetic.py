"""Synthetic data pipelines (offline container: no real datasets).

Image stream: a *learnable* toy distribution for the DDIM reproduction —
each image is a 2D Gaussian bump with random center/width/amplitude plus a
linear gradient background. A small UNet trained on this distribution
denoises visibly, which is all the paper-validation metrics need
(trajectory MSE / denoising gap between FP and quantized models).

Token stream: Zipf-distributed ids with short-range repetition structure
(so next-token loss is learnable), sharded per data-parallel host.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np


def gaussian_bump_images(key, n: int, size: int, channels: int = 3) -> jnp.ndarray:
    """(n, size, size, channels) in [-1, 1]."""
    ks = jax.random.split(key, 5)
    cx = jax.random.uniform(ks[0], (n, 1, 1, 1), minval=0.2, maxval=0.8) * size
    cy = jax.random.uniform(ks[1], (n, 1, 1, 1), minval=0.2, maxval=0.8) * size
    w = jax.random.uniform(ks[2], (n, 1, 1, 1), minval=0.08, maxval=0.25) * size
    amp = jax.random.uniform(ks[3], (n, 1, 1, channels), minval=0.5, maxval=1.0)
    sign = jnp.where(jax.random.bernoulli(ks[4], 0.5, (n, 1, 1, channels)),
                     1.0, -1.0)
    xs = jnp.arange(size, dtype=jnp.float32)[None, :, None, None]
    ys = jnp.arange(size, dtype=jnp.float32)[None, None, :, None]
    bump = jnp.exp(-(((xs - cx) ** 2 + (ys - cy) ** 2) / (2 * w**2)))
    grad = (xs / size - 0.5) * 0.6
    img = sign * amp * bump + grad
    return jnp.clip(img, -1.0, 1.0)


def image_batches(key, batch: int, size: int, channels: int = 3
                  ) -> Iterator[jnp.ndarray]:
    while True:
        key, k = jax.random.split(key)
        yield gaussian_bump_images(k, batch, size, channels)


def zipf_tokens(key, batch: int, seq: int, vocab: int) -> jnp.ndarray:
    """Zipf ids with periodic copy structure (learnable bigram-ish stream)."""
    k1, k2 = jax.random.split(key)
    ranks = jnp.arange(1, vocab + 1, dtype=jnp.float32)
    probs = 1.0 / ranks
    probs = probs / probs.sum()
    base = jax.random.categorical(
        k1, jnp.log(probs)[None, None, :], shape=(batch, seq))
    # inject determinism: every 4th token repeats (t-3), creating structure
    idx = jnp.arange(seq)
    shifted = jnp.roll(base, 3, axis=1)
    mask = (idx % 4 == 0) & (idx >= 3)
    return jnp.where(mask[None, :], shifted, base)


def token_batches(key, batch: int, seq: int, vocab: int
                  ) -> Iterator[jnp.ndarray]:
    while True:
        key, k = jax.random.split(key)
        yield zipf_tokens(k, batch, seq, vocab)


@dataclasses.dataclass
class ShardedLoader:
    """Host-sharded loader: each data-parallel host draws a disjoint key

    stream; batches are placed with the provided sharding (pjit input)."""
    batch: int
    make_batch: callable
    sharding: object | None = None
    seed: int = 0
    host_id: int = 0
    n_hosts: int = 1

    def __iter__(self):
        key = jax.random.PRNGKey(self.seed * 1000003 + self.host_id)
        while True:
            key, k = jax.random.split(key)
            b = self.make_batch(k)
            if self.sharding is not None:
                b = jax.device_put(b, self.sharding)
            yield b
