"""data substrate."""
