"""Per-architecture configs + shape sets + diffusion presets."""
