"""Assigned input-shape set for the LM-family architectures.

train_4k    -> train_step (next-token XE + Adam) on (batch, seq)
prefill_32k -> serve prefill: full-sequence forward producing logits
decode_32k  -> serve_step: one new token against a seq_len KV cache
long_500k   -> serve_step at 524288 context — sub-quadratic archs only
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# long_500k runs only for archs with sub-quadratic context cost (SSM /
# hybrid / mostly-sliding-window). Pure full-attention archs skip it —
# noted in DESIGN.md §Model-structure decisions.
LONG_OK = {"mamba2-370m", "zamba2-2.7b", "gemma3-4b", "gemma3-27b"}


def cells(arch_ids):
    """Every (arch, shape) dry-run cell."""
    out = []
    for a in arch_ids:
        for s in SHAPES:
            if s == "long_500k" and a not in LONG_OK:
                continue
            out.append((a, s))
    return out
