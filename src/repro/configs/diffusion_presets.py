"""The paper's own model configs (DDIM / LDM UNets) + reduced variants."""
from repro.nn.unet import UNetConfig


def ddim_cifar10() -> UNetConfig:
    return UNetConfig(image_size=32, ch=128, ch_mult=(1, 2, 2, 2),
                      num_res_blocks=2, attn_resolutions=(16,))


def ddim_celeba() -> UNetConfig:
    return UNetConfig(image_size=64, ch=128, ch_mult=(1, 2, 2, 2, 4),
                      num_res_blocks=2, attn_resolutions=(16,))


def ldm4_bedroom() -> UNetConfig:
    # LDM-4: 256x256 images -> 64x64x3 latents
    return UNetConfig(image_size=64, in_ch=3, out_ch=3, ch=224,
                      ch_mult=(1, 2, 3, 4), num_res_blocks=2,
                      attn_resolutions=(32, 16, 8))


def ldm8_church() -> UNetConfig:
    # LDM-8: 256x256 -> 32x32x4 latents
    return UNetConfig(image_size=32, in_ch=4, out_ch=4, ch=192,
                      ch_mult=(1, 2, 2, 4), num_res_blocks=2,
                      attn_resolutions=(16, 8))


def ldm4_imagenet() -> UNetConfig:
    return UNetConfig(image_size=64, in_ch=3, out_ch=3, ch=192,
                      ch_mult=(1, 2, 3, 5), num_res_blocks=2,
                      attn_resolutions=(32, 16, 8), num_classes=1000)


def tiny_ddim(size: int = 16) -> UNetConfig:
    """CPU-trainable reduced config used by tests + paper validation."""
    return UNetConfig(image_size=size, ch=32, ch_mult=(1, 2),
                      num_res_blocks=1, attn_resolutions=(size // 2,),
                      gn_groups=8)


DIFFUSION_PRESETS = {
    "ddim-cifar10": ddim_cifar10,
    "ddim-celeba": ddim_celeba,
    "ldm4-bedroom": ldm4_bedroom,
    "ldm8-church": ldm8_church,
    "ldm4-imagenet": ldm4_imagenet,
    "tiny-ddim": tiny_ddim,
}
