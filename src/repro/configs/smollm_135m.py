"""smollm-135m [dense] — llama-arch small (hf:HuggingFaceTB/SmolLM-135M).

30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152, tied embeddings.
"""
import jax.numpy as jnp
from repro.models.lm import LMConfig


def full() -> LMConfig:
    return LMConfig("smollm-135m", n_layers=30, d_model=576, n_heads=9,
                    n_kv=3, d_ff=1536, vocab=49152, tie_embeddings=True,
                    head_dim=64)


def smoke() -> LMConfig:
    return LMConfig("smollm-135m-smoke", n_layers=3, d_model=48, n_heads=3,
                    n_kv=1, d_ff=96, vocab=128, tie_embeddings=True,
                    head_dim=16, dtype=jnp.float32, q_chunk=8)
