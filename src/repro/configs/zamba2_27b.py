"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention (2411.15242).

54L d_model=2560, ssm_state=64, headdim=64; one shared transformer block
(attn 32H + GeLU MLP d_ff=10240) applied every 6 mamba layers with shared
weights (9 invocations, per-invocation KV cache).
"""
import jax.numpy as jnp
from repro.models.lm import LMConfig, SSM


def full() -> LMConfig:
    return LMConfig("zamba2-2.7b", family="hybrid", n_layers=54,
                    d_model=2560, n_heads=32, n_kv=32, d_ff=10240,
                    vocab=32000, head_dim=80, mlp_kind="gelu",
                    layer_pattern=((SSM, None, 10_000.0),) * 6,
                    shared_attn_every=6, ssm_d_state=64, ssm_headdim=64,
                    ssm_chunk=256)


def smoke() -> LMConfig:
    return LMConfig("zamba2-smoke", family="hybrid", n_layers=4, d_model=64,
                    n_heads=4, n_kv=4, d_ff=128, vocab=128, head_dim=16,
                    mlp_kind="gelu", layer_pattern=((SSM, None, 10_000.0),) * 2,
                    shared_attn_every=2, ssm_d_state=16, ssm_headdim=16,
                    ssm_chunk=8, dtype=jnp.float32, q_chunk=8)
