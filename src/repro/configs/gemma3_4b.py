"""gemma3-4b [dense] — 5:1 local:global, 128k context.

34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144, GeGLU,
head_dim=256. 34 = 4 leading local layers + 5 scanned groups of 6.
"""
import jax.numpy as jnp
from repro.models.lm import LMConfig, ATTN

_PAT = ((ATTN, 1024, 10_000.0),) * 5 + ((ATTN, None, 1_000_000.0),)


def full() -> LMConfig:
    return LMConfig("gemma3-4b", n_layers=34, d_model=2560, n_heads=8,
                    n_kv=4, d_ff=10240, vocab=262144, mlp_kind="geglu",
                    head_dim=256, scale_embed=True, layer_pattern=_PAT,
                    first_k_dense=4)


def smoke() -> LMConfig:
    return LMConfig("gemma3-4b-smoke", n_layers=10, d_model=64, n_heads=4,
                    n_kv=2, d_ff=128, vocab=128, mlp_kind="geglu",
                    head_dim=16, scale_embed=True,
                    layer_pattern=((ATTN, 8, 10_000.0),) * 5
                    + ((ATTN, None, 1_000_000.0),),
                    first_k_dense=4, dtype=jnp.float32, q_chunk=8)
