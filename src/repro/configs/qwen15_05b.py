"""qwen1.5-0.5b [dense] — QKV bias (hf:Qwen/Qwen1.5-0.5B).

24L d_model=1024 16H (kv=16) d_ff=2816 vocab=151936, tied embeddings.
"""
import jax.numpy as jnp
from repro.models.lm import LMConfig


def full() -> LMConfig:
    return LMConfig("qwen1.5-0.5b", n_layers=24, d_model=1024, n_heads=16,
                    n_kv=16, d_ff=2816, vocab=151936, qkv_bias=True,
                    tie_embeddings=True, head_dim=64)


def smoke() -> LMConfig:
    return LMConfig("qwen1.5-0.5b-smoke", n_layers=3, d_model=64, n_heads=4,
                    n_kv=4, d_ff=128, vocab=128, qkv_bias=True,
                    tie_embeddings=True, head_dim=16, dtype=jnp.float32,
                    q_chunk=8)
