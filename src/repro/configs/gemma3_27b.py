"""gemma3-27b [dense] — 5:1 local:global attention, 128k context.

62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144, GeGLU,
head_dim=128, sliding window 1024 on local layers, rope theta 10k local /
1M global. 62 = 2 leading (unscanned) local layers + 10 scanned groups of
(5 local + 1 global).
"""
import jax.numpy as jnp
from repro.models.lm import LMConfig, ATTN

_PAT = ((ATTN, 1024, 10_000.0),) * 5 + ((ATTN, None, 1_000_000.0),)


def full() -> LMConfig:
    return LMConfig("gemma3-27b", n_layers=62, d_model=5376, n_heads=32,
                    n_kv=16, d_ff=21504, vocab=262144, mlp_kind="geglu",
                    head_dim=128, scale_embed=True, layer_pattern=_PAT,
                    first_k_dense=2)


def smoke() -> LMConfig:
    return LMConfig("gemma3-27b-smoke", n_layers=8, d_model=64, n_heads=4,
                    n_kv=2, d_ff=128, vocab=128, mlp_kind="geglu",
                    head_dim=16, scale_embed=True,
                    layer_pattern=((ATTN, 8, 10_000.0),) * 5
                    + ((ATTN, None, 1_000_000.0),),
                    first_k_dense=2, dtype=jnp.float32, q_chunk=8)
