"""kimi-k2-1t-a32b [moe] — trillion-param MoE (arXiv:2501.kimi2 table).

61L d_model=7168 64H (GQA kv=8) vocab=163840, MoE 384 experts top-8 +
1 shared expert, expert d_ff=2048, first layer dense. head_dim=112.
Training this on a v5e pod requires bf16 Adam moments + full remat (see
EXPERIMENTS §Roofline).
"""
import jax.numpy as jnp
from repro.models.lm import LMConfig


def full() -> LMConfig:
    return LMConfig("kimi-k2-1t-a32b", family="moe", n_layers=61,
                    d_model=7168, n_heads=64, n_kv=8, d_ff=0, vocab=163840,
                    head_dim=112, n_experts=384, top_k=8, moe_d_ff=2048,
                    n_shared=1, first_k_dense=1)


def smoke() -> LMConfig:
    return LMConfig("kimi-k2-smoke", family="moe", n_layers=3, d_model=64,
                    n_heads=4, n_kv=2, d_ff=0, vocab=128, head_dim=16,
                    n_experts=8, top_k=2, moe_d_ff=32, n_shared=1,
                    first_k_dense=1, capacity_factor=2.0, dtype=jnp.float32,
                    q_chunk=8)
