"""llama4-scout-17b-a16e [moe] — 16 experts top-1 + shared expert.

48L d_model=5120 40H (GQA kv=8) expert d_ff=8192 vocab=202048.
(Modality early-fusion is out of scope for the LM backbone cells.)
"""
import jax.numpy as jnp
from repro.models.lm import LMConfig


def full() -> LMConfig:
    return LMConfig("llama4-scout-17b-a16e", family="moe", n_layers=48,
                    d_model=5120, n_heads=40, n_kv=8, d_ff=0, vocab=202048,
                    head_dim=128, n_experts=16, top_k=1, moe_d_ff=8192,
                    n_shared=1)


def smoke() -> LMConfig:
    return LMConfig("llama4-scout-smoke", family="moe", n_layers=2,
                    d_model=64, n_heads=4, n_kv=2, d_ff=0, vocab=128,
                    head_dim=16, n_experts=4, top_k=1, moe_d_ff=32,
                    n_shared=1, capacity_factor=2.0, dtype=jnp.float32,
                    q_chunk=8)
