"""musicgen-large [audio] — decoder-only over EnCodec tokens (2306.05284).

48L d_model=2048 32H (MHA kv=32) d_ff=8192 vocab=2048, GELU MLP,
sinusoidal positions. The EnCodec frontend is a stub per the assignment:
input_specs provide token ids (the 4-codebook interleave is flattened).
"""
import jax.numpy as jnp
from repro.models.lm import LMConfig


def full() -> LMConfig:
    return LMConfig("musicgen-large", family="audio", n_layers=48,
                    d_model=2048, n_heads=32, n_kv=32, d_ff=8192, vocab=2048,
                    mlp_kind="gelu", pos="sinusoidal", head_dim=64)


def smoke() -> LMConfig:
    return LMConfig("musicgen-smoke", family="audio", n_layers=3, d_model=64,
                    n_heads=4, n_kv=4, d_ff=128, vocab=64, mlp_kind="gelu",
                    pos="sinusoidal", head_dim=16, dtype=jnp.float32,
                    q_chunk=8)
