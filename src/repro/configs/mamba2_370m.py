"""mamba2-370m [ssm] — SSD, attention-free (arXiv:2405.21060).

48L d_model=1024, ssm_state=128, vocab=50280, headdim=64 (d_inner=2048 ->
32 SSM heads). No attention => no KV cache; decode carries (state, conv).
"""
import jax.numpy as jnp
from repro.models.lm import LMConfig, SSM

_PAT = ((SSM, None, 10_000.0),)


def full() -> LMConfig:
    return LMConfig("mamba2-370m", family="ssm", n_layers=48, d_model=1024,
                    n_heads=16, n_kv=16, d_ff=0, vocab=50280,
                    layer_pattern=_PAT, ssm_d_state=128, ssm_headdim=64,
                    ssm_chunk=256, tie_embeddings=True)


def smoke() -> LMConfig:
    return LMConfig("mamba2-370m-smoke", family="ssm", n_layers=4, d_model=64,
                    n_heads=4, n_kv=4, d_ff=0, vocab=128, layer_pattern=_PAT,
                    ssm_d_state=16, ssm_headdim=16, ssm_chunk=8,
                    tie_embeddings=True, dtype=jnp.float32)
