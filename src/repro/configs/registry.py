"""Architecture registry: --arch <id> -> LMConfig (full or smoke)."""
from __future__ import annotations

from repro.configs import (gemma3_27b, gemma3_4b, kimi_k2_1t,
                           llama4_scout, llava_next_mistral_7b, mamba2_370m,
                           musicgen_large, qwen15_05b, smollm_135m,
                           zamba2_27b)
from repro.configs.shapes import LONG_OK, SHAPES, ShapeSpec, cells

ARCHS = {
    "mamba2-370m": mamba2_370m,
    "qwen1.5-0.5b": qwen15_05b,
    "gemma3-27b": gemma3_27b,
    "gemma3-4b": gemma3_4b,
    "smollm-135m": smollm_135m,
    "kimi-k2-1t-a32b": kimi_k2_1t,
    "llama4-scout-17b-a16e": llama4_scout,
    "musicgen-large": musicgen_large,
    "llava-next-mistral-7b": llava_next_mistral_7b,
    "zamba2-2.7b": zamba2_27b,
}

ARCH_IDS = list(ARCHS)


def _validate() -> None:
    """Fail at import on a malformed registry (duplicate names, missing
    config builders, shape references to unknown archs) — the gateway's
    model registry and the dry-run cell matrix both trust these entries,
    so a bad one must not survive to first use."""
    seen: dict[str, str] = {}
    for arch, mod in ARCHS.items():
        for attr in ("full", "smoke"):
            if not callable(getattr(mod, attr, None)):
                raise ImportError(f"configs registry: {arch!r} module "
                                  f"{mod.__name__} lacks a callable "
                                  f"{attr}()")
        name = mod.full().name
        if name in seen:
            raise ImportError(f"configs registry: duplicate config name "
                              f"{name!r} ({seen[name]} vs {arch})")
        seen[name] = arch
    unknown = set(LONG_OK) - set(ARCHS)
    if unknown:
        raise ImportError(f"configs registry: LONG_OK references unknown "
                          f"archs {sorted(unknown)}")
    if not SHAPES:
        raise ImportError("configs registry: SHAPES is empty")


_validate()


def get_config(arch: str, smoke: bool = False):
    mod = ARCHS[arch]
    return mod.smoke() if smoke else mod.full()


def list_models() -> list[str]:
    """Registered arch ids, sorted — the gateway registry and the
    ``--models`` flag help text both enumerate from here."""
    return sorted(ARCHS)


def all_cells():
    return cells(ARCH_IDS)
