"""llava-next-mistral-7b [vlm] — mistral-7b backbone, anyres tiling stub.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000, head_dim=128,
rope theta 1e6. Vision frontend is a stub per the assignment:
input_specs provide 576 precomputed patch embeddings (d_vision=1024)
projected and placed at the sequence head.
"""
import jax.numpy as jnp
from repro.models.lm import LMConfig, ATTN


def full() -> LMConfig:
    return LMConfig("llava-next-mistral-7b", family="vlm", n_layers=32,
                    d_model=4096, n_heads=32, n_kv=8, d_ff=14336,
                    vocab=32000, head_dim=128,
                    layer_pattern=((ATTN, None, 1_000_000.0),),
                    n_img_tokens=576, d_vision=1024)


def smoke() -> LMConfig:
    return LMConfig("llava-next-smoke", family="vlm", n_layers=2, d_model=64,
                    n_heads=4, n_kv=2, d_ff=128, vocab=128, head_dim=16,
                    layer_pattern=((ATTN, None, 1_000_000.0),),
                    n_img_tokens=8, d_vision=32, dtype=jnp.float32, q_chunk=8)
