"""Pallas TPU kernel: fused MSFP fake-quantization (quantize-dequantize).

Bandwidth-bound elementwise op: one HBM read + one write per element,
snapping to the ExMy grid arithmetically in VMEM (exponent via log2,
mantissa rounding at the octave step) — no LUT, no gather. Tiles are
(block_rows, block_cols) with the trailing dim a multiple of 128 lanes.

The (maxval, zero_point) pair is traced data (searched per site), passed
as a (1, 2) operand broadcast to every tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.quant.fakequant import KIND_FP_SIGNED, QuantizerParams
from repro.quant.formats import FPFormat


def _qdq_block(x, maxval, zp, fmt: FPFormat, signed: bool):
    """The in-VMEM snap — mirrors quant.fakequant.fp_qdq exactly."""
    xf = x.astype(jnp.float32)
    scale = maxval / fmt.base_max
    inv = jnp.where(scale > 0, 1.0 / jnp.maximum(scale, 1e-30), 0.0)
    y = jnp.abs(xf) * inv if signed else jnp.clip((xf - zp) * inv, 0.0, None)
    man = fmt.man_bits
    if fmt.exp_bits == 0:
        step = 2.0**-man
        q = jnp.minimum(jnp.round(y / step) * step, fmt.base_max)
    else:
        max_oct = 2**fmt.exp_bits - 2
        safe = jnp.maximum(y, 2.0**-40)
        oct_ = jnp.clip(jnp.floor(jnp.log2(safe)), 0, max_oct)
        step = jnp.exp2(oct_ - man)
        q = jnp.minimum(jnp.round(y / step) * step, fmt.base_max)
    if signed:
        out = jnp.sign(xf) * q * scale
    else:
        out = q * scale + zp
    return out.astype(x.dtype)


def _kernel(x_ref, mz_ref, o_ref, *, fmt: FPFormat, signed: bool):
    maxval = mz_ref[0, 0]
    zp = mz_ref[0, 1]
    o_ref[...] = _qdq_block(x_ref[...], maxval, zp, fmt, signed)


@functools.partial(jax.jit, static_argnames=("exp_bits", "man_bits", "signed",
                                             "block_rows", "block_cols",
                                             "interpret"))
def msfp_qdq_2d(x: jnp.ndarray, maxval: jnp.ndarray, zero_point: jnp.ndarray,
                *, exp_bits: int, man_bits: int, signed: bool,
                block_rows: int = 256, block_cols: int = 512,
                interpret: bool = False) -> jnp.ndarray:
    """x: (M, N); returns fake-quantized x. Pads to block multiples."""
    fmt = FPFormat(exp_bits, man_bits, signed)
    m, n = x.shape
    bm = min(block_rows, m)
    bn = min(block_cols, n)
    pm = (-m) % bm
    pn = (-n) % bn
    xp = jnp.pad(x, ((0, pm), (0, pn))) if (pm or pn) else x
    mz = jnp.stack([jnp.asarray(maxval, jnp.float32),
                    jnp.asarray(zero_point, jnp.float32)]).reshape(1, 2)
    out = pl.pallas_call(
        functools.partial(_kernel, fmt=fmt, signed=signed),
        grid=(xp.shape[0] // bm, xp.shape[1] // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, 2), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
        interpret=interpret,
    )(xp, mz)
    return out[:m, :n] if (pm or pn) else out


def msfp_qdq(x: jnp.ndarray, qp: QuantizerParams, *,
             interpret: bool = False) -> jnp.ndarray:
    """Arbitrary-rank wrapper: flattens to 2D tiles."""
    shape = x.shape
    n = shape[-1] if x.ndim > 1 else shape[0]
    x2 = x.reshape(-1, n)
    out = msfp_qdq_2d(x2, qp.maxval, qp.zero_point,
                      exp_bits=qp.exp_bits, man_bits=qp.man_bits,
                      signed=(qp.kind == KIND_FP_SIGNED), interpret=interpret)
    return out.reshape(shape)
