"""Packed-W4 conv2d via im2col feeding the fused W4A4 Pallas matmul.

Conv sites are the UNet's workhorse, and the serving path must give them
the same treatment dense sites get: packed nibbles decoded in VMEM, with
the MSFP activation snap fused into the matmul. Rather than a bespoke
conv kernel, the route lowers NHWC conv (stride + SAME/VALID) to a GEMM:

  1. ``im2col`` unfolds x into a (B*OH*OW, kh*kw*cin) patch matrix whose
     column order matches the HWIO weight flattened to (kh*kw*cin, cout)
     — exactly the 2D layout ``core.qmodule.pack_weight`` uses for 4D
     weights, so the *same* split-half nibble packs and (per-output-
     channel) scale operands feed ``w4_matmul_2d`` / ``w4a4_matmul_2d``.
  2. The fused kernel applies the MSFP act-quant snap to each patch tile
     in VMEM before the dot (``msfp_quant._qdq_block``), so activations
     are quantized on the way into the MXU with no extra HBM pass.

Zero-padding correctness: SAME padding inserts exact zeros into the patch
matrix. A *signed* MSFP snap maps 0 -> 0, so fusing the snap over patches
equals quantize-then-pad (the fake-quant oracle's order). Unsigned
formats map 0 to the grid floor (the zero-point), so ``ops.w4a4_conv2d``
pre-quantizes x for those and runs the plain packed matmul — parity is
preserved for the full format space, fusion for the common signed case.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro.core.qmodule import PackedW4
from repro.kernels.w4_matmul import w4_matmul_2d, w4a4_matmul_2d
from repro.quant.fakequant import KIND_FP_SIGNED, QuantizerParams


def conv_pads(h: int, w: int, kh: int, kw: int, stride: tuple[int, int],
              padding) -> tuple[tuple[int, int], tuple[int, int]]:
    """Resolve a conv padding spec ('SAME'/'VALID' or explicit pairs) to
    ((ph_lo, ph_hi), (pw_lo, pw_hi)) for the spatial dims."""
    if isinstance(padding, str):
        pads = lax.padtype_to_pads((h, w), (kh, kw), stride, padding)
    else:
        pads = [tuple(p) for p in padding]
    (p0, p1), (p2, p3) = pads
    return (int(p0), int(p1)), (int(p2), int(p3))


def im2col(x: jnp.ndarray, kh: int, kw: int, *, stride: tuple[int, int],
           padding) -> tuple[jnp.ndarray, tuple[int, int, int]]:
    """NHWC x -> (B*OH*OW, kh*kw*cin) patch matrix + (B, OH, OW).

    Patch columns are ordered (kh, kw, cin)-major — the flattening of an
    HWIO kernel's leading axes — so ``patches @ w.reshape(-1, cout)``
    equals ``conv_general_dilated(x, w)``.
    """
    b, h, w, c = x.shape
    sh, sw = stride
    (ph0, ph1), (pw0, pw1) = conv_pads(h, w, kh, kw, stride, padding)
    if ph0 or ph1 or pw0 or pw1:
        x = jnp.pad(x, ((0, 0), (ph0, ph1), (pw0, pw1), (0, 0)))
    oh = (h + ph0 + ph1 - kh) // sh + 1
    ow = (w + pw0 + pw1 - kw) // sw + 1
    cols = []
    for i in range(kh):
        for j in range(kw):
            cols.append(x[:, i:i + sh * (oh - 1) + 1:sh,
                          j:j + sw * (ow - 1) + 1:sw, :])
    patches = cols[0] if len(cols) == 1 else jnp.concatenate(cols, axis=-1)
    return patches.reshape(b * oh * ow, kh * kw * c), (b, oh, ow)


def w4a4_conv2d_im2col(x: jnp.ndarray, pw: PackedW4,
                       act_qp: QuantizerParams | None, *,
                       stride: tuple[int, int], padding,
                       interpret: bool = False) -> jnp.ndarray:
    """x: (B, H, W, cin) @ packed HWIO W4 -> (B, OH, OW, cout).

    ``act_qp`` (signed, per-tensor) fuses the MSFP act snap into the
    matmul kernel; None runs the plain packed matmul (caller pre-quantized
    or no act quant planned).
    """
    kh, kw, cin, cout = pw.shape
    assert x.shape[-1] == cin, (x.shape, pw.shape)
    patches, (b, oh, ow) = im2col(x, kh, kw, stride=stride, padding=padding)
    if act_qp is None:
        out = w4_matmul_2d(patches, pw.packed, pw.scale, pw.zero_point,
                           exp_bits=pw.exp_bits, man_bits=pw.man_bits,
                           signed=pw.signed, interpret=interpret)
    else:
        assert act_qp.kind == KIND_FP_SIGNED and jnp.ndim(act_qp.maxval) == 0
        out = w4a4_matmul_2d(
            patches, pw.packed, pw.scale, pw.zero_point,
            act_qp.maxval, act_qp.zero_point,
            exp_bits=pw.exp_bits, man_bits=pw.man_bits, signed=pw.signed,
            act_exp_bits=act_qp.exp_bits, act_man_bits=act_qp.man_bits,
            act_signed=True, interpret=interpret)
    return out.reshape(b, oh, ow, cout)
