"""Packed-W4 conv2d: implicit GEMM (no patch matrix) + im2col fallback.

Conv sites are the UNet's workhorse, and the serving path must give them
the same treatment dense sites get: packed nibbles decoded in VMEM, with
the MSFP activation snap fused into the matmul. Two routes:

**Implicit GEMM** (``w4a4_conv2d_implicit``, the fix for the patch-matrix
HBM round-trip): the unfold is folded into the kernel's ``BlockSpec``
index maps. The grid is (B, half, cout-blocks, cin-blocks); each program
receives the whole (padded) spatial slab of one batch element for one
cin block straight from the NHWC activation — the (B*OH*OW, kh*kw*cin)
patch matrix is never materialized in HBM. The kernel statically unrolls
the kh*kw taps as strided in-VMEM slices of the slab, accumulating
``slab[ki::sh, kj::sw, :] @ W[ki, kj]`` against the nibble pack reshaped
(kh*kw, cin, cout/2) — a free view of the flattened 2D pack. The MSFP
act snap runs once per (batch, cin-block) on the in-VMEM slab (snap-once
scratch, as in ``w4_matmul``), and per-tile iota masks restore exact
zeros at the SAME-padding / alignment-padding positions afterwards — so
*unsigned* activation grids (which map 0 to the zero-point) fuse too,
matching the oracle's quantize-then-pad order without the old
pre-quantize HBM pass.

**im2col fallback** (``w4a4_conv2d_im2col``): unfolds x into the patch
matrix and feeds the fused W4A4 matmul. Kept as the oracle for the
implicit route's index maps and as the fallback when the implicit
kernel's VMEM footprint (whole-slab blocks) exceeds budget.

Zero-padding correctness (im2col route): SAME padding inserts exact
zeros into the patch matrix. A *signed* MSFP snap maps 0 -> 0, so fusing
the snap over patches equals quantize-then-pad (the fake-quant oracle's
order). Unsigned formats map 0 to the grid floor (the zero-point), so on
this route ``ops.w4a4_conv2d`` pre-quantizes x for those and runs the
plain packed matmul; the implicit route handles them in-kernel instead.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.qmodule import PackedW4
from repro.kernels.msfp_quant import _qdq_block
from repro.kernels.w4_matmul import (_decode_block, _split_half_rows,
                                     w4_matmul_2d, w4a4_matmul_2d)
from repro.quant.fakequant import KIND_FP_SIGNED, QuantizerParams
from repro.quant.formats import FPFormat


def conv_pads(h: int, w: int, kh: int, kw: int, stride: tuple[int, int],
              padding) -> tuple[tuple[int, int], tuple[int, int]]:
    """Resolve a conv padding spec ('SAME'/'VALID' or explicit pairs) to
    ((ph_lo, ph_hi), (pw_lo, pw_hi)) for the spatial dims."""
    if isinstance(padding, str):
        pads = lax.padtype_to_pads((h, w), (kh, kw), stride, padding)
    else:
        pads = [tuple(p) for p in padding]
    (p0, p1), (p2, p3) = pads
    return (int(p0), int(p1)), (int(p2), int(p3))


def im2col(x: jnp.ndarray, kh: int, kw: int, *, stride: tuple[int, int],
           padding) -> tuple[jnp.ndarray, tuple[int, int, int]]:
    """NHWC x -> (B*OH*OW, kh*kw*cin) patch matrix + (B, OH, OW).

    Patch columns are ordered (kh, kw, cin)-major — the flattening of an
    HWIO kernel's leading axes — so ``patches @ w.reshape(-1, cout)``
    equals ``conv_general_dilated(x, w)``.
    """
    b, h, w, c = x.shape
    sh, sw = stride
    (ph0, ph1), (pw0, pw1) = conv_pads(h, w, kh, kw, stride, padding)
    if ph0 or ph1 or pw0 or pw1:
        x = jnp.pad(x, ((0, 0), (ph0, ph1), (pw0, pw1), (0, 0)))
    oh = (h + ph0 + ph1 - kh) // sh + 1
    ow = (w + pw0 + pw1 - kw) // sw + 1
    cols = []
    for i in range(kh):
        for j in range(kw):
            cols.append(x[:, i:i + sh * (oh - 1) + 1:sh,
                          j:j + sw * (ow - 1) + 1:sw, :])
    patches = cols[0] if len(cols) == 1 else jnp.concatenate(cols, axis=-1)
    return patches.reshape(b * oh * ow, kh * kw * c), (b, oh, ow)


def w4a4_conv2d_im2col(x: jnp.ndarray, pw: PackedW4,
                       act_qp: QuantizerParams | None, *,
                       stride: tuple[int, int], padding,
                       interpret: bool = False) -> jnp.ndarray:
    """x: (B, H, W, cin) @ packed HWIO W4 -> (B, OH, OW, cout).

    ``act_qp`` (signed, per-tensor) fuses the MSFP act snap into the
    matmul kernel; None runs the plain packed matmul (caller pre-quantized
    or no act quant planned).
    """
    kh, kw, cin, cout = pw.shape
    assert x.shape[-1] == cin, (x.shape, pw.shape)
    patches, (b, oh, ow) = im2col(x, kh, kw, stride=stride, padding=padding)
    if act_qp is None:
        out = w4_matmul_2d(patches, pw.packed, pw.scale, pw.zero_point,
                           exp_bits=pw.exp_bits, man_bits=pw.man_bits,
                           signed=pw.signed, interpret=interpret)
    else:
        assert act_qp.kind == KIND_FP_SIGNED and jnp.ndim(act_qp.maxval) == 0
        out = w4a4_matmul_2d(
            patches, pw.packed, pw.scale, pw.zero_point,
            act_qp.maxval, act_qp.zero_point,
            exp_bits=pw.exp_bits, man_bits=pw.man_bits, signed=pw.signed,
            act_exp_bits=act_qp.exp_bits, act_man_bits=act_qp.man_bits,
            act_signed=True, interpret=interpret)
    return out.reshape(b, oh, ow, cout)


# ---------------------------------------------------------------------------
# Implicit GEMM: the unfold lives in the BlockSpec index maps.
# ---------------------------------------------------------------------------

# Per-program VMEM footprint cap for the implicit route (slab + snap-once
# scratch + packed block + accumulator). Above this the dispatcher falls
# back to the im2col route.
IMPLICIT_VMEM_BUDGET = 8 * 1024 * 1024


def _conv_geometry(x_shape, kh, kw, stride, padding):
    """Static geometry: output size and the exact input span the taps read.

    ``hs = (oh-1)*sh + kh`` (and ``ws`` likewise) is the padded-input span
    the strided taps actually touch — it can be *smaller* than the padded
    input when the stride doesn't cover the tail, so the slab is sliced,
    never over-read.
    """
    _, h, w, _ = x_shape
    sh, sw = stride
    (ph0, ph1), (pw0, pw1) = conv_pads(h, w, kh, kw, stride, padding)
    oh = (h + ph0 + ph1 - kh) // sh + 1
    ow = (w + pw0 + pw1 - kw) // sw + 1
    hs = (oh - 1) * sh + kh
    ws = (ow - 1) * sw + kw
    return oh, ow, hs, ws, ph0, pw0


def implicit_vmem_bytes(x_shape, pw_shape, stride, padding, *,
                        fused: bool, itemsize: int = 4,
                        bc: int = 128, bn: int = 128) -> int:
    """Worst-case per-program VMEM bytes for ``w4a4_conv2d_implicit``."""
    kh, kw, cin, cout = pw_shape
    oh, ow, hs, ws, _, _ = _conv_geometry(x_shape, kh, kw, stride, padding)
    bc = min(bc, cin)
    bn = min(bn, max(cout // 2, 1))
    cin_p = cin + (-cin) % bc
    mp = oh * ow + (-(oh * ow)) % 8
    slab = hs * ws * bc * itemsize
    xq = hs * ws * cin_p * itemsize if fused else 0
    packed = kh * kw * bc * bn
    acc = mp * bn * 4
    return slab + xq + packed + acc


def _implicit_kernel(x_ref, p_ref, s_ref, z_ref, amz_ref, o_ref, acc_ref,
                     *xq_ref, fmt: FPFormat, act_fmt: FPFormat | None,
                     act_signed: bool, kh, kw, sh, sw, oh, ow, nc, bc,
                     valid, mp):
    """One program: every tap's contribution of one cin block to one
    (batch, half, cout-block) output tile. Grid (B, 2, nj, nc), c innermost
    accumulating; the x slab arrives as a (1, hs, ws, bc) block gathered
    straight from the padded NHWC activation by the index map."""
    hh = pl.program_id(1)
    j = pl.program_id(2)
    c = pl.program_id(3)
    ph0, h, pw0, w, cin = valid

    @pl.when(c == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    if act_fmt is not None and xq_ref:
        xq = xq_ref[0]

        @pl.when((hh == 0) & (j == 0))
        def _snap():
            slab = _qdq_block(x_ref[0], amz_ref[0, 0], amz_ref[0, 1],
                              act_fmt, act_signed)
            if not act_signed:
                # Unsigned grids map 0 to the zero-point: restore exact
                # zeros at every padded position (SAME/alignment spatial
                # pad, cin alignment pad) so the taps and the zp rowsum
                # see quantize-then-pad — the fake-quant oracle's order.
                r = lax.broadcasted_iota(jnp.int32, slab.shape, 0)
                col = lax.broadcasted_iota(jnp.int32, slab.shape, 1)
                ch = lax.broadcasted_iota(jnp.int32, slab.shape, 2)
                ok = ((r >= ph0) & (r < ph0 + h)
                      & (col >= pw0) & (col < pw0 + w)
                      & (ch + c * bc < cin))
                slab = jnp.where(ok, slab, jnp.zeros_like(slab))
            xq[:, :, pl.ds(c * bc, bc)] = slab

        slab = xq[:, :, pl.ds(c * bc, bc)]
    else:
        slab = x_ref[0]

    shift = hh * 4
    codes = (p_ref[...].astype(jnp.int32) >> shift) & 0xF
    scale = s_ref[0, :] * (1.0 / fmt.base_max)
    wt = _decode_block(codes, fmt, scale[None, None, :]).astype(slab.dtype)

    acc = jnp.zeros((oh * ow, acc_ref.shape[1]), jnp.float32)
    for ki in range(kh):
        for kj in range(kw):
            xv = slab[ki:ki + sh * (oh - 1) + 1:sh,
                      kj:kj + sw * (ow - 1) + 1:sw, :]
            xv = xv.reshape(oh * ow, xv.shape[-1])
            acc += jnp.dot(xv, wt[ki * kw + kj],
                           preferred_element_type=jnp.float32)
            if not fmt.signed:
                rowsum = jnp.sum(xv.astype(jnp.float32), axis=1,
                                 keepdims=True)
                acc += rowsum * z_ref[0, :][None, :]
    if mp != oh * ow:
        acc = jnp.pad(acc, ((0, mp - oh * ow), (0, 0)))
    acc_ref[...] += acc

    @pl.when(c == nc - 1)
    def _flush():
        o_ref[...] = acc_ref[...][None].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("stride", "padding", "bc", "bn",
                                             "interpret"))
def w4a4_conv2d_implicit(x: jnp.ndarray, pw: PackedW4,
                         act_qp: QuantizerParams | None, *,
                         stride: tuple[int, int], padding,
                         bc: int = 128, bn: int = 128,
                         interpret: bool = False) -> jnp.ndarray:
    """Implicit-GEMM conv: x (B, H, W, cin) @ packed HWIO W4 -> NHWC out.

    No patch matrix: x is zero-padded once (spatial + cin/lane alignment)
    and the kernel's index maps hand each program the slab it gathers taps
    from. ``act_qp`` may be *signed or unsigned* per-tensor FP — the snap
    runs in-kernel with per-tile pad masking (see ``_implicit_kernel``).
    """
    kh, kw, cin, cout = pw.shape
    b, h, w, c = x.shape
    assert c == cin, (x.shape, pw.shape)
    sh, sw = stride
    oh, ow, hs, ws, ph0, pw0 = _conv_geometry(x.shape, kh, kw, stride,
                                              padding)
    bc = min(bc, cin)
    pc = (-cin) % bc
    nc = (cin + pc) // bc

    # Pad to the exact tap span (the span can undershoot the padded input
    # when the stride skips the tail — slice in that case), plus cin pad.
    xp = jnp.pad(x, ((0, 0), (ph0, max(0, hs - h - ph0)),
                     (pw0, max(0, ws - w - pw0)), (0, pc)))
    xp = xp[:, :hs, :ws, :]

    n_half = cout // 2
    pn = (-n_half) % min(bn, n_half)
    bn = min(bn, n_half)
    nj = (n_half + pn) // bn
    packed3 = pw.packed.reshape(kh * kw, cin, n_half)
    if pc or pn:
        packed3 = jnp.pad(packed3, ((0, 0), (0, pc), (0, pn)))
    nh = n_half + pn

    sc = jnp.asarray(pw.scale, jnp.float32)
    sc = jnp.broadcast_to(sc.reshape(-1) if sc.ndim else sc, (cout,))
    zp = jnp.asarray(pw.zero_point, jnp.float32)
    zp = jnp.broadcast_to(zp.reshape(-1) if zp.ndim else zp, (cout,))
    s_op = _split_half_rows(sc, n_half, pn)
    z_op = _split_half_rows(zp, n_half, pn)

    fmt = FPFormat(pw.exp_bits, pw.man_bits, pw.signed)
    if act_qp is not None:
        act_fmt = act_qp.fmt
        act_signed = act_qp.kind == KIND_FP_SIGNED
        amz = jnp.stack([jnp.asarray(act_qp.maxval, jnp.float32),
                         jnp.asarray(act_qp.zero_point, jnp.float32)])
    else:
        act_fmt, act_signed = None, True
        amz = jnp.zeros((2,), jnp.float32)
    amz = amz.reshape(1, 2)

    mp = oh * ow + (-(oh * ow)) % 8
    scratch = [pltpu.VMEM((mp, bn), jnp.float32)]
    if act_fmt is not None:
        scratch.append(pltpu.VMEM((hs, ws, cin + pc), x.dtype))

    out = pl.pallas_call(
        functools.partial(
            _implicit_kernel, fmt=fmt, act_fmt=act_fmt,
            act_signed=act_signed, kh=kh, kw=kw, sh=sh, sw=sw, oh=oh,
            ow=ow, nc=nc, bc=bc, valid=(ph0, h, pw0, w, cin), mp=mp),
        grid=(b, 2, nj, nc),
        in_specs=[
            pl.BlockSpec((1, hs, ws, bc), lambda bi, hh, j, c: (bi, 0, 0, c)),
            pl.BlockSpec((kh * kw, bc, bn), lambda bi, hh, j, c: (0, c, j)),
            pl.BlockSpec((1, bn), lambda bi, hh, j, c: (hh, j)),
            pl.BlockSpec((1, bn), lambda bi, hh, j, c: (hh, j)),
            pl.BlockSpec((1, 2), lambda bi, hh, j, c: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, mp, bn),
                               lambda bi, hh, j, c: (bi, 0, hh * nj + j)),
        out_shape=jax.ShapeDtypeStruct((b, mp, 2 * nh), x.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(xp, packed3, s_op, z_op, amz)
    out = out[:, :oh * ow]
    if pn:
        out = jnp.concatenate([out[..., :n_half], out[..., nh:nh + n_half]],
                              axis=-1)
    else:
        out = out[..., :cout]
    return out.reshape(b, oh, ow, cout)
