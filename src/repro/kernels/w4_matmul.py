"""Pallas TPU kernel: packed-FP4 weight matmul with in-VMEM dequant.

y = x @ W where W is stored as packed nibbles (split-half layout:
packed[k, j] holds logical columns j (lo nibble) and j + N/2 (hi)).
HBM traffic for the weight is the *packed* bytes (K*N/2); nibbles are
expanded and decoded to bf16 inside VMEM, then fed to the MXU.

Grid: (half, M/bm, (N/2)/bn, K/bk) — the `half` axis selects the nibble
and addresses the corresponding output column block, so no lane interleave
is ever needed. K is the innermost (arbitrary) axis accumulating into an
f32 VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.qmodule import PackedW4
from repro.quant.formats import FPFormat


def _decode_block(codes, fmt: FPFormat, scale):
    """Nibble codes (already masked to 4 bits) -> f32 values * scale."""
    man = fmt.man_bits
    nbits = fmt.exp_bits + fmt.man_bits
    c = codes.astype(jnp.int32)
    if fmt.signed:
        sign = (c >> nbits) & 1
        c = c & ((1 << nbits) - 1)
    if fmt.exp_bits == 0:
        mag = c.astype(jnp.float32) / 2**man
    else:
        p = c >> man
        m = (c & (2**man - 1)).astype(jnp.float32)
        mag = jnp.where(p == 0, m / 2**man,
                        jnp.exp2((p - 1).astype(jnp.float32)) * (1 + m / 2**man))
    val = mag * scale
    if fmt.signed:
        val = jnp.where(sign == 1, -val, val)
    return val


def _kernel(x_ref, p_ref, s_ref, o_ref, acc_ref, *, fmt: FPFormat, nk: int):
    h = pl.program_id(0)
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    shift = h * 4
    codes = (p_ref[...].astype(jnp.int32) >> shift) & 0xF
    scale = s_ref[0, 0] / fmt.base_max
    w = _decode_block(codes, fmt, scale).astype(x_ref.dtype)
    acc_ref[...] += jnp.dot(x_ref[...], w,
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("exp_bits", "man_bits", "signed",
                                             "bm", "bn", "bk", "interpret"))
def w4_matmul_2d(x: jnp.ndarray, packed: jnp.ndarray, scale: jnp.ndarray,
                 *, exp_bits: int, man_bits: int, signed: bool = True,
                 bm: int = 128, bn: int = 128, bk: int = 512,
                 interpret: bool = False) -> jnp.ndarray:
    """x: (M, K) bf16; packed: (K, N/2) uint8 -> (M, N) x.dtype."""
    fmt = FPFormat(exp_bits, man_bits, signed)
    m, k = x.shape
    k2, n_half = packed.shape
    assert k == k2, (x.shape, packed.shape)
    bm = min(bm, m)
    bn = min(bn, n_half)
    bk = min(bk, k)
    pm, pk, pn = (-m) % bm, (-k) % bk, (-n_half) % bn
    if pm or pk:
        x = jnp.pad(x, ((0, pm), (0, pk)))
    if pk or pn:
        packed = jnp.pad(packed, ((0, pk), (0, pn)))
    mm, kk = x.shape
    nh = packed.shape[1]
    nk = kk // bk
    sc = jnp.asarray(scale, jnp.float32).reshape(1, 1)
    out = pl.pallas_call(
        functools.partial(_kernel, fmt=fmt, nk=nk),
        grid=(2, mm // bm, nh // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda h, i, j, kb: (i, kb)),
            pl.BlockSpec((bk, bn), lambda h, i, j, kb: (kb, j)),
            pl.BlockSpec((1, 1), lambda h, i, j, kb: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn),
                               lambda h, i, j, kb: (i, h * (nh // bn) + j)),
        out_shape=jax.ShapeDtypeStruct((mm, 2 * nh), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, packed, sc)
    return out[:m, : 2 * n_half]
