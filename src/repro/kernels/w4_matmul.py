"""Pallas TPU kernels: packed-FP4 weight matmul with in-VMEM dequant.

y = x @ W where W is stored as packed nibbles (split-half layout:
packed[k, j] holds logical columns j (lo nibble) and j + N/2 (hi)).
HBM traffic for the weight is the *packed* bytes (K*N/2); nibbles are
expanded and decoded to bf16 inside VMEM, then fed to the MXU.

Covered format space (the full MSFP family):
  * signed ExMy, scalar or per-output-channel scale;
  * unsigned ExMy with zero-point: dequant is ``mag * scale + zp``. The
    additive zp never materializes in the weight tile — it contributes
    ``zp_n * sum_k x[i, k]`` to output (i, n), accumulated per k-block
    alongside the MXU dot (one VPU row-reduction per block).
  * fused W4A4 (``w4a4_matmul_2d``): the MSFP activation fake-quant snap
    (``msfp_quant._qdq_block``) is applied to the x tile in VMEM before
    the dot, removing the separate qdq kernel's HBM round-trip over x.

Grid: (M/bm, half, (N/2)/bn, K/bk) — the `half` axis selects the nibble
and addresses the corresponding output column block, so no lane interleave
is ever needed. K is the innermost (arbitrary) axis accumulating into an
f32 VMEM scratch. Scales/zero-points ride as a (2, N/2) operand blocked
(1, bn) and indexed by the (half, j) grid axes, so each program sees
exactly the scales of the columns it decodes.

Snap-once re-tiling: with M outermost, every (half, j) program for a fixed
row block i revisits the same x tiles, so the fused path snaps each
(bm, bk) x tile exactly once — on the first (h == 0, j == 0) sweep over
k-blocks — into a persistent (bm, K) VMEM scratch that later programs
read back. The old layout recomputed the snap per (half, j) program,
2 * N/(2*bn) times per tile. Falls back to per-program snapping when the
scratch would exceed ``XQ_VMEM_BUDGET`` (huge K). Accumulation order per
output tile is unchanged, so outputs are bit-identical either way.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.msfp_quant import _qdq_block
from repro.quant.formats import FPFormat


def _decode_block(codes, fmt: FPFormat, scale):
    """Nibble codes (already masked to 4 bits) -> f32 values * scale.

    ``scale`` broadcasts: a scalar (per-tensor) or a (1, bn) row
    (per-output-channel). Unsigned zero-points are handled by the caller
    via the rank-1 correction term, never here.
    """
    man = fmt.man_bits
    nbits = fmt.exp_bits + fmt.man_bits
    c = codes.astype(jnp.int32)
    if fmt.signed:
        sign = (c >> nbits) & 1
        c = c & ((1 << nbits) - 1)
    if fmt.exp_bits == 0:
        mag = c.astype(jnp.float32) / 2**man
    else:
        p = c >> man
        m = (c & (2**man - 1)).astype(jnp.float32)
        mag = jnp.where(p == 0, m / 2**man,
                        jnp.exp2((p - 1).astype(jnp.float32)) * (1 + m / 2**man))
    val = mag * scale
    if fmt.signed:
        val = jnp.where(sign == 1, -val, val)
    return val


# Fused-path activation scratch cap: above this the snap-once (bm, K)
# buffer no longer fits comfortably alongside the operand tiles and the
# kernel reverts to per-program snapping (same outputs, more VPU work).
XQ_VMEM_BUDGET = 4 * 1024 * 1024


def _snap_tile(x, amz_ref, k, bk, k_valid, act_fmt, act_signed):
    """MSFP-snap one (bm, bk) activation tile in VMEM."""
    x = _qdq_block(x, amz_ref[0, 0], amz_ref[0, 1], act_fmt, act_signed)
    if not act_signed:
        # Unsigned act quant maps the zero-padded K rows to qdq(0) != 0
        # (the grid floor is the zero-point); zero them back so neither
        # the dot nor the zp rowsum sees phantom rows.
        col = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
        x = jnp.where(col + k * bk < k_valid, x, jnp.zeros_like(x))
    return x


def _kernel(x_ref, p_ref, s_ref, z_ref, amz_ref, o_ref, acc_ref, *xq_ref,
            fmt: FPFormat, nk: int, k_valid: int, act_fmt: FPFormat | None,
            act_signed: bool, bk: int):
    h = pl.program_id(1)
    j = pl.program_id(2)
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    if act_fmt is not None and xq_ref:
        # Snap-once: the first (h, j) = (0, 0) sweep over k writes the
        # snapped tiles into the persistent (bm, K) scratch; every later
        # (h, j) program for this row block reads them back.
        xq = xq_ref[0]

        @pl.when((h == 0) & (j == 0))
        def _snap():
            xq[:, pl.ds(k * bk, bk)] = _snap_tile(
                x_ref[...], amz_ref, k, bk, k_valid, act_fmt, act_signed)

        x = xq[:, pl.ds(k * bk, bk)]
    elif act_fmt is not None:
        # Fallback (scratch over budget): snap per program, old behavior.
        x = _snap_tile(x_ref[...], amz_ref, k, bk, k_valid, act_fmt,
                       act_signed)
    else:
        x = x_ref[...]

    shift = h * 4
    codes = (p_ref[...].astype(jnp.int32) >> shift) & 0xF
    scale = s_ref[0, :] * (1.0 / fmt.base_max)          # (bn,) per-channel
    w = _decode_block(codes, fmt, scale[None, :]).astype(x.dtype)
    acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)
    if not fmt.signed:
        # zp contributes zp_n * sum_k x_ik; accumulate the block's rowsum.
        rowsum = jnp.sum(x.astype(jnp.float32), axis=1, keepdims=True)
        acc_ref[...] += rowsum * z_ref[0, :][None, :]

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _split_half_rows(vec: jnp.ndarray, n_half: int, pad: int) -> jnp.ndarray:
    """(N,) channel vector -> (2, N/2 [+pad]) rows matching the nibble halves."""
    op = jnp.stack([vec[:n_half], vec[n_half:]])
    if pad:
        op = jnp.pad(op, ((0, 0), (0, pad)))
    return op


def _w4_call(x, packed, scale, zero_point, act_mz, *, fmt: FPFormat,
             act_fmt: FPFormat | None, act_signed: bool,
             bm: int, bn: int, bk: int, interpret: bool) -> jnp.ndarray:
    m, k = x.shape
    k2, n_half = packed.shape
    assert k == k2, (x.shape, packed.shape)
    n = 2 * n_half
    bm = min(bm, m)
    bn = min(bn, n_half)
    bk = min(bk, k)
    pm, pk, pn = (-m) % bm, (-k) % bk, (-n_half) % bn
    if pm or pk:
        x = jnp.pad(x, ((0, pm), (0, pk)))
    if pk or pn:
        packed = jnp.pad(packed, ((0, pk), (0, pn)))
    mm, kk = x.shape
    nh = packed.shape[1]
    nk = kk // bk

    # Normalize scale / zero_point to per-channel rows in split-half layout;
    # padded columns get scale 0 so their (sliced-off) outputs stay finite.
    sc = jnp.asarray(scale, jnp.float32)
    sc = jnp.broadcast_to(sc.reshape(-1) if sc.ndim else sc, (n,))
    zp = jnp.asarray(zero_point, jnp.float32)
    zp = jnp.broadcast_to(zp.reshape(-1) if zp.ndim else zp, (n,))
    s_op = _split_half_rows(sc, n_half, pn)
    z_op = _split_half_rows(zp, n_half, pn)
    amz = jnp.stack([jnp.asarray(act_mz[0], jnp.float32),
                     jnp.asarray(act_mz[1], jnp.float32)]).reshape(1, 2)

    # Snap-once scratch: one (bm, K) activation buffer, persistent across
    # the sequential grid so all (half, j) programs of a row block share it.
    scratch = [pltpu.VMEM((bm, bn), jnp.float32)]
    snap_once = (act_fmt is not None
                 and bm * kk * x.dtype.itemsize <= XQ_VMEM_BUDGET)
    if snap_once:
        scratch.append(pltpu.VMEM((bm, kk), x.dtype))

    out = pl.pallas_call(
        functools.partial(_kernel, fmt=fmt, nk=nk, k_valid=k,
                          act_fmt=act_fmt, act_signed=act_signed, bk=bk),
        grid=(mm // bm, 2, nh // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, h, j, kb: (i, kb)),
            pl.BlockSpec((bk, bn), lambda i, h, j, kb: (kb, j)),
            pl.BlockSpec((1, bn), lambda i, h, j, kb: (h, j)),
            pl.BlockSpec((1, bn), lambda i, h, j, kb: (h, j)),
            pl.BlockSpec((1, 2), lambda i, h, j, kb: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn),
                               lambda i, h, j, kb: (i, h * (nh // bn) + j)),
        out_shape=jax.ShapeDtypeStruct((mm, 2 * nh), x.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(x, packed, s_op, z_op, amz)
    out = out[:m]
    if pn:
        # Column pad puts the hi half at offset nh, not n_half: re-join.
        out = jnp.concatenate([out[:, :n_half], out[:, nh:nh + n_half]],
                              axis=1)
    else:
        out = out[:, :n]
    return out


def pick_tiles(m: int, k: int, n: int, *, bm: int = 128, bn: int = 128,
               bk: int = 512) -> dict:
    """The (clamped) tile sizes ``_w4_call`` uses at this shape.

    The bench records these per row so wall-clock numbers stay comparable
    across PRs that change the tiling."""
    return {"bm": min(bm, m), "bn": min(bn, n // 2), "bk": min(bk, k)}


@functools.partial(jax.jit, static_argnames=("exp_bits", "man_bits", "signed",
                                             "bm", "bn", "bk", "interpret"))
def w4_matmul_2d(x: jnp.ndarray, packed: jnp.ndarray, scale: jnp.ndarray,
                 zero_point: jnp.ndarray | float = 0.0,
                 *, exp_bits: int, man_bits: int, signed: bool = True,
                 bm: int = 128, bn: int = 128, bk: int = 512,
                 interpret: bool = False) -> jnp.ndarray:
    """x: (M, K) bf16/f32; packed: (K, N/2) uint8 -> (M, N) x.dtype.

    ``scale`` (grid maxval) and ``zero_point`` are scalars or (N,) vectors
    (per-output-channel). ``zero_point`` is only meaningful for unsigned
    formats (``signed=False``).
    """
    fmt = FPFormat(exp_bits, man_bits, signed)
    return _w4_call(x, packed, scale, zero_point, (0.0, 0.0), fmt=fmt,
                    act_fmt=None, act_signed=True, bm=bm, bn=bn, bk=bk,
                    interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "exp_bits", "man_bits", "signed", "act_exp_bits", "act_man_bits",
    "act_signed", "bm", "bn", "bk", "interpret"))
def w4a4_matmul_2d(x: jnp.ndarray, packed: jnp.ndarray, scale: jnp.ndarray,
                   zero_point: jnp.ndarray | float,
                   act_maxval: jnp.ndarray, act_zero_point: jnp.ndarray,
                   *, exp_bits: int, man_bits: int, signed: bool,
                   act_exp_bits: int, act_man_bits: int, act_signed: bool,
                   bm: int = 128, bn: int = 128, bk: int = 512,
                   interpret: bool = False) -> jnp.ndarray:
    """Fused act-quant + W4 matmul: qdq(x) @ dequant(packed) in one pass.

    Equivalent to ``msfp_qdq(x, act_qp)`` followed by ``w4_matmul_2d`` but
    without writing/re-reading the quantized activations through HBM.
    ``act_maxval`` / ``act_zero_point`` are the searched per-tensor MSFP
    activation parameters.
    """
    fmt = FPFormat(exp_bits, man_bits, signed)
    act_fmt = FPFormat(act_exp_bits, act_man_bits, act_signed)
    return _w4_call(x, packed, scale, zero_point,
                    (act_maxval, act_zero_point), fmt=fmt, act_fmt=act_fmt,
                    act_signed=act_signed, bm=bm, bn=bn, bk=bk,
                    interpret=interpret)
