"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro.core.qmodule import (PackedW4, decode_codes, dequant_weight,
                                unpack_nibbles)
from repro.quant.fakequant import QuantizerParams, apply_qdq
from repro.quant.formats import FPFormat

KV4_FMT = FPFormat(2, 1, True)  # signed E2M1 for KV-cache values


def ref_msfp_qdq(x: jnp.ndarray, qp: QuantizerParams) -> jnp.ndarray:
    """Oracle for the fused fake-quant kernel."""
    return apply_qdq(x, qp)


def ref_w4_matmul(x: jnp.ndarray, pw: PackedW4,
                  dtype=jnp.bfloat16) -> jnp.ndarray:
    """Oracle for the packed-W4 matmul kernel: decode then dot."""
    codes = unpack_nibbles(pw.packed)
    w = decode_codes(codes, pw.fmt, pw.scale, pw.zero_point, jnp.float32)
    return (x.astype(jnp.float32) @ w).astype(dtype)


def ref_w4a4_matmul(x: jnp.ndarray, pw: PackedW4, act_qp: QuantizerParams,
                    dtype=jnp.bfloat16) -> jnp.ndarray:
    """Oracle for the fused W4A4 kernel: qdq(x) through HBM, then matmul."""
    return ref_w4_matmul(apply_qdq(x, act_qp), pw, dtype)


def ref_w4a4_conv2d(x: jnp.ndarray, pw: PackedW4,
                    act_qp: QuantizerParams | None = None, *,
                    stride: tuple[int, int] = (1, 1), padding="SAME",
                    dtype=jnp.bfloat16) -> jnp.ndarray:
    """Oracle for the im2col conv route: qdq(x), decode W, XLA conv.

    Act quant precedes the conv's zero padding — the fake-quant model's
    order — which the fused route matches (signed snaps keep 0 at 0;
    unsigned acts are pre-quantized by the dispatcher).
    """
    if act_qp is not None:
        x = apply_qdq(x, act_qp)
    w = dequant_weight(pw, jnp.float32)   # reshaped back to HWIO
    y = lax.conv_general_dilated(
        x.astype(jnp.float32), w, window_strides=stride, padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y.astype(dtype)


def ref_kv4_encode(t: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Oracle for FP4 KV-cache encode: per-(…, head) absmax scale, E2M1.

    t: (..., hd) -> packed (..., hd/2) uint8, scale (...,) f16.
    """
    from repro.core.qmodule import encode_codes, pack_nibbles

    absmax = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(absmax, 1e-6)
    codes = encode_codes(t, KV4_FMT, scale[..., None])
    return pack_nibbles(codes), scale.astype(jnp.float16)


def ref_kv4_decode(packed: jnp.ndarray, scale: jnp.ndarray,
                   dtype=jnp.bfloat16) -> jnp.ndarray:
    codes = unpack_nibbles(packed)
    return decode_codes(codes, KV4_FMT, scale.astype(jnp.float32)[..., None],
                        0.0, dtype)
