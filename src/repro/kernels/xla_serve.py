"""Fast XLA serving path for non-TPU backends.

Hosts without a TPU can't run the Pallas kernels compiled, and the
pure-jnp oracles in ``ref.py`` — while the ground truth — leave easy
wall-clock on the table. This module is the XLA mirror of the Pallas
fixes, used by the ``ops`` dispatcher when ``FORCE`` is unset on CPU/GPU
(``FORCE="xla"`` still pins the untouched oracles):

* ``fast_qdq`` — the MSFP snap with the octave read from the float32
  exponent *field* (one bitcast + shift) instead of ``floor(log2 y)``,
  the step and its reciprocal rebuilt by bitcasting the exponent back
  (power-of-two scaling is exact, so multiply-by-reciprocal == divide),
  and the sign restored with a bit-or instead of a ``sign(x)`` multiply.
  Equal to ``quant.fakequant.fp_qdq`` for every input (see the gate
  note below). ~4x faster than the transcendental path on CPU.

* ``fast_decode`` / ``dequant_halves`` — the packed-nibble decode with
  the magnitude's float32 bits *constructed* (exponent field
  ``p + 126``, mantissa field ``m << (23 - man)``) instead of calling
  ``exp2``, reading each nibble straight out of the packed byte. The
  split-half pack layout means the lo/hi nibbles are the weight's left/
  right column halves, so the decode never concatenates a full-width
  code matrix — the matmuls below consume the two halves directly.

* ``w4_matmul`` / ``fused_matmul`` — decode-and-dot with the weight as
  a *runtime* operand (in the engine, params are jit arguments: nothing
  here constant-folds away). The activation snap's output stays in
  float32 through the dot — the oracle's intermediate re-round to the
  input dtype is skipped, so for sub-f32 inputs the result differs from
  ``ref_w4a4_matmul`` by at most that one rounding; for float32 inputs
  the two are equal (same snap, same decode, same per-column
  accumulation order). On this class of host the packed route beats the
  bf16 dense path it replaces because the bf16 GEMM re-converts its 2x
  bigger weight to f32 every call, which costs more than nibble decode.

* ``implicit_conv`` — the tap-loop implicit GEMM: quantize, pad once,
  then kh*kw strided-slice matmuls accumulated in f32. No
  (B*OH*OW, kh*kw*cin) patch matrix is ever built, which is what makes
  the packed conv route cheaper than decode-then-``lax.conv`` in wall
  time, not just bytes. Differs from the ``lax.conv`` oracle only by
  f32 accumulation order (<= 1 bf16 ulp).

Exactness gate: the bitcast paths are exact by construction, but the
*references* lower ``exp2`` through ``exp(x * ln2)`` on XLA CPU, which
lands off the exact power of two for large octaves (e.g. ``exp2(13) ->
8192.004``). Up to E3's octave range both are exact and equal, so
formats with ``exp_bits > 3`` (and INT-affine, which has no octave)
fall back to the reference implementations.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro.core.qmodule import PackedW4, decode_codes
from repro.kernels import ref as _ref
from repro.kernels.conv import conv_pads
from repro.quant.fakequant import KIND_INT_AFFINE, QuantizerParams
from repro.quant.formats import FPFormat

# Plain int (not a jnp array): this module is often first imported inside
# a traced function, and a module-level jnp constant born under a trace
# leaks that trace into later jits.
_SIGN_BIT = -(2**31)


def _fast_snap(y: jnp.ndarray, fmt: FPFormat) -> jnp.ndarray:
    """Round base-grid-scaled magnitudes (y >= 0, f32) to the grid.

    Mirrors ``formats.snap_to_base_grid`` with the octave from the
    exponent field: for normal f32, ``(bits >> 23) - 127 == floor(log2)``
    exactly. ``step = 2^t`` and ``1/step = 2^-t`` are built by placing
    the exponent back into an f32 bit pattern; scaling by a power of two
    is exact, so ``round(y * inv) * step == round(y / step) * step``
    bit for bit, without the vector divide.
    """
    man = fmt.man_bits
    if fmt.exp_bits == 0:
        step = 2.0**-man
        return jnp.minimum(jnp.round(y * 2.0**man) * step, fmt.base_max)
    max_oct = 2**fmt.exp_bits - 2
    safe = jnp.maximum(y, 2.0**-40)
    e = (lax.bitcast_convert_type(safe, jnp.int32) >> 23) - 127
    t = jnp.clip(e, 0, max_oct) - man
    step = lax.bitcast_convert_type((t + 127) << 23, jnp.float32)
    inv = lax.bitcast_convert_type((127 - t) << 23, jnp.float32)
    return jnp.minimum(jnp.round(y * inv) * step, fmt.base_max)


def _qdq_f32(x: jnp.ndarray, qp: QuantizerParams) -> jnp.ndarray:
    """The snap of ``fast_qdq``, input upcast to f32 and *left* there.

    Callers that feed a dot keep the snapped activation in f32 (the
    values sit on a scaled grid that bf16 can't always represent; the
    oracle's re-round to the input dtype is the one step skipped).
    Signed formats restore the sign by OR-ing the input's sign bit onto
    the snapped magnitude — same result as ``sign(x) * v`` up to the
    sign of zero, which compares equal.
    """
    fmt = qp.fmt
    xf = x.astype(jnp.float32)
    if qp.kind == KIND_INT_AFFINE or qp.exp_bits > 3:
        return _ref.ref_msfp_qdq(xf, qp)
    maxval = jnp.asarray(qp.maxval, jnp.float32)
    scale = maxval / fmt.base_max
    inv = jnp.where(scale > 0, 1.0 / jnp.maximum(scale, 1e-30), 0.0)
    if fmt.signed:
        yq = _fast_snap(jnp.abs(xf) * inv, fmt) * scale
        sb = lax.bitcast_convert_type(xf, jnp.int32) & _SIGN_BIT
        return lax.bitcast_convert_type(
            lax.bitcast_convert_type(yq, jnp.int32) | sb, jnp.float32)
    z = jnp.asarray(qp.zero_point, jnp.float32)
    y = jnp.clip((xf - z) * inv, 0.0, None)
    return _fast_snap(y, fmt) * scale + z


def fast_qdq(x: jnp.ndarray, qp: QuantizerParams) -> jnp.ndarray:
    """Drop-in ``apply_qdq``: equal results, bitcast octave selection.

    INT-affine quantizers have no octave to select and high-exponent
    formats (E4+) hit the references' inexact ``exp2`` (module
    docstring), so both stay on the reference path. ``maxval`` may be a
    scalar or any shape broadcastable against ``x`` (per-channel), like
    the reference.
    """
    if qp.kind == KIND_INT_AFFINE or qp.exp_bits > 3:
        return _ref.ref_msfp_qdq(x, qp)
    return _qdq_f32(x, qp).astype(x.dtype)


def fast_decode(code: jnp.ndarray, fmt: FPFormat, scale, zero_point=0.0,
                dtype=jnp.float32) -> jnp.ndarray:
    """``qmodule.decode_codes`` with the magnitude's f32 bits constructed.

    A normal code (p >= 1) decodes to ``2^(p-1) * (1 + m/2^M)``, whose
    float32 representation is literally exponent field ``p + 126`` and
    mantissa field ``m << (23 - M)`` — one shift-or-bitcast instead of
    an ``exp2`` call per element. Subnormals (p == 0) are ``m * 2^-M``,
    an exact int-to-float convert and constant multiply. Equal to
    ``decode_codes`` for ``exp_bits <= 3`` (callers gate; see module
    docstring).
    """
    man = fmt.man_bits
    code = code.astype(jnp.int32)
    nbits = fmt.exp_bits + fmt.man_bits
    if fmt.signed:
        sign = (code >> nbits) & 1
        code = code & ((1 << nbits) - 1)
    if fmt.exp_bits == 0:
        mag = code.astype(jnp.float32) * (2.0**-man)
    else:
        p = code >> man
        m = code & (2**man - 1)
        norm = lax.bitcast_convert_type(
            ((p + 126) << 23) | (m << (23 - man)), jnp.float32)
        mag = jnp.where(p == 0, m.astype(jnp.float32) * (2.0**-man), norm)
    val = mag * (jnp.asarray(scale, jnp.float32) / fmt.base_max)
    if fmt.signed:
        val = jnp.where(sign == 1, -val, val)
    else:
        val = val + zero_point
    return val.astype(dtype)


def _half_params(v, half: int, hi: bool):
    """Slice a scale/zero-point to one pack half: per-channel vectors
    (last axis spanning the full 2*half output width) split; scalars and
    keepdims shapes broadcast over both halves unsliced."""
    v = jnp.asarray(v, jnp.float32)
    if v.ndim == 0 or v.shape[-1] != 2 * half:
        return v
    return v[..., half:] if hi else v[..., :half]


def dequant_halves(pw: PackedW4,
                   dtype=jnp.float32) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Decode a 2D pack's lo/hi nibbles as the two (K, N/2) column halves.

    Reads each nibble straight from the packed byte — no unpacked code
    matrix, no full-width concat; the caller dots against the halves and
    joins the *outputs* (2x smaller). Falls back to ``decode_codes`` per
    half for formats past the exactness gate.
    """
    fmt = pw.fmt
    half = pw.packed.shape[-1]
    dec = fast_decode if fmt.exp_bits <= 3 else decode_codes
    c = pw.packed.astype(jnp.int32)
    lo = dec(c & 0xF, fmt, _half_params(pw.scale, half, False),
             _half_params(pw.zero_point, half, False), dtype)
    hi = dec((c >> 4) & 0xF, fmt, _half_params(pw.scale, half, True),
             _half_params(pw.zero_point, half, True), dtype)
    return lo, hi


def serve_dequant(pw: PackedW4, dtype=jnp.float32) -> jnp.ndarray:
    """Full decoded weight (any pack rank), ``fast_decode`` where exact."""
    lo, hi = dequant_halves(pw, dtype)
    return jnp.concatenate([lo, hi], axis=-1).reshape(pw.shape)


def _dot_halves(xq: jnp.ndarray, pw: PackedW4, dtype) -> jnp.ndarray:
    lo, hi = dequant_halves(pw, jnp.float32)
    return jnp.concatenate([xq @ lo, xq @ hi], axis=-1).astype(dtype)


def w4_matmul(x2: jnp.ndarray, pw: PackedW4, dtype=jnp.bfloat16) -> jnp.ndarray:
    """x2 (M, K) @ decoded pack, f32 accumulate, two half-width dots."""
    return _dot_halves(x2.astype(jnp.float32), pw, dtype)


def fused_matmul(x2: jnp.ndarray, pw: PackedW4, act_qp: QuantizerParams,
                 dtype=jnp.bfloat16) -> jnp.ndarray:
    """qdq(x) @ dequant(W), the snapped activation held in f32 (module
    docstring) — the serving replacement for the bf16-fallback chain."""
    return _dot_halves(_qdq_f32(x2, act_qp), pw, dtype)


def implicit_conv(x: jnp.ndarray, pw: PackedW4,
                  act_qp: QuantizerParams | None = None, *,
                  stride: tuple[int, int] = (1, 1), padding="SAME",
                  dtype=jnp.bfloat16) -> jnp.ndarray:
    """Tap-loop implicit-GEMM conv on the packed HWIO weight.

    Quantizes before padding (the fake-quant oracle's order — the
    inserted zeros are exact), then accumulates one strided-slice matmul
    per tap; the patch matrix never exists.
    """
    kh, kw, cin, cout = pw.shape
    b, h, w, c = x.shape
    assert c == cin, (x.shape, pw.shape)
    xf = (_qdq_f32(x, act_qp) if act_qp is not None
          else x.astype(jnp.float32))
    sh, sw = stride
    (ph0, ph1), (pw0, pw1) = conv_pads(h, w, kh, kw, stride, padding)
    oh = (h + ph0 + ph1 - kh) // sh + 1
    ow = (w + pw0 + pw1 - kw) // sw + 1
    if ph0 or ph1 or pw0 or pw1:
        xf = jnp.pad(xf, ((0, 0), (ph0, ph1), (pw0, pw1), (0, 0)))
    wd = serve_dequant(pw, jnp.float32)
    acc = None
    for i in range(kh):
        for j in range(kw):
            sl = xf[:, i:i + sh * (oh - 1) + 1:sh,
                    j:j + sw * (ow - 1) + 1:sw, :].reshape(-1, cin)
            t = sl @ wd[i, j]
            acc = t if acc is None else acc + t
    return acc.reshape(b, oh, ow, cout).astype(dtype)
