"""Pallas TPU kernels: FP4 (signed E2M1) KV-cache encode / decode.

Beyond-paper extension of MSFP to the decode-time memory bottleneck:
K/V vectors are quantized per-(token, kv-head) with an absmax scale to the
signed E2M1 grid and packed 2 codes/byte — 4.25x smaller cache traffic
than bf16 (incl. fp16 scales), which is what a memory-bound decode step
actually pays for. Encode runs once per generated token; decode runs on
the full cache read each step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.quant.formats import FPFormat

FMT = FPFormat(2, 1, True)  # E2M1 grid {0,.5,1,1.5,2,3,4,6} * scale/6


def _encode_block(t, scale_inv):
    """t: (r, hd) f32, scale_inv: (r, 1). Returns 4-bit codes (r, hd)."""
    y = jnp.abs(t) * scale_inv * FMT.base_max          # into [0, 6]
    oct_ = jnp.clip(jnp.floor(jnp.log2(jnp.maximum(y, 2.0**-40))), 0, 2)
    step = jnp.exp2(oct_ - 1)
    v = jnp.minimum(jnp.round(y / step) * step, FMT.base_max)
    is_sub = v < 1.0
    p = jnp.where(is_sub, 0, jnp.clip(jnp.floor(jnp.log2(jnp.maximum(v, 2.0**-40))), 0, 2).astype(jnp.int32) + 1)
    m_sub = jnp.round(v * 2.0)
    m_norm = jnp.round((v / jnp.exp2(jnp.clip(jnp.floor(jnp.log2(jnp.maximum(v, 2.0**-40))), 0, 2)) - 1.0) * 2.0)
    m = jnp.where(is_sub, m_sub, m_norm).astype(jnp.int32)
    code = (p << 1) | m
    return code | (jnp.where(t < 0, 8, 0))


def _enc_kernel(t_ref, p_ref, s_ref):
    t = t_ref[...].astype(jnp.float32)
    absmax = jnp.max(jnp.abs(t), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-6)
    codes = _encode_block(t, 1.0 / scale)
    half = codes.shape[-1] // 2
    lo = codes[..., :half]
    hi = codes[..., half:]
    p_ref[...] = (lo | (hi << 4)).astype(jnp.uint8)
    s_ref[...] = scale[..., 0].astype(jnp.float16)


def _dec_kernel(p_ref, s_ref, o_ref):
    packed = p_ref[...].astype(jnp.int32)
    codes = jnp.concatenate([packed & 0xF, (packed >> 4) & 0xF], axis=-1)
    sign = (codes >> 3) & 1
    c = codes & 7
    p = c >> 1
    m = (c & 1).astype(jnp.float32)
    mag = jnp.where(p == 0, m * 0.5,
                    jnp.exp2((p - 1).astype(jnp.float32)) * (1 + 0.5 * m))
    val = jnp.where(sign == 1, -mag, mag)
    scale = s_ref[...].astype(jnp.float32)[..., None] / FMT.base_max
    o_ref[...] = (val * scale).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def kv4_encode_2d(t: jnp.ndarray, *, block_rows: int = 256,
                  interpret: bool = False):
    """t: (R, hd) -> packed (R, hd/2) uint8, scale (R,) f16."""
    r, hd = t.shape
    br = min(block_rows, r)
    pr = (-r) % br
    tp = jnp.pad(t, ((0, pr), (0, 0))) if pr else t
    packed, scale = pl.pallas_call(
        _enc_kernel,
        grid=(tp.shape[0] // br,),
        in_specs=[pl.BlockSpec((br, hd), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((br, hd // 2), lambda i: (i, 0)),
                   pl.BlockSpec((br,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((tp.shape[0], hd // 2), jnp.uint8),
                   jax.ShapeDtypeStruct((tp.shape[0],), jnp.float16)],
        interpret=interpret,
    )(tp)
    return (packed[:r], scale[:r]) if pr else (packed, scale)


@functools.partial(jax.jit, static_argnames=("dtype", "block_rows", "interpret"))
def kv4_decode_2d(packed: jnp.ndarray, scale: jnp.ndarray, *,
                  dtype=jnp.bfloat16, block_rows: int = 256,
                  interpret: bool = False):
    """packed: (R, hd/2), scale: (R,) -> (R, hd) dtype."""
    r, hh = packed.shape
    br = min(block_rows, r)
    pr = (-r) % br
    if pr:
        packed = jnp.pad(packed, ((0, pr), (0, 0)))
        scale = jnp.pad(scale, ((0, pr),))
    out = pl.pallas_call(
        _dec_kernel,
        grid=(packed.shape[0] // br,),
        in_specs=[pl.BlockSpec((br, hh), lambda i: (i, 0)),
                  pl.BlockSpec((br,), lambda i: (i,))],
        out_specs=pl.BlockSpec((br, 2 * hh), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((packed.shape[0], 2 * hh), dtype),
        interpret=interpret,
    )(packed, scale)
    return out[:r] if pr else out
