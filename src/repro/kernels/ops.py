"""Jit'd wrappers around the Pallas kernels with XLA fallbacks.

Dispatch policy: on TPU the Pallas kernels run compiled; on CPU (this
container) the fast XLA serving path (``kernels.xla_serve``) runs for
real numerics, while tests exercise the kernels in interpret mode against
the ref oracles. Set ``FORCE="pallas"`` / ``"xla"`` / ``"interpret"`` to
override (tests use it) — ``"xla"`` pins the *pure reference* oracles,
bypassing the fast serving path too.

Conv routing (``CONV_ROUTE``): the Pallas conv has two routes — the
implicit-GEMM kernel (no patch matrix; the default on compiled TPU when
its whole-slab blocks fit VMEM) and the im2col + fused-matmul route (the
index-map oracle, and what interpret mode runs by default so the golden
replay trace keeps its pinned digest). ``"implicit"`` / ``"im2col"``
force a route; ``"auto"`` applies the policy above.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.qmodule import PackedW4
from repro.kernels import ref as _ref
from repro.quant.fakequant import (KIND_FP_SIGNED, KIND_FP_UNSIGNED,
                                   KIND_INT_AFFINE, QuantizerParams)

FORCE: str | None = None
CONV_ROUTE: str = "auto"  # "auto" | "implicit" | "im2col"

# Profiling hook (serving/obs/kernel_profile installs it; ops never
# imports obs). When set, every dispatch decision routes through
# PROFILER.call(op, route_label, thunk, probe) — counted when tracing
# into a jit program, timed when eager. None costs one global read.
PROFILER = None


def _dispatch(op: str, route: str, thunk, probe=None):
    if PROFILER is None:
        return thunk()
    return PROFILER.call(op, route, thunk, probe=probe)


def _route_label() -> str:
    """Label for the Pallas branch: compiled vs interpret-mode."""
    return "interpret" if _interpret() else "pallas"


def _use_pallas() -> bool:
    if FORCE == "pallas" or FORCE == "interpret":
        return True
    if FORCE == "xla":
        return False
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return FORCE == "interpret" or jax.default_backend() != "tpu"


def _use_fast_xla() -> bool:
    """The fast XLA serving path: default (unforced) dispatch off-TPU."""
    return FORCE is None and jax.default_backend() != "tpu"


def msfp_quantize(x: jnp.ndarray, qp: QuantizerParams) -> jnp.ndarray:
    """Fused fake-quant (no STE — serving path; training uses quant.ste_qdq).

    The Pallas kernel takes per-tensor FP parameters; INT-affine and
    vector (per-channel) maxvals fall back to the XLA reference.
    """
    if _use_pallas() and qp.kind != 2 and jnp.ndim(qp.maxval) == 0:
        from repro.kernels.msfp_quant import msfp_qdq
        return _dispatch("msfp_quantize", _route_label(),
                         lambda: msfp_qdq(x, qp, interpret=_interpret()),
                         probe=x)
    if _use_fast_xla():
        from repro.kernels import xla_serve
        return _dispatch("msfp_quantize", "xla_fast",
                         lambda: xla_serve.fast_qdq(x, qp),  # bit-exact
                         probe=x)
    return _dispatch("msfp_quantize", "ref",
                     lambda: _ref.ref_msfp_qdq(x, qp), probe=x)


def _pallas_w4_ok(pw: PackedW4) -> bool:
    """The Pallas kernel covers the full MSFP format space (signed and
    unsigned ExMy, scalar or per-output-channel scale) for single 2D packs;
    stacked (scanned) packs with per-slice scales stay on the XLA path."""
    if jnp.ndim(pw.packed) != 2:
        return False
    if jnp.ndim(pw.scale) == 0:
        return True
    return (jnp.ndim(pw.scale) == 1
            and pw.scale.shape[0] == 2 * pw.packed.shape[-1])


def w4_matmul(x: jnp.ndarray, pw: PackedW4) -> jnp.ndarray:
    """x: (..., K) @ packed W4 (K, N/2-packed) -> (..., N)."""
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k)
    if _use_pallas() and _pallas_w4_ok(pw):
        from repro.kernels.w4_matmul import w4_matmul_2d
        out = _dispatch(
            "w4_matmul", _route_label(),
            lambda: w4_matmul_2d(x2, pw.packed, pw.scale, pw.zero_point,
                                 exp_bits=pw.exp_bits, man_bits=pw.man_bits,
                                 signed=pw.signed, interpret=_interpret()),
            probe=x)
    elif _use_fast_xla() and jnp.ndim(pw.packed) == 2:
        from repro.kernels import xla_serve
        out = _dispatch("w4_matmul", "xla_fast",
                        lambda: xla_serve.w4_matmul(x2, pw, x.dtype),
                        probe=x)
    else:
        out = _dispatch("w4_matmul", "ref",
                        lambda: _ref.ref_w4_matmul(x2, pw, x.dtype),
                        probe=x)
    return out.reshape(*lead, out.shape[-1])


def w4a4_matmul(x: jnp.ndarray, pw: PackedW4,
                act_qp: QuantizerParams | None) -> jnp.ndarray:
    """Fused activation-quant + W4 matmul: qdq(x, act_qp) @ W in one kernel.

    Saves one full HBM round-trip over x versus msfp_quantize followed by
    w4_matmul. ``act_qp`` must be an FP (signed/unsigned) per-tensor
    quantizer; INT-affine activations fall back to qdq-then-matmul.
    """
    if act_qp is None:
        return w4_matmul(x, pw)
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k)
    if (_use_pallas() and _pallas_w4_ok(pw)
            and act_qp.kind != KIND_INT_AFFINE
            and jnp.ndim(act_qp.maxval) == 0):
        from repro.kernels.w4_matmul import w4a4_matmul_2d
        out = _dispatch(
            "w4a4_matmul", _route_label(),
            lambda: w4a4_matmul_2d(
                x2, pw.packed, pw.scale, pw.zero_point,
                act_qp.maxval, act_qp.zero_point,
                exp_bits=pw.exp_bits, man_bits=pw.man_bits,
                signed=pw.signed,
                act_exp_bits=act_qp.exp_bits, act_man_bits=act_qp.man_bits,
                act_signed=(act_qp.kind == KIND_FP_SIGNED),
                interpret=_interpret()),
            probe=x)
    elif _use_fast_xla() and act_qp.kind != KIND_INT_AFFINE:
        from repro.kernels import xla_serve
        out = _dispatch("w4a4_matmul", "xla_fast",
                        lambda: xla_serve.fused_matmul(x2, pw, act_qp,
                                                       x.dtype),
                        probe=x)
    else:
        out = _dispatch("w4a4_matmul", "ref",
                        lambda: _ref.ref_w4a4_matmul(x2, pw, act_qp,
                                                     x.dtype),
                        probe=x)
    return out.reshape(*lead, out.shape[-1])


def _normalize_stride(stride) -> tuple[int, int]:
    return (stride, stride) if isinstance(stride, int) else tuple(stride)


def _normalize_padding(padding):
    """Hashable (jit-static) padding spec."""
    if isinstance(padding, str):
        return padding
    return tuple(tuple(int(q) for q in p) for p in padding)


def _conv_route(x, pw, strides, pads, fused: bool) -> str:
    """Pick the Pallas conv route. ``auto``: compiled TPU runs the
    implicit-GEMM kernel when its whole-slab blocks fit the VMEM budget;
    interpret mode keeps the im2col oracle route (the golden replay
    trace's digest is pinned to its accumulation order)."""
    if CONV_ROUTE in ("implicit", "im2col"):
        return CONV_ROUTE
    if _interpret():
        return "im2col"
    from repro.kernels.conv import IMPLICIT_VMEM_BUDGET, implicit_vmem_bytes
    fits = implicit_vmem_bytes(
        x.shape, pw.shape, strides, pads, fused=fused,
        itemsize=x.dtype.itemsize) <= IMPLICIT_VMEM_BUDGET
    return "implicit" if fits else "im2col"


def w4a4_conv2d(x: jnp.ndarray, pw: PackedW4,
                act_qp: QuantizerParams | None = None, *,
                stride=1, padding="SAME") -> jnp.ndarray:
    """NHWC conv on a packed HWIO W4 weight.

    Pallas routes (see ``_conv_route``):
      * implicit GEMM — the index maps gather input slabs straight from
        the NHWC activation (no patch matrix). Signed *and* unsigned
        per-tensor FP act quantizers fuse: the in-kernel snap masks the
        pad positions back to exact zeros per tile, so the old
        pre-quantize-through-HBM round-trip only remains for INT-affine
        and per-channel act params.
      * im2col + fused matmul — the index-map oracle and VMEM-overflow
        fallback. Only signed per-tensor acts fuse here (SAME padding's
        zeros must survive the snap; unsigned grids map 0 to the
        zero-point), others pre-quantize.
    Off-TPU the fast XLA tap-loop (``xla_serve.implicit_conv``) serves
    unforced dispatch; ``FORCE="xla"`` pins the decode+conv oracle.
    """
    strides = _normalize_stride(stride)
    pads = _normalize_padding(padding)
    if _use_pallas() and len(pw.shape) == 4 and _pallas_w4_ok(pw):
        route = _conv_route(x, pw, strides, pads, fused=act_qp is not None)
        fusable = (KIND_FP_SIGNED, KIND_FP_UNSIGNED) if route == "implicit" \
            else (KIND_FP_SIGNED,)
        if act_qp is not None and not (act_qp.kind in fusable
                                       and jnp.ndim(act_qp.maxval) == 0):
            x = msfp_quantize(x, act_qp)
            act_qp = None
        if route == "implicit":
            from repro.kernels.conv import w4a4_conv2d_implicit
            return _dispatch(
                "w4a4_conv2d", f"{_route_label()}:implicit",
                lambda: w4a4_conv2d_implicit(x, pw, act_qp, stride=strides,
                                             padding=pads,
                                             interpret=_interpret()),
                probe=x)
        from repro.kernels.conv import w4a4_conv2d_im2col
        return _dispatch(
            "w4a4_conv2d", f"{_route_label()}:im2col",
            lambda: w4a4_conv2d_im2col(x, pw, act_qp, stride=strides,
                                       padding=pads, interpret=_interpret()),
            probe=x)
    fast = _use_fast_xla() and len(pw.shape) == 4 and _pallas_w4_ok(pw)
    fusable = (KIND_FP_SIGNED, KIND_FP_UNSIGNED) if fast \
        else (KIND_FP_SIGNED,)
    if act_qp is not None and not (act_qp.kind in fusable
                                   and jnp.ndim(act_qp.maxval) == 0):
        x = msfp_quantize(x, act_qp)
        act_qp = None
    if fast:
        from repro.kernels import xla_serve
        return _dispatch(
            "w4a4_conv2d", "xla_fast",
            lambda: xla_serve.implicit_conv(x, pw, act_qp, stride=strides,
                                            padding=pads, dtype=x.dtype),
            probe=x)
    return _dispatch(
        "w4a4_conv2d", "ref",
        lambda: _ref.ref_w4a4_conv2d(x, pw, act_qp, stride=strides,
                                     padding=pads, dtype=x.dtype),
        probe=x)


def kv4_encode(t: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """t: (..., hd) -> packed (..., hd/2) uint8 + scale (...,) f16."""
    lead = t.shape[:-1]
    hd = t.shape[-1]
    t2 = t.reshape(-1, hd)
    if _use_pallas():
        from repro.kernels.kv4 import kv4_encode_2d
        packed, scale = _dispatch(
            "kv4_encode", _route_label(),
            lambda: kv4_encode_2d(t2, interpret=_interpret()), probe=t)
    else:
        packed, scale = _dispatch("kv4_encode", "ref",
                                  lambda: _ref.ref_kv4_encode(t2), probe=t)
    return packed.reshape(*lead, hd // 2), scale.reshape(lead)


def kv4_decode(packed: jnp.ndarray, scale: jnp.ndarray,
               dtype=jnp.bfloat16) -> jnp.ndarray:
    lead = packed.shape[:-1]
    hh = packed.shape[-1]
    p2 = packed.reshape(-1, hh)
    s2 = scale.reshape(-1)
    if _use_pallas():
        from repro.kernels.kv4 import kv4_decode_2d
        out = _dispatch(
            "kv4_decode", _route_label(),
            lambda: kv4_decode_2d(p2, s2, dtype=dtype,
                                  interpret=_interpret()),
            probe=packed)
    else:
        out = _dispatch("kv4_decode", "ref",
                        lambda: _ref.ref_kv4_decode(p2, s2, dtype),
                        probe=packed)
    return out.reshape(*lead, 2 * hh)
