"""Jit'd wrappers around the Pallas kernels with XLA fallbacks.

Dispatch policy: on TPU the Pallas kernels run compiled; on CPU (this
container) the XLA reference path runs for real numerics, while tests
exercise the kernels in interpret mode against the ref oracles. Set
``FORCE=\"pallas\"`` / ``\"xla\"`` / ``\"interpret\"`` to override (tests use it).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.qmodule import PackedW4
from repro.kernels import ref as _ref
from repro.quant.fakequant import KIND_FP_SIGNED, KIND_INT_AFFINE, QuantizerParams

FORCE: str | None = None


def _use_pallas() -> bool:
    if FORCE == "pallas" or FORCE == "interpret":
        return True
    if FORCE == "xla":
        return False
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return FORCE == "interpret" or jax.default_backend() != "tpu"


def msfp_quantize(x: jnp.ndarray, qp: QuantizerParams) -> jnp.ndarray:
    """Fused fake-quant (no STE — serving path; training uses quant.ste_qdq).

    The Pallas kernel takes per-tensor FP parameters; INT-affine and
    vector (per-channel) maxvals fall back to the XLA reference.
    """
    if _use_pallas() and qp.kind != 2 and jnp.ndim(qp.maxval) == 0:
        from repro.kernels.msfp_quant import msfp_qdq
        return msfp_qdq(x, qp, interpret=_interpret())
    return _ref.ref_msfp_qdq(x, qp)


def _pallas_w4_ok(pw: PackedW4) -> bool:
    """The Pallas kernel covers the full MSFP format space (signed and
    unsigned ExMy, scalar or per-output-channel scale) for single 2D packs;
    stacked (scanned) packs with per-slice scales stay on the XLA path."""
    if jnp.ndim(pw.packed) != 2:
        return False
    if jnp.ndim(pw.scale) == 0:
        return True
    return (jnp.ndim(pw.scale) == 1
            and pw.scale.shape[0] == 2 * pw.packed.shape[-1])


def w4_matmul(x: jnp.ndarray, pw: PackedW4) -> jnp.ndarray:
    """x: (..., K) @ packed W4 (K, N/2-packed) -> (..., N)."""
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k)
    if _use_pallas() and _pallas_w4_ok(pw):
        from repro.kernels.w4_matmul import w4_matmul_2d
        out = w4_matmul_2d(x2, pw.packed, pw.scale, pw.zero_point,
                           exp_bits=pw.exp_bits, man_bits=pw.man_bits,
                           signed=pw.signed, interpret=_interpret())
    else:
        out = _ref.ref_w4_matmul(x2, pw, x.dtype)
    return out.reshape(*lead, out.shape[-1])


def w4a4_matmul(x: jnp.ndarray, pw: PackedW4,
                act_qp: QuantizerParams | None) -> jnp.ndarray:
    """Fused activation-quant + W4 matmul: qdq(x, act_qp) @ W in one kernel.

    Saves one full HBM round-trip over x versus msfp_quantize followed by
    w4_matmul. ``act_qp`` must be an FP (signed/unsigned) per-tensor
    quantizer; INT-affine activations fall back to qdq-then-matmul.
    """
    if act_qp is None:
        return w4_matmul(x, pw)
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k)
    if (_use_pallas() and _pallas_w4_ok(pw)
            and act_qp.kind != KIND_INT_AFFINE
            and jnp.ndim(act_qp.maxval) == 0):
        from repro.kernels.w4_matmul import w4a4_matmul_2d
        out = w4a4_matmul_2d(
            x2, pw.packed, pw.scale, pw.zero_point,
            act_qp.maxval, act_qp.zero_point,
            exp_bits=pw.exp_bits, man_bits=pw.man_bits, signed=pw.signed,
            act_exp_bits=act_qp.exp_bits, act_man_bits=act_qp.man_bits,
            act_signed=(act_qp.kind == KIND_FP_SIGNED),
            interpret=_interpret())
    else:
        out = _ref.ref_w4a4_matmul(x2, pw, act_qp, x.dtype)
    return out.reshape(*lead, out.shape[-1])


def _normalize_stride(stride) -> tuple[int, int]:
    return (stride, stride) if isinstance(stride, int) else tuple(stride)


def w4a4_conv2d(x: jnp.ndarray, pw: PackedW4,
                act_qp: QuantizerParams | None = None, *,
                stride=1, padding="SAME") -> jnp.ndarray:
    """NHWC conv on a packed HWIO W4 weight via im2col + fused matmul.

    The Pallas route unfolds x into the (B*OH*OW, kh*kw*cin) patch matrix
    matching the 2D conv pack layout and applies the MSFP act snap to the
    patch tiles in VMEM (``w4a4_matmul_2d``). Only signed per-tensor act
    quantizers fuse: SAME padding's zeros must stay exactly zero through
    the snap, and unsigned grids map 0 to the zero-point — those (and
    INT-affine) pre-quantize x with ``msfp_quantize`` and run the plain
    packed matmul. Fallback elsewhere is the jnp oracle (decode + conv).
    """
    strides = _normalize_stride(stride)
    if act_qp is not None and not (act_qp.kind == KIND_FP_SIGNED
                                   and jnp.ndim(act_qp.maxval) == 0):
        x = msfp_quantize(x, act_qp)
        act_qp = None
    if _use_pallas() and len(pw.shape) == 4 and _pallas_w4_ok(pw):
        from repro.kernels.conv import w4a4_conv2d_im2col
        return w4a4_conv2d_im2col(x, pw, act_qp, stride=strides,
                                  padding=padding, interpret=_interpret())
    return _ref.ref_w4a4_conv2d(x, pw, act_qp, stride=strides,
                                padding=padding, dtype=x.dtype)


def kv4_encode(t: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """t: (..., hd) -> packed (..., hd/2) uint8 + scale (...,) f16."""
    lead = t.shape[:-1]
    hd = t.shape[-1]
    t2 = t.reshape(-1, hd)
    if _use_pallas():
        from repro.kernels.kv4 import kv4_encode_2d
        packed, scale = kv4_encode_2d(t2, interpret=_interpret())
    else:
        packed, scale = _ref.ref_kv4_encode(t2)
    return packed.reshape(*lead, hd // 2), scale.reshape(lead)


def kv4_decode(packed: jnp.ndarray, scale: jnp.ndarray,
               dtype=jnp.bfloat16) -> jnp.ndarray:
    lead = packed.shape[:-1]
    hh = packed.shape[-1]
    p2 = packed.reshape(-1, hh)
    s2 = scale.reshape(-1)
    if _use_pallas():
        from repro.kernels.kv4 import kv4_decode_2d
        out = kv4_decode_2d(p2, s2, dtype=dtype, interpret=_interpret())
    else:
        out = _ref.ref_kv4_decode(p2, s2, dtype)
    return out.reshape(*lead, 2 * hh)
