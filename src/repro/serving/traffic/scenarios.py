"""Named traffic scenarios: generator + engine shaping + SLO, one handle.

A scenario is the unit the bench and launcher iterate over — every
traffic-level perf claim ("prefetch helps under bursts") is made against
a named scenario so the number is reproducible. ``run_scenario`` is the
one driver: it attaches a metrics collector, feeds the engine (open-loop
trace submit, closed-loop live drive, or trace-file replay), runs to
drain, and returns the SLO-scored summary.

Registry (see ``SCENARIOS``):

  * ``steady``       — Poisson baseline; the PR-2 launcher default.
  * ``burst``        — Markov-modulated flash crowds.
  * ``diurnal``      — compressed daily ramp (inhomogeneous Poisson).
  * ``heavy_tail``   — Pareto inter-arrivals; queue-tail stress.
  * ``closed_loop``  — N users with think time; rate adapts to service.
  * ``deadline_mix`` — tiered deadlines + priorities over Poisson; the
    goodput/expiry scenario (tight-budget requests expire under load).
  * ``tight_deadlines`` — a minority of requests carry tight budgets at
    uniform priority, so *admission* cannot save them — only deadline-
    aware group selection can. The fifo-vs-slo policy discriminator
    (largest-group-wins demonstrably misses the tight tier).
  * ``golden``       — replay of the checked-in CI fixture trace.
  * ``mixed_model``  — two gateway models interleaved 1:1 over Poisson;
    the cross-model capacity-contention scenario (run against a
    ``ServingGateway``; a plain engine serves everything itself).
  * ``per_model_slo`` — the same two-model interleave where only the
    diffusion model's requests carry deadlines: goodput is judged
    per model, not per fleet.
"""
from __future__ import annotations

import dataclasses
import os

from repro.common.clock import wall_clock
from repro.serving.traffic.generators import (ClosedLoopGenerator,
                                              RequestMix, open_loop_trace)
from repro.serving.traffic.metrics import SLO, MetricsCollector
from repro.serving.traffic.trace import (TraceRequest, load_trace,
                                         submit_trace)

GOLDEN_TRACE = os.path.join("tests", "data", "golden_trace.jsonl")


@dataclasses.dataclass(frozen=True)
class Scenario:
    name: str
    desc: str
    kind: str                      # "open" | "closed" | "trace"
    gen: str = "poisson"           # open-loop generator name
    gen_kw: tuple = ()             # ((key, value), ...) — hashable/frozen
    n_requests: int = 8
    mix: RequestMix = RequestMix()
    n_users: int = 4               # closed-loop shape
    requests_per_user: int = 3
    think_mean_s: float = 0.2
    trace_path: str | None = None
    max_batch: int = 4             # engine shaping hint for builders
    slo: SLO = SLO()


SCENARIOS: dict[str, Scenario] = {}


def register(scn: Scenario) -> Scenario:
    SCENARIOS[scn.name] = scn
    return scn


def get_scenario(name: str) -> Scenario:
    if name not in SCENARIOS:
        raise KeyError(f"unknown scenario {name!r} "
                       f"(known: {sorted(SCENARIOS)})")
    return SCENARIOS[name]


def list_scenarios() -> list[str]:
    return sorted(SCENARIOS)


register(Scenario(
    name="steady", kind="open", gen="poisson", gen_kw=(("rate", 20.0),),
    desc="Poisson arrivals at a steady 20 req/s; the baseline row.",
    mix=RequestMix(samplers=("ddim", "plms"), steps=10, steps_jitter=2),
    slo=SLO(p95_s=120.0)))

register(Scenario(
    name="burst", kind="open", gen="bursty",
    gen_kw=(("rate_base", 4.0), ("rate_burst", 40.0),
            ("dwell_base_s", 1.0), ("dwell_burst_s", 0.25)),
    desc="Markov-modulated Poisson: 4 req/s base with 40 req/s bursts.",
    mix=RequestMix(samplers=("ddim",), steps=10, steps_jitter=2),
    slo=SLO(p95_s=120.0)))

register(Scenario(
    name="diurnal", kind="open", gen="diurnal",
    gen_kw=(("rate_min", 2.0), ("rate_max", 30.0), ("period_s", 4.0)),
    desc="Raised-cosine rate ramp 2->30 req/s (compressed diurnal cycle).",
    mix=RequestMix(samplers=("ddim", "dpm_solver2"), steps=10,
                   steps_jitter=2),
    slo=SLO(p95_s=120.0)))

register(Scenario(
    name="heavy_tail", kind="open", gen="pareto",
    gen_kw=(("rate", 15.0), ("alpha", 1.5)),
    desc="Pareto(1.5) inter-arrivals, mean 15 req/s; queue-tail stress.",
    mix=RequestMix(samplers=("ddim",), steps=10, steps_jitter=2),
    slo=SLO(p95_s=120.0)))

register(Scenario(
    name="closed_loop", kind="closed",
    desc="4 users, think-time feedback loop, 3 requests each.",
    n_users=4, requests_per_user=3, think_mean_s=0.2,
    mix=RequestMix(samplers=("ddim", "plms"), steps=10, steps_jitter=1),
    slo=SLO(p95_s=120.0, goodput_min=0.99)))

register(Scenario(
    name="deadline_mix", kind="open", gen="poisson",
    gen_kw=(("rate", 25.0),),
    desc="Tiered SLOs over Poisson: tight/loose/no deadline x priority.",
    mix=RequestMix(samplers=("ddim",), steps=10, steps_jitter=1,
                   deadline_s=(2.0, 30.0, None), priorities=(2, 1, 0)),
    slo=SLO(goodput_min=0.25)))

register(Scenario(
    name="tight_deadlines", kind="open", gen="poisson",
    gen_kw=(("rate", 50.0),),
    desc="Every 3rd request has a tight budget, all at equal priority; "
         "only deadline-aware selection meets the tight tier.",
    n_requests=12,
    mix=RequestMix(samplers=("ddim",), steps=6, steps_jitter=1,
                   deadline_s=(1.2, None, None), priorities=(0,)),
    max_batch=6, slo=SLO(goodput_min=0.9)))

register(Scenario(
    name="golden", kind="trace", trace_path=GOLDEN_TRACE,
    desc="Checked-in CI fixture trace; deterministic replay smoke.",
    max_batch=2, slo=SLO()))

# Multi-model gateway scenarios. Model names are routing keys the run's
# submission surface resolves (the gateway registry's defaults pair the
# tiny diffusion preset with the smollm smoke LM); a surface without
# routing (plain engine) ignores them and serves every request itself.
register(Scenario(
    name="mixed_model", kind="open", gen="poisson", gen_kw=(("rate", 20.0),),
    desc="Two models interleaved 1:1 over Poisson arrivals; the gateway "
         "cross-model contention baseline.",
    mix=RequestMix(samplers=("ddim",), steps=6, steps_jitter=1,
                   models=("tiny-ddim", "smollm-135m")),
    slo=SLO(p95_s=120.0)))

register(Scenario(
    name="per_model_slo", kind="open", gen="poisson",
    gen_kw=(("rate", 25.0),),
    desc="Two models 1:1 where only the diffusion requests carry "
         "deadlines — per-model goodput under cross-model contention.",
    mix=RequestMix(samplers=("ddim",), steps=6, steps_jitter=1,
                   models=("tiny-ddim", "smollm-135m"),
                   deadline_s=(1.5, None)),
    slo=SLO(goodput_min=0.25)))


def resolve_trace_path(path: str) -> str:
    """Absolute, cwd-relative, or repo-root-relative trace location."""
    if os.path.isabs(path) or os.path.exists(path):
        return path
    here = os.path.abspath(__file__)
    root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.dirname(here)))))   # src/repro/serving/traffic
    cand = os.path.join(root, path)
    return cand if os.path.exists(cand) else path


def build_trace(scn: Scenario, seed: int = 0,
                n: int | None = None) -> list[TraceRequest]:
    """Materialize an open-loop or trace-file scenario as trace requests."""
    if scn.kind == "trace":
        reqs, _ = load_trace(resolve_trace_path(scn.trace_path))
        return reqs
    if scn.kind == "open":
        return open_loop_trace(scn.gen, n or scn.n_requests, seed,
                               scn.mix, **dict(scn.gen_kw))
    raise ValueError(f"scenario {scn.name!r} is {scn.kind}; its trace is "
                     "realized by driving an engine (run_scenario)")


def run_scenario(scn: Scenario, engine, *, seed: int = 0,
                 collector: MetricsCollector | None = None) -> dict:
    """Feed the engine with the scenario's workload, run to drain, and
    return the metrics summary + SLO verdict."""
    collector = collector or MetricsCollector()
    collector.attach(engine)
    t0 = wall_clock()
    if scn.kind == "closed":
        gen = ClosedLoopGenerator(n_users=scn.n_users,
                                  requests_per_user=scn.requests_per_user,
                                  think_mean_s=scn.think_mean_s,
                                  mix=scn.mix, seed=seed)
        gen.drive(engine)
    else:
        submit_trace(engine, build_trace(scn, seed=seed))
        engine.run()
    out = collector.summary()
    out["scenario"] = scn.name
    out["wall_s"] = wall_clock() - t0
    out["slo"] = collector.evaluate(scn.slo)
    return out
