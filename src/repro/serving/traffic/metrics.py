"""SLO metrics for the serving engine: sliding windows + run summaries.

``MetricsCollector`` hangs off the engine's callback hooks (no engine
import — anything with ``on_complete``/``on_expire``/``on_tick_end``
lists and a ``now()`` works) and owns every latency/throughput number
the launcher and bench report:

  * per-request: latency from *arrival* (not submit), deadline met/miss,
    expiry (refused admission past deadline),
  * per-tick: queue depth, in-flight count, cumulative bank hits/misses,
  * derived: sliding-window throughput / p50 / p95 / p99 / goodput /
    mean queue depth / window cache hit rate (``windows``), whole-run
    ``summary``, and SLO pass/fail (``evaluate``).

``percentile`` is the single nearest-rank implementation shared with
``engine.stats()`` (previously duplicated ad-hoc in the launcher path).
"""
from __future__ import annotations

import bisect
import collections
import dataclasses


def percentile(sorted_vals, p: float) -> float:
    """Nearest-rank percentile over an ascending-sorted sequence."""
    if not sorted_vals:
        return 0.0
    k = min(len(sorted_vals) - 1,
            int(round(p / 100 * (len(sorted_vals) - 1))))
    return sorted_vals[max(k, 0)]


def _win_index(t: float, w: float) -> int:
    """Half-open window index for time ``t`` at width ``w``.

    Plain ``int(t // w)`` puts a value landing *exactly* on a boundary in
    the window below it whenever ``t / w`` floats just under the integer
    (``0.3 // 0.1 == 2.0``), breaking the documented ``[i*w, (i+1)*w)``
    contract; snap quotients whose fractional part is within 1e-9 of 1
    up to the next integer instead.
    """
    q = t / w
    i = int(q)
    if q - i > 1.0 - 1e-9:
        i += 1
    return i


@dataclasses.dataclass(frozen=True)
class SLO:
    """Thresholds a scenario is judged against (None = not enforced)."""

    p95_s: float | None = None          # latency-from-arrival ceiling
    goodput_min: float | None = None    # fraction finishing within deadline
    throughput_min: float | None = None  # finished requests / second


@dataclasses.dataclass(frozen=True)
class _Event:
    arrival: float
    finished: float
    latency: float | None      # None for expired requests
    met_deadline: bool
    expired: bool


class MetricsCollector:
    def __init__(self, window_s: float = 1.0):
        assert window_s > 0
        self.window_s = window_s
        self.events: list[_Event] = []
        self.ticks: list[tuple] = []   # (now, pending, inflight, hits, misses)

    # -- engine hooks --------------------------------------------------------

    def attach(self, engine) -> "MetricsCollector":
        engine.on_complete.append(self.on_complete)
        engine.on_expire.append(self.on_expire)
        engine.on_tick_end.append(self.on_tick_end)
        return self

    def on_complete(self, rs) -> None:
        dl = rs.req.deadline
        self.events.append(_Event(
            arrival=max(rs.submitted_at, rs.req.arrival),
            finished=rs.finished_at, latency=rs.latency,
            met_deadline=(dl is None or rs.finished_at <= dl),
            expired=False))

    def on_expire(self, rs) -> None:
        self.events.append(_Event(
            arrival=max(rs.submitted_at, rs.req.arrival),
            finished=rs.finished_at, latency=None,
            met_deadline=False, expired=True))

    def on_tick_end(self, engine) -> None:
        now = engine.now()
        # queue depth = *arrived* but not yet admitted; an open-loop trace
        # submits its whole future up front and that is not a backlog.
        # pending stays sorted by arrival, so the due prefix bisects.
        queued = bisect.bisect_right(engine.batcher.pending, now,
                                     key=lambda rs: rs.req.arrival)
        self.ticks.append((now, queued, len(engine.batcher.inflight),
                           engine.bank.hits, engine.bank.misses))

    # -- derived views -------------------------------------------------------

    def windows(self, window_s: float | None = None) -> list[dict]:
        """Sliding-window rows over [0, end) at ``window_s`` granularity."""
        w = window_s or self.window_s
        if not self.events and not self.ticks:
            return []
        end = max([e.finished for e in self.events]
                  + [t[0] for t in self.ticks])
        rows = []
        # half-open windows [i*w, (i+1)*w); +1 so an event landing exactly
        # on the last boundary still has a window
        n_win = _win_index(end, w) + 1 if end > 0 else 1
        ev_by_win = collections.defaultdict(list)
        for e in self.events:
            ev_by_win[_win_index(e.finished, w)].append(e)
        ticks_by_win = collections.defaultdict(list)
        for t in self.ticks:
            ticks_by_win[_win_index(t[0], w)].append(t)
        prev_h = prev_m = 0   # cumulative counters at previous window's end
        for i in range(n_win):
            lo = i * w
            evs = ev_by_win.get(i, [])
            lats = sorted(e.latency for e in evs if e.latency is not None)
            ticks = ticks_by_win.get(i, [])
            done = [e for e in evs if not e.expired]
            row = {"t": lo,
                   "throughput_rps": len(done) / w,
                   "p50_s": percentile(lats, 50),
                   "p95_s": percentile(lats, 95),
                   "p99_s": percentile(lats, 99),
                   "goodput_rps": sum(e.met_deadline for e in evs) / w,
                   "expired": sum(e.expired for e in evs),
                   "queue_depth": (sum(t[1] for t in ticks) / len(ticks)
                                   if ticks else 0.0),
                   "inflight": (sum(t[2] for t in ticks) / len(ticks)
                                if ticks else 0.0)}
            if ticks:
                h = ticks[-1][3] - prev_h
                m = ticks[-1][4] - prev_m
                row["cache_hit_rate"] = h / (h + m) if (h + m) else None
                prev_h, prev_m = ticks[-1][3], ticks[-1][4]
            rows.append(row)
        return rows

    def summary(self) -> dict:
        done = [e for e in self.events if not e.expired]
        lats = sorted(e.latency for e in done if e.latency is not None)
        n_met = sum(e.met_deadline for e in self.events)
        duration = 0.0
        if self.events:
            duration = (max(e.finished for e in self.events)
                        - min(e.arrival for e in self.events))
        duration = max(duration, 1e-9)
        return {
            "requests": len(done),
            "expired": sum(e.expired for e in self.events),
            "deadline_misses": sum(not e.met_deadline for e in self.events),
            "duration_s": duration,
            "throughput_rps": len(done) / duration,
            "goodput_rps": n_met / duration,
            "goodput_frac": (n_met / len(self.events)
                             if self.events else 1.0),
            "p50_s": percentile(lats, 50),
            "p95_s": percentile(lats, 95),
            "p99_s": percentile(lats, 99),
            "peak_queue_depth": max((t[1] for t in self.ticks), default=0),
            "mean_inflight": (sum(t[2] for t in self.ticks) / len(self.ticks)
                              if self.ticks else 0.0),
        }

    def evaluate(self, slo: SLO) -> dict:
        """{'passed': bool, 'checks': {name: {...}}} for the set thresholds."""
        s = self.summary()
        checks = {}
        if slo.p95_s is not None:
            checks["p95_s"] = {"limit": slo.p95_s, "actual": s["p95_s"],
                               "ok": s["p95_s"] <= slo.p95_s}
        if slo.goodput_min is not None:
            checks["goodput_frac"] = {"limit": slo.goodput_min,
                                      "actual": s["goodput_frac"],
                                      "ok": s["goodput_frac"]
                                      >= slo.goodput_min}
        if slo.throughput_min is not None:
            checks["throughput_rps"] = {"limit": slo.throughput_min,
                                        "actual": s["throughput_rps"],
                                        "ok": s["throughput_rps"]
                                        >= slo.throughput_min}
        return {"passed": all(c["ok"] for c in checks.values()),
                "checks": checks}
