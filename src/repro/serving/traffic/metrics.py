"""SLO metrics for the serving engine: sliding windows + run summaries.

``MetricsCollector`` hangs off the engine's callback hooks (no engine
import — anything with ``on_complete``/``on_expire``/``on_tick_end``
lists and a ``now()`` works) and owns every latency/throughput number
the launcher and bench report:

  * per-request: latency from *arrival* (not submit), deadline met/miss,
    expiry (refused admission past deadline),
  * per-tick: queue depth, in-flight count, cumulative bank hits/misses,
  * derived: sliding-window throughput / p50 / p95 / p99 / goodput /
    mean queue depth / window cache hit rate (``windows``), whole-run
    ``summary``, and SLO pass/fail (``evaluate``),
  * scheduler/bank counters: ``summary()`` folds in ``preemptions`` /
    ``deadline_saves`` and the weight bank's ``builds`` /
    ``build_joins`` / ``prefetch_hits`` from the attached engine (these
    used to exist only as launcher print lines).

Memory is bounded: ``events``/``ticks`` are retention-capped buffers
(``max_events``/``max_ticks``). When a cap is hit, the oldest entries
are *compacted* into running aggregates instead of dropped — counts,
goodput, duration, peak queue depth and mean in-flight stay exact over
the whole run; latency percentiles and ``windows()`` cover the retained
window only (``summary()['compacted_events']`` says how much was folded
away). With nothing compacted, every number is identical to the
unbounded behavior.

``percentile`` is the single nearest-rank implementation shared with
``engine.stats()`` (previously duplicated ad-hoc in the launcher path).
"""
from __future__ import annotations

import bisect
import collections
import dataclasses


def percentile(sorted_vals, p: float) -> float:
    """Nearest-rank percentile over an ascending-sorted sequence."""
    if not sorted_vals:
        return 0.0
    k = min(len(sorted_vals) - 1,
            int(round(p / 100 * (len(sorted_vals) - 1))))
    return sorted_vals[max(k, 0)]


def _win_index(t: float, w: float) -> int:
    """Half-open window index for time ``t`` at width ``w``.

    Plain ``int(t // w)`` puts a value landing *exactly* on a boundary in
    the window below it whenever ``t / w`` floats just under the integer
    (``0.3 // 0.1 == 2.0``), breaking the documented ``[i*w, (i+1)*w)``
    contract; snap quotients whose fractional part is within 1e-9 of 1
    up to the next integer instead.
    """
    q = t / w
    i = int(q)
    if q - i > 1.0 - 1e-9:
        i += 1
    return i


@dataclasses.dataclass(frozen=True)
class SLO:
    """Thresholds a scenario is judged against (None = not enforced)."""

    p95_s: float | None = None          # latency-from-arrival ceiling
    goodput_min: float | None = None    # fraction finishing within deadline
    throughput_min: float | None = None  # finished requests / second


@dataclasses.dataclass(frozen=True)
class _Event:
    arrival: float
    finished: float
    latency: float | None      # None for expired requests
    met_deadline: bool
    expired: bool


class _Bounded(collections.deque):
    """Append-compatible retention buffer: beyond ``cap`` entries, the
    oldest is handed to ``fold`` (compacted into aggregates) before the
    new one is appended. ``cap=None`` never compacts."""

    def __init__(self, cap: int | None, fold):
        super().__init__()
        self._cap = cap
        self._fold = fold

    def append(self, item) -> None:
        if self._cap is not None and len(self) >= self._cap:
            self._fold(self.popleft())
        super().append(item)


class MetricsCollector:
    def __init__(self, window_s: float = 1.0,
                 max_events: int | None = 200_000,
                 max_ticks: int | None = 200_000):
        assert window_s > 0
        self.window_s = window_s
        self.events: collections.deque = _Bounded(max_events,
                                                  self._fold_event)
        # (now, pending, inflight, hits, misses)
        self.ticks: collections.deque = _Bounded(max_ticks, self._fold_tick)
        self._engine = None
        # compacted-entry aggregates (all zero until a cap is hit); kept
        # exact so summary() totals never depend on retention
        self._f_events = 0
        self._f_done = 0
        self._f_expired = 0
        self._f_met = 0
        self._f_min_arrival: float | None = None
        self._f_max_finished: float | None = None
        self._f_ticks = 0
        self._f_inflight_sum = 0.0
        self._f_peak_queue = 0

    def _fold_event(self, e: "_Event") -> None:
        self._f_events += 1
        self._f_done += not e.expired
        self._f_expired += e.expired
        self._f_met += e.met_deadline
        self._f_min_arrival = (e.arrival if self._f_min_arrival is None
                               else min(self._f_min_arrival, e.arrival))
        self._f_max_finished = (e.finished if self._f_max_finished is None
                                else max(self._f_max_finished, e.finished))

    def _fold_tick(self, t: tuple) -> None:
        self._f_ticks += 1
        self._f_peak_queue = max(self._f_peak_queue, t[1])
        self._f_inflight_sum += t[2]

    # -- engine hooks --------------------------------------------------------

    def attach(self, engine) -> "MetricsCollector":
        engine.on_complete.append(self.on_complete)
        engine.on_expire.append(self.on_expire)
        engine.on_tick_end.append(self.on_tick_end)
        self._engine = engine   # scheduler/bank counters read at summary()
        return self

    def on_complete(self, rs) -> None:
        dl = rs.req.deadline
        self.events.append(_Event(
            arrival=max(rs.submitted_at, rs.req.arrival),
            finished=rs.finished_at, latency=rs.latency,
            met_deadline=(dl is None or rs.finished_at <= dl),
            expired=False))

    def on_expire(self, rs) -> None:
        self.events.append(_Event(
            arrival=max(rs.submitted_at, rs.req.arrival),
            finished=rs.finished_at, latency=None,
            met_deadline=False, expired=True))

    def on_tick_end(self, engine) -> None:
        now = engine.now()
        # queue depth = *arrived* but not yet admitted; an open-loop trace
        # submits its whole future up front and that is not a backlog.
        # pending stays sorted by arrival, so the due prefix bisects.
        queued = bisect.bisect_right(engine.batcher.pending, now,
                                     key=lambda rs: rs.req.arrival)
        self.ticks.append((now, queued, len(engine.batcher.inflight),
                           engine.bank.hits, engine.bank.misses))

    # -- derived views -------------------------------------------------------

    def windows(self, window_s: float | None = None) -> list[dict]:
        """Sliding-window rows over [0, end) at ``window_s`` granularity."""
        w = window_s or self.window_s
        if not self.events and not self.ticks:
            return []
        end = max([e.finished for e in self.events]
                  + [t[0] for t in self.ticks])
        rows = []
        # half-open windows [i*w, (i+1)*w); +1 so an event landing exactly
        # on the last boundary still has a window
        n_win = _win_index(end, w) + 1 if end > 0 else 1
        ev_by_win = collections.defaultdict(list)
        for e in self.events:
            ev_by_win[_win_index(e.finished, w)].append(e)
        ticks_by_win = collections.defaultdict(list)
        for t in self.ticks:
            ticks_by_win[_win_index(t[0], w)].append(t)
        prev_h = prev_m = 0   # cumulative counters at previous window's end
        for i in range(n_win):
            lo = i * w
            evs = ev_by_win.get(i, [])
            lats = sorted(e.latency for e in evs if e.latency is not None)
            ticks = ticks_by_win.get(i, [])
            done = [e for e in evs if not e.expired]
            row = {"t": lo,
                   "throughput_rps": len(done) / w,
                   "p50_s": percentile(lats, 50),
                   "p95_s": percentile(lats, 95),
                   "p99_s": percentile(lats, 99),
                   "goodput_rps": sum(e.met_deadline for e in evs) / w,
                   "expired": sum(e.expired for e in evs),
                   "queue_depth": (sum(t[1] for t in ticks) / len(ticks)
                                   if ticks else 0.0),
                   "inflight": (sum(t[2] for t in ticks) / len(ticks)
                                if ticks else 0.0)}
            if ticks:
                h = ticks[-1][3] - prev_h
                m = ticks[-1][4] - prev_m
                row["cache_hit_rate"] = h / (h + m) if (h + m) else None
                prev_h, prev_m = ticks[-1][3], ticks[-1][4]
            rows.append(row)
        return rows

    def summary(self) -> dict:
        done = [e for e in self.events if not e.expired]
        # percentiles cover the retained window; every count below folds
        # in the compacted aggregates, so totals stay exact under caps
        lats = sorted(e.latency for e in done if e.latency is not None)
        n_events = self._f_events + len(self.events)
        n_done = self._f_done + len(done)
        n_expired = self._f_expired + sum(e.expired for e in self.events)
        n_met = self._f_met + sum(e.met_deadline for e in self.events)
        duration = 0.0
        if n_events:
            arrivals = [e.arrival for e in self.events]
            finishes = [e.finished for e in self.events]
            if self._f_min_arrival is not None:
                arrivals.append(self._f_min_arrival)
                finishes.append(self._f_max_finished)
            duration = max(finishes) - min(arrivals)
        duration = max(duration, 1e-9)
        n_ticks = self._f_ticks + len(self.ticks)
        out = {
            "requests": n_done,
            "expired": n_expired,
            "deadline_misses": n_events - n_met,
            "duration_s": duration,
            "throughput_rps": n_done / duration,
            "goodput_rps": n_met / duration,
            "goodput_frac": n_met / n_events if n_events else 1.0,
            "p50_s": percentile(lats, 50),
            "p95_s": percentile(lats, 95),
            "p99_s": percentile(lats, 99),
            "peak_queue_depth": max([self._f_peak_queue]
                                    + [t[1] for t in self.ticks]),
            "mean_inflight": ((self._f_inflight_sum
                               + sum(t[2] for t in self.ticks)) / n_ticks
                              if n_ticks else 0.0),
            "compacted_events": self._f_events,
            "compacted_ticks": self._f_ticks,
        }
        out.update(self._engine_counters())
        return out

    def _engine_counters(self) -> dict:
        """Scheduler preemption and weight-bank build/prefetch counters
        from the attached engine — read live at summary time (so post-run
        ``bank.drain()`` builds are included), zeros when unattached."""
        eng = self._engine
        batcher = getattr(eng, "batcher", None)
        bank = getattr(eng, "bank", None)
        return {
            "preemptions": getattr(batcher, "preemptions", 0),
            "deadline_saves": getattr(batcher, "deadline_saves", 0),
            "bank_builds": getattr(bank, "builds", 0),
            "bank_build_joins": getattr(bank, "build_joins", 0),
            "prefetch_hits": getattr(bank, "prefetch_hits", 0),
        }

    def evaluate(self, slo: SLO) -> dict:
        """{'passed': bool, 'checks': {name: {...}}} for the set thresholds."""
        s = self.summary()
        checks = {}
        if slo.p95_s is not None:
            checks["p95_s"] = {"limit": slo.p95_s, "actual": s["p95_s"],
                               "ok": s["p95_s"] <= slo.p95_s}
        if slo.goodput_min is not None:
            checks["goodput_frac"] = {"limit": slo.goodput_min,
                                      "actual": s["goodput_frac"],
                                      "ok": s["goodput_frac"]
                                      >= slo.goodput_min}
        if slo.throughput_min is not None:
            checks["throughput_rps"] = {"limit": slo.throughput_min,
                                        "actual": s["throughput_rps"],
                                        "ok": s["throughput_rps"]
                                        >= slo.throughput_min}
        return {"passed": all(c["ok"] for c in checks.values()),
                "checks": checks}
