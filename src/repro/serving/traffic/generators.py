"""Load generators: open-loop arrival processes + a closed-loop driver.

Every generator emits the same trace schema (``trace.TraceRequest``)
deterministically from a seed, so a generated workload can be saved,
diffed, and replayed like a captured one.

Open-loop processes (arrivals independent of service times):

  * ``poisson``  — memoryless baseline, exponential inter-arrivals.
  * ``bursty``   — Markov-modulated Poisson: two rate states (base /
    burst) with exponential dwell times; models flash crowds.
  * ``diurnal``  — inhomogeneous Poisson with a raised-cosine rate curve
    between ``rate_min`` and ``rate_max`` (thinning simulation); models
    the daily ramp, compressed to a test-friendly period.
  * ``pareto``   — heavy-tail (Pareto) inter-arrivals with the same mean
    rate; stresses queue tails a Poisson trace never exercises.

The closed-loop generator models N users who each *wait for their result
and think* before issuing the next request — arrival rate adapts to
service rate, which is the feedback an open-loop replay cannot express.
It drives a live engine through its completion callbacks and returns the
realized trace (with ``user``/``parent``/``think_s`` links) for capture.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.serving.traffic.trace import TraceRequest


@dataclasses.dataclass(frozen=True)
class RequestMix:
    """Deterministic per-index request shaping shared by all generators.

    Field cycles are indexed by the request number (for closed-loop:
    ``user * requests_per_user + k``), never by an RNG, so the schema
    side of a trace is identical across runs even when arrival times are
    wall-clock (closed-loop under a real clock).
    """

    samplers: tuple = ("ddim",)
    steps: int = 10
    steps_jitter: int = 2           # request i runs steps + i % (jitter+1)
    eta: float = 0.0
    seed0: int = 0                  # request i samples with seed0 + i
    deadline_s: tuple = (None,)     # latency budgets (s), cycled; None = no SLO
    priorities: tuple = (0,)        # cycled
    models: tuple = (None,)         # gateway routing targets, cycled;
    #                                 None = the surface's default model.
    #                                 Align the cycle length with
    #                                 deadline_s to express per-model SLOs
    #                                 (e.g. models=(a, b) with
    #                                 deadline_s=(1.5, None) gives model a
    #                                 a deadline and b none).

    def make(self, i: int, arrival: float, *, user: int | None = None,
             parent: int | None = None,
             think_s: float | None = None) -> TraceRequest:
        budget = self.deadline_s[i % len(self.deadline_s)]
        return TraceRequest(
            arrival=float(arrival),
            steps=self.steps + i % (self.steps_jitter + 1),
            eta=self.eta, seed=self.seed0 + i,
            sampler=self.samplers[i % len(self.samplers)],
            deadline=None if budget is None else float(arrival) + budget,
            priority=self.priorities[i % len(self.priorities)],
            model=self.models[i % len(self.models)],
            user=user, parent=parent, think_s=think_s)


# ---------------------------------------------------------------------------
# Open-loop arrival processes (cumulative times, seconds from trace start).
# ---------------------------------------------------------------------------


def poisson_arrivals(n: int, rng, *, rate: float = 20.0) -> np.ndarray:
    return np.cumsum(rng.exponential(1.0 / max(rate, 1e-9), size=n))


def pareto_arrivals(n: int, rng, *, rate: float = 20.0,
                    alpha: float = 1.5) -> np.ndarray:
    """Pareto(alpha) inter-arrivals scaled to mean 1/rate (alpha > 1)."""
    assert alpha > 1.0, "alpha <= 1 has infinite mean inter-arrival"
    scale = (alpha - 1.0) / (alpha * max(rate, 1e-9))
    return np.cumsum((rng.pareto(alpha, size=n) + 1.0) * scale)


def bursty_arrivals(n: int, rng, *, rate_base: float = 4.0,
                    rate_burst: float = 40.0, dwell_base_s: float = 1.0,
                    dwell_burst_s: float = 0.25) -> np.ndarray:
    """Two-state Markov-modulated Poisson process (exact simulation:
    next event is min(arrival at the current rate, state switch))."""
    rates = (max(rate_base, 1e-9), max(rate_burst, 1e-9))
    dwells = (max(dwell_base_s, 1e-9), max(dwell_burst_s, 1e-9))
    t, state = 0.0, 0
    next_switch = rng.exponential(dwells[state])
    out: list[float] = []
    while len(out) < n:
        ia = rng.exponential(1.0 / rates[state])
        if t + ia < next_switch:
            t += ia
            out.append(t)
        else:
            t = next_switch
            state = 1 - state
            next_switch = t + rng.exponential(dwells[state])
    return np.asarray(out)


def diurnal_arrivals(n: int, rng, *, rate_min: float = 2.0,
                     rate_max: float = 30.0,
                     period_s: float = 4.0) -> np.ndarray:
    """Raised-cosine rate curve simulated by thinning at rate_max."""
    assert rate_max >= rate_min > 0
    t = 0.0
    out: list[float] = []
    while len(out) < n:
        t += rng.exponential(1.0 / rate_max)
        lam = rate_min + (rate_max - rate_min) * 0.5 * (
            1.0 - np.cos(2.0 * np.pi * t / period_s))
        if rng.uniform() * rate_max <= lam:
            out.append(t)
    return np.asarray(out)


OPEN_LOOP = {"poisson": poisson_arrivals, "bursty": bursty_arrivals,
             "diurnal": diurnal_arrivals, "pareto": pareto_arrivals}


def open_loop_trace(kind: str, n: int, seed: int,
                    mix: RequestMix = RequestMix(),
                    **gen_kw) -> list[TraceRequest]:
    """n requests from a named arrival process, deterministic in seed."""
    if kind not in OPEN_LOOP:
        raise KeyError(f"unknown generator {kind!r} "
                       f"(known: {sorted(OPEN_LOOP)})")
    rng = np.random.default_rng(seed)
    arrivals = OPEN_LOOP[kind](n, rng, **gen_kw)
    return [dataclasses.replace(mix.make(i, t), rid=i)
            for i, t in enumerate(arrivals)]


# ---------------------------------------------------------------------------
# Closed loop: N users, think time, next request issued on completion.
# ---------------------------------------------------------------------------


class ClosedLoopGenerator:
    """Drives a live engine: each user issues, waits, thinks, re-issues.

    Think times come from one RNG stream per user (seeded ``[seed, u]``),
    so the think schedule — and under a virtual clock the whole run — is
    deterministic; request shaping is index-cycled via ``mix`` and never
    depends on completion interleaving. Expired requests also count as a
    completed turn (the user saw a failure and thinks before retrying),
    so the session always terminates after ``requests_per_user`` turns.
    """

    def __init__(self, n_users: int = 4, requests_per_user: int = 3,
                 think_mean_s: float = 0.2,
                 mix: RequestMix = RequestMix(), seed: int = 0):
        assert n_users >= 1 and requests_per_user >= 1
        self.n_users = n_users
        self.requests_per_user = requests_per_user
        self.think_mean_s = think_mean_s
        self.mix = mix
        self.seed = seed

    def drive(self, engine) -> list[TraceRequest]:
        rngs = [np.random.default_rng([self.seed, u])
                for u in range(self.n_users)]
        counts = [0] * self.n_users
        rid_user: dict[int, int] = {}
        issued: list[TraceRequest] = []

        routes = getattr(engine, "routes_models", False)

        def issue(user: int, arrival: float, parent: int | None = None,
                  think_s: float | None = None) -> None:
            k = counts[user]
            counts[user] += 1
            tr = self.mix.make(user * self.requests_per_user + k, arrival,
                               user=user, parent=parent, think_s=think_s)
            kw = {"model": tr.model} if routes else {}
            rid = engine.submit(steps=tr.steps, eta=tr.eta, seed=tr.seed,
                                sampler=tr.sampler, y=tr.y,
                                guidance_scale=tr.guidance_scale,
                                arrival=tr.arrival, deadline=tr.deadline,
                                priority=tr.priority, user=user,
                                parent=parent, think_s=think_s, **kw)
            rid_user[rid] = user
            issued.append(dataclasses.replace(tr, rid=rid))

        def on_done(rs) -> None:
            # a gateway annotates rs.gid (its surface-level rid — what
            # submit() returned); plain engines complete with req.rid
            rid = getattr(rs, "gid", rs.req.rid)
            user = rid_user.get(rid)
            if user is None or counts[user] >= self.requests_per_user:
                return
            think = float(rngs[user].exponential(self.think_mean_s))
            issue(user, float(rs.finished_at) + think,
                  parent=rid, think_s=think)

        engine.on_complete.append(on_done)
        engine.on_expire.append(on_done)
        for u in range(self.n_users):
            issue(u, float(rngs[u].exponential(self.think_mean_s)))
        engine.run()
        return sorted(issued, key=lambda tr: (tr.arrival, tr.rid))
