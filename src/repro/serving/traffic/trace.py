"""Replayable traffic traces: a versioned JSONL format for the engine.

A trace file is the unit of workload reproducibility: every load
generator (``generators.py``) emits it, the launcher replays it, and a
live engine run can be captured back into one (``TraceWriter``), so a
production incident or a synthetic scenario replays bit-for-bit against
any future engine build.

Layout — line 1 is a header object, every following line one request::

    {"format": "repro.traffic.trace", "version": 1, "meta": {...}}
    {"arrival": 0.013, "steps": 3, "sampler": "ddim", "eta": 0.0,
     "seed": 7, "guidance_scale": 0.0, "deadline": 60.0, "priority": 1}

Times (``arrival``, ``deadline``) are absolute seconds from trace start.
``deadline`` is the SLO cutoff the metrics collector scores goodput
against and past which the scheduler refuses admission. ``user`` /
``parent`` / ``think_s`` are the think-time links a closed-loop
generator leaves behind: request ``rid`` was issued ``think_s`` seconds
after request ``parent`` of session ``user`` completed.

Version history:

  * v1 — original schema (single-model engines).
  * v2 — adds the optional ``model`` field: the registered model name a
    multi-model gateway routes the request to. Absent/None means "the
    default model" — a v1 file therefore loads unchanged (every request
    gets the default), and a v2 file whose requests never set ``model``
    is line-identical to the v1 encoding apart from the header.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os

from repro.diffusion.samplers import STEP_SAMPLERS

FORMAT = "repro.traffic.trace"
VERSION = 2
_READABLE_VERSIONS = (1, 2)


@dataclasses.dataclass(frozen=True)
class TraceRequest:
    """One generation request as recorded in a trace line."""

    arrival: float                  # seconds from trace start
    steps: int = 10
    eta: float = 0.0
    seed: int = 0
    sampler: str = "ddim"
    y: int | None = None            # class label (class-conditional models)
    guidance_scale: float = 0.0
    deadline: float | None = None   # absolute SLO cutoff, seconds
    priority: int = 0               # higher admits first under contention
    user: int | None = None         # closed-loop session id
    parent: int | None = None       # rid whose completion triggered this one
    think_s: float | None = None    # think time preceding this request
    rid: int | None = None          # assigned on load / capture
    model: str | None = None        # gateway routing target (v2); None =
    #                                 the submission surface's default model

    def to_obj(self) -> dict:
        return {k: v for k, v in dataclasses.asdict(self).items()
                if v is not None}


def request_from_obj(obj: dict) -> TraceRequest:
    known = {f.name for f in dataclasses.fields(TraceRequest)}
    extra = set(obj) - known
    if extra:
        raise ValueError(f"unknown trace fields {sorted(extra)}")
    return TraceRequest(**obj)


def validate_trace(reqs: list[TraceRequest]) -> None:
    """Raise ValueError on the first malformed request."""
    rids = [tr.rid for tr in reqs if tr.rid is not None]
    if len(rids) != len(set(rids)):
        dupes = sorted({r for r in rids if rids.count(r) > 1})
        raise ValueError(f"duplicate rids in trace: {dupes}")
    for i, tr in enumerate(reqs):
        where = f"trace line {i} (rid={tr.rid})"
        if not (math.isfinite(tr.arrival) and tr.arrival >= 0):
            raise ValueError(f"{where}: bad arrival {tr.arrival}")
        if not (isinstance(tr.steps, int) and tr.steps >= 1):
            raise ValueError(f"{where}: steps must be a positive int, "
                             f"got {tr.steps!r}")
        if tr.sampler not in STEP_SAMPLERS:
            raise ValueError(f"{where}: unknown sampler {tr.sampler!r} "
                             f"(known: {STEP_SAMPLERS})")
        if tr.eta < 0 or tr.guidance_scale < 0:
            raise ValueError(f"{where}: eta/guidance_scale must be >= 0")
        if tr.guidance_scale > 0 and tr.y is None:
            raise ValueError(f"{where}: guidance_scale > 0 needs a class "
                             "label y")
        if tr.deadline is not None and tr.deadline <= tr.arrival:
            raise ValueError(f"{where}: deadline {tr.deadline} not after "
                             f"arrival {tr.arrival}")
        if not isinstance(tr.priority, int):
            raise ValueError(f"{where}: priority must be an int")
        if tr.model is not None and (not isinstance(tr.model, str)
                                     or not tr.model):
            raise ValueError(f"{where}: model must be a non-empty string "
                             f"or absent, got {tr.model!r}")


def save_trace(path: str, reqs: list[TraceRequest],
               meta: dict | None = None) -> None:
    validate_trace(reqs)
    with open(path, "w") as f:
        f.write(json.dumps({"format": FORMAT, "version": VERSION,
                            "meta": meta or {}}) + "\n")
        for tr in reqs:
            f.write(json.dumps(tr.to_obj(), sort_keys=True) + "\n")


def load_trace(path: str, *, validate: bool = True
               ) -> tuple[list[TraceRequest], dict]:
    """Load (requests sorted by arrival, header). rids are assigned by
    arrival order when the file carries none."""
    with open(path) as f:
        lines = [ln for ln in (raw.strip() for raw in f) if ln]
    if not lines:
        raise ValueError(f"{path}: empty trace")
    header = json.loads(lines[0])
    if header.get("format") != FORMAT:
        raise ValueError(f"{path}: not a {FORMAT} file "
                         f"(header {header.get('format')!r})")
    if header.get("version") not in _READABLE_VERSIONS:
        raise ValueError(f"{path}: unsupported trace version "
                         f"{header.get('version')!r} "
                         f"(readable: {_READABLE_VERSIONS})")
    reqs = [request_from_obj(json.loads(ln)) for ln in lines[1:]]
    reqs.sort(key=lambda tr: (tr.arrival,
                              tr.rid if tr.rid is not None else 0))
    # fill rids missing from the file without colliding with explicit ones
    used = {tr.rid for tr in reqs if tr.rid is not None}
    nxt = 0
    filled = []
    for tr in reqs:
        if tr.rid is None:
            while nxt in used:
                nxt += 1
            used.add(nxt)
            tr = dataclasses.replace(tr, rid=nxt)
        filled.append(tr)
    reqs = filled
    if validate:
        validate_trace(reqs)
    return reqs, header


def submit_trace(engine, reqs: list[TraceRequest]) -> dict[int, int]:
    """Submit every trace request to the engine; {trace rid: engine rid}.

    A routing surface (the multi-model gateway) advertises
    ``routes_models = True`` and receives each request's ``model`` field;
    a plain single-model engine never sees the kwarg, so v1 replay
    behavior — and its golden digest — is untouched.
    """
    routes = getattr(engine, "routes_models", False)
    mapping = {}
    for tr in sorted(reqs, key=lambda t: (t.arrival, t.rid or 0)):
        kw = {"model": tr.model} if routes else {}
        rid = engine.submit(steps=tr.steps, eta=tr.eta, seed=tr.seed,
                            sampler=tr.sampler, y=tr.y,
                            guidance_scale=tr.guidance_scale,
                            arrival=tr.arrival, deadline=tr.deadline,
                            priority=tr.priority, user=tr.user,
                            parent=tr.parent, think_s=tr.think_s, **kw)
        mapping[tr.rid if tr.rid is not None else rid] = rid
    return mapping


class TraceWriter:
    """Capture a live engine run back into a trace file.

    Attach to an engine before submitting; every ``engine.submit`` —
    including requests a closed-loop generator issues mid-run — appends
    one line, so the realized workload (actual arrivals) replays later
    via ``load_trace`` + ``submit_trace``.
    """

    def __init__(self, path: str, meta: dict | None = None):
        self.path = path
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._f = open(path, "w")
        self._f.write(json.dumps({"format": FORMAT, "version": VERSION,
                                  "meta": meta or {}}) + "\n")
        self.n = 0

    def record(self, tr: TraceRequest) -> None:
        self._f.write(json.dumps(tr.to_obj(), sort_keys=True) + "\n")
        self.n += 1

    def attach(self, engine) -> "TraceWriter":
        engine.on_submit.append(self._on_submit)
        return self

    def _on_submit(self, rs) -> None:
        req = rs.req
        # ``rs.model`` / ``rs.gid`` are the gateway's routing annotations
        # (set before the engine's on_submit hooks fire). The gateway-wide
        # gid replaces the engine-local rid in the capture — two engines
        # both count rids from 0, so raw rids would collide in one file.
        self.record(TraceRequest(
            arrival=req.arrival, steps=req.steps, eta=req.eta,
            seed=req.seed, sampler=req.sampler, y=req.y,
            guidance_scale=req.guidance_scale, deadline=req.deadline,
            priority=req.priority, user=req.user, parent=req.parent,
            think_s=req.think_s, rid=getattr(rs, "gid", req.rid),
            model=getattr(rs, "model", None)))

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
