"""Deterministic simulated service clock for scheduler-policy studies.

Wall-clock goodput comparisons are machine-dependent (a slow CI runner
turns every deadline into a miss), so the bench's fifo-vs-slo rows and
the scheduler test suites score policies under simulated time instead:
each batched forward costs ``tick_base_s + sample_s * padded rows``
(CFG partitions bucket separately, exactly like the engine pads them)
and an idle tick costs ``tick_base_s``.

The forward's cost is charged *inside* the tick — through the engine's
``on_forward`` hook, which fires with the padded row count before
completions are stamped — so a finishing request has already paid for
its own forward; charging in ``on_tick_end`` instead would score every
completion one full tick early (deadline verdicts systematically
optimistic). The scheduler's ``CostModel`` is primed with the same
rates, so slack estimates and preemptive splits are live from tick 0
and consistent with what the clock actually charges. Attaching also
forces *synchronous* prefetch builds: simulated time does not model
build wall time, and a real background thread finishing earlier or
later on a loaded machine would otherwise flip warm/mid-build switch
penalties — and therefore selection — per machine.
"""
from __future__ import annotations


class SimClock:
    """now_fn-compatible clock advanced by the engine's own compute.

    ``build_s`` > 0 additionally charges every weight-bank segment build
    (merge + pack) through the bank's ``on_build`` seam — the cost that
    makes cold segment switches *matter* in simulated time (the fleet's
    affinity-vs-round-robin rows hinge on it). The default 0.0 keeps
    every pre-existing bench row and the obs-overhead gate's pinned
    goodput baseline bit-identical.
    """

    def __init__(self, tick_base_s: float = 0.02, sample_s: float = 0.015,
                 build_s: float = 0.0):
        self.tick_base_s = tick_base_s
        self.sample_s = sample_s
        self.build_s = build_s
        self.t = 0.0
        # forward counters are tracked per attached engine: one SimClock
        # serves every engine behind a multi-model gateway, and engine A's
        # forwards must not mask engine B's idle ticks
        self._fwd_seen: dict[int, int] = {}

    def now(self) -> float:
        return self.t

    def attach(self, engine) -> "SimClock":
        """Wire the clock into an engine built with ``now_fn=clock.now``
        (and ``max_idle_sleep=0.0`` so idle waits spin through ticks).
        Attach every engine sharing the simulation to the same instance —
        simulated time is then one global axis their ticks interleave on."""
        engine.async_prefetch = False    # thread timing must not leak in

        def charge_forward(e, padded_rows):
            self.t += self.tick_base_s + self.sample_s * padded_rows

        engine.on_forward.append(charge_forward)

        def idle_advance(e):
            if e.n_forwards == self._fwd_seen.get(id(e), 0):  # no forward
                self.t += self.tick_base_s
            self._fwd_seen[id(e)] = e.n_forwards

        engine.on_tick_end.append(idle_advance)
        if self.build_s > 0:
            def charge_build(bank, seg):
                self.t += self.build_s

            engine.bank.on_build.append(charge_build)
        engine.batcher.cost.sample_s = self.sample_s
        # prime the switch estimate with what the clock actually charges
        # per cold build (tick_base_s when builds are free, as before)
        engine.batcher.cost.switch_s = self.build_s or self.tick_base_s
        return self
