"""Traffic subsystem: replayable traces, load generators, SLO metrics,
and the named scenario registry for the diffusion serving engine.

The measurement backbone for every traffic-level perf claim: a workload
is either a versioned JSONL trace (``trace``) or a seeded generator
(``generators``); ``metrics.MetricsCollector`` scores the run against a
``metrics.SLO``; ``scenarios`` binds all three under stable names the
launcher (``--scenario``) and bench iterate over.
"""
from repro.serving.traffic.trace import (FORMAT, VERSION, TraceRequest,
                                         TraceWriter, load_trace,
                                         save_trace, submit_trace,
                                         validate_trace)
from repro.serving.traffic.generators import (OPEN_LOOP, ClosedLoopGenerator,
                                              RequestMix, open_loop_trace)
from repro.serving.traffic.metrics import SLO, MetricsCollector, percentile
from repro.serving.traffic.scenarios import (SCENARIOS, Scenario,
                                             build_trace, get_scenario,
                                             list_scenarios, run_scenario)
from repro.serving.traffic.sim import SimClock
