"""Multi-host serving fleet: N engine replicas, pluggable placement.

See ``fleet.fleet`` for the router design (placement-at-arrival,
segment-affinity routing against per-replica weight banks, the shared
clock run() driver, and the 1-replica golden identity).
"""
from repro.serving.fleet.fleet import (PLACEMENTS, EngineReplica,
                                       FleetRouter)

__all__ = ["FleetRouter", "EngineReplica", "PLACEMENTS"]
