"""FleetRouter: N engine replicas behind one placement-routing surface.

The multi-host story: each replica is a full serving engine with its
*own* ``WeightBank``, so which replica a request lands on decides whether
its denoising trajectory runs against warm pre-merged segments (82 µs
hits) or stalls on cold TALoRA merge+pack builds (seconds). Placement is
therefore a first-class scheduling decision, not plumbing — the router
supports three policies (``PLACEMENTS``):

  * ``round_robin``     — the baseline: placement counter mod N.
  * ``least_loaded``    — minimize queue depth + in-flight padded rows
    (the same ``group_padded_rows`` bucket arithmetic the scheduler's
    cost model prices, so "load" means the rows the replica will
    actually compute).
  * ``segment_affinity`` — route to a replica whose bank already holds
    (``is_cached``) or is mid-build on (``is_building``) the request's
    *first* routing segment; ready beats mid-build, then ties break by
    load, then registration order. Universal miss falls back to
    least-loaded. This is the policy that multiplies the weight-bank
    cache-hit win: concentrating a segment's requests on its holder
    amortizes one build over many ticks instead of paying it per
    replica, and keeps LRU banks from thrashing.

Unlike the multi-model gateway (which forwards ``submit`` immediately —
its routing key is carried by the request), the router places requests
*at arrival time*: ``submit`` queues them fleet-side ordered by
``(arrival, gid)``, and the ``run`` driver places each one when the
fleet clock reaches its arrival. Placing at submit time would make
affinity a no-op — an open-loop trace submits its whole future up front
while every bank is still empty, so ``is_cached`` could never hit.

Gid/hook fan-in mirrors the gateway: requests get a fleet-wide gid,
each engine's hooks forward into the router's own hook lists after
annotating ``rs.gid`` / ``rs.replica``, so one shared
``MetricsCollector`` / ``TraceWriter`` / closed-loop generator attaches
to the router exactly like to a single engine, while per-replica
collectors power ``stats()``'s breakdowns.

Determinism: with one replica under ``round_robin`` the driver's
advance condition and tick sequence reduce to the bare engine's
(``engine.run``), so a 1-replica golden replay reproduces the
standalone golden digest bit-for-bit (the "fleet adds zero behavior" CI
assertion). Multi-replica runs are deterministic under a shared
``VirtualClock`` or per-replica ``SimClock``s — replicas tick in
registration order, placement is pure arithmetic over replica state.

Clocks: pass a shared ``VirtualClock`` (replay), a shared-origin
``now_fn`` (wall), or neither — in which case the fleet clock is the
*minimum* over replica clocks, the per-replica-``SimClock`` topology
where each replica charges compute on its own parallel service axis
(that is what makes replica-count sweeps show actual scaling; a shared
sim axis would serialize the fleet). A request is placed once every
replica's clock has reached its arrival — the lagging replica still has
simulated work to run before global time gets there.
"""
from __future__ import annotations

import bisect
import dataclasses
import time
from typing import Callable

from repro.diffusion.schedule import sample_timesteps
from repro.serving.obs import NULL_OBS, Observability
from repro.serving.scheduler import group_padded_rows
from repro.serving.traffic.metrics import MetricsCollector

PLACEMENTS = ("round_robin", "least_loaded", "segment_affinity")


class EngineReplica:
    """One fleet member: an engine + its own bank, with the live load and
    bank-contents introspection placement policies read."""

    def __init__(self, name: str, engine):
        self.name = name
        self.engine = engine
        engine.replica = name          # obs: {replica=...} labels + track
        self.gid_of: dict[int, int] = {}   # engine rid -> fleet gid
        self.collector = MetricsCollector()
        self.n_placed = 0

    @property
    def bank(self):
        return self.engine.bank

    @property
    def batcher(self):
        return self.engine.batcher

    @property
    def queue_depth(self) -> int:
        """Arrived-or-future requests placed here but not yet admitted."""
        return len(self.batcher.pending)

    @property
    def inflight_rows(self) -> int:
        """Padded rows the in-flight set costs per tick (per-partition
        power-of-two buckets — the engine's real compute unit)."""
        return group_padded_rows(self.batcher.inflight)

    @property
    def load(self) -> int:
        return self.queue_depth + self.inflight_rows

    def holds(self, seg: int) -> str | None:
        """'cached' (warm, zero-stall), 'building' (mid merge+pack — a
        fetch would join), or None."""
        if self.bank.is_cached(seg):
            return "cached"
        if self.bank.is_building(seg):
            return "building"
        return None

    @property
    def live(self) -> bool:
        return bool(self.batcher.pending or self.batcher.inflight)

    def describe(self) -> dict:
        with self.bank._lock:   # snapshot, not point-queries per segment
            cached = sorted(self.bank._cache)
            building = sorted(self.bank._building)
        return {"name": self.name, "queue_depth": self.queue_depth,
                "inflight_rows": self.inflight_rows, "load": self.load,
                "placed": self.n_placed,
                "cached_segments": cached, "building_segments": building}


@dataclasses.dataclass
class _Queued:
    """A submitted request waiting for its arrival time to be placed."""

    gid: int
    arrival: float
    kw: dict            # the engine.submit signature, verbatim
    seg0: int | None    # first routing segment (None when unknowable)


class FleetRouter:
    """Load-balancing router over N ``EngineReplica``s."""

    def __init__(self, *, placement: str = "round_robin", clock=None,
                 now_fn: Callable[[], float] | None = None,
                 max_idle_sleep: float = 0.25,
                 obs: Observability | None = None):
        if placement not in PLACEMENTS:
            raise ValueError(f"placement {placement!r} not in {PLACEMENTS}")
        self.placement = placement
        self.replicas: list[EngineReplica] = []
        if clock is not None:
            self._now_fn = clock.now
            self._advance = clock.advance_to
        else:
            self._now_fn = now_fn      # None -> min over replica clocks
            self._advance = None
        self.max_idle_sleep = max_idle_sleep
        self.obs = obs or NULL_OBS
        self._next_gid = 0
        self._pending_submit: tuple[str, int] | None = None
        self._unplaced: list[_Queued] = []   # sorted by (arrival, gid)
        self.route: dict[int, tuple[str, int]] = {}  # gid -> (replica, rid)
        self.results: dict[int, object] = {}         # gid -> RequestState
        self.n_idle_sleeps = 0
        self.reason_counts: dict[str, int] = {}
        # router-surface hooks, same contract as an engine's: receive the
        # per-engine RequestState annotated with ``rs.replica``/``rs.gid``
        self.on_submit: list[Callable] = []
        self.on_complete: list[Callable] = []
        self.on_expire: list[Callable] = []
        self.on_tick_end: list[Callable] = []

    # -- registration --------------------------------------------------------

    def add_replica(self, engine, name: str | None = None) -> "FleetRouter":
        """Host ``engine`` as the next replica. It must be idle and built
        on the fleet's clock topology (shared VirtualClock / shared-origin
        now_fn / its own SimClock)."""
        name = name if name is not None else f"r{len(self.replicas)}"
        if any(r.name == name for r in self.replicas):
            raise ValueError(f"replica {name!r} already registered")
        if engine.batcher.pending or engine.batcher.inflight:
            raise ValueError(f"engine for replica {name!r} already has "
                             "requests")
        rep = EngineReplica(name, engine)
        rep.collector.attach(engine)

        def fwd_submit(rs, _rep=rep, _name=name):
            # runs inside engine.submit during placement: the router
            # stashed (name, gid) just before calling it. Direct
            # engine.submit calls keep rs un-annotated.
            if self._pending_submit is not None:
                pname, gid = self._pending_submit
                if pname == _name:
                    rs.replica = _name
                    rs.gid = gid
                    _rep.gid_of[rs.req.rid] = gid
            for cb in self.on_submit:
                cb(rs)

        def fwd_done(rs, _rep=rep, expire=False):
            gid = _rep.gid_of.get(rs.req.rid)
            if gid is not None:
                self.results[gid] = rs
            for cb in (self.on_expire if expire else self.on_complete):
                cb(rs)

        engine.on_submit.append(fwd_submit)
        engine.on_complete.append(lambda rs: fwd_done(rs))
        engine.on_expire.append(lambda rs: fwd_done(rs, expire=True))
        engine.on_tick_end.append(
            lambda e: [cb(e) for cb in self.on_tick_end])
        self.replicas.append(rep)
        return self

    def replica(self, name: str) -> EngineReplica:
        for r in self.replicas:
            if r.name == name:
                return r
        raise KeyError(f"unknown replica {name!r} "
                       f"(fleet: {[r.name for r in self.replicas]})")

    # -- clock ---------------------------------------------------------------

    def now(self) -> float:
        """The fleet clock. With per-replica SimClocks this is the
        *minimum* replica time: a request arriving at global time t is
        placed only once every replica's axis has reached t."""
        if self._now_fn is not None:
            return self._now_fn()
        if not self.replicas:
            return 0.0
        return min(r.engine.now() for r in self.replicas)

    # -- request lifecycle ---------------------------------------------------

    def submit(self, *, model: str | None = None, **kw) -> int:
        """Queue one request for placement at its arrival time; returns
        its fleet-wide gid. ``kw`` is the engine submit signature."""
        if model is not None:
            raise ValueError("fleet replicas serve one model; route "
                             "multi-model traffic through the gateway")
        if not self.replicas:
            raise RuntimeError("fleet has no replicas registered")
        gid = self._next_gid
        self._next_gid += 1
        q = _Queued(gid, float(kw.get("arrival", 0.0)), kw,
                    self._first_segment(kw))
        bisect.insort(self._unplaced, q, key=lambda x: (x.arrival, x.gid))
        return gid

    def _first_segment(self, kw: dict) -> int | None:
        """The routing segment of the first timestep this request's
        sampler will evaluate. Every step sampler starts from the top of
        its subsequence (``sample_timesteps(T, steps)[0]``), and routing
        segmentation is identical across replicas, so replica 0's bank
        answers for the whole fleet."""
        bank = self.replicas[0].bank
        try:
            t0 = int(sample_timesteps(bank.T, int(kw.get("steps", 20)))[0])
            return bank.segment_of(t0)
        except Exception:
            return None    # stub banks without a schedule: affinity
        #                    degrades to least-loaded for this request

    def pop_result(self, gid: int):
        """Hand a finished request over and drop every per-request
        bookkeeping entry (results, gid route, replica rid->gid map) —
        the same leak the gateway's pop_result had to close."""
        rs = self.results.pop(gid)
        name, rid = self.route.pop(gid)
        rep = self.replica(name)
        rep.engine.results.pop(rid, None)
        rep.gid_of.pop(rid, None)
        return rs

    # -- placement -----------------------------------------------------------

    def _least_loaded(self) -> int:
        return min(range(len(self.replicas)),
                   key=lambda i: (self.replicas[i].load, i))

    def _choose(self, q: _Queued) -> tuple[int, str]:
        """(replica index, reason) under the configured policy."""
        if self.placement == "round_robin":
            return self._next_gid_rr(), "rr"
        if self.placement == "least_loaded":
            return self._least_loaded(), "least_loaded"
        # segment_affinity
        if q.seg0 is not None:
            ranked = []
            for i, r in enumerate(self.replicas):
                h = r.holds(q.seg0)
                if h is not None:
                    # ready beats mid-build; then lightest; then index
                    ranked.append((h != "cached", r.load, i))
            if ranked:
                cold, _, i = min(ranked)
                return i, ("affinity_building" if cold else "affinity_hit")
        return self._least_loaded(), "affinity_miss"

    def _next_gid_rr(self) -> int:
        i = getattr(self, "_rr", 0)
        self._rr = i + 1
        return i % len(self.replicas)

    def _place(self, q: _Queued) -> None:
        i, reason = self._choose(q)
        rep = self.replicas[i]
        self._pending_submit = (rep.name, q.gid)
        try:
            rid = rep.engine.submit(**q.kw)
        finally:
            self._pending_submit = None
        rep.gid_of[rid] = q.gid
        rep.n_placed += 1
        self.route[q.gid] = (rep.name, rid)
        self.reason_counts[reason] = self.reason_counts.get(reason, 0) + 1
        if self.obs.enabled:
            self.obs.tracer.set_track("router")
            self.obs.tracer.instant(
                "route", cat="fleet",
                args={"gid": q.gid, "replica": rep.name,
                      "placement": self.placement, "reason": reason,
                      "seg0": q.seg0, "load": rep.load - 1})

    def _place_due(self, now: float) -> None:
        while self._unplaced and self._unplaced[0].arrival <= now:
            self._place(self._unplaced.pop(0))

    # -- driver --------------------------------------------------------------

    def run(self, *, max_idle_sleep: float | None = None) -> dict:
        """Place + tick until every submitted request finished or expired;
        returns ``results`` keyed by gid.

        Generalizes the single-engine driver: under a virtual clock,
        advance to the earliest event any replica could act on (its own
        next placed arrival with a free slot, or the head unplaced
        arrival when any slot is free anywhere) before ticking; replicas
        tick in registration order. With one replica this reduces
        exactly to ``engine.run``'s advance condition — the golden
        identity. Without an advancing clock, unplaced work also ticks
        otherwise-idle replicas so per-replica SimClocks keep moving
        toward the next arrival.
        """
        cap = self.max_idle_sleep if max_idle_sleep is None else max_idle_sleep
        if not self.replicas:
            return self.results

        def has_slot(r: EngineReplica) -> bool:
            return len(r.batcher.inflight) < r.batcher.max_batch

        while self._unplaced or any(r.live for r in self.replicas):
            if self._advance is not None:
                nxts = [r.batcher.next_arrival() for r in self.replicas
                        if r.batcher.pending and has_slot(r)]
                if self._unplaced and any(has_slot(r)
                                          for r in self.replicas):
                    nxts.append(self._unplaced[0].arrival)
                if nxts:
                    nxt = min(nxts)
                    if nxt > self.now():
                        self._advance(nxt)
                        self.n_idle_sleeps += 1
            self._place_due(self.now())
            for r in self.replicas:
                # unplaced work ticks idle replicas too when no advancing
                # clock exists: their SimClocks must idle forward for the
                # fleet min-clock to reach the next arrival
                if r.live or (self._advance is None and self._unplaced):
                    r.engine.tick()
            if (self._advance is None and cap > 0
                    and all(not r.batcher.inflight for r in self.replicas)
                    and (self._unplaced
                         or any(r.batcher.pending for r in self.replicas))):
                nxts = [r.batcher.next_arrival() for r in self.replicas
                        if r.batcher.pending]
                if self._unplaced:
                    nxts.append(self._unplaced[0].arrival)
                wait = min(nxts) - self.now()
                if wait > 0:
                    time.sleep(min(wait, cap))
                    self.n_idle_sleeps += 1
        for r in self.replicas:
            r.engine.bank.drain()
        return self.results

    # -- metrics -------------------------------------------------------------

    def stats(self) -> dict:
        """Aggregate + per-replica view: each replica's full engine
        stats (bank counters included) and traffic summary, fleet-wide
        totals, pooled bank hit rate, and the placement-decision
        histogram."""
        per = {}
        for r in self.replicas:
            per[r.name] = {"engine": r.engine.stats(),
                           "summary": r.collector.summary(),
                           "placed": r.n_placed,
                           "load": r.load}
        hits = sum(r.bank.hits for r in self.replicas)
        misses = sum(r.bank.misses for r in self.replicas)
        agg = {
            "replicas": [r.name for r in self.replicas],
            "placement": self.placement,
            "requests": sum(p["engine"]["requests"] for p in per.values()),
            "expired": sum(p["engine"]["expired"] for p in per.values()),
            "ticks": sum(p["engine"]["ticks"] for p in per.values()),
            "forwards": sum(p["engine"]["forwards"] for p in per.values()),
            "idle_sleeps": self.n_idle_sleeps,
            "bank_hits": hits,
            "bank_misses": misses,
            "bank_hit_rate": hits / (hits + misses) if hits + misses else 0.0,
            "bank_builds": sum(r.bank.builds for r in self.replicas),
            "bank_evictions": sum(r.bank.evictions for r in self.replicas),
            "placements": {r.name: r.n_placed for r in self.replicas},
            "placement_reasons": dict(sorted(self.reason_counts.items())),
        }
        return {"aggregate": agg, "per_replica": per}
