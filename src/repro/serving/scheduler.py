"""Continuous-batching scheduler for diffusion generation requests.

Policy (documented for the README/tests):

  * **Admission** — FIFO by (arrival, rid). A request is admissible once
    its arrival time has passed and an in-flight slot (``max_batch``) is
    free; requests admit/retire *mid-flight*, the batch never drains.
  * **Grouping** — in-flight requests are grouped by the weight-bank
    segment of the timestep their sampler needs next. Requests inside a
    segment batch into one model forward even at different timesteps
    (``t`` is per-sample in the UNet).
  * **Selection** — each tick advances one segment group: the largest
    (ties: the group containing the earliest-admitted request), except
    that a request that has not advanced for ``starvation_ticks`` ticks
    promotes its own group (no segment starves under skewed traffic).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp

from repro.diffusion.samplers import SamplerState


@dataclasses.dataclass(frozen=True)
class GenRequest:
    """One user's generation job (per-request steps/eta/seed/guidance)."""

    rid: int
    steps: int = 20
    eta: float = 0.0
    seed: int = 0
    sampler: str = "ddim"
    y: int | None = None            # class label (class-conditional models)
    guidance_scale: float = 0.0     # > 0 pairs a cond + uncond eval (CFG)
    arrival: float = 0.0            # seconds from trace start


@dataclasses.dataclass
class RequestState:
    """Scheduler-side lifecycle wrapper around a SamplerState."""

    req: GenRequest
    state: SamplerState
    submitted_at: float = 0.0
    admitted_at: float | None = None
    finished_at: float | None = None
    last_advance_tick: int = -1
    n_evals: int = 0
    x0: jnp.ndarray | None = None

    @property
    def latency(self) -> float | None:
        """Service latency from *arrival* (a trace request submitted ahead
        of its arrival time hasn't waited while merely scheduled)."""
        if self.finished_at is None:
            return None
        return self.finished_at - max(self.submitted_at, self.req.arrival)

    @property
    def queue_wait(self) -> float | None:
        if self.admitted_at is None:
            return None
        return self.admitted_at - max(self.submitted_at, self.req.arrival)


class ContinuousBatcher:
    def __init__(self, max_batch: int = 8, starvation_ticks: int = 4):
        assert max_batch >= 1
        self.max_batch = max_batch
        self.starvation_ticks = max(1, starvation_ticks)
        self.pending: list[RequestState] = []
        self.inflight: list[RequestState] = []

    def submit(self, rs: RequestState) -> None:
        self.pending.append(rs)
        self.pending.sort(key=lambda r: (r.req.arrival, r.req.rid))

    def next_arrival(self) -> float | None:
        return self.pending[0].req.arrival if self.pending else None

    def admit(self, now: float, tick: int) -> list[RequestState]:
        admitted = []
        while (self.pending and len(self.inflight) < self.max_batch
               and self.pending[0].req.arrival <= now):
            rs = self.pending.pop(0)
            rs.admitted_at = now
            rs.last_advance_tick = tick  # freshly admitted, not starved
            self.inflight.append(rs)
            admitted.append(rs)
        return admitted

    def groups(self, seg_fn: Callable[[RequestState], int]
               ) -> dict[int, list[RequestState]]:
        out: dict[int, list[RequestState]] = {}
        for rs in self.inflight:
            out.setdefault(seg_fn(rs), []).append(rs)
        return out

    def select(self, groups: dict[int, list[RequestState]], tick: int
               ) -> tuple[int, list[RequestState]]:
        assert groups
        starved = [rs for rs in self.inflight
                   if tick - rs.last_advance_tick >= self.starvation_ticks]
        if starved:
            oldest = min(starved, key=lambda r: (r.last_advance_tick,
                                                 r.req.rid))
            for seg, members in groups.items():
                if oldest in members:
                    return seg, members
        # largest group; ties -> the group holding the smallest rid
        def rank(item):
            seg, members = item
            return (-len(members), min(r.req.rid for r in members))

        seg, members = min(groups.items(), key=rank)
        return seg, members

    def retire(self, rs: RequestState) -> None:
        self.inflight.remove(rs)
