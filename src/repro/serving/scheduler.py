"""Continuous-batching scheduler for diffusion generation requests.

Policy (documented for the README/tests):

  * **Admission** — by (priority desc, arrival, rid); plain FIFO when
    every request carries the default priority 0. A request is
    admissible once its arrival time has passed and an in-flight slot
    (``max_batch``) is free; requests admit/retire *mid-flight*, the
    batch never drains. A due request whose ``deadline`` has already
    passed is *expired* instead of admitted (it could not possibly meet
    its SLO) — admitted requests always run to completion and are scored
    against the deadline by the metrics collector instead. Admission is
    identical under both selection policies.
  * **Grouping** — in-flight requests are grouped by the weight-bank
    segment of the timestep their sampler needs next. Requests inside a
    segment batch into one model forward even at different timesteps
    (``t`` is per-sample in the UNet).
  * **Selection** — one segment group advances per tick.

    ``policy="fifo"`` (the PR-2 baseline): the largest group wins
    (ties: the group holding the smallest rid).

    ``policy="slo"``: slack-aware. Each group scores
    ``min-slack + switch-penalty`` and the *lowest* score runs, where a
    member's slack is ``deadline - now - remaining_evals * eval_cost``
    (``CostModel`` EWMA estimates; deadline-free members contribute the
    ``horizon_s`` ceiling) and the switch penalty is the estimated
    segment build time — zero when the group is the batcher's
    ``current_seg`` or the weight bank reports it warm. With no deadline
    pressure every group sits at the horizon, so the penalty makes the
    scheduler *stay on the current bank segment* (segment switches are
    the expensive event under TALoRA routing); at equal score the larger
    group wins, recovering throughput-first behavior.

    Under either policy a request that has not advanced for
    ``starvation_ticks`` ticks promotes its own group first (no segment
    starves under skewed traffic or deadline pressure).
  * **Preemption** (``slo`` only) — a selected group may *split*: when a
    tight-slack member would miss its deadline at the full group's
    padded-bucket cost but meets it at a smaller bucket, only the
    most-urgent members that fill the smaller bucket run this tick; the
    rest are deferred in place (they stay in flight, aging toward the
    starvation backstop). ``preemptions`` counts deferred members;
    ``deadline_saves`` counts split-triggering requests that then
    retired within their deadline.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Callable

import jax.numpy as jnp

from repro.diffusion.samplers import SamplerState
from repro.serving.obs import NULL_OBS

POLICIES = ("fifo", "slo")


def bucket_of(n: int) -> int:
    """Smallest power of two >= n — the engine pads partition batches to
    these buckets, so scheduling cost estimates must use them too."""
    b = 1
    while b < n:
        b *= 2
    return b


def remaining_evals(rs: "RequestState") -> int:
    """Model-forward evaluations a request still needs (upper estimate:
    DPM-Solver-2 runs ~2 evals per remaining step pair)."""
    st = rs.state
    if st.done:
        return 0
    left = st.steps_left
    return 2 * left if st.kind == "dpm_solver2" else left


def group_padded_rows(members: list["RequestState"]) -> int:
    """Padded rows a group's tick actually runs. The engine partitions
    eval items by class conditioning — a CFG-guided request contributes
    one row to *each* partition (uncond + cond), a plain one a single
    row to its own — and pads every partition to its own power-of-two
    bucket, so the cost model must price the sum of per-partition
    buckets, not one joint bucket."""
    n_none = n_y = 0
    for rs in members:
        if rs.req.guidance_scale > 0:
            n_none += 1
            n_y += 1
        elif rs.req.y is None:
            n_none += 1
        else:
            n_y += 1
    return ((bucket_of(n_none) if n_none else 0)
            + (bucket_of(n_y) if n_y else 0))


@dataclasses.dataclass
class CostModel:
    """EWMA service-time estimates (seconds) feeding slack computations.

    ``sample_s`` is one sample's share of one batched forward at bucket
    granularity (a group of n costs ``sample_s * bucket_of(n)``);
    ``switch_s`` is one cold weight-bank segment build (merge + pack).
    Zero-duration observations are ignored — under a ``VirtualClock``
    compute takes no clock time, so the model stays at its seed values
    and slack degrades to pure EDF (deterministic replay preserved).
    """

    sample_s: float = 0.0
    switch_s: float = 0.0
    alpha: float = 0.25

    def _ewma(self, old: float, new: float) -> float:
        return new if old == 0.0 else (1 - self.alpha) * old + self.alpha * new

    def observe_eval(self, dt: float, padded_rows: int) -> None:
        """Record one tick's compute over the *padded* rows it actually
        ran (sum of per-partition buckets — the engine passes this), so
        sample_s matches what slack() prices."""
        if dt > 0 and padded_rows > 0:
            self.sample_s = self._ewma(self.sample_s, dt / padded_rows)

    def observe_switch(self, dt: float) -> None:
        if dt > 0:
            self.switch_s = self._ewma(self.switch_s, dt)

    def eval_s(self, batch_n: int) -> float:
        """Estimated cost of one forward over a batch of ``batch_n``."""
        return self.sample_s * bucket_of(max(batch_n, 1))


@dataclasses.dataclass(frozen=True)
class GenRequest:
    """One user's generation job (per-request steps/eta/seed/guidance)."""

    rid: int
    steps: int = 20
    eta: float = 0.0
    seed: int = 0
    sampler: str = "ddim"
    y: int | None = None            # class label (class-conditional models)
    guidance_scale: float = 0.0     # > 0 pairs a cond + uncond eval (CFG)
    arrival: float = 0.0            # seconds from trace start
    deadline: float | None = None   # absolute SLO cutoff, seconds
    priority: int = 0               # higher admits first under contention
    user: int | None = None         # closed-loop session id (trace metadata)
    parent: int | None = None       # rid whose completion triggered this one
    think_s: float | None = None    # think time preceding this request


@dataclasses.dataclass
class RequestState:
    """Scheduler-side lifecycle wrapper around a SamplerState."""

    req: GenRequest
    state: SamplerState
    submitted_at: float = 0.0
    admitted_at: float | None = None
    finished_at: float | None = None
    last_advance_tick: int = -1
    n_evals: int = 0
    x0: jnp.ndarray | None = None
    expired: bool = False           # refused admission past its deadline

    @property
    def latency(self) -> float | None:
        """Service latency from *arrival* (a trace request submitted ahead
        of its arrival time hasn't waited while merely scheduled). None
        until completion — and None forever for expired requests, which
        never ran: folding their refusal time into completion percentiles
        would poison p95/p99 (see ``expired_after_s``)."""
        if self.finished_at is None or self.expired:
            return None
        return self.finished_at - max(self.submitted_at, self.req.arrival)

    @property
    def expired_after_s(self) -> float | None:
        """How long past arrival an expired request waited before the
        scheduler refused it; None for non-expired requests."""
        if not self.expired or self.finished_at is None:
            return None
        return self.finished_at - max(self.submitted_at, self.req.arrival)

    @property
    def queue_wait(self) -> float | None:
        if self.admitted_at is None:
            return None
        return self.admitted_at - max(self.submitted_at, self.req.arrival)


class ContinuousBatcher:
    def __init__(self, max_batch: int = 8, starvation_ticks: int = 4,
                 policy: str = "fifo", horizon_s: float = 60.0):
        assert max_batch >= 1
        assert policy in POLICIES, f"policy {policy!r} not in {POLICIES}"
        self.max_batch = max_batch
        self.starvation_ticks = max(1, starvation_ticks)
        self.policy = policy
        self.horizon_s = horizon_s
        self.cost = CostModel()
        self.current_seg: int | None = None     # segment served last tick
        self.segment_warm: Callable[[int], bool] | None = None
        self.segment_building: Callable[[int], bool] | None = None
        self.obs = NULL_OBS                     # engine propagates its obs
        self.preemptions = 0                    # members deferred by splits
        self.deadline_saves = 0                 # split-urgent reqs that met
        self._save_watch: set[int] = set()      # rids whose split is pending
        self.pending: list[RequestState] = []
        self.inflight: list[RequestState] = []

    def slack(self, rs: RequestState, now: float, padded_rows: int
              ) -> float:
        """Seconds to spare if every remaining eval runs in a tick that
        computes ``padded_rows`` rows (``group_padded_rows`` of the
        request's group); ``horizon_s`` for deadline-free requests."""
        if rs.req.deadline is None:
            return self.horizon_s
        return (rs.req.deadline - now
                - remaining_evals(rs) * self.cost.sample_s * padded_rows)

    def submit(self, rs: RequestState) -> None:
        # pending must stay sorted by (arrival, rid) — admit() relies on
        # the due prefix. insort is O(n) per submit; re-sorting the whole
        # list each time was O(n^2 log n) over a bulk trace ingest.
        bisect.insort(self.pending, rs,
                      key=lambda r: (r.req.arrival, r.req.rid))

    def next_arrival(self) -> float | None:
        return self.pending[0].req.arrival if self.pending else None

    def admit(self, now: float, tick: int
              ) -> tuple[list[RequestState], list[RequestState]]:
        """Admit due requests into free slots; returns (admitted, expired).

        Due requests whose deadline has already passed are expired
        (removed from pending, never run) regardless of slot pressure;
        the rest admit by (priority desc, arrival, rid).
        """
        # pending stays sorted by (arrival, rid): the due requests are a
        # prefix, so a tick with nothing due costs O(1), not O(pending)
        n_due = 0
        while (n_due < len(self.pending)
               and self.pending[n_due].req.arrival <= now):
            n_due += 1
        if not n_due:
            return [], []
        due = self.pending[:n_due]
        expired = []
        for rs in due:
            if rs.req.deadline is not None and now > rs.req.deadline:
                rs.expired = True
                expired.append(rs)
        admitted = []
        for rs in sorted((rs for rs in due if not rs.expired),
                         key=lambda r: (-r.req.priority, r.req.arrival,
                                        r.req.rid)):
            if len(self.inflight) >= self.max_batch:
                break
            rs.admitted_at = now
            rs.last_advance_tick = tick  # freshly admitted, not starved
            self.inflight.append(rs)
            admitted.append(rs)
        taken = {id(rs) for rs in admitted} | {id(rs) for rs in expired}
        self.pending[:n_due] = [rs for rs in due if id(rs) not in taken]
        return admitted, expired

    def groups(self, seg_fn: Callable[[RequestState], int]
               ) -> dict[int, list[RequestState]]:
        out: dict[int, list[RequestState]] = {}
        for rs in self.inflight:
            out.setdefault(seg_fn(rs), []).append(rs)
        return out

    def select(self, groups: dict[int, list[RequestState]], tick: int,
               now: float = 0.0) -> tuple[int, list[RequestState]]:
        """Pick the segment group (possibly a split subset) to advance.

        The starvation backstop runs first under both policies and always
        serves the starved request's *full* group — a split can never
        defer a request the backstop just promoted.
        """
        assert groups
        starved = [rs for rs in self.inflight
                   if tick - rs.last_advance_tick >= self.starvation_ticks]
        if starved:
            oldest = min(starved, key=lambda r: (r.last_advance_tick,
                                                 r.req.rid))
            for seg, members in groups.items():
                if oldest in members:
                    if self.obs.enabled:
                        self.obs.tracer.instant(
                            "select", cat="sched",
                            args={"policy": self.policy, "seg": seg,
                                  "n": len(members), "starved": True,
                                  "starved_rid": oldest.req.rid})
                    return seg, members
        if self.policy == "slo":
            return self._select_slo(groups, tick, now)
        # fifo: largest group; ties -> the group holding the smallest rid
        def rank(item):
            seg, members = item
            return (-len(members), min(r.req.rid for r in members))

        seg, members = min(groups.items(), key=rank)
        if self.obs.enabled:
            self.obs.tracer.instant(
                "select", cat="sched",
                args={"policy": "fifo", "seg": seg, "n": len(members)})
        return seg, members

    # -- slo policy ----------------------------------------------------------

    def _switch_penalty(self, seg: int) -> float:
        if seg == self.current_seg:
            return 0.0
        if self.segment_warm is not None and self.segment_warm(seg):
            return 0.0
        if self.segment_building is not None and self.segment_building(seg):
            # a fetch would join the in-progress build mid-way: expected
            # remaining stall ~ half a cold build, not zero (pricing it
            # free would switch onto a barely-started build and stall)
            return 0.5 * self.cost.switch_s
        return self.cost.switch_s

    def _group_pressure(self, seg: int, members: list[RequestState],
                        now: float) -> tuple[float, float]:
        """(min-slack, switch-penalty) for one group — the two components
        the slo score adds. Members whose deadline has already passed are
        guaranteed misses: they exert no urgency (an arbitrarily negative
        slack would otherwise monopolize selection and starve
        still-savable groups until the backstop)."""
        n = group_padded_rows(members)
        sl = min((self.slack(rs, now, n) for rs in members
                  if rs.req.deadline is not None
                  and rs.req.deadline >= now),
                 default=self.horizon_s)
        return min(sl, self.horizon_s), self._switch_penalty(seg)

    def _select_slo(self, groups: dict[int, list[RequestState]], tick: int,
                    now: float) -> tuple[int, list[RequestState]]:
        def score(item):
            seg, members = item
            sl, penalty = self._group_pressure(seg, members, now)
            return (sl + penalty, -len(members),
                    min(r.req.rid for r in members))

        seg, members = min(groups.items(), key=score)
        if self.obs.enabled:
            sl, penalty = self._group_pressure(seg, members, now)
            self.obs.tracer.instant(
                "select", cat="sched",
                args={"policy": "slo", "seg": seg, "n": len(members),
                      "slack_s": sl, "switch_penalty_s": penalty})
        return seg, self._maybe_split(members, tick, now)

    def _maybe_split(self, members: list[RequestState], tick: int,
                     now: float) -> list[RequestState]:
        """Preempt: serve only the urgent prefix of a group when the full
        group's padded bucket would make a tight-slack member miss its
        deadline that a smaller bucket still meets (strict inequality:
        slack exactly 0 at the full bucket is a meet, not a miss)."""
        if len(members) < 2 or self.cost.sample_s <= 0:
            return members
        full_rows = group_padded_rows(members)
        # already-missed members (deadline < now) are guaranteed misses:
        # they are not worth splitting for AND must not inflate the
        # small bucket (a doomed groupmate would otherwise cancel a
        # split that saves a still-reachable request) — consistent with
        # the selection score's exclusion above
        tight = [rs for rs in members
                 if rs.req.deadline is not None and rs.req.deadline >= now
                 and self.slack(rs, now, full_rows) < 0]
        if not tight or len(tight) == len(members):
            return members
        small_rows = group_padded_rows(tight)
        if small_rows >= full_rows:
            return members
        # the split must actually save someone at the smaller bucket
        saved = [rs for rs in tight if self.slack(rs, now, small_rows) >= 0]
        if not saved:
            return members
        # every tight member runs (the tight prefix's padded rows are
        # exactly small_rows by construction — a merely-low-slack
        # non-tight member must never displace the request the split
        # exists to save); spare bucket capacity fills with the
        # most-urgent remainder, where a guaranteed-miss member again
        # carries horizon urgency (its raw slack is hugely negative and
        # would steal the spare slot from a still-savable groupmate)
        tight_ids = {id(rs) for rs in tight}

        def fill_slack(rs):
            if rs.req.deadline is not None and rs.req.deadline < now:
                return self.horizon_s
            return self.slack(rs, now, small_rows)

        by_urgency = sorted(
            members, key=lambda rs: (id(rs) not in tight_ids,
                                     fill_slack(rs), rs.req.rid))
        run, deferred = [], []
        for rs in by_urgency:
            if group_padded_rows(run + [rs]) <= small_rows:
                run.append(rs)
            else:
                deferred.append(rs)
        # never defer a member about to trip the starvation backstop
        if any(tick - rs.last_advance_tick >= self.starvation_ticks - 1
               for rs in deferred):
            return members
        self.preemptions += len(deferred)
        self._save_watch.update(rs.req.rid for rs in saved)
        if self.obs.enabled:
            self.obs.tracer.instant(
                "preempt", cat="sched",
                args={"run": [rs.req.rid for rs in run],
                      "deferred": [rs.req.rid for rs in deferred],
                      "saved": [rs.req.rid for rs in saved],
                      "full_rows": full_rows, "small_rows": small_rows})
        return run

    def retire(self, rs: RequestState) -> None:
        self.inflight.remove(rs)
        if rs.req.rid in self._save_watch:
            self._save_watch.discard(rs.req.rid)
            # watched rids always carry a deadline (saved ⊆ tight)
            if (rs.finished_at is not None
                    and rs.finished_at <= rs.req.deadline):
                self.deadline_saves += 1
