"""Continuous-batching scheduler for diffusion generation requests.

Policy (documented for the README/tests):

  * **Admission** — by (priority desc, arrival, rid); plain FIFO when
    every request carries the default priority 0. A request is
    admissible once its arrival time has passed and an in-flight slot
    (``max_batch``) is free; requests admit/retire *mid-flight*, the
    batch never drains. A due request whose ``deadline`` has already
    passed is *expired* instead of admitted (it could not possibly meet
    its SLO) — admitted requests always run to completion and are scored
    against the deadline by the metrics collector instead.
  * **Grouping** — in-flight requests are grouped by the weight-bank
    segment of the timestep their sampler needs next. Requests inside a
    segment batch into one model forward even at different timesteps
    (``t`` is per-sample in the UNet).
  * **Selection** — each tick advances one segment group: the largest
    (ties: the group containing the earliest-admitted request), except
    that a request that has not advanced for ``starvation_ticks`` ticks
    promotes its own group (no segment starves under skewed traffic).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp

from repro.diffusion.samplers import SamplerState


@dataclasses.dataclass(frozen=True)
class GenRequest:
    """One user's generation job (per-request steps/eta/seed/guidance)."""

    rid: int
    steps: int = 20
    eta: float = 0.0
    seed: int = 0
    sampler: str = "ddim"
    y: int | None = None            # class label (class-conditional models)
    guidance_scale: float = 0.0     # > 0 pairs a cond + uncond eval (CFG)
    arrival: float = 0.0            # seconds from trace start
    deadline: float | None = None   # absolute SLO cutoff, seconds
    priority: int = 0               # higher admits first under contention
    user: int | None = None         # closed-loop session id (trace metadata)
    parent: int | None = None       # rid whose completion triggered this one
    think_s: float | None = None    # think time preceding this request


@dataclasses.dataclass
class RequestState:
    """Scheduler-side lifecycle wrapper around a SamplerState."""

    req: GenRequest
    state: SamplerState
    submitted_at: float = 0.0
    admitted_at: float | None = None
    finished_at: float | None = None
    last_advance_tick: int = -1
    n_evals: int = 0
    x0: jnp.ndarray | None = None
    expired: bool = False           # refused admission past its deadline

    @property
    def latency(self) -> float | None:
        """Service latency from *arrival* (a trace request submitted ahead
        of its arrival time hasn't waited while merely scheduled)."""
        if self.finished_at is None:
            return None
        return self.finished_at - max(self.submitted_at, self.req.arrival)

    @property
    def queue_wait(self) -> float | None:
        if self.admitted_at is None:
            return None
        return self.admitted_at - max(self.submitted_at, self.req.arrival)


class ContinuousBatcher:
    def __init__(self, max_batch: int = 8, starvation_ticks: int = 4):
        assert max_batch >= 1
        self.max_batch = max_batch
        self.starvation_ticks = max(1, starvation_ticks)
        self.pending: list[RequestState] = []
        self.inflight: list[RequestState] = []

    def submit(self, rs: RequestState) -> None:
        self.pending.append(rs)
        self.pending.sort(key=lambda r: (r.req.arrival, r.req.rid))

    def next_arrival(self) -> float | None:
        return self.pending[0].req.arrival if self.pending else None

    def admit(self, now: float, tick: int
              ) -> tuple[list[RequestState], list[RequestState]]:
        """Admit due requests into free slots; returns (admitted, expired).

        Due requests whose deadline has already passed are expired
        (removed from pending, never run) regardless of slot pressure;
        the rest admit by (priority desc, arrival, rid).
        """
        # pending stays sorted by (arrival, rid): the due requests are a
        # prefix, so a tick with nothing due costs O(1), not O(pending)
        n_due = 0
        while (n_due < len(self.pending)
               and self.pending[n_due].req.arrival <= now):
            n_due += 1
        if not n_due:
            return [], []
        due = self.pending[:n_due]
        expired = []
        for rs in due:
            if rs.req.deadline is not None and now > rs.req.deadline:
                rs.expired = True
                expired.append(rs)
        admitted = []
        for rs in sorted((rs for rs in due if not rs.expired),
                         key=lambda r: (-r.req.priority, r.req.arrival,
                                        r.req.rid)):
            if len(self.inflight) >= self.max_batch:
                break
            rs.admitted_at = now
            rs.last_advance_tick = tick  # freshly admitted, not starved
            self.inflight.append(rs)
            admitted.append(rs)
        taken = {id(rs) for rs in admitted} | {id(rs) for rs in expired}
        self.pending[:n_due] = [rs for rs in due if id(rs) not in taken]
        return admitted, expired

    def groups(self, seg_fn: Callable[[RequestState], int]
               ) -> dict[int, list[RequestState]]:
        out: dict[int, list[RequestState]] = {}
        for rs in self.inflight:
            out.setdefault(seg_fn(rs), []).append(rs)
        return out

    def select(self, groups: dict[int, list[RequestState]], tick: int
               ) -> tuple[int, list[RequestState]]:
        assert groups
        starved = [rs for rs in self.inflight
                   if tick - rs.last_advance_tick >= self.starvation_ticks]
        if starved:
            oldest = min(starved, key=lambda r: (r.last_advance_tick,
                                                 r.req.rid))
            for seg, members in groups.items():
                if oldest in members:
                    return seg, members
        # largest group; ties -> the group holding the smallest rid
        def rank(item):
            seg, members = item
            return (-len(members), min(r.req.rid for r in members))

        seg, members = min(groups.items(), key=rank)
        return seg, members

    def retire(self, rs: RequestState) -> None:
        self.inflight.remove(rs)
