"""ServingGateway: one submit/complete surface over N model engines.

The gateway owns one engine per registered model — a
``DiffusionServingEngine`` or the thin ``LMServingEngine`` adapter, each
with its *own* ``WeightBank`` — and routes every submitted request by
its ``model`` field (``None`` -> the default model, the first one
added). Requests get a gateway-wide id (*gid*) so two engines counting
their local rids from zero never collide on the gateway surface;
``results`` and the return of ``submit``/``run`` are keyed by gid.

Hook fan-in: each engine's ``on_submit`` / ``on_complete`` /
``on_expire`` / ``on_tick_end`` hooks forward into the gateway's own
hook lists after annotating the request state with its routing
(``rs.model``, ``rs.gid``) — so one shared ``MetricsCollector`` (or a
``TraceWriter``, or a closed-loop generator) attaches to the gateway
exactly like it would to a single engine. Per-model collectors attach in
``add_model`` and power ``stats()``'s per-model summaries and SLO
verdicts.

Determinism: ``run()`` generalizes the single-engine driver — under a
shared ``VirtualClock`` it advances time to the earliest next arrival
across engines that could admit it, then ticks every live engine in
registration order; with exactly one model the tick sequence is
*identical* to ``engine.run()``, so a single-model golden replay through
the gateway reproduces the engine's golden digest (the "gateway adds
zero behavior" CI assertion). Under a shared ``SimClock`` both engines'
compute charges the same simulated time axis, which is what makes
cross-model contention measurable and machine-independent.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

from repro.serving.gateway.registry import ModelEntry
from repro.serving.traffic.metrics import SLO, MetricsCollector


@dataclasses.dataclass
class HostedModel:
    """One registered model: its entry, engine, and routing bookkeeping."""

    entry: ModelEntry
    engine: object
    collector: MetricsCollector
    gid_of: dict[int, int] = dataclasses.field(default_factory=dict)


class ServingGateway:
    """Multi-model request router over per-model engines + weight banks."""

    # submit_trace / generators pass each request's ``model`` field only
    # to surfaces that advertise routing
    routes_models = True

    def __init__(self, *, clock=None,
                 now_fn: Callable[[], float] | None = None,
                 max_idle_sleep: float = 0.25):
        self._models: dict[str, HostedModel] = {}
        self.default_model: str | None = None
        if clock is not None:
            self._now = clock.now
            self._advance = clock.advance_to
        else:
            t0 = time.monotonic()
            self._now = now_fn or (lambda: time.monotonic() - t0)
            self._advance = None
        self.max_idle_sleep = max_idle_sleep
        self._next_gid = 0
        self._pending_submit: tuple[str, int] | None = None
        self.route: dict[int, tuple[str, int]] = {}   # gid -> (name, rid)
        self.results: dict[int, object] = {}          # gid -> RequestState
        self.n_idle_sleeps = 0
        # gateway-surface hooks: same contract as an engine's (the shared
        # MetricsCollector / TraceWriter / closed-loop generator attach
        # here); receive the per-engine RequestState annotated with
        # ``rs.model`` / ``rs.gid``
        self.on_submit: list[Callable] = []
        self.on_complete: list[Callable] = []
        self.on_expire: list[Callable] = []
        self.on_tick_end: list[Callable] = []

    # -- registration --------------------------------------------------------

    def add_model(self, entry: ModelEntry, engine) -> "ServingGateway":
        """Host ``engine`` under ``entry.name``. The engine must be idle
        (no submitted requests) and share the gateway's clock — builders
        construct it with the same ``clock=`` / ``now_fn=`` the gateway
        was given."""
        name = entry.name
        if name in self._models:
            raise ValueError(f"model {name!r} already hosted")
        if engine.batcher.pending or engine.batcher.inflight:
            raise ValueError(f"engine for {name!r} already has requests")
        m = HostedModel(entry=entry, engine=engine,
                        collector=MetricsCollector())
        m.collector.attach(engine)

        def fwd_submit(rs, _m=m, _name=name):
            # runs inside engine.submit: the gateway stashed (name, gid)
            # just before calling it. Direct engine.submit calls (not
            # through the gateway) keep rs un-annotated.
            if self._pending_submit is not None:
                pname, gid = self._pending_submit
                if pname == _name:
                    rs.model = _name
                    rs.gid = gid
                    _m.gid_of[rs.req.rid] = gid
            for cb in self.on_submit:
                cb(rs)

        def fwd_done(rs, _m=m, _name=name, expire=False):
            gid = _m.gid_of.get(rs.req.rid)
            if gid is not None:
                self.results[gid] = rs
            for cb in (self.on_expire if expire else self.on_complete):
                cb(rs)

        engine.on_submit.append(fwd_submit)
        engine.on_complete.append(lambda rs: fwd_done(rs))
        engine.on_expire.append(lambda rs: fwd_done(rs, expire=True))
        engine.on_tick_end.append(
            lambda e: [cb(e) for cb in self.on_tick_end])
        self._models[name] = m
        if self.default_model is None:
            self.default_model = name
        return self

    def list_models(self) -> list[str]:
        return list(self._models)          # registration order

    def engine(self, name: str):
        return self._models[name].engine

    def _resolve(self, model: str | None) -> str:
        if model is None:
            if self.default_model is None:
                raise RuntimeError("gateway has no models registered")
            return self.default_model
        if model not in self._models:
            raise KeyError(f"unknown model {model!r} "
                           f"(hosted: {self.list_models()})")
        return model

    # -- request lifecycle -----------------------------------------------------

    def submit(self, *, model: str | None = None, **kw) -> int:
        """Route one request; returns its gateway-wide gid. ``kw`` is the
        engine submit signature (steps/eta/seed/sampler/.../think_s)."""
        name = self._resolve(model)
        m = self._models[name]
        gid = self._next_gid
        self._next_gid += 1
        self._pending_submit = (name, gid)
        try:
            rid = m.engine.submit(**kw)
        finally:
            self._pending_submit = None
        m.gid_of[rid] = gid
        self.route[gid] = (name, rid)
        return gid

    def pop_result(self, gid: int):
        """Hand a finished request to its caller and drop *every* piece of
        per-request bookkeeping — ``results``, the gid routing entry, and
        the hosted model's rid->gid map. A long-lived gateway that popped
        results but kept route/gid_of entries would leak one dict entry
        per request forever."""
        rs = self.results.pop(gid)
        name, rid = self.route.pop(gid)
        m = self._models[name]
        m.engine.results.pop(rid, None)
        m.gid_of.pop(rid, None)
        return rs

    # -- driver ------------------------------------------------------------

    def run(self, *, max_idle_sleep: float | None = None) -> dict:
        """Tick every engine to drain; returns ``results`` keyed by gid.

        Mirrors the single-engine driver exactly (see ``engine.run``):
        under a virtual clock, advance to the earliest next arrival any
        engine could admit *before* ticking; under a wall clock, sleep
        while every engine is idle. Engines tick in registration order,
        so a multi-model replay is deterministic under the virtual clock.
        """
        cap = self.max_idle_sleep if max_idle_sleep is None else max_idle_sleep
        engines = [m.engine for m in self._models.values()]
        if not engines:
            return self.results

        def live(e):
            return e.batcher.pending or e.batcher.inflight

        while any(live(e) for e in engines):
            if self._advance is not None:
                nxts = [e.batcher.next_arrival() for e in engines
                        if e.batcher.pending
                        and len(e.batcher.inflight) < e.batcher.max_batch]
                if nxts:
                    nxt = min(nxts)
                    if nxt > self._now():
                        self._advance(nxt)
                        self.n_idle_sleeps += 1
            for e in engines:
                if live(e):
                    e.tick()
            if (self._advance is None
                    and all(not e.batcher.inflight for e in engines)
                    and any(e.batcher.pending for e in engines)):
                wait = min(e.batcher.next_arrival() for e in engines
                           if e.batcher.pending) - self._now()
                # cap <= 0 disables sleeping entirely (see engine.run)
                if wait > 0 and cap > 0:
                    time.sleep(min(wait, cap))
                    self.n_idle_sleeps += 1
        for e in engines:
            e.bank.drain()
        return self.results

    # -- metrics -----------------------------------------------------------

    def stats(self) -> dict:
        """Aggregate + per-model view. Per-model entries carry the
        engine's full ``stats()`` (bank counters included), the model's
        traffic summary, and its SLO verdict against the registry entry's
        thresholds; the aggregate sums the cross-model totals."""
        per = {}
        for name, m in self._models.items():
            s = m.engine.stats()
            summary = m.collector.summary()
            per[name] = {"engine": s, "summary": summary,
                         "slo": m.collector.evaluate(m.entry.slo),
                         "family": m.entry.family}
        agg = {
            "models": self.list_models(),
            "requests": sum(p["engine"]["requests"] for p in per.values()),
            "expired": sum(p["engine"]["expired"] for p in per.values()),
            "ticks": sum(p["engine"]["ticks"] for p in per.values()),
            "forwards": sum(p["engine"]["forwards"] for p in per.values()),
            "idle_sleeps": self.n_idle_sleeps,
            "goodput_frac": {name: p["summary"]["goodput_frac"]
                             for name, p in per.items()},
        }
        return {"aggregate": agg, "per_model": per}
