"""Thin LM serving engine: the gateway adapter around the decode path.

Wraps the autoregressive decode loop (``models.lm.decode_step`` — the
same step ``launch/serve.py`` drives by hand) in the engine surface the
serving stack already speaks: ``submit``/``tick``/``run``, the
``ContinuousBatcher`` admission/expiry/selection machinery, a
``WeightBank`` (single segment, packing through its ``build_fn`` seam),
the traffic hooks (``on_submit``/``on_complete``/``on_expire``/
``on_tick_end``/``on_forward``), and the obs instrumentation points — so
one ``ServingGateway`` can host diffusion and LM models behind the same
submit/complete surface, meter them with the same ``MetricsCollector``,
and replay them under the same virtual/simulated clocks.

Request mapping: a generation request's ``steps`` is the number of
tokens to decode greedily after a deterministic seed-derived prompt;
``sampler``/``eta``/``y``/``guidance_scale`` are diffusion-only shaping
and are ignored. The finished ``x0`` is the generated token id array, so
the launcher's outcome digest covers LM results unchanged.

Thinness (documented limitation): ``decode_step`` takes a *scalar*
position, so requests at different positions cannot share one batched
forward — each in-flight request runs its own batch-1 decode per tick
(prefill, also per-request, teacher-forces the prompt through the same
step on first advance). Batched mixed-position decode needs a vector-pos
kernel and is future work; the adapter keeps every scheduling, metering
and replay property without it.
"""
from __future__ import annotations

import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import LMConfig, decode_step, init_caches
from repro.serving.obs import NULL_OBS, Observability
from repro.serving.scheduler import (ContinuousBatcher, GenRequest,
                                     RequestState)
from repro.serving.traffic.metrics import percentile
from repro.serving.weight_bank import WeightBank


class DecodeState:
    """One request's decode trajectory (duck-types the sampler-state
    surface the scheduler reads: ``done`` / ``steps_left`` / ``kind``)."""

    kind = "lm"

    def __init__(self, cfg: LMConfig, seed: int, gen_len: int,
                 prompt_len: int):
        self.prompt_len = prompt_len
        self.gen_len = gen_len
        self.gen_left = gen_len
        self.prompt = jax.random.randint(jax.random.PRNGKey(seed),
                                         (1, prompt_len), 0, cfg.vocab)
        self.caches = init_caches(cfg, 1, prompt_len + gen_len)
        self.pos = 0               # next cache write position
        self.tok = None            # next input token (1, 1), post-prefill
        self.prefilled = False
        self.done = False
        self.output: np.ndarray | None = None
        self._out: list[int] = []

    @property
    def steps_left(self) -> int:
        if self.done:
            return 0
        return self.gen_left + (self.prompt_len if not self.prefilled else 0)

    def prefill(self, params, dec) -> int:
        """Teacher-force the prompt through the decode step (fills the KV
        cache); returns the number of forwards run."""
        logits = None
        for i in range(self.prompt_len):
            logits, self.caches = dec(params, self.caches,
                                      self.prompt[:, i:i + 1], jnp.int32(i))
        self.tok = jnp.argmax(logits[:, -1:], axis=-1)
        self.pos = self.prompt_len
        self.prefilled = True
        return self.prompt_len

    def step(self, params, dec) -> None:
        """Emit the current greedy token, decode it, pick the next."""
        self._out.append(int(np.asarray(self.tok)[0, 0]))
        logits, self.caches = dec(params, self.caches, self.tok,
                                  jnp.int32(self.pos))
        self.pos += 1
        self.tok = jnp.argmax(logits[:, -1:], axis=-1)
        self.gen_left -= 1
        if self.gen_left <= 0:
            self.done = True
            self.output = np.asarray(self._out, np.int32)
            self.caches = None     # release the KV cache with the request


class LMServingEngine:
    """Continuous-batching engine over per-request greedy decode."""

    def __init__(self, cfg: LMConfig, bank: WeightBank, *,
                 ctx=None, max_batch: int = 8, starvation_ticks: int = 4,
                 policy: str = "fifo",
                 now_fn: Callable[[], float] | None = None,
                 clock=None, max_idle_sleep: float = 0.25,
                 prompt_len: int = 4,
                 obs: Observability | None = None,
                 model: str | None = None):
        self.cfg = cfg
        self.bank = bank
        self.ctx = ctx
        self.model = model
        self.prompt_len = prompt_len
        self.batcher = ContinuousBatcher(max_batch, starvation_ticks,
                                         policy=policy)
        self.batcher.segment_warm = bank.is_cached
        self.batcher.segment_building = bank.is_building
        if clock is not None:
            self._now = clock.now
            self._advance = clock.advance_to
        else:
            t0 = time.monotonic()
            self._now = now_fn or (lambda: time.monotonic() - t0)
            self._advance = None
        self.max_idle_sleep = max_idle_sleep
        # one segment, fetched on the first tick: nothing to prefetch,
        # but SimClock.attach writes this flag on any engine it drives
        self.async_prefetch = False
        self.obs = obs or NULL_OBS
        if self.obs.enabled:
            self.obs.bind_engine(self)
            self.batcher.obs = self.obs
            if self.bank.obs is NULL_OBS:
                self.bank.obs = self.obs
        self._jit: dict[tuple, Callable] = {}
        self._next_rid = 0
        self.tick_count = 0
        self.n_forwards = 0
        self.n_samples_batched = 0
        self.n_padded_samples = 0     # batch-1 decodes never pad
        self.n_idle_sleeps = 0
        self.n_finished = 0
        self.n_expired = 0
        self._latencies: list[float] = []
        self.results: dict[int, RequestState] = {}
        self.on_submit: list[Callable] = []
        self.on_complete: list[Callable] = []
        self.on_expire: list[Callable] = []
        self.on_tick_end: list[Callable] = []
        self.on_forward: list[Callable] = []

    def now(self) -> float:
        return self._now()

    def _dec(self) -> Callable:
        key = ("decode",)
        if key not in self._jit:
            cfg, ctx = self.cfg, self.ctx
            self._jit[key] = jax.jit(
                lambda p, c, tok, pos: decode_step(p, cfg, c, tok, pos,
                                                   ctx=ctx))
        return self._jit[key]

    # -- request lifecycle ---------------------------------------------------

    def submit(self, *, steps: int = 20, eta: float = 0.0, seed: int = 0,
               sampler: str = "ddim", y: int | None = None,
               guidance_scale: float = 0.0, arrival: float = 0.0,
               deadline: float | None = None, priority: int = 0,
               user: int | None = None, parent: int | None = None,
               think_s: float | None = None) -> int:
        """Same signature as the diffusion engine. ``steps`` = tokens to
        generate; ``eta``/``sampler``/``y``/``guidance_scale`` are
        diffusion shaping and are recorded but ignored."""
        rid = self._next_rid
        self._next_rid += 1
        req = GenRequest(rid, steps, eta, seed, sampler, y, guidance_scale,
                         arrival, deadline, priority, user, parent, think_s)
        state = DecodeState(self.cfg, seed, steps, self.prompt_len)
        rs = RequestState(req, state, submitted_at=self._now())
        self.batcher.submit(rs)
        if self.obs.enabled:
            self.obs.tracer.set_track(self.model)
            self.obs.tracer.async_begin(
                "request", rid, cat="request",
                args={"steps": steps, "arrival": arrival,
                      "deadline": deadline, "priority": priority,
                      "family": "lm"})
        for cb in self.on_submit:
            cb(rs)
        return rid

    # -- one engine tick -------------------------------------------------------

    def tick(self) -> list[RequestState]:
        obs = self.obs
        tick_span = None
        if obs.enabled:
            obs.tracer.set_track(self.model)
            tick_span = obs.tracer.begin(
                "tick", cat="engine", args={"tick": self.tick_count})
        now = self._now()
        admitted, expired = self.batcher.admit(now, self.tick_count)
        if obs.enabled:
            for rs in admitted:
                obs.tracer.async_instant("admit", rs.req.rid, cat="request")
        for rs in expired:
            rs.finished_at = now
            self.results[rs.req.rid] = rs
            self.n_expired += 1
            if obs.enabled:
                obs.tracer.async_end("request", rs.req.rid, cat="request",
                                     args={"outcome": "expired"})
            for cb in self.on_expire:
                cb(rs)
        if not self.batcher.inflight:
            if obs.enabled:
                tick_span.args["idle"] = True
                obs.tracer.end(tick_span)
                obs.sample(self)
            for cb in self.on_tick_end:
                cb(self)
            return []
        groups = self.batcher.groups(lambda rs: 0)   # one weight segment
        seg, members = self.batcher.select(groups, self.tick_count, now=now)
        self.batcher.current_seg = seg
        t_fetch = self._now()
        misses_before = self.bank.misses
        params = self.bank.params_for_segment(seg)
        if self.bank.misses > misses_before:
            self.batcher.cost.observe_switch(self._now() - t_fetch)

        fwd_span = None
        if obs.enabled:
            fwd_span = obs.tracer.begin("forward", cat="engine",
                                        args={"items": len(members)})
        t_compute = self._now()
        dec = self._dec()
        rows = 0
        finished = []
        tick = self.tick_count
        for rs in members:
            st = rs.state
            if not st.prefilled:
                rows += st.prefill(params, dec)
            st.step(params, dec)
            rows += 1
            rs.last_advance_tick = tick
            rs.n_evals += 1
            if obs.enabled:
                obs.tracer.async_instant("eval", rs.req.rid, cat="request",
                                         args={"n_evals": rs.n_evals})
            if st.done:
                rs.x0 = st.output
                rs.finished_at = self._now()
                self.batcher.retire(rs)
                self.results[rs.req.rid] = rs
                self.n_finished += 1
                self._latencies.append(rs.latency)
                finished.append(rs)
                if obs.enabled:
                    obs.tracer.async_end(
                        "request", rs.req.rid, cat="request",
                        args={"outcome": "complete", "n_evals": rs.n_evals,
                              "latency_s": rs.latency})
                for cb in self.on_complete:
                    cb(rs)
        self.n_forwards += rows
        self.n_samples_batched += len(members)
        self.batcher.cost.observe_eval(self._now() - t_compute, rows)
        if obs.enabled:
            fwd_span.args["rows"] = rows
            obs.tracer.end(fwd_span)
        self.tick_count += 1
        for cb in self.on_forward:
            cb(self, rows)
        if obs.enabled:
            tick_span.args["finished"] = len(finished)
            obs.tracer.end(tick_span)
            obs.sample(self)
        for cb in self.on_tick_end:
            cb(self)
        return finished

    def pop_result(self, rid: int) -> RequestState:
        return self.results.pop(rid)

    # -- driver ----------------------------------------------------------------

    def run(self, *, max_idle_sleep: float | None = None
            ) -> dict[int, RequestState]:
        """Tick to drain — the same idle/advance policy as the diffusion
        engine's driver (see ``engine.DiffusionServingEngine.run``)."""
        cap = self.max_idle_sleep if max_idle_sleep is None else max_idle_sleep
        while self.batcher.pending or self.batcher.inflight:
            if (self._advance is not None and self.batcher.pending
                    and len(self.batcher.inflight) < self.batcher.max_batch):
                nxt = self.batcher.next_arrival()
                if nxt > self._now():
                    self._advance(nxt)
                    self.n_idle_sleeps += 1
            self.tick()
            if (self._advance is None and not self.batcher.inflight
                    and self.batcher.pending):
                wait = self.batcher.next_arrival() - self._now()
                # cap <= 0 disables sleeping entirely (see engine.run)
                if wait > 0 and cap > 0:
                    time.sleep(min(wait, cap))
                    self.n_idle_sleeps += 1
        self.bank.drain()
        return self.results

    # -- metrics -----------------------------------------------------------

    def stats(self) -> dict:
        lat = sorted(self._latencies)
        d = {"requests": self.n_finished, "ticks": self.tick_count,
             "expired": self.n_expired,
             "policy": self.batcher.policy,
             "preemptions": self.batcher.preemptions,
             "deadline_saves": self.batcher.deadline_saves,
             "forwards": self.n_forwards,
             "mean_batch": (self.n_samples_batched / self.tick_count
                            if self.tick_count else 0.0),
             "compiled_forwards": len(self._jit),
             "buckets": [1],                      # batch-1 decode only
             "padded_samples": self.n_padded_samples,
             "idle_sleeps": self.n_idle_sleeps,
             "prefetch_hits": self.bank.prefetch_hits,
             "p50_s": percentile(lat, 50), "p95_s": percentile(lat, 95),
             "p99_s": percentile(lat, 99)}
        d.update({f"bank_{k}": v for k, v in self.bank.describe().items()})
        return d
