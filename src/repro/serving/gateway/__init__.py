"""Multi-model serving gateway: registry + router over per-model engines.

Layer contract: ``serving.gateway`` sits *above* the engine, traffic and
obs sub-layers — it may import any of them, nothing below imports it
(see ``tools/analysis/repolint.toml``). Engine construction stays in the
launcher (``launch/serve_gateway``): the registry is data-only, the
gateway hosts whatever engines the builders hand it.
"""
from repro.serving.gateway.gateway import ServingGateway
from repro.serving.gateway.lm_engine import DecodeState, LMServingEngine
from repro.serving.gateway.registry import (FAMILIES, ModelEntry,
                                            ModelRegistry, default_entries,
                                            default_registry)

__all__ = ["ServingGateway", "LMServingEngine", "DecodeState",
           "ModelEntry", "ModelRegistry", "FAMILIES",
           "default_entries", "default_registry"]
