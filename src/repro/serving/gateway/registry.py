"""Model registry for the multi-model serving gateway.

A ``ModelEntry`` is the curated record the gateway needs to host one
quantized model: its routing name, family (``diffusion`` | ``lm``), the
config reference it is built from, the quant recipe its weight bank
packs with, the bank's LRU capacity, and the default SLO its traffic is
judged against. The registry is deliberately *data only* — engines are
constructed by builders the launcher supplies (``launch/serve_gateway``),
so this layer never imports model/launch code it would drag below the
import DAG.

``default_entries()`` ships the two-model development pair every smoke /
bench run uses: the tiny diffusion preset plus the smollm smoke LM. LM
entries must name an arch from ``configs.registry`` (validated against
``list_models()``); diffusion entries name a ``DIFFUSION_PRESETS`` key.
"""
from __future__ import annotations

import dataclasses

from repro.configs.diffusion_presets import DIFFUSION_PRESETS
from repro.configs.registry import list_models
from repro.serving.traffic.metrics import SLO

FAMILIES = ("diffusion", "lm")


@dataclasses.dataclass(frozen=True)
class ModelEntry:
    """One hostable model: routing name + everything a builder needs."""

    name: str                      # routing key (trace ``model`` field)
    family: str                    # "diffusion" | "lm"
    config: str                    # DIFFUSION_PRESETS key or configs arch id
    quant: str = "absmax-w4"       # bank packing recipe (builder-resolved)
    bank_cap: int = 4              # LRU cap on cached segment weight-sets
    slo: SLO = SLO()               # default verdict thresholds
    max_batch: int = 4             # in-flight slots for this model's engine
    smoke: bool = True             # lm only: smoke() vs full() config

    def validate(self) -> None:
        if not self.name or not isinstance(self.name, str):
            raise ValueError(f"model entry needs a non-empty name, "
                             f"got {self.name!r}")
        if self.family not in FAMILIES:
            raise ValueError(f"{self.name}: family {self.family!r} "
                             f"not in {FAMILIES}")
        if self.family == "diffusion":
            if self.config not in DIFFUSION_PRESETS:
                raise ValueError(
                    f"{self.name}: unknown diffusion preset "
                    f"{self.config!r} (known: {sorted(DIFFUSION_PRESETS)})")
        elif self.config not in list_models():
            raise ValueError(f"{self.name}: unknown LM arch "
                             f"{self.config!r} (known: {list_models()})")
        if self.bank_cap < 1 or self.max_batch < 1:
            raise ValueError(f"{self.name}: bank_cap/max_batch must be "
                             ">= 1")


class ModelRegistry:
    """Name -> ModelEntry with validation; the gateway resolves against
    one of these, the launcher populates it from ``--models``."""

    def __init__(self, entries: list[ModelEntry] | None = None):
        self._entries: dict[str, ModelEntry] = {}
        for e in entries or []:
            self.register(e)

    def register(self, entry: ModelEntry) -> ModelEntry:
        entry.validate()
        if entry.name in self._entries:
            raise ValueError(f"model {entry.name!r} already registered")
        self._entries[entry.name] = entry
        return entry

    def resolve(self, name: str) -> ModelEntry:
        if name not in self._entries:
            raise KeyError(f"unknown model {name!r} "
                           f"(registered: {self.list()})")
        return self._entries[name]

    def list(self) -> list[str]:
        return sorted(self._entries)

    def __contains__(self, name: str) -> bool:
        return name in self._entries

    def __len__(self) -> int:
        return len(self._entries)


def default_entries() -> list[ModelEntry]:
    """The curated development pair: one tiny diffusion model + one smoke
    LM — the models the ``mixed_model`` / ``per_model_slo`` scenarios
    name and the gateway smoke runs register."""
    return [
        ModelEntry(name="tiny-ddim", family="diffusion", config="tiny-ddim",
                   quant="absmax-w4", bank_cap=4, max_batch=4,
                   slo=SLO(p95_s=120.0)),
        ModelEntry(name="smollm-135m", family="lm", config="smollm-135m",
                   quant="absmax-w4", bank_cap=1, max_batch=4, smoke=True,
                   slo=SLO(p95_s=120.0)),
    ]


def default_registry() -> ModelRegistry:
    return ModelRegistry(default_entries())
