"""Per-route kernel profiling hooks for ``kernels/ops`` dispatch.

``kernels/ops`` stays dependency-free: it exposes a module-level
``PROFILER`` slot (``None`` by default — one global read + branch per
dispatch) and calls ``PROFILER.call(op, route, thunk, probe=x)`` around
the chosen route when a profiler is installed. This module provides that
profiler, backed by the obs metrics registry and span tracer.

Two recording regimes, selected per call by the ``probe`` operand:

  * **Traced** (``probe`` is a jax ``Tracer`` — the op is being traced
    into a jit program, the engine's serving path): wall-clock here
    would measure tracing, not compute, so only the route *counter*
    increments (labelled ``traced``) and an instant span marks the
    dispatch decision (op, route, shapes) — once per compiled forward.
  * **Eager** (concrete operands — benches, direct kernel calls): the
    call is timed with ``block_until_ready`` and recorded as a duration
    span plus a ``kernel_call_seconds`` histogram observation per
    (op, route).

The timings recorded here are the same engine-clock observations the
scheduler's ``CostModel`` EWMA consumes at forward granularity (the
engine mirrors its ``observe_eval``/``observe_switch`` samples into the
registry); the per-route histograms attribute that time to kernels
without introducing a second timing source for scheduling decisions.

Route label vocabulary (must stay reconcilable with the dispatch
booby-trap tests in ``tests/test_kernels.py``): ``pallas``,
``interpret``, ``xla_fast``, ``ref``; conv routes carry their sub-route,
e.g. ``interpret:im2col``, ``pallas:implicit``.
"""
from __future__ import annotations

import threading
import time

import jax

from repro.kernels import ops as _ops


def _is_tracer(x) -> bool:
    return isinstance(x, jax.core.Tracer)


class KernelProfiler:
    """Counts + times ops-dispatch routes into an obs bundle."""

    def __init__(self, obs, lock_factory=None):
        self.obs = obs
        # lock_factory: lockcheck instrumentation seam (see weight_bank)
        self._lock = (lock_factory("kernel_profiler._lock")
                      if lock_factory is not None else threading.Lock())
        self._counts: dict[tuple, int] = {}     # (op, route, traced) -> n

    # -- installation --------------------------------------------------------

    def install(self) -> "KernelProfiler":
        _ops.PROFILER = self
        return self

    def uninstall(self) -> None:
        if _ops.PROFILER is self:
            _ops.PROFILER = None

    def __enter__(self) -> "KernelProfiler":
        return self.install()

    def __exit__(self, *exc):
        self.uninstall()
        return False

    # -- the ops hook --------------------------------------------------------

    def call(self, op: str, route: str, thunk, probe=None):
        traced = _is_tracer(probe)
        with self._lock:
            key = (op, route, traced)
            self._counts[key] = self._counts.get(key, 0) + 1
        m = self.obs.metrics
        m.counter("kernel_calls_total",
                  help="ops dispatch decisions by route",
                  op=op, route=route,
                  mode="traced" if traced else "eager").inc()
        tr = self.obs.tracer
        if traced:
            if tr.enabled:
                tr.instant(f"{op}[{route}]", cat="kernel",
                           args={"op": op, "route": route, "traced": True,
                                 **_shape_args(probe)})
            return thunk()
        t0 = time.perf_counter()
        out = thunk()
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        m.histogram("kernel_call_seconds",
                    help="eager wall-clock per ops dispatch",
                    op=op, route=route).observe(dt)
        if tr.enabled:
            sp = tr.begin(f"{op}[{route}]", cat="kernel",
                          args={"op": op, "route": route,
                                "wall_s": dt, **_shape_args(probe)})
            tr.end(sp)
        return out

    # -- read side -----------------------------------------------------------

    def route_counts(self) -> dict[str, int]:
        """``{"op:route": n}`` summed over traced + eager calls."""
        with self._lock:
            out: dict[str, int] = {}
            for (op, route, _traced), n in self._counts.items():
                k = f"{op}:{route}"
                out[k] = out.get(k, 0) + n
            return out


def _shape_args(probe) -> dict:
    shape = getattr(probe, "shape", None)
    return {"shape": list(shape)} if shape is not None else {}
