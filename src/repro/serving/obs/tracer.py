"""Structured span tracer for the serving stack.

Event model is the Chrome trace-event format (the JSON Perfetto and
``chrome://tracing`` load directly): duration spans (``ph="X"``), async
request-lifecycle events (``ph="b"/"n"/"e"`` keyed by ``cat`` + ``id``),
instant annotations (``ph="i"``) and counter series (``ph="C"``). The
tracer buffers plain event dicts and serializes on demand — either as
one Chrome JSON object (``export_chrome``) or as newline-delimited JSON
(``export_jsonl``) for ad-hoc grepping/stream processing.

Design constraints (see ``serving/obs/__init__``):

  * **Deterministic timestamps** — the tracer never reads a wall clock
    itself; ``set_clock`` binds it to the *engine's* clock, so a
    ``VirtualClock`` replay emits the same timestamps on every machine
    and tracing can never perturb the golden-replay digest (the clock is
    only read, never advanced).
  * **Thread safety** — spans arrive from the engine thread *and* the
    weight bank's background prefetch worker. Every buffer mutation
    happens under one lock; an event dict is fully built before it is
    published, so a reader can never observe a torn event.
  * **Bounded memory** — the buffer is a ring (``max_events``); overflow
    drops the oldest events and counts them in ``dropped``.
  * **Cheap when disabled** — every public method early-returns on
    ``self.enabled`` (and the instrumentation points in engine/bank/
    scheduler guard with a single ``obs.enabled`` branch before even
    building the args dict).

Thread identity: the first thread to emit gets tid 0 (the engine thread
in practice), later threads get ascending tids in first-emission order;
``thread_name`` metadata events carry the Python thread names (the bank
worker shows up as ``weight-bank-prefetch_0``).
"""
from __future__ import annotations

import collections
import json
import threading

_PID = 1


class Span:
    """An open duration span; ``end()`` (via the tracer) publishes it as
    one complete ``ph="X"`` event. ``args`` may be mutated until then —
    annotations discovered mid-span (chosen segment, padded rows) attach
    to the span they describe."""

    __slots__ = ("name", "cat", "ts", "tid", "args")

    def __init__(self, name: str, cat: str, ts: float, tid: int,
                 args: dict | None):
        self.name = name
        self.cat = cat
        self.ts = ts
        self.tid = tid
        self.args = args if args is not None else {}


class SpanTracer:
    def __init__(self, clock=None, max_events: int = 500_000,
                 lock_factory=None):
        self.enabled = True
        self._clock = clock or (lambda: 0.0)
        # lock_factory: lockcheck instrumentation seam (see weight_bank)
        self._lock = (lock_factory("tracer._lock")
                      if lock_factory is not None else threading.Lock())
        self._events: collections.deque = collections.deque()
        self.max_events = max_events
        self.dropped = 0
        # track key: (thread ident, track-name override). The override
        # (``set_track``) lets one thread emit onto several named tracks —
        # the multi-model gateway runs every engine on the driver thread
        # and labels each model's spans with its own track.
        self._tids: dict[tuple, int] = {}     # (ident, track) -> stable tid
        self._tid_names: dict[int, str] = {}  # tid -> track/thread name
        self._stacks: dict[int, list] = {}    # tid -> open-span stack
        self._local = threading.local()

    def set_clock(self, clock) -> None:
        self._clock = clock

    def now_us(self) -> float:
        return self._clock() * 1e6

    # -- internals -----------------------------------------------------------

    def set_track(self, name: str | None) -> None:
        """Name the current thread's track: events emitted by this thread
        land on a tid labeled ``name`` until the next ``set_track``
        (``None`` restores the plain thread track). Tids still assign in
        first-emission order; the call is a thread-local write, so it is
        cheap enough for once-per-tick use and safe from any thread."""
        if not self.enabled:
            return
        self._local.track = name

    def _tid(self) -> int:
        track = getattr(self._local, "track", None)
        key = (threading.get_ident(), track)
        tid = self._tids.get(key)
        if tid is None:
            with self._lock:
                tid = self._tids.get(key)
                if tid is None:
                    tid = len(self._tids)
                    self._tids[key] = tid
                    self._tid_names[tid] = (
                        track if track is not None
                        else threading.current_thread().name)
        return tid

    def _emit(self, ev: dict) -> None:
        with self._lock:
            if len(self._events) >= self.max_events:
                self._events.popleft()
                self.dropped += 1
            self._events.append(ev)

    # -- duration spans ------------------------------------------------------

    def begin(self, name: str, *, cat: str = "engine",
              args: dict | None = None) -> Span | None:
        if not self.enabled:
            return None
        sp = Span(name, cat, self.now_us(), self._tid(), args)
        with self._lock:
            self._stacks.setdefault(sp.tid, []).append(sp)
        return sp

    def end(self, span: Span | None) -> None:
        if not self.enabled or span is None:
            return
        with self._lock:
            stack = self._stacks.get(span.tid, [])
            # pop through (tolerates a leaked inner span on error paths
            # rather than corrupting every later span's nesting)
            while stack and stack.pop() is not span:
                pass
        self._emit({"ph": "X", "name": span.name, "cat": span.cat,
                    "pid": _PID, "tid": span.tid, "ts": span.ts,
                    "dur": max(self.now_us() - span.ts, 0.0),
                    "args": span.args})

    class _SpanCtx:
        __slots__ = ("_tr", "span")

        def __init__(self, tr, span):
            self._tr = tr
            self.span = span

        def set(self, key, val):
            if self.span is not None:
                self.span.args[key] = val

        def __enter__(self):
            return self

        def __exit__(self, *exc):
            self._tr.end(self.span)
            return False

    def span(self, name: str, *, cat: str = "engine",
             args: dict | None = None) -> "_SpanCtx":
        """``with tracer.span("bank_build", cat="bank") as sp: ...``"""
        return self._SpanCtx(self, self.begin(name, cat=cat, args=args))

    # -- instants / counters -------------------------------------------------

    def instant(self, name: str, *, cat: str = "engine",
                args: dict | None = None) -> None:
        if not self.enabled:
            return
        self._emit({"ph": "i", "name": name, "cat": cat, "pid": _PID,
                    "tid": self._tid(), "ts": self.now_us(), "s": "t",
                    "args": args or {}})

    def counter(self, name: str, values: dict) -> None:
        """One sample of a counter track (Perfetto renders a time series)."""
        if not self.enabled:
            return
        self._emit({"ph": "C", "name": name, "cat": "metrics", "pid": _PID,
                    "tid": self._tid(), "ts": self.now_us(), "args": values})

    # -- async (request-lifecycle) events ------------------------------------
    # Perfetto groups b/n/e events by (cat, id) onto one async track, so a
    # request's whole lifecycle reads as one slice with instant marks.

    def _async(self, ph: str, name: str, aid, cat: str,
               args: dict | None) -> None:
        self._emit({"ph": ph, "name": name, "cat": cat, "id": str(aid),
                    "pid": _PID, "tid": self._tid(), "ts": self.now_us(),
                    "args": args or {}})

    def async_begin(self, name: str, aid, *, cat: str = "request",
                    args: dict | None = None) -> None:
        if self.enabled:
            self._async("b", name, aid, cat, args)

    def async_instant(self, name: str, aid, *, cat: str = "request",
                      args: dict | None = None) -> None:
        if self.enabled:
            self._async("n", name, aid, cat, args)

    def async_end(self, name: str, aid, *, cat: str = "request",
                  args: dict | None = None) -> None:
        if self.enabled:
            self._async("e", name, aid, cat, args)

    # -- export --------------------------------------------------------------

    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)

    def _metadata_events(self) -> list[dict]:
        with self._lock:
            names = dict(self._tid_names)
        return [{"ph": "M", "name": "thread_name", "pid": _PID, "tid": tid,
                 "ts": 0, "args": {"name": name}}
                for tid, name in sorted(names.items())]

    def export_chrome(self, path: str) -> int:
        """Write one Chrome trace-event JSON object (Perfetto-loadable);
        returns the event count."""
        evs = self._metadata_events() + self.events()
        with open(path, "w") as f:
            json.dump({"traceEvents": evs,
                       "displayTimeUnit": "ms",
                       "otherData": {"producer": "repro.serving.obs"}}, f)
        return len(evs)

    def export_jsonl(self, path: str) -> int:
        """Write newline-delimited JSON, one event per line."""
        evs = self._metadata_events() + self.events()
        with open(path, "w") as f:
            for ev in evs:
                f.write(json.dumps(ev) + "\n")
        return len(evs)

    def export(self, path: str) -> int:
        """Format by extension: ``.jsonl`` -> JSONL, else Chrome JSON."""
        if path.endswith(".jsonl"):
            return self.export_jsonl(path)
        return self.export_chrome(path)


class NullTracer(SpanTracer):
    """Disabled tracer: every method is a no-op behind one branch."""

    def __init__(self):
        super().__init__(max_events=0)
        self.enabled = False
