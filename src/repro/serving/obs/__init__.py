"""Unified observability layer for the W4A4 serving stack.

One ``Observability`` bundle carries the three concerns every component
hangs telemetry off:

  * ``tracer`` — structured spans (``obs.tracer``): request lifecycle
    (submit -> admit -> per-eval -> complete/expire, as Chrome async
    events keyed by rid), engine ticks with scheduler decision
    annotations, weight-bank build/prefetch spans (including from the
    background prefetch worker thread), and per-dispatch kernel-route
    marks. Exports Chrome trace-event JSON (Perfetto-loadable) or JSONL.
  * ``metrics`` — the counter/gauge/histogram registry
    (``obs.metrics``): the single machine-readable home for the numbers
    previously scattered across ``engine.stats()``, ``bank.describe()``,
    scheduler attributes and launcher print lines. ``sample(engine)``
    refreshes the engine/bank/scheduler gauges once per tick (and emits
    Perfetto counter-track samples); ``finalize`` folds in the run-end
    summary.
  * ``kernel_profiler`` — per-route dispatch counts/timings installed
    into ``kernels/ops`` (see ``kernel_profile``).

Contracts:

  * **Determinism** — the tracer's clock is the *engine's* clock
    (``bind_engine``), never a wall clock of its own; under a
    ``VirtualClock`` replay the whole trace is deterministic and the
    golden outcome digest is unchanged whether obs is on or off (the
    layer only reads state; pinned by tests/test_obs.py).
  * **Near-zero disabled overhead** — ``NULL_OBS`` (the default
    everywhere) has ``enabled=False``; every instrumentation point in
    engine/scheduler/bank guards with that single branch before building
    any args, and the kernels hook is one module-global ``None`` check.
  * **Thread safety** — see ``tracer``/``metrics`` module docs; bank
    spans are emitted from the prefetch worker under churn without
    corrupting the buffer (pinned by the obs thread-safety test).
"""
from __future__ import annotations

from repro.serving.obs.kernel_profile import KernelProfiler
from repro.serving.obs.metrics import (Counter, Gauge, Histogram,
                                       MetricsRegistry)
from repro.serving.obs.tracer import NullTracer, Span, SpanTracer


class Observability:
    def __init__(self, enabled: bool = True, *, clock=None,
                 max_events: int = 500_000, lock_factory=None):
        # lock_factory propagates to every obs-owned lock (tracer buffer,
        # registry + instruments, kernel profiler) — the seam
        # tools/analysis/lockcheck.py uses to install order-tracking
        # locks for the lock-discipline tests.
        self.enabled = enabled
        self.tracer = (SpanTracer(clock=clock, max_events=max_events,
                                  lock_factory=lock_factory)
                       if enabled else NullTracer())
        self.metrics = MetricsRegistry(lock_factory=lock_factory)
        self.kernel_profiler = (KernelProfiler(self,
                                               lock_factory=lock_factory)
                                if enabled else None)

    # -- wiring --------------------------------------------------------------

    def bind_engine(self, engine) -> "Observability":
        """Point the tracer at the engine's clock (virtual, simulated, or
        wall — whatever the engine runs on, timestamps follow it)."""
        self.tracer.set_clock(engine.now)
        return self

    def install_kernels(self) -> "Observability":
        if self.kernel_profiler is not None:
            self.kernel_profiler.install()
        return self

    def uninstall_kernels(self) -> None:
        if self.kernel_profiler is not None:
            self.kernel_profiler.uninstall()

    # -- per-tick / run-end registry sync ------------------------------------

    @staticmethod
    def _engine_labels(engine) -> dict:
        lab = {}
        if getattr(engine, "model", None):
            lab["model"] = engine.model
        if getattr(engine, "replica", None):
            lab["replica"] = engine.replica
        return lab

    def sample(self, engine) -> None:
        """Cheap per-tick snapshot of engine/bank/scheduler counters into
        registry gauges + a Perfetto counter-track sample. Reads plain
        attributes only (never ``engine.stats()``, which sorts latency
        lists) so a tick pays O(#gauges) dict work, nothing more."""
        if not self.enabled:
            return
        m = self.metrics
        b = engine.batcher
        bank = engine.bank
        # engines hosted behind the gateway carry a model identity, fleet
        # replicas a replica identity: their gauges become labeled series
        # so two engines never clobber one family; a standalone engine
        # (model=None, replica=None) keeps the unlabeled names
        # byte-identical to the pre-gateway exposition
        lab = self._engine_labels(engine)
        m.set("engine_ticks", engine.tick_count, **lab)
        m.set("engine_forwards", engine.n_forwards, **lab)
        m.set("engine_finished", engine.n_finished, **lab)
        m.set("engine_expired", engine.n_expired, **lab)
        m.set("engine_pending", len(b.pending), **lab)
        m.set("engine_inflight", len(b.inflight), **lab)
        m.set("engine_padded_samples", engine.n_padded_samples, **lab)
        m.set("engine_compiled_forwards", len(engine._jit), **lab)
        m.set("sched_preemptions", b.preemptions, **lab)
        m.set("sched_deadline_saves", b.deadline_saves, **lab)
        m.set("sched_cost_sample_s", b.cost.sample_s, **lab)
        m.set("sched_cost_switch_s", b.cost.switch_s, **lab)
        m.set("bank_hits", bank.hits, **lab)
        m.set("bank_misses", bank.misses, **lab)
        m.set("bank_builds", bank.builds, **lab)
        m.set("bank_build_joins", bank.build_joins, **lab)
        m.set("bank_build_failures", bank.build_failures, **lab)
        m.set("bank_prefetches", bank.prefetches, **lab)
        m.set("bank_prefetch_hits", bank.prefetch_hits, **lab)
        m.set("bank_evictions", bank.evictions, **lab)
        tr = self.tracer
        tr.counter("queue", {"pending": len(b.pending),
                             "inflight": len(b.inflight)})
        tr.counter("bank", {"hits": bank.hits, "misses": bank.misses,
                            "builds": bank.builds})

    def finalize(self, engine, collector=None) -> None:
        """Run-end sync: full ``engine.stats()`` plus the traffic
        collector's summary land in the registry, so ``to_text()`` /
        ``snapshot()`` expose every number the launcher prints."""
        if not self.enabled:
            return
        self.sample(engine)
        m = self.metrics
        lab = self._engine_labels(engine)
        for k, v in engine.stats().items():
            if isinstance(v, (int, float, bool)):
                m.set(f"engine_{k}", float(v), **lab)
        if collector is not None:
            for k, v in collector.summary().items():
                if isinstance(v, (int, float, bool)):
                    m.set(f"traffic_{k}", float(v), **lab)
        if self.kernel_profiler is not None:
            m.set("kernel_routes", len(self.kernel_profiler.route_counts()))
        m.set("trace_events", len(self.tracer.events()))
        m.set("trace_events_dropped", self.tracer.dropped)


NULL_OBS = Observability(enabled=False)

__all__ = ["Observability", "NULL_OBS", "SpanTracer", "NullTracer", "Span",
           "MetricsRegistry", "Counter", "Gauge", "Histogram",
           "KernelProfiler"]
