"""Metrics registry: counters, gauges, histograms, text exposition.

One home for every number the serving stack produces. Instruments are
registered by ``(name, labels)`` and are get-or-create — calling
``registry.counter("kernel_calls_total", op="w4_matmul", route="ref")``
twice returns the same ``Counter``. ``snapshot()`` flattens the whole
registry into a plain dict (the launcher's ``--report-json`` payload);
``to_text()`` dumps a Prometheus-style exposition (``--metrics-out``).

Engine / weight-bank / scheduler counters are *sampled* into gauges once
per tick by ``Observability.sample`` rather than incremented at-site:
the sources keep their existing lock disciplines (the bank mutates its
counters under its own lock from two threads) and the registry can never
introduce a lock-order hazard or perturb scheduling. Numbers born in the
obs layer itself — kernel route counts/timings, trace bookkeeping — live
here natively as counters/histograms.

All mutation is thread-safe: one registry lock guards instrument
creation, each instrument carries its own lock for updates (the kernel
profiler observes from whatever thread runs an eager op; bank samples
arrive from the engine thread while the prefetch worker runs).
"""
from __future__ import annotations

import threading

# Default histogram buckets: log-spaced seconds, micro to minute scale
# (covers kernel calls, bank fetches, forwards, and segment builds).
DEFAULT_BUCKETS = (1e-6, 1e-5, 1e-4, 1e-3, 1e-2, 0.1, 0.5, 1.0, 5.0,
                   30.0, 60.0)


class Counter:
    """Monotonically increasing count."""

    __slots__ = ("value", "_lock")

    def __init__(self, lock=None):
        self.value = 0
        self._lock = lock if lock is not None else threading.Lock()

    def inc(self, n=1) -> None:
        with self._lock:
            self.value += n


class Gauge:
    """Last-written value (per-tick samples of engine/bank/sched state)."""

    __slots__ = ("value", "_lock")

    def __init__(self, lock=None):
        self.value = 0.0
        self._lock = lock if lock is not None else threading.Lock()

    def set(self, v) -> None:
        with self._lock:
            self.value = v


class Histogram:
    """Fixed-bucket histogram with exact sum/count (cumulative ``le``
    bucket counts in the exposition, like Prometheus)."""

    __slots__ = ("buckets", "counts", "sum", "count", "_lock")

    def __init__(self, buckets=DEFAULT_BUCKETS, lock=None):
        self.buckets = tuple(sorted(buckets))
        self.counts = [0] * (len(self.buckets) + 1)   # +inf overflow
        self.sum = 0.0
        self.count = 0
        self._lock = lock if lock is not None else threading.Lock()

    def observe(self, v: float) -> None:
        with self._lock:
            i = 0
            while i < len(self.buckets) and v > self.buckets[i]:
                i += 1
            self.counts[i] += 1
            self.sum += v
            self.count += 1

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0


def _label_key(labels: dict) -> tuple:
    return tuple(sorted(labels.items()))


def _label_str(labels: tuple) -> str:
    if not labels:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in labels) + "}"


class MetricsRegistry:
    def __init__(self, lock_factory=None):
        # lock_factory: lockcheck instrumentation seam — wraps the
        # registry lock and every instrument lock it hands out, so lock-
        # order tests see the full obs lock population (see weight_bank)
        self._lock_factory = lock_factory
        self._lock = (lock_factory("metrics._lock")
                      if lock_factory is not None else threading.Lock())
        # name -> (kind, help, {labels_tuple: instrument})
        self._families: dict[str, tuple] = {}

    def _inst_lock(self, name: str):
        if self._lock_factory is None:
            return None
        return self._lock_factory(f"metrics.{name}")

    def _get(self, name: str, kind: str, help_: str, labels: dict,
             factory):
        key = _label_key(labels)
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = (kind, help_, {})
                self._families[name] = fam
            elif fam[0] != kind:
                raise ValueError(f"metric {name!r} already registered as "
                                 f"{fam[0]}, not {kind}")
            inst = fam[2].get(key)
            if inst is None:
                inst = fam[2][key] = factory()
            return inst

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        return self._get(name, "counter", help, labels,
                         lambda: Counter(lock=self._inst_lock(name)))

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        return self._get(name, "gauge", help, labels,
                         lambda: Gauge(lock=self._inst_lock(name)))

    def histogram(self, name: str, help: str = "",
                  buckets=DEFAULT_BUCKETS, **labels) -> Histogram:
        return self._get(name, "histogram", help, labels,
                         lambda: Histogram(buckets,
                                           lock=self._inst_lock(name)))

    def set(self, name: str, value, **labels) -> None:
        """Shorthand: gauge get-or-create + set."""
        self.gauge(name, **labels).set(value)

    # -- read side -----------------------------------------------------------

    def _items(self):
        with self._lock:
            return [(name, kind, help_, dict(series))
                    for name, (kind, help_, series) in
                    sorted(self._families.items())]

    def snapshot(self) -> dict:
        """Flat ``{name{labels}: value}`` dict (histograms contribute
        ``_count``/``_sum``/``_mean`` entries) — the JSON-report view."""
        out = {}
        for name, kind, _help, series in self._items():
            for labels, inst in sorted(series.items()):
                full = name + _label_str(labels)
                if kind == "histogram":
                    out[full + "_count"] = inst.count
                    out[full + "_sum"] = inst.sum
                    out[full + "_mean"] = inst.mean
                else:
                    out[full] = inst.value
        return out

    def to_text(self) -> str:
        """Prometheus-style exposition dump."""
        lines = []
        for name, kind, help_, series in self._items():
            if help_:
                lines.append(f"# HELP {name} {help_}")
            lines.append(f"# TYPE {name} {kind}")
            for labels, inst in sorted(series.items()):
                if kind == "histogram":
                    cum = 0
                    for le, c in zip(inst.buckets, inst.counts):
                        cum += c
                        lab = _label_str(labels + (("le", le),))
                        lines.append(f"{name}_bucket{lab} {cum}")
                    lab = _label_str(labels + (("le", "+Inf"),))
                    lines.append(f"{name}_bucket{lab} {inst.count}")
                    lines.append(f"{name}_sum{_label_str(labels)} "
                                 f"{inst.sum}")
                    lines.append(f"{name}_count{_label_str(labels)} "
                                 f"{inst.count}")
                else:
                    lines.append(f"{name}{_label_str(labels)} {inst.value}")
        return "\n".join(lines) + "\n"
