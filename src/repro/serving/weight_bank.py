"""Weight bank: per-routing-segment TALoRA merge + real FP4 pre-packing.

The TALoRA router maps each timestep to one adapter slot per layer
(``core.talora``). Sweeping the router over the full schedule yields a
small number of contiguous timestep segments with identical routing; within
a segment the merged weights ``W_q + A_sel B_sel * alpha/r`` are constant.
The bank therefore:

  1. sweeps ``routing_signatures`` once to find the segments,
  2. on demand merges each segment's adapters into the quantized base
     (``talora.merge_into_tree``) and *re-packs* every quantizable site to
     real packed FP4 (``core.qmodule.pack_weight``) under the plan's
     searched parameters — sampling then runs integer-packed weights
     end-to-end (kernels/ops dispatch) instead of fake-quant,
  3. keeps at most ``max_cached`` segment weight-sets alive (LRU; a
     trained router uses few segments — App. E.2's h=2 gives 2-4 — but an
     untrained or large-h router can fragment the schedule).

Sites the 4-bit packer cannot represent — 8-bit io sites, INT-affine
plans, odd output widths, 1-D leaves — fall back to dense ``bf16`` so the
forward stays total.

Re-packing note: fine-tuning computes the merged weight in float; packing
snaps it back onto the searched FP4 grid (values pushed past ``maxval`` by
the adapter clip). This is the standard merged-LoRA deployment trade and
is what the engine's parity test measures.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.tree import flatten_paths, unflatten_paths
from repro.core import talora
from repro.core.msfp import QuantPlan, SiteInfo
from repro.core.qmodule import PackedW4, pack_weight
from repro.quant.fakequant import (KIND_FP_SIGNED, KIND_INT_AFFINE,
                                   QuantizerParams)
from repro.serving.obs import NULL_OBS


@dataclasses.dataclass(frozen=True)
class Segment:
    """Maximal run of timesteps [t_lo, t_hi] with identical routing."""

    index: int
    t_lo: int
    t_hi: int                 # inclusive
    slots: tuple              # per-layer selected hub slot (len = n_layers)

    def __contains__(self, t: int) -> bool:
        return self.t_lo <= t <= self.t_hi


def segments_of(signatures: np.ndarray) -> list[Segment]:
    """Contiguous equal-row runs of a (T, n_layers) signature sweep."""
    sig = np.asarray(signatures)
    assert sig.ndim == 2, sig.shape
    segs: list[Segment] = []
    lo = 0
    for t in range(1, sig.shape[0] + 1):
        if t == sig.shape[0] or not np.array_equal(sig[t], sig[lo]):
            segs.append(Segment(len(segs), lo, t - 1, tuple(sig[lo].tolist())))
            lo = t
    return segs


def _packable(site: str, w, plan: QuantPlan) -> bool:
    if site not in plan.sites or not plan.sites[site].is_weight:
        return False
    qp = plan.sites[site].qp
    if qp.bits != 4 or qp.kind == KIND_INT_AFFINE:
        return False
    if getattr(w, "ndim", 0) < 2 or w.shape[-1] % 2 != 0:
        return False
    mv = jnp.asarray(qp.maxval)
    if mv.ndim == 1 and not (w.ndim in (2, 4)          # dense or HWIO conv
                             and mv.shape[0] == w.shape[-1]):
        return False
    return mv.ndim <= 1


def pack_param_tree(params: dict, plan: QuantPlan, *,
                    fallback_dtype=jnp.bfloat16) -> tuple[dict, dict]:
    """Pack every plan-covered 4-bit FP weight; bf16 the rest of the planned
    weights; leave unplanned leaves (biases, norms) untouched.

    HWIO conv weights pack as their (kh*kw*cin, cout) flattening (see
    ``pack_weight``), so conv sites ride the same im2col Pallas matmul
    route as dense sites instead of the bf16-fallback bucket.

    Returns (tree, stats) with stats = {'packed': [...], 'fallback': [...]}.
    """
    flat = dict(flatten_paths(params))
    packed_sites, fallback_sites = [], []
    for site, w in flat.items():
        if isinstance(w, PackedW4):
            packed_sites.append(site)
            continue
        if _packable(site, w, plan):
            flat[site] = pack_weight(w, plan.sites[site].qp)
            packed_sites.append(site)
        elif site in plan.sites and plan.sites[site].is_weight:
            flat[site] = w.astype(fallback_dtype)
            fallback_sites.append(site)
    return unflatten_paths(flat), {"packed": packed_sites,
                                   "fallback": fallback_sites}


def default_serving_plan(weights: dict[str, Any], *,
                         io_sites: frozenset | set = frozenset()
                         ) -> QuantPlan:
    """Calibration-free deployment plan: signed E2M1 with abs-max grids.

    The searched plan (``msfp.build_mixed_plan``) is the paper-faithful
    path; this is the cheap bring-up default for the serving CLI / tests —
    every weight site gets a per-tensor abs-max signed FP4 quantizer, io
    sites get 8-bit (E4M3) which the packer treats as bf16 fallback.
    """
    sites: dict[str, SiteInfo] = {}
    for name, w in weights.items():
        mv = jnp.maximum(jnp.max(jnp.abs(w)).astype(jnp.float32), 1e-8)
        if name in io_sites:
            qp = QuantizerParams(KIND_FP_SIGNED, 4, 3, 8, mv)
        else:
            qp = QuantizerParams(KIND_FP_SIGNED, 2, 1, 4, mv)
        sites[name] = SiteInfo(qp, True, False, 0.0)
    return QuantPlan(sites, 4, 4, "msfp")


def absmax_talora_setup(params: dict, talora_cfg: talora.TALoRAConfig, key,
                        *, io_sites: frozenset | set = frozenset()
                        ) -> tuple[QuantPlan, dict, dict]:
    """Calibration-free bank inputs for a raw param tree.

    Shared by the serving launcher and bench: filters the packable weight
    sites, builds the abs-max plan, and initializes TALoRA hubs + router
    (untrained — routing is still a deterministic segmenting function).
    Returns (plan, hubs, router).
    """
    weights = {k: v for k, v in flatten_paths(params).items()
               if k.endswith("/w") and getattr(v, "ndim", 0) >= 2}
    plan = default_serving_plan(weights, io_sites=io_sites)
    dims = talora.lora_target_dims_from_weights(weights)
    k1, k2 = jax.random.split(key)
    hubs = talora.init_lora_hub(k1, dims, talora_cfg)
    router = talora.init_router(k2, len(dims), talora_cfg)
    return plan, hubs, router


def act_qps_from_plan(plan: QuantPlan | None) -> dict[str, QuantizerParams]:
    """Per-site activation quantizers the fused W4A4 kernel can consume.

    Serve-mode ``QuantContext`` feeds these to packed dense sites; only
    per-tensor FP quantizers qualify (INT-affine falls back to the plain
    packed matmul, which is still integer-packed — just not act-fused).
    """
    if plan is None:
        return {}
    out = {}
    for name, info in plan.sites.items():
        if info.is_weight or info.qp.kind == KIND_INT_AFFINE:
            continue
        if info.qp.bits != 4 or jnp.ndim(info.qp.maxval) != 0:
            continue
        out[name] = info.qp
    return out


class WeightBank:
    """LRU cache of per-segment TALoRA-merged, FP4-packed weight sets."""

    def __init__(self, q_params: dict, plan: QuantPlan | None, hubs: dict,
                 router: dict, talora_cfg: talora.TALoRAConfig, T: int, *,
                 max_cached: int = 4, fallback_dtype=jnp.bfloat16,
                 lock_factory=None, build_fn=None, signatures=None):
        self.q_params = q_params
        self.plan = plan
        # build_fn: alternative packer ``params -> packed tree`` replacing
        # the plan-driven ``pack_param_tree`` — the seam non-diffusion
        # engines (the gateway's LM adapter) use to reuse the bank's LRU /
        # single-build / counter machinery with their own quant recipe.
        # TALoRA merging still runs first when hubs are present.
        self.build_fn = build_fn
        if plan is None and build_fn is None:
            raise ValueError("WeightBank needs a QuantPlan or a build_fn")
        self.hubs = hubs
        self.router = router
        self.talora_cfg = talora_cfg
        self.T = T
        self.max_cached = max(1, max_cached)
        self.fallback_dtype = fallback_dtype
        self.names = sorted(hubs) if hubs else []

        if signatures is not None:
            # precomputed (T, k) routing-signature array overriding the
            # router evaluation — the seam fleet benches and placement
            # tests use to pin an exact segmentation (e.g. per-timestep)
            # without training a router to produce it
            sig = np.asarray(signatures)
            if sig.shape[0] != T:
                raise ValueError(f"signatures rows {sig.shape[0]} != T={T}")
        elif hubs and router is not None:
            sig = np.asarray(talora.routing_signatures(
                router, jnp.arange(T), self.names, talora_cfg))
        else:
            sig = np.zeros((T, 1), np.int32)   # no TALoRA: one segment
        self.signatures = sig
        self.segments = segments_of(sig)
        self._t_to_seg = np.zeros((T,), np.int32)
        for s in self.segments:
            self._t_to_seg[s.t_lo:s.t_hi + 1] = s.index

        # One lock guards the cache, the in-progress build registry, and
        # every counter: the async prefetch worker and the engine thread
        # race on all of them. Builds themselves (merge + pack jax work)
        # run outside the lock; a (seg -> Future) entry in ``_building``
        # is the single-build guarantee — any concurrent fetch joins the
        # future instead of building again. ``lock_factory`` is the
        # instrumentation seam: tools/analysis/lockcheck.py installs an
        # order-tracking lock here to verify that discipline at test time.
        self._lock = (lock_factory("bank._lock") if lock_factory is not None
                      else threading.Lock())
        self._building: dict[int, Future] = {}
        self._executor: ThreadPoolExecutor | None = None
        self._cache: OrderedDict[int, dict] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.prefetches = 0
        self.prefetch_hits = 0
        # builds + build_failures == misses + prefetches once drained;
        # build_joins = fetches that waited on an in-progress build.
        # build_failures keeps a background prefetch whose merge+pack
        # raised (the error only surfaces to whoever joins the future)
        # from silently breaking that reconciliation.
        self.builds = 0
        self.build_joins = 0
        self.build_failures = 0
        self._prefetched: set[int] = set()
        self.pack_stats: dict | None = None
        # (bank, seg) after every completed build install — the seam
        # simulated service clocks charge merge+pack time through (the
        # engine's on_forward equivalent for segment switches). Fired
        # outside ``_lock``; under a SimClock builds are synchronous
        # (attach forces sync prefetch), so the charge lands inside the
        # tick that stalled on the build.
        self.on_build: list = []
        # observability: the engine propagates its bundle here so build/
        # prefetch spans (including those emitted from the background
        # worker thread) land in the same trace buffer. Spans are emitted
        # *outside* ``_lock`` — the tracer has its own lock and must
        # never nest inside the bank's.
        self.obs = NULL_OBS

    # -- segment lookup ----------------------------------------------------

    def segment_of(self, t: int) -> int:
        t = int(t)
        if not 0 <= t < self.T:
            raise ValueError(f"timestep {t} outside schedule [0, {self.T})")
        return int(self._t_to_seg[t])

    @property
    def n_segments(self) -> int:
        return len(self.segments)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    # -- weight materialization --------------------------------------------

    def is_cached(self, seg: int) -> bool:
        """Ready now — switching to ``seg`` pays no build stall at all."""
        with self._lock:
            return seg in self._cache

    def is_building(self, seg: int) -> bool:
        """Mid-build — a fetch would join the in-progress build and stall
        for part of a merge+pack (the slo scheduler prices this at half
        the cold-build estimate)."""
        with self._lock:
            return seg in self._building

    def params_for_t(self, t: int) -> dict:
        return self.params_for_segment(self.segment_of(t))

    def params_for_segment(self, seg: int) -> dict:
        build_fut = None
        with self._lock:
            if seg in self._cache:
                self.hits += 1
                if seg in self._prefetched:
                    self.prefetch_hits += 1
                    self._prefetched.discard(seg)
                self._cache.move_to_end(seg)
                return self._cache[seg]
            fut = self._building.get(seg)
            if fut is None:
                self.misses += 1
                build_fut = fut = Future()
                self._building[seg] = fut
            else:
                # join the in-progress build instead of building twice;
                # the stall is shorter than a cold build, so it scores as
                # a hit (and a prefetch_hit when a prefetch started it)
                self.hits += 1
                self.build_joins += 1
                if seg in self._prefetched:
                    self.prefetch_hits += 1
                    self._prefetched.discard(seg)
        if build_fut is not None:
            return self._build_install(seg, build_fut)
        return fut.result()

    def prefetch(self, seg: int, *, block: bool = True) -> bool:
        """Eagerly build + cache a segment before any request asks for it
        (the engine calls this when in-flight samplers are about to cross
        into segment ``seg``). Not counted as a miss; the later
        ``params_for_segment`` hit on it counts as a ``prefetch_hit``.

        ``block=False`` hands the build to a single background worker
        thread so the next segment merges/packs while the current
        segment's forwards run; ``block=True`` builds inline (the
        VirtualClock replay path — thread interleaving must not be able
        to change admission/batching). Returns False without building
        when the segment is already cached or already being built.
        """
        with self._lock:
            if seg in self._cache or seg in self._building:
                return False
            fut = Future()
            self._building[seg] = fut
            self.prefetches += 1
            self._prefetched.add(seg)
            if not block:
                # create + submit under the lock: a concurrent drain()
                # swaps the executor out under the same lock, so a build
                # can never be enqueued on a shut-down worker
                if self._executor is None:
                    self._executor = ThreadPoolExecutor(
                        max_workers=1,
                        thread_name_prefix="weight-bank-prefetch")
                self._executor.submit(self._build_install, seg, fut)
        if self.obs.enabled:
            self.obs.tracer.instant("prefetch", cat="bank",
                                    args={"seg": seg, "block": block})
        if block:
            self._build_install(seg, fut)
        return True

    def drain(self) -> None:
        """Wait for every in-progress build to install (stats like
        ``builds == misses + prefetches`` only reconcile at rest), then
        release the idle worker thread — the next non-blocking prefetch
        lazily recreates it, so long-lived processes that churn through
        banks don't accumulate parked executors."""
        while True:
            with self._lock:
                futs = list(self._building.values())
            if not futs:
                break
            for f in futs:
                try:
                    f.result()
                except Exception:        # surfaced to the build's owner
                    pass
        with self._lock:
            executor, self._executor = self._executor, None
        if executor is not None:
            executor.shutdown(wait=True)

    def _build_install(self, seg: int, fut: Future) -> dict:
        """Build outside the lock, install under it, resolve the future.
        Only the thread that registered ``fut`` in ``_building`` runs
        this, so each registered build executes exactly once."""
        span = None
        if self.obs.enabled:
            # may run on the prefetch worker thread: the span lands on
            # that thread's track (tracer assigns tids per thread)
            span = self.obs.tracer.begin(
                "bank_build", cat="bank",
                args={"seg": seg,
                      "prefetch": seg in self._prefetched})
        try:
            params = self._build(self.segments[seg])
        except BaseException as e:
            with self._lock:
                self._building.pop(seg, None)
                self._prefetched.discard(seg)
                self.build_failures += 1
            if span is not None:
                span.args["error"] = repr(e)
                self.obs.tracer.end(span)
            fut.set_exception(e)
            raise
        if span is not None:
            self.obs.tracer.end(span)
        with self._lock:
            self._cache[seg] = params
            self._cache.move_to_end(seg)
            self._building.pop(seg, None)
            self.builds += 1
            self._trim()
        for cb in self.on_build:      # outside _lock, like the spans
            cb(self, seg)
        fut.set_result(params)
        return params

    def _trim(self) -> None:
        # caller holds self._lock
        while len(self._cache) > self.max_cached:
            evicted, _ = self._cache.popitem(last=False)
            self._prefetched.discard(evicted)
            self.evictions += 1

    def _build(self, seg: Segment) -> dict:
        params = self.q_params
        if self.hubs and self.router is not None:
            sels = {name: jax.nn.one_hot(seg.slots[i],
                                         self.talora_cfg.hub_size)
                    for i, name in enumerate(self.names)}
            params = talora.merge_into_tree(params, self.hubs, sels,
                                            self.talora_cfg)
        if self.build_fn is not None:
            packed = self.build_fn(params)
            flat = flatten_paths(packed)
            stats = {"packed": [k for k, v in flat.items()
                                if isinstance(v, PackedW4)],
                     "fallback": []}
        else:
            packed, stats = pack_param_tree(
                params, self.plan, fallback_dtype=self.fallback_dtype)
        if self.pack_stats is None:
            self.pack_stats = stats
        return packed

    def describe(self) -> dict:
        d = {"segments": self.n_segments, "cached": len(self._cache),
             "max_cached": self.max_cached, "hits": self.hits,
             "misses": self.misses, "evictions": self.evictions,
             "hit_rate": self.hit_rate, "prefetches": self.prefetches,
             "prefetch_hits": self.prefetch_hits, "builds": self.builds,
             "build_joins": self.build_joins,
             "build_failures": self.build_failures}
        if self.pack_stats is not None:
            d["packed_sites"] = len(self.pack_stats["packed"])
            d["fallback_sites"] = len(self.pack_stats["fallback"])
        return d
