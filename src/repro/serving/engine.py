"""Diffusion serving engine: continuous-batched denoising on packed W4A4.

One engine *tick*:

  1. admit arrived requests into free in-flight slots (priority desc,
     then FIFO; due requests past their deadline are expired instead —
     see ``scheduler.ContinuousBatcher.admit``),
  2. group in-flight requests by the weight-bank segment of the timestep
     each sampler needs next, pick one group (scheduler policy),
  3. fetch that segment's pre-merged, pre-packed weights from the bank
     (LRU — the common case is a hit, since consecutive sampler steps
     stay inside a routing segment),
  4. run ONE batched model forward per class-conditioning partition
     (per-sample ``t``; CFG-guided requests contribute a cond + uncond
     pair and are recombined as ``eps_u + s * (eps_c - eps_u)``) — batches
     pad to power-of-two buckets (outputs masked by slicing) so the jit
     cache stays bounded under churny in-flight counts,
  5. advance each request's sampler state; retire finished requests.

The forward runs under a *serve-mode* ``QuantContext`` — activation
quantization happens inside the fused W4A4 kernel for packed dense sites
and there is no fake-quant anywhere on this path; weights are real packed
uint8 nibbles end-to-end (``kernels/ops`` dispatch).

The engine exposes callback hooks for the traffic subsystem
(``serving/traffic``): ``on_submit`` (trace capture), ``on_complete`` /
``on_expire`` (closed-loop generators, SLO metrics), ``on_tick_end``
(queue-depth / cache time series). After each tick it prefetches the
weight-bank segments that in-flight samplers will need next, so a
segment boundary crossing finds its merged+packed weights already built
(``stats()['prefetch_hits']``). Under a wall clock the prefetch is
*asynchronous* — the bank's background thread merges/packs the next
segment while the current segment's forwards run; under a
``VirtualClock`` it stays synchronous so replay digests are
deterministic.

``policy="slo"`` switches group selection from largest-group-wins to the
slack-aware scheduler (EDF pressure weighted against segment-switch
cost, with group-splitting preemption — see ``scheduler``); the engine
feeds the scheduler's ``CostModel`` with observed forward and
segment-build durations measured on the engine clock.

Passing an enabled ``serving.obs.Observability`` turns on structured
telemetry: request/tick/fetch/forward spans on the engine clock, per-tick
registry samples, and propagation of the obs bundle into the scheduler
and weight bank (their decision/build spans land in the same trace).
With the default ``NULL_OBS`` every instrumentation point is one
``obs.enabled`` branch — the serving path is unchanged.
"""
from __future__ import annotations

import time
from typing import Callable

import jax
import jax.numpy as jnp

from repro.diffusion.samplers import (sampler_advance, sampler_init,
                                      sampler_needed_t)
from repro.diffusion.schedule import NoiseSchedule
from repro.nn.unet import UNetConfig, unet_apply
from repro.quant.calibrate import QuantContext
from repro.serving.obs import NULL_OBS, Observability
from repro.serving.scheduler import (ContinuousBatcher, GenRequest,
                                     RequestState, bucket_of)
from repro.serving.traffic.metrics import percentile
from repro.serving.weight_bank import WeightBank

# role of one eval item in its request: plain, or half of a CFG pair
_PLAIN, _UNCOND, _COND = 0, 1, 2


class VirtualClock:
    """Deterministic replay clock: time only moves when the idle driver
    advances it to the next arrival, never during compute. Trace replay
    under a virtual clock admits/batches identically across runs and
    machines (the CI determinism check), at the cost of wall-latency
    metrics — latencies read ~0 and deadlines never expire, so use the
    default wall clock when measuring SLOs."""

    def __init__(self, t: float = 0.0):
        self.t = t

    def now(self) -> float:
        return self.t

    def advance_to(self, t: float) -> None:
        self.t = max(self.t, t)


class DiffusionServingEngine:
    """Owns the denoising loop for many concurrent generation requests."""

    def __init__(self, cfg: UNetConfig, sched: NoiseSchedule,
                 bank: WeightBank, *,
                 act_qps: dict | None = None,
                 apply_fn: Callable | None = None,
                 max_batch: int = 8, starvation_ticks: int = 4,
                 policy: str = "fifo",
                 now_fn: Callable[[], float] | None = None,
                 clock: VirtualClock | None = None,
                 max_idle_sleep: float = 0.25,
                 prefetch: bool = True,
                 async_prefetch: bool = True,
                 obs: Observability | None = None,
                 model: str | None = None):
        # model: identity label when hosted behind the multi-model gateway
        # (obs gauges/spans carry it; None keeps single-model output
        # byte-identical to the pre-gateway format)
        self.model = model
        # replica: identity label when hosted as a fleet replica (the
        # FleetRouter sets it after construction); obs gauges gain a
        # {replica=...} label and spans land on a per-replica track
        self.replica: str | None = None
        self.cfg = cfg
        self.sched = sched
        self.bank = bank
        self.ctx = QuantContext("serve", act_qps=act_qps or {})
        self._apply = apply_fn or (
            lambda params, x, tb, y, ctx: unet_apply(params, x, tb, cfg,
                                                     y=y, ctx=ctx))
        self.batcher = ContinuousBatcher(max_batch, starvation_ticks,
                                         policy=policy)
        self.batcher.segment_warm = bank.is_cached
        self.batcher.segment_building = bank.is_building
        if clock is not None:
            self._now = clock.now
            self._advance = clock.advance_to
        else:
            t0 = time.monotonic()
            self._now = now_fn or (lambda: time.monotonic() - t0)
            self._advance = None
        self.max_idle_sleep = max_idle_sleep
        self.prefetch_enabled = prefetch
        # background builds only make sense when real time passes during
        # compute; a VirtualClock replay must build synchronously so the
        # golden-trace digest stays deterministic.
        self.async_prefetch = async_prefetch and self._advance is None
        # observability: the tracer follows the *engine's* clock (so a
        # VirtualClock replay traces deterministically) and propagates to
        # the scheduler and bank so their spans land in the same buffer.
        self.obs = obs or NULL_OBS
        if self.obs.enabled:
            self.obs.bind_engine(self)
            self.batcher.obs = self.obs
            if self.bank.obs is NULL_OBS:
                self.bank.obs = self.obs
            self._h_forward = self.obs.metrics.histogram(
                "engine_forward_seconds",
                help="engine-clock batched-forward durations (the same "
                     "observations the scheduler cost EWMA consumes)")
            self._h_fetch = self.obs.metrics.histogram(
                "bank_fetch_seconds",
                help="engine-clock stalls fetching the tick's segment")
        self._jit: dict[tuple, Callable] = {}
        self._last_padded_rows = 0
        self._next_rid = 0
        self.tick_count = 0
        self.n_forwards = 0
        self.n_samples_batched = 0
        self.n_padded_samples = 0
        self.n_idle_sleeps = 0
        self.n_finished = 0
        self.n_expired = 0
        self._latencies: list[float] = []    # scalars only; never evicted
        self.results: dict[int, RequestState] = {}
        # traffic-subsystem hooks; each receives the RequestState (or the
        # engine itself for on_tick_end)
        self.on_submit: list[Callable] = []
        self.on_complete: list[Callable] = []
        self.on_expire: list[Callable] = []
        self.on_tick_end: list[Callable] = []
        # (engine, padded_rows) once per tick's batched forwards — the
        # seam simulated service clocks charge compute through
        self.on_forward: list[Callable] = []

    def now(self) -> float:
        return self._now()

    # -- request lifecycle -------------------------------------------------

    def submit(self, *, steps: int = 20, eta: float = 0.0, seed: int = 0,
               sampler: str = "ddim", y: int | None = None,
               guidance_scale: float = 0.0, arrival: float = 0.0,
               deadline: float | None = None, priority: int = 0,
               user: int | None = None, parent: int | None = None,
               think_s: float | None = None) -> int:
        if guidance_scale > 0 and (y is None or not self.cfg.num_classes):
            raise ValueError("guidance needs a class label y and a "
                             "class-conditional model")
        rid = self._next_rid
        self._next_rid += 1
        req = GenRequest(rid, steps, eta, seed, sampler, y, guidance_scale,
                         arrival, deadline, priority, user, parent, think_s)
        shape = (1, self.cfg.image_size, self.cfg.image_size, self.cfg.in_ch)
        state = sampler_init(sampler, self.sched, shape,
                             jax.random.PRNGKey(seed), steps=steps, eta=eta)
        rs = RequestState(req, state, submitted_at=self._now())
        self.batcher.submit(rs)
        if self.obs.enabled:
            self.obs.tracer.set_track(self.replica or self.model)
            self.obs.tracer.async_begin(
                "request", rid, cat="request",
                args={"steps": steps, "sampler": sampler,
                      "arrival": arrival, "deadline": deadline,
                      "priority": priority,
                      "cfg": guidance_scale > 0})
        for cb in self.on_submit:
            cb(rs)
        return rid

    # -- one engine tick ---------------------------------------------------

    def tick(self) -> list[RequestState]:
        obs = self.obs
        tick_span = None
        if obs.enabled:
            obs.tracer.set_track(self.replica or self.model)
            tick_span = obs.tracer.begin(
                "tick", cat="engine", args={"tick": self.tick_count})
        now = self._now()
        admitted, expired = self.batcher.admit(now, self.tick_count)
        if obs.enabled:
            for rs in admitted:
                obs.tracer.async_instant("admit", rs.req.rid, cat="request")
        for rs in expired:
            rs.finished_at = now
            self.results[rs.req.rid] = rs
            self.n_expired += 1
            if obs.enabled:
                obs.tracer.async_end("request", rs.req.rid, cat="request",
                                     args={"outcome": "expired"})
            for cb in self.on_expire:
                cb(rs)
        if not self.batcher.inflight:
            if obs.enabled:
                tick_span.args["idle"] = True
                obs.tracer.end(tick_span)
                obs.sample(self)
            for cb in self.on_tick_end:
                cb(self)
            return []
        groups = self.batcher.groups(
            lambda rs: self.bank.segment_of(sampler_needed_t(rs.state)))
        seg, members = self.batcher.select(groups, self.tick_count, now=now)
        self.batcher.current_seg = seg
        fetch_span = None
        if obs.enabled:
            tick_span.args.update(
                {"seg": seg, "members": [rs.req.rid for rs in members],
                 "n_groups": len(groups), "policy": self.batcher.policy})
            fetch_span = obs.tracer.begin("bank_fetch", cat="bank",
                                          args={"seg": seg})
        t_fetch = self._now()
        misses_before = self.bank.misses
        joins_before = self.bank.build_joins
        params = self.bank.params_for_segment(seg)
        if self.bank.misses > misses_before:
            # cold fetch: the observed stall is the segment-switch cost
            self.batcher.cost.observe_switch(self._now() - t_fetch)
        elif self.bank.build_joins > joins_before:
            # joined an async build mid-way: with prefetch on this is the
            # common cold path (prefetch registers the build before the
            # fetch, so `misses` never moves) — without it the switch
            # EWMA would stay pinned to the first cold build forever.
            # The stall is the remaining ~half of a build on average.
            self.batcher.cost.observe_switch(2 * (self._now() - t_fetch))
        if obs.enabled:
            fetch_span.args["outcome"] = (
                "miss" if self.bank.misses > misses_before
                else "join" if self.bank.build_joins > joins_before
                else "hit")
            obs.tracer.end(fetch_span)
            self._h_fetch.observe(self._now() - t_fetch)

        # build eval items: (rs, role, t, x (1,H,W,C), y)
        items = []
        for rs in members:
            t = sampler_needed_t(rs.state)
            x = rs.state.eval_x
            if rs.req.guidance_scale > 0:
                items.append((rs, _UNCOND, t, x, None))
                items.append((rs, _COND, t, x, rs.req.y))
            else:
                items.append((rs, _PLAIN, t, x, rs.req.y))

        fwd_span = None
        if obs.enabled:
            fwd_span = obs.tracer.begin("forward", cat="engine",
                                        args={"items": len(items)})
        t_compute = self._now()
        n_jit_before = len(self._jit)
        eps_by_item = self._run_partitions(params, items)
        compiled = len(self._jit) > n_jit_before
        if not compiled:
            # skip ticks that traced+compiled a new (bucket, has_y)
            # forward: seeding the EWMA with compile time would poison
            # slack estimates for many subsequent ticks
            self.batcher.cost.observe_eval(self._now() - t_compute,
                                           self._last_padded_rows)
        if obs.enabled:
            dt = self._now() - t_compute
            fwd_span.args.update({"padded_rows": self._last_padded_rows,
                                  "compiled": compiled})
            obs.tracer.end(fwd_span)
            # the same engine-clock observation the cost EWMA consumed
            if not compiled:
                self._h_forward.observe(dt)

        finished = []
        tick = self.tick_count
        for rs in members:
            parts = eps_by_item[id(rs)]
            if _PLAIN in parts:
                eps = parts[_PLAIN]
            else:
                s = rs.req.guidance_scale
                eps = parts[_UNCOND] + s * (parts[_COND] - parts[_UNCOND])
            sampler_advance(rs.state, eps)
            rs.last_advance_tick = tick
            rs.n_evals += 1
            if obs.enabled:
                obs.tracer.async_instant("eval", rs.req.rid, cat="request",
                                         args={"n_evals": rs.n_evals})
            if rs.state.done:
                rs.x0 = rs.state.x
                rs.finished_at = self._now()
                self.batcher.retire(rs)
                self.results[rs.req.rid] = rs
                self.n_finished += 1
                self._latencies.append(rs.latency)
                finished.append(rs)
                if obs.enabled:
                    obs.tracer.async_end(
                        "request", rs.req.rid, cat="request",
                        args={"outcome": "complete",
                              "n_evals": rs.n_evals,
                              "latency_s": rs.latency})
                for cb in self.on_complete:
                    cb(rs)
        self.tick_count += 1
        if self.prefetch_enabled:
            # Requests that just advanced may cross into a new routing
            # segment next step — build/pack it before it is asked for.
            # Async mode hands the build to the bank's background thread
            # so the next segment merges/packs while this segment's
            # forwards keep running; a later fetch joins the in-progress
            # build instead of rebuilding.
            for s in {self.bank.segment_of(sampler_needed_t(rs.state))
                      for rs in members if not rs.state.done}:
                self.bank.prefetch(s, block=not self.async_prefetch)
        if obs.enabled:
            tick_span.args["finished"] = len(finished)
            obs.tracer.end(tick_span)
            obs.sample(self)
        for cb in self.on_tick_end:
            cb(self)
        return finished

    def _run_partitions(self, params, items) -> dict[int, dict]:
        """One batched forward per class-conditioning partition.

        ``unet_apply`` takes a single optional ``y`` array, so items with
        and without a label cannot share a forward; each partition still
        batches arbitrary timesteps (``t`` is per-sample).
        """
        eps_by_item: dict[int, dict] = {}
        padded_rows = 0
        for has_y in (False, True):
            part = [it for it in items if (it[4] is not None) == has_y]
            if not part:
                continue
            x = jnp.concatenate([it[3] for it in part], axis=0)
            tb = jnp.asarray([it[2] for it in part], jnp.float32)
            y = (jnp.asarray([it[4] for it in part], jnp.int32)
                 if has_y else None)
            eps = self._forward(params, x, tb, y)
            self.n_forwards += 1
            self.n_samples_batched += len(part)
            padded_rows += self._bucket(len(part))
            for j, (rs, role, *_rest) in enumerate(part):
                eps_by_item.setdefault(id(rs), {})[role] = eps[j:j + 1]
        self._last_padded_rows = padded_rows
        for cb in self.on_forward:
            cb(self, padded_rows)
        return eps_by_item

    # Partition batches pad to power-of-two buckets so churny in-flight
    # counts reuse a handful of compiled forwards instead of one jit entry
    # per distinct batch size; the scheduler's cost model shares the same
    # bucket function so slack estimates price the padding.
    _bucket = staticmethod(bucket_of)

    def _forward(self, params, x, tb, y):
        n = x.shape[0]
        b = self._bucket(n)
        if b != n:
            # Pad with copies of row 0 (always finite through norms) and
            # mask by slicing the padded outputs away below.
            pad = b - n
            x = jnp.concatenate([x, jnp.repeat(x[:1], pad, axis=0)], axis=0)
            tb = jnp.concatenate([tb, jnp.repeat(tb[:1], pad)], axis=0)
            if y is not None:
                y = jnp.concatenate([y, jnp.repeat(y[:1], pad)], axis=0)
            self.n_padded_samples += pad
        key = (b, y is not None)
        if key not in self._jit:
            if y is None:
                self._jit[key] = jax.jit(
                    lambda p, x, tb: self._apply(p, x, tb, None, self.ctx))
            else:
                self._jit[key] = jax.jit(
                    lambda p, x, tb, y: self._apply(p, x, tb, y, self.ctx))
        fn = self._jit[key]
        eps = fn(params, x, tb) if y is None else fn(params, x, tb, y)
        return eps[:n]

    def pop_result(self, rid: int) -> RequestState:
        """Hand a finished request to its caller and release the engine's
        reference (a long-lived engine must not retain every generated
        latent; latency scalars stay for ``stats``)."""
        return self.results.pop(rid)

    # -- driver ------------------------------------------------------------

    def run(self, *, max_idle_sleep: float | None = None
            ) -> dict[int, RequestState]:
        """Tick until every submitted request has finished or expired.

        While idle (nothing in flight, next arrival in the future) the
        driver sleeps until that arrival in one shot — capped at
        ``max_idle_sleep`` (engine default unless overridden here) as a
        clock-skew guard — instead of spinning a millisecond poll loop.

        Under a ``VirtualClock`` the driver instead advances the clock to
        the next arrival whenever an in-flight slot is free — arrival
        gaps are treated as instantaneous relative to service, so replay
        batches greedily and deterministically. The trace's arrival
        *order* and priorities still apply, but deadlines can never
        expire (virtual time never passes a pending request's own
        arrival) — score SLOs under the wall clock.
        """
        cap = self.max_idle_sleep if max_idle_sleep is None else max_idle_sleep
        while self.batcher.pending or self.batcher.inflight:
            if (self._advance is not None and self.batcher.pending
                    and len(self.batcher.inflight) < self.batcher.max_batch):
                nxt = self.batcher.next_arrival()
                if nxt > self._now():
                    self._advance(nxt)
                    self.n_idle_sleeps += 1
            self.tick()
            if (self._advance is None and not self.batcher.inflight
                    and self.batcher.pending):
                wait = self.batcher.next_arrival() - self._now()
                # cap <= 0 means "never sleep" (simulated clocks spin
                # through ticks to advance time) — sleep(0) would busy-
                # spin while still counting as an idle sleep
                if wait > 0 and cap > 0:
                    time.sleep(min(wait, cap))
                    self.n_idle_sleeps += 1
        # settle outstanding background builds so post-run stats (builds
        # vs misses+prefetches) reconcile deterministically
        self.bank.drain()
        return self.results

    # -- metrics -----------------------------------------------------------

    def stats(self) -> dict:
        lat = sorted(self._latencies)
        buckets = sorted({k[0] for k in self._jit})
        d = {"requests": self.n_finished, "ticks": self.tick_count,
             "expired": self.n_expired,
             "policy": self.batcher.policy,
             "preemptions": self.batcher.preemptions,
             "deadline_saves": self.batcher.deadline_saves,
             "forwards": self.n_forwards,
             "mean_batch": (self.n_samples_batched / self.n_forwards
                            if self.n_forwards else 0.0),
             "compiled_forwards": len(self._jit),
             "buckets": buckets,
             "padded_samples": self.n_padded_samples,
             "idle_sleeps": self.n_idle_sleeps,
             "prefetch_hits": self.bank.prefetch_hits,
             "p50_s": percentile(lat, 50), "p95_s": percentile(lat, 95),
             "p99_s": percentile(lat, 99)}
        d.update({f"bank_{k}": v for k, v in self.bank.describe().items()})
        return d
