"""Diffusion serving: TALoRA-merged weight bank + continuous-batched engine.

The deployment story of App. E made concrete: the TALoRA router is a
deterministic function of the timestep, so the denoising trajectory splits
into contiguous *segments* with identical routing. ``WeightBank``
pre-merges and pre-packs one real packed-FP4 weight set per segment;
``DiffusionServingEngine`` continuously batches many users' generation
requests through one quantized UNet forward per tick.
"""
from repro.serving.weight_bank import (WeightBank, Segment, segments_of,
                                       absmax_talora_setup, act_qps_from_plan,
                                       default_serving_plan)
from repro.serving.scheduler import GenRequest, RequestState, ContinuousBatcher
from repro.serving.engine import DiffusionServingEngine, VirtualClock
from repro.serving import traffic
from repro.serving import obs
from repro.serving.obs import NULL_OBS, Observability
