"""Mesh-agnostic activation sharding hints.

Model code annotates activations with *logical* specs (axis-name strings);
``shard_hint`` filters them against the ambient mesh (axes that exist,
divisibility) so the same model runs on 1 CPU device, a 16x16 pod, or the
2x16x16 multi-pod mesh without edits.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

# Logical data-parallel axes. ``DP`` is a *sentinel* resolved at trace
# time against ``_DP_AXES`` so model modules that imported it by value
# still honor set_dp_axes() — the small-model pure-DP mode (dpall) extends
# batch sharding over the model axis and the activation hints must agree
# with the input shardings or GSPMD inserts reshards.
DP = "__dp__"
MODEL = "model"
_DP_AXES: tuple = ("pod", "data")


def set_dp_axes(axes: tuple) -> None:
    global _DP_AXES
    _DP_AXES = tuple(axes)


def _expand(entry):
    if entry == DP:
        return _DP_AXES
    if isinstance(entry, tuple):
        out = []
        for e in entry:
            out.extend(_DP_AXES if e == DP else (e,))
        return tuple(out)
    return entry


def ambient_mesh():
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and m.axis_names:
            return m
    except Exception:
        pass
    # Older JAX (no get_abstract_mesh / jax.set_mesh): ``with mesh:`` sets
    # the thread-resources physical mesh instead.
    try:
        from jax.interpreters import pxla
        m = pxla.thread_resources.env.physical_mesh
        if m is not None and not m.empty and m.axis_names:
            return m
    except Exception:
        pass
    return None


def _filter_entry(entry, dim: int, axis_sizes: dict[str, int]):
    if entry is None:
        return None
    names = (entry,) if isinstance(entry, str) else tuple(entry)
    kept = []
    prod = 1
    for n in names:
        if n in axis_sizes and dim % (prod * axis_sizes[n]) == 0:
            kept.append(n)
            prod *= axis_sizes[n]
    if not kept:
        return None
    return kept[0] if len(kept) == 1 else tuple(kept)


def logical_spec(shape: tuple, entries: tuple) -> P:
    """Resolve logical entries against the ambient mesh; P() if no mesh."""
    mesh = ambient_mesh()
    if mesh is None:
        return P()
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    resolved = [_filter_entry(_expand(e), shape[i], sizes)
                for i, e in enumerate(entries)]
    return P(*resolved)


def shard_hint(x, *entries):
    """with_sharding_constraint against the ambient mesh; no-op without one.

    entries: per-dim logical axis name(s) or None, e.g.
    ``shard_hint(h, DP, None, None)`` for (batch, seq, d_model).
    """
    mesh = ambient_mesh()
    if mesh is None:
        return x
    spec = logical_spec(x.shape, entries)
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x
