"""The one sanctioned wall-clock seam for serving/launch code.

Everything inside ``serving/`` and ``launch/`` that needs a timestamp
for *scheduling or replay* must go through the engine's clock (the
``now_fn``/``clock`` constructor seams on ``DiffusionServingEngine``) so
``VirtualClock``/``SimClock`` replays stay bit-identical — repolint's
``clock-discipline`` rule bans ``time.time()`` / ``time.perf_counter()``
/ argless ``datetime.now()`` there outside clock classes.

Human-facing *diagnostic* timing (startup prints, ``wall_s`` report
fields) is the one legitimate wall-clock consumer left, and it funnels
through ``wall_clock()`` here: one site to audit, one name the linter
recognizes as sanctioned, and one place to swap if diagnostics ever
need to follow a replay clock too. Never feed ``wall_clock()`` into
admission, batching, deadlines, or anything a replay digest covers.
"""
from __future__ import annotations

import time


def wall_clock() -> float:
    """Monotonic seconds for diagnostic durations (``t1 - t0``).

    Deliberately ``perf_counter`` (not ``time.time``): it never jumps on
    NTP adjustments, so startup/report durations can't go negative.
    """
    return time.perf_counter()
