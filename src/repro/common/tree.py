"""Path-keyed pytree utilities (nested dicts of arrays)."""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp


def flatten_paths(tree: Any, prefix: str = "", sep: str = "/") -> dict[str, Any]:
    """Nested dicts/lists -> {'a/b/#0/c': leaf} (lists keyed '#<idx>')."""
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(flatten_paths(v, f"{prefix}{k}{sep}", sep))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(flatten_paths(v, f"{prefix}#{i}{sep}", sep))
    else:
        out[prefix[: -len(sep)]] = tree
    return out


def unflatten_paths(flat: dict[str, Any], sep: str = "/") -> Any:
    root: dict = {}
    for path, leaf in flat.items():
        parts = path.split(sep)
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = leaf

    def listify(node):
        if not isinstance(node, dict):
            return node
        node = {k: listify(v) for k, v in node.items()}
        if node and all(k.startswith("#") for k in node):
            return [node[f"#{i}"] for i in range(len(node))]
        return node

    return listify(root)


def map_with_path(fn: Callable[[str, Any], Any], tree: Any,
                  prefix: str = "", sep: str = "/") -> Any:
    if isinstance(tree, dict):
        return {k: map_with_path(fn, v, f"{prefix}{k}{sep}", sep)
                for k, v in tree.items()}
    return fn(prefix[: -len(sep)], tree)


def get_path(tree: Any, path: str, sep: str = "/") -> Any:
    node = tree
    for p in path.split(sep):
        node = node[p]
    return node


def set_path(tree: dict, path: str, value: Any, sep: str = "/") -> dict:
    """Functional set: returns a new tree with ``path`` replaced."""
    parts = path.split(sep)
    new = dict(tree)
    node = new
    for p in parts[:-1]:
        node[p] = dict(node[p])
        node = node[p]
    node[parts[-1]] = value
    return new


def tree_bytes(tree: Any) -> int:
    leaves = jax.tree.leaves(tree)
    return sum(l.size * l.dtype.itemsize for l in leaves
               if hasattr(l, "size") and hasattr(l, "dtype"))


def count_params(tree: Any) -> int:
    return sum(l.size for l in jax.tree.leaves(tree) if hasattr(l, "size"))


def cast_tree(tree: Any, dtype) -> Any:
    return jax.tree.map(
        lambda l: l.astype(dtype) if hasattr(l, "astype")
        and jnp.issubdtype(l.dtype, jnp.floating) else l, tree)
