"""Shared utilities: pytree paths, sharding hints, the wall-clock seam."""
from repro.common.clock import wall_clock

__all__ = ["wall_clock"]
