"""Shared utilities: pytree paths, sharding hints."""
