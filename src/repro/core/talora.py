"""TALoRA — Timestep-Aware LoRA hub + learnable router (paper §4.2).

Each quantized layer carries a hub of ``h`` LoRA adapters. A single router,
shared across all timesteps, maps the (pre-trained, frozen) sinusoidal
timestep embedding through an MLP to per-(layer, slot) logits; a
straight-through argmax turns those into a hard one-of-h selection, so
exactly one adapter is active per layer per timestep (App. E: inference
cost equals a single LoRA) while gradients still reach the router through
the softmax.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

# repolint: disable=import-layering — TA-LoRA conditions its hub mixture
# on the same sinusoidal timestep embedding the model consumes (paper
# Sec. 4.2); duplicating the embedding here would let the two drift.
# Accepted single upward edge core -> nn until the embedding moves to a
# shared home.
from repro.nn.embeddings import timestep_embedding


@dataclasses.dataclass(frozen=True)
class TALoRAConfig:
    hub_size: int = 2          # h — paper finds h=2 optimal (App. E.2)
    rank: int = 32             # paper App. C
    alpha: float = 32.0        # scaling = alpha / rank
    router_hidden: int = 128
    t_emb_dim: int = 128       # timestep embedding dim fed to the router


def init_lora_hub(key, layer_dims: dict[str, tuple[int, int]],
                  cfg: TALoRAConfig, dtype=jnp.float32) -> dict[str, Any]:
    """Per-layer hubs: A ~ N(0, 1/r) (h, in, r); B = 0 (h, r, out)."""
    hubs = {}
    for name, (d_in, d_out) in layer_dims.items():
        key, k = jax.random.split(key)
        hubs[name] = {
            "A": (jax.random.normal(k, (cfg.hub_size, d_in, cfg.rank), dtype)
                  / jnp.sqrt(cfg.rank)),
            "B": jnp.zeros((cfg.hub_size, cfg.rank, d_out), dtype),
        }
    return hubs


def init_router(key, n_layers: int, cfg: TALoRAConfig,
                dtype=jnp.float32) -> dict[str, Any]:
    k1, k2 = jax.random.split(key)
    scale1 = 1.0 / jnp.sqrt(cfg.t_emb_dim)
    scale2 = 1.0 / jnp.sqrt(cfg.router_hidden)
    return {
        "w1": jax.random.normal(k1, (cfg.t_emb_dim, cfg.router_hidden), dtype) * scale1,
        "b1": jnp.zeros((cfg.router_hidden,), dtype),
        "w2": jax.random.normal(k2, (cfg.router_hidden, n_layers * cfg.hub_size), dtype) * scale2,
        "b2": jnp.zeros((n_layers * cfg.hub_size,), dtype),
    }


def router_logits(router: dict, t: jnp.ndarray, n_layers: int,
                  cfg: TALoRAConfig) -> jnp.ndarray:
    """(n_layers, h) logits for scalar timestep t."""
    emb = timestep_embedding(jnp.asarray(t, jnp.float32), cfg.t_emb_dim)
    hdn = jnp.tanh(emb @ router["w1"] + router["b1"])
    out = hdn @ router["w2"] + router["b2"]
    return out.reshape(n_layers, cfg.hub_size)


def ste_one_hot(logits: jnp.ndarray) -> jnp.ndarray:
    """Hard one-hot over the last axis; softmax gradient (STE, ref. [1])."""
    soft = jax.nn.softmax(logits, axis=-1)
    hard = jax.nn.one_hot(jnp.argmax(logits, axis=-1), logits.shape[-1],
                          dtype=soft.dtype)
    return soft + jax.lax.stop_gradient(hard - soft)


def route(router: dict, t: jnp.ndarray, layer_names: list[str],
          cfg: TALoRAConfig) -> dict[str, jnp.ndarray]:
    """Per-layer hard selection weights (h,) for timestep t."""
    sel = ste_one_hot(router_logits(router, t, len(layer_names), cfg))
    return {name: sel[i] for i, name in enumerate(layer_names)}


def lora_delta(x: jnp.ndarray, hub: dict[str, jnp.ndarray],
               sel: jnp.ndarray, cfg: TALoRAConfig) -> jnp.ndarray:
    """Selected adapter's contribution: (x @ A_sel) @ B_sel * alpha/r.

    ``sel`` is the (h,) STE one-hot; contracting the hub with it keeps the
    router differentiable while executing a single adapter's math.
    """
    a_sel = jnp.einsum("h,hir->ir", sel, hub["A"])
    b_sel = jnp.einsum("h,hro->ro", sel, hub["B"])
    scale = cfg.alpha / cfg.rank
    return ((x @ a_sel) @ b_sel) * scale


def lora_apply(x: jnp.ndarray, w_q: jnp.ndarray, hub: dict | None,
               sel: jnp.ndarray | None, cfg: TALoRAConfig) -> jnp.ndarray:
    """y = x @ W_quantized + LoRA_sel(x)."""
    y = x @ w_q
    if hub is not None and sel is not None:
        y = y + lora_delta(x, hub, sel, cfg)
    return y


def merged_weight(w_q: jnp.ndarray, hub: dict, sel: jnp.ndarray,
                  cfg: TALoRAConfig) -> jnp.ndarray:
    """W_q + A_sel B_sel * alpha/r — used to fold the adapter for serving."""
    a_sel = jnp.einsum("h,hir->ir", sel, hub["A"])
    b_sel = jnp.einsum("h,hro->ro", sel, hub["B"])
    return w_q + (a_sel @ b_sel) * (cfg.alpha / cfg.rank)


def lora_target_dims_from_weights(weights: dict[str, jnp.ndarray],
                                  cfg: TALoRAConfig | None = None
                                  ) -> dict[str, tuple[int, int]]:
    """Generic LoRA dims for flat path->weight maps: (prod(in dims), out).

    Covers dense (in, out) and conv (kh, kw, cin, cout) sites uniformly —
    a conv LoRA with A reshaped to (kh, kw, cin, r) is exactly the low-rank
    kernel update ``(A @ B).reshape(w.shape)``.
    """
    dims = {}
    for name, w in weights.items():
        if hasattr(w, "ndim") and w.ndim >= 2:
            d_in = 1
            for s in w.shape[:-1]:
                d_in *= s
            dims[name] = (d_in, w.shape[-1])
    return dims


def merge_into_tree(params: dict, hubs: dict[str, dict],
                    sels: dict[str, jnp.ndarray], cfg: TALoRAConfig) -> dict:
    """Fold each site's selected adapter into its (frozen, fake-quantized)
    weight: w_eff = w_q + (A_sel @ B_sel).reshape(w.shape) * alpha/r.

    Identical math to running the adapter as a parallel branch (for both
    dense and conv sites) but keeps model code LoRA-agnostic. ``params`` is
    a nested tree; hub keys are '/'-joined weight paths (ending in the
    param leaf name, e.g. 'mid/attn/q/w').
    """
    from repro.common.tree import flatten_paths, unflatten_paths

    flat = flatten_paths(params)
    scale = cfg.alpha / cfg.rank
    for site, hub in hubs.items():
        sel = sels[site]
        w = flat[site]
        a_sel = jnp.einsum("h,hir->ir", sel, hub["A"])
        b_sel = jnp.einsum("h,hro->ro", sel, hub["B"])
        delta = (a_sel @ b_sel).reshape(w.shape) * scale
        flat[site] = jax.lax.stop_gradient(w) + delta.astype(w.dtype)
    return unflatten_paths(flat)


def routing_signatures(router: dict, timesteps: jnp.ndarray,
                       layer_names: list[str],
                       cfg: TALoRAConfig) -> jnp.ndarray:
    """(T, n_layers) int32 hard slot selection per timestep.

    The router is a deterministic function of t, so this sweep defines the
    contiguous timestep *segments* with identical routing — the unit the
    serving weight bank pre-merges and pre-packs (one merged LoRA per
    segment, App. E's deployment cost argument).
    """
    n = len(layer_names)

    def per_t(t):
        return jnp.argmax(router_logits(router, t, n, cfg), axis=-1)

    return jax.vmap(per_t)(jnp.asarray(timesteps, jnp.float32)).astype(
        jnp.int32)


def allocation_histogram(router: dict, timesteps: jnp.ndarray,
                         layer_names: list[str],
                         cfg: TALoRAConfig) -> jnp.ndarray:
    """(T, h) fraction of layers routed to each hub slot per timestep —

    reproduces the paper's Fig. 7/9 allocation-over-timesteps plots."""
    def per_t(t):
        logits = router_logits(router, t, len(layer_names), cfg)
        hard = jax.nn.one_hot(jnp.argmax(logits, axis=-1), cfg.hub_size)
        return hard.mean(axis=0)

    return jax.vmap(per_t)(timesteps)
