"""DFA — Denoising-Factor loss Alignment (paper §4.3, Eq. 4/9).

The plain distillation loss L_t = ||eps_fp - eps_q||^2 mis-weights
timesteps: Eq. 3 applies the predicted noise with coefficient

    gamma_t = (1 / sqrt(alpha_t)) * (1 - alpha_t) / sqrt(1 - alpha_bar_t)

so an eps-error at step t moves x_{t-1} by gamma_t * error. DFA rescales
the per-step loss by gamma_t (Eq. 9), aligning fine-tuning pressure with
the actual quantization-induced denoising gap (Fig. 3).
"""
from __future__ import annotations

import jax.numpy as jnp


def denoising_factor(alphas: jnp.ndarray, alpha_bars: jnp.ndarray) -> jnp.ndarray:
    """gamma_t for every t (Eq. 4). alphas/alpha_bars: (T,)."""
    return (1.0 / jnp.sqrt(alphas)) * (1.0 - alphas) / jnp.sqrt(1.0 - alpha_bars)


def eps_mse(eps_fp: jnp.ndarray, eps_q: jnp.ndarray) -> jnp.ndarray:
    """Per-sample MSE between teacher and student noise predictions."""
    d = (eps_fp.astype(jnp.float32) - eps_q.astype(jnp.float32)) ** 2
    return d.reshape(d.shape[0], -1).mean(axis=-1)


def dfa_loss(eps_fp: jnp.ndarray, eps_q: jnp.ndarray,
             gamma_t: jnp.ndarray) -> jnp.ndarray:
    """Eq. 9: mean over batch of gamma_t * ||eps_fp - eps_q||^2.

    gamma_t: per-sample (B,) factor for each sample's timestep.
    """
    return jnp.mean(gamma_t * eps_mse(eps_fp, eps_q))


def plain_loss(eps_fp: jnp.ndarray, eps_q: jnp.ndarray,
               gamma_t: jnp.ndarray | None = None) -> jnp.ndarray:
    """Eq. 7 baseline (gamma ignored) — kept for the ablation."""
    return jnp.mean(eps_mse(eps_fp, eps_q))


def denoising_gap(x_prev_fp: jnp.ndarray, x_prev_q: jnp.ndarray) -> jnp.ndarray:
    """MSE(x_{t-1}, x_hat_{t-1}) — the paper's 'performance gap' metric

    (Fig. 3's ground-truth curve) used to verify loss/impact alignment."""
    d = (x_prev_fp.astype(jnp.float32) - x_prev_q.astype(jnp.float32)) ** 2
    return d.reshape(d.shape[0], -1).mean(axis=-1)
