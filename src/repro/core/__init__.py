"""The paper's contribution: MSFP + TALoRA + DFA, composable JAX modules."""
from repro.core.msfp import (QuantPlan, SiteInfo, build_plan, build_mixed_plan,
                             quantize_act, quantize_weight_tree,
                             plan_mse_report, PLAN_MODES)
from repro.core.talora import (TALoRAConfig, init_lora_hub, init_router,
                               router_logits, ste_one_hot, route, lora_delta,
                               lora_apply, merged_weight, allocation_histogram,
                               lora_target_dims_from_weights, merge_into_tree,
                               routing_signatures)
from repro.core.dfa import (denoising_factor, dfa_loss, plain_loss, eps_mse,
                            denoising_gap)
from repro.core.qmodule import (PackedW4, pack_weight, dequant_weight,
                                w4_dense_xla, quantize_param_tree,
                                encode_codes, decode_codes, pack_nibbles,
                                unpack_nibbles)
