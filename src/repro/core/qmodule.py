"""W4 packed-weight representation for quantized serving.

Fake-quant (quantize-dequantize in bf16) proves quality; deployment stores
each quantized weight as packed 4-bit codes (two per uint8) plus a scalar
(or per-channel) scale and reconstructs bf16 values on the fly. On TPU the
reconstruction happens inside the Pallas matmul kernel (HBM traffic =
packed bytes); the XLA fallback here decodes then calls ``dot``.

Code layout (matches ``repro.quant.formats.quant_codes``):
  [sign | exponent p | mantissa m]   (sign bit only for signed formats)
  p = 0 -> subnormal m/2^M ; p >= 1 -> 2^(p-1) * (1 + m/2^M)
"""
from __future__ import annotations

import dataclasses
from math import prod as _prod
from typing import Any

import jax
import jax.numpy as jnp

from repro.quant.fakequant import QuantizerParams
from repro.quant.formats import FPFormat, snap_to_base_grid


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PackedW4:
    """A weight quantized to a 4-bit FP format and packed 2-codes/byte."""

    packed: jnp.ndarray                                   # uint8, (..., K/2)
    scale: jnp.ndarray                                    # f32 scalar or (out,)
    zero_point: jnp.ndarray                               # f32 (unsigned fmts)
    exp_bits: int = dataclasses.field(metadata=dict(static=True))
    man_bits: int = dataclasses.field(metadata=dict(static=True))
    signed: bool = dataclasses.field(metadata=dict(static=True))
    shape: tuple = dataclasses.field(metadata=dict(static=True))

    @property
    def fmt(self) -> FPFormat:
        return FPFormat(self.exp_bits, self.man_bits, self.signed)


def encode_codes(w: jnp.ndarray, fmt: FPFormat, maxval: jnp.ndarray,
                 zero_point: jnp.ndarray | float = 0.0) -> jnp.ndarray:
    """Arithmetic nearest-code encode (jit-able; no LUT search)."""
    w = w.astype(jnp.float32)
    scale = jnp.asarray(maxval, jnp.float32) / fmt.base_max
    inv = 1.0 / jnp.maximum(scale, 1e-30)
    if fmt.signed:
        y = jnp.abs(w) * inv
        sign = (w < 0).astype(jnp.uint8)
    else:
        y = jnp.clip((w - zero_point) * inv, 0.0, None)
        sign = None
    v = snap_to_base_grid(y, fmt)
    man = fmt.man_bits
    if fmt.exp_bits == 0:
        code = jnp.round(v * 2**man).astype(jnp.uint8)
    else:
        # v is exactly representable; recover (p, m).
        safe = jnp.maximum(v, 2.0**-40)
        oct_ = jnp.clip(jnp.floor(jnp.log2(safe)), 0, 2**fmt.exp_bits - 2)
        is_sub = v < 1.0
        p = jnp.where(is_sub, 0, oct_.astype(jnp.int32) + 1)
        m_sub = jnp.round(v * 2**man)
        m_norm = jnp.round((v / jnp.exp2(oct_) - 1.0) * 2**man)
        m = jnp.where(is_sub, m_sub, m_norm).astype(jnp.int32)
        code = ((p << man) | m).astype(jnp.uint8)
    if fmt.signed:
        code = code | (sign << (fmt.exp_bits + fmt.man_bits))
    return code


def decode_codes(code: jnp.ndarray, fmt: FPFormat, scale: jnp.ndarray,
                 zero_point: jnp.ndarray | float = 0.0,
                 dtype=jnp.bfloat16) -> jnp.ndarray:
    """Arithmetic code -> value decode (the in-kernel dequant, XLA version)."""
    man = fmt.man_bits
    code = code.astype(jnp.int32)
    nbits = fmt.exp_bits + fmt.man_bits
    if fmt.signed:
        sign = (code >> nbits) & 1
        code = code & ((1 << nbits) - 1)
    if fmt.exp_bits == 0:
        mag = code.astype(jnp.float32) / 2**man
    else:
        p = code >> man
        m = (code & (2**man - 1)).astype(jnp.float32)
        mag = jnp.where(p == 0, m / 2**man,
                        jnp.exp2((p - 1).astype(jnp.float32)) * (1 + m / 2**man))
    s = jnp.asarray(scale, jnp.float32) / fmt.base_max * fmt.base_max  # noqa: keep f32
    val = mag * (jnp.asarray(scale, jnp.float32) / fmt.base_max)
    if fmt.signed:
        val = jnp.where(sign == 1, -val, val)
    else:
        val = val + zero_point
    return val.astype(dtype)


def pack_nibbles(codes: jnp.ndarray) -> jnp.ndarray:
    """(..., K) uint8 codes<16 -> (..., K/2), split-half layout:

    packed[..., j] = codes[..., j] | codes[..., j + K/2] << 4.
    Split-half (vs adjacent-interleave) keeps the unpack a concat — no
    lane interleave — so the Pallas matmul kernel can address the two
    output halves with a grid dimension instead of a shuffle.
    """
    assert codes.shape[-1] % 2 == 0, codes.shape
    half = codes.shape[-1] // 2
    lo = codes[..., :half]
    hi = codes[..., half:]
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_nibbles(packed: jnp.ndarray) -> jnp.ndarray:
    lo = packed & 0xF
    hi = (packed >> 4) & 0xF
    return jnp.concatenate([lo, hi], axis=-1)


def pack_weight(w: jnp.ndarray, qp: QuantizerParams) -> PackedW4:
    """Quantize + pack one weight under its searched parameters.

    ``qp.maxval`` may be a scalar (per-tensor) or, when the plan's search
    produced per-output-channel maxima, an (out,) vector — the resulting
    PackedW4 carries the vector scale and the Pallas kernel dequantizes
    per channel.

    4D HWIO conv weights (scalar or per-output-channel ``maxval``) pack as
    their (kh*kw*cin, cout) flattening — the exact GEMM layout the im2col
    conv route feeds to ``w4_matmul_2d`` — while ``shape`` keeps the
    original HWIO tuple so fallback paths can reconstruct the kernel.
    Stacked (scanned / per-expert) weights carry per-slice keepdims
    ``maxval`` and pack over their last axis as-is.
    """
    fmt = qp.fmt
    assert fmt.bits == 4, f"packing is 4-bit only, got {fmt.bits}"
    orig_shape = tuple(w.shape)
    if w.ndim == 4 and jnp.ndim(qp.maxval) <= 1:
        w = w.reshape(-1, orig_shape[-1])
    scale = jnp.asarray(qp.maxval, jnp.float32)
    if scale.ndim == 1:
        assert w.ndim == 2 and scale.shape[0] == w.shape[-1], \
            f"per-channel scale {scale.shape} vs weight {orig_shape}"
    codes = encode_codes(w, fmt, qp.maxval, qp.zero_point)
    # zero_point mirrors the scale's shape so stacked (per-layer) packs stay
    # scannable (lax.scan needs equal leading dims on every leaf)
    zp = jnp.broadcast_to(jnp.asarray(qp.zero_point, jnp.float32), scale.shape)
    return PackedW4(pack_nibbles(codes), scale, zp,
                    fmt.exp_bits, fmt.man_bits, fmt.signed, orig_shape)


def dequant_weight(pw: PackedW4, dtype=jnp.bfloat16) -> jnp.ndarray:
    codes = unpack_nibbles(pw.packed)
    out = decode_codes(codes, pw.fmt, pw.scale, pw.zero_point, dtype)
    if out.ndim == 2 and len(pw.shape) == 4 and out.size == _prod(pw.shape):
        out = out.reshape(pw.shape)  # flattened HWIO conv pack -> back to 4D
    return out


def w4_dense_xla(x: jnp.ndarray, pw: PackedW4, dtype=jnp.bfloat16) -> jnp.ndarray:
    """XLA fallback: decode -> dot. (TPU path: kernels.ops.w4_matmul.)"""
    w = dequant_weight(pw, dtype)
    return x.astype(dtype) @ w


def quantize_param_tree(params: dict, plan, prefix: str = "") -> Any:
    """Replace planned 4-bit weights with PackedW4 leaves (serving form).

    Walks nested dicts; leaf site names are '/'-joined paths. Non-planned
    leaves and non-4-bit sites stay dense.
    """
    out = {}
    for k, v in params.items():
        path = f"{prefix}{k}"
        if isinstance(v, dict):
            out[k] = quantize_param_tree(v, plan, path + "/")
        elif (path in plan.sites and plan.sites[path].is_weight
              and plan.sites[path].qp.bits == 4 and v.ndim >= 2):
            out[k] = pack_weight(v, plan.sites[path].qp)
        else:
            out[k] = v
    return out
