"""MSFP — Mixup-Sign Floating-Point quantization framework (paper §4.1).

Builds a ``QuantPlan`` for a model: every quantized site (layer weight or
layer input activation) gets searched quantizer parameters. NAL activations
and all weights use signed FP; AAL activations additionally search unsigned
FP with a zero-point and keep the MSE-minimal candidate — the "mixup-sign"
selection of Alg. 1.

Plan modes (used by benchmarks/ablations):
  'msfp'        the paper's method (signed everywhere + unsigned for AALs)
  'signed'      signed-FP-only baseline (the paper's baseline row)
  'signed_zp'   signed FP with zero point for AALs (Fig. 4's 3rd strategy)
  'int'         INT-affine baseline (Q-Diffusion-style)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro.quant.calibrate import AALConfig, CalibrationDB
from repro.quant.fakequant import (KIND_FP_UNSIGNED, QuantizerParams,
                                   apply_qdq, ste_qdq)
from repro.quant.search import (SearchResult, search_activation_params,
                                search_int_affine, search_signed_fp,
                                search_weight_params)

PLAN_MODES = ("msfp", "signed", "signed_zp", "int")


@dataclasses.dataclass
class SiteInfo:
    qp: QuantizerParams
    is_weight: bool
    is_aal: bool
    mse: float
    diagnostics: dict[str, float] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class QuantPlan:
    """Static quantization plan: site name -> searched quantizer params."""

    sites: dict[str, SiteInfo]
    bits_w: int
    bits_a: int
    mode: str

    def qp(self, name: str) -> QuantizerParams:
        return self.sites[name].qp

    def act_sites(self) -> list[str]:
        return [n for n, s in self.sites.items() if not s.is_weight]

    def weight_sites(self) -> list[str]:
        return [n for n, s in self.sites.items() if s.is_weight]

    def n_unsigned(self) -> int:
        return sum(1 for s in self.sites.values()
                   if s.qp.kind == KIND_FP_UNSIGNED)

    def summary(self) -> dict[str, Any]:
        return {
            "mode": self.mode, "bits_w": self.bits_w, "bits_a": self.bits_a,
            "sites": len(self.sites),
            "aal_sites": sum(1 for s in self.sites.values() if s.is_aal),
            "unsigned_sites": self.n_unsigned(),
        }


def _search_act(samples: np.ndarray, bits: int, mode: str,
                is_aal: bool) -> SearchResult:
    if mode == "int":
        return search_int_affine(samples, bits)
    if mode == "signed":
        return search_activation_params(samples, bits, allow_unsigned=False)
    if mode == "signed_zp":
        # Fig. 4 strategy: signed grid shifted by a zero point. Emulated as a
        # signed search over zp-shifted data; the paper shows this helps
        # little — kept for the ablation benchmark.
        best = None
        for zp in np.linspace(-0.3, 0.0, 6):
            r = search_signed_fp(samples - zp, bits)
            if best is None or r.mse < best[0].mse:
                best = (r, zp)
        r, zp = best
        qp = dataclasses.replace(r.params, zero_point=jnp.float32(zp))
        return SearchResult(qp, r.mse, r.per_format)
    # msfp
    return search_activation_params(samples, bits, allow_unsigned=is_aal)


def build_plan(weights: Mapping[str, Any], act_db: CalibrationDB, *,
               bits_w: int = 4, bits_a: int = 4, mode: str = "msfp",
               aal_cfg: AALConfig | None = None,
               skip: Callable[[str], bool] | None = None,
               progress: Callable[[str], None] | None = None) -> QuantPlan:
    """Search quantizer parameters for every weight and activation site.

    ``weights`` maps site name -> weight array (flattened module tree);
    ``act_db`` holds calibration samples recorded under the same site names.
    ``skip(name)`` exempts sites kept in high precision (paper keeps model
    input/output layers at 8-bit — callers encode that by passing those
    sites through a second ``build_plan`` with bits=8, see
    ``build_mixed_plan``).
    """
    assert mode in PLAN_MODES, mode
    sites: dict[str, SiteInfo] = {}
    for name, w in weights.items():
        if skip and skip(name):
            continue
        if progress:
            progress(f"weight:{name}")
        if mode == "int":
            r = search_int_affine(np.asarray(w), bits_w, symmetric=True)
        else:
            r = search_weight_params(np.asarray(w), bits_w)
        sites[name] = SiteInfo(r.params, True, False, r.mse, r.per_format)
    classes = act_db.classify(aal_cfg)
    for name, stats in act_db.sites.items():
        if skip and skip(name):
            continue
        if progress:
            progress(f"act:{name}")
        is_aal = classes[name]
        r = _search_act(stats.samples, bits_a, mode, is_aal)
        sites[name] = SiteInfo(r.params, False, is_aal, r.mse, r.per_format)
    return QuantPlan(sites, bits_w, bits_a, mode)


def build_mixed_plan(weights, act_db, *, bits_w=4, bits_a=4, mode="msfp",
                     io_sites: set[str] = frozenset(), io_bits: int = 8,
                     aal_cfg=None) -> QuantPlan:
    """Standard paper configuration: io layers at 8-bit, the rest at target."""
    inner = build_plan(weights, act_db, bits_w=bits_w, bits_a=bits_a,
                       mode=mode, aal_cfg=aal_cfg,
                       skip=lambda n: n in io_sites)
    if io_sites:
        outer = build_plan(
            {k: v for k, v in weights.items() if k in io_sites}, act_db,
            bits_w=io_bits, bits_a=io_bits, mode=mode, aal_cfg=aal_cfg,
            skip=lambda n: n not in io_sites)
        inner.sites.update(outer.sites)
    return inner


# ---------------------------------------------------------------------------
# Application: fake-quant weights / activations under a plan.
# ---------------------------------------------------------------------------


def quantize_act(name: str, x: jnp.ndarray, plan: QuantPlan) -> jnp.ndarray:
    """Activation fake-quant with STE gradients; identity if unplanned."""
    if plan is None or name not in plan.sites:
        return x
    return ste_qdq(x, plan.sites[name].qp)


def quantize_weight_tree(weights: Mapping[str, Any], plan: QuantPlan) -> dict:
    """Fake-quantize every planned weight (frozen quantized base for QLoRA)."""
    out = {}
    for name, w in weights.items():
        if name in plan.sites and plan.sites[name].is_weight:
            out[name] = apply_qdq(w, plan.sites[name].qp)
        else:
            out[name] = w
    return out


def plan_mse_report(plan: QuantPlan) -> dict[str, dict]:
    """Per-site search MSE + chosen format — Fig. 4-style evidence."""
    return {
        n: dict(format=s.qp.fmt.name if s.qp.kind != 2 else f"int{s.qp.bits}",
                kind=s.qp.kind, is_aal=s.is_aal, is_weight=s.is_weight,
                mse=s.mse, maxval=float(s.qp.maxval),
                zp=float(s.qp.zero_point))
        for n, s in plan.sites.items()
    }
