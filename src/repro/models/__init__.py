"""Model assemblies: unified LM family + diffusion wrapper + registry."""
