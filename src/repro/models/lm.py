"""Unified LM-family model: dense / GQA / MoE / SSM / hybrid / audio / vlm.

One config-driven assembly covers all 10 assigned architectures. Layers are
stacked and scanned (``lax.scan`` over parameter stacks) so HLO size and
compile time are O(1) in depth — essential for the 80-compile dry-run
matrix. Heterogeneous depth patterns (gemma3's 5 local : 1 global, zamba2's
shared attention every k mamba blocks) scan over period-sized groups.

Three entry points per architecture:
  forward(...)      full-sequence logits (training / prefill)
  loss_fn(...)      next-token cross-entropy (+ MoE aux loss)
  decode_step(...)  one token against KV caches / SSM states (serving)
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.common.sharding import DP, MODEL, shard_hint
from repro.nn.attention import (AttnConfig, attn_apply, attn_decode, attn_init,
                                init_kv_cache, kv_cache_spec)
from repro.nn.embeddings import rope_frequencies, timestep_embedding
from repro.nn.layers import dense_apply, dense_init, rmsnorm_apply, rmsnorm_init
from repro.nn.mlp import mlp_apply, mlp_init
from repro.nn.moe import MoEConfig, moe_apply, moe_apply_ep, moe_init
from repro.nn.ssm import (SSMConfig, init_ssm_state, ssm_apply, ssm_decode,
                          ssm_init, ssm_state_spec)

ATTN, SSM = "attn", "ssm"


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    family: str = "dense"            # dense|moe|ssm|hybrid|audio|vlm
    head_dim: int | None = None
    qkv_bias: bool = False
    qk_norm: bool = False
    mlp_kind: str = "swiglu"         # swiglu | geglu | gelu
    pos: str = "rope"                # rope | sinusoidal
    scale_embed: bool = False        # gemma: h *= sqrt(d_model)
    tie_embeddings: bool = False
    # depth pattern, period P entries of (kind, window|None, rope_theta)
    layer_pattern: tuple = ((ATTN, None, 10_000.0),)
    # --- moe ---
    moe_impl: str = "global"         # global | ep (shard_map expert parallel)
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_shared: int = 0
    first_k_dense: int = 0
    capacity_factor: float = 1.25
    # --- ssm ---
    ssm_d_state: int = 0
    ssm_headdim: int = 64
    ssm_n_groups: int = 1
    ssm_chunk: int = 256
    # --- hybrid (zamba2) ---
    shared_attn_every: int = 0       # apply the shared attn block every k layers
    # --- vlm / audio stubs ---
    n_img_tokens: int = 0
    d_vision: int = 0
    # --- execution ---
    dtype: Any = jnp.bfloat16
    remat: bool = True
    unroll: bool = False         # dry-run cost mode: Python-loop all scans
    q_chunk: int = 512
    kv_dtype: str = "bf16"           # bf16 | fp8 | fp4  (serving KV cache)
    logits_softcap: float | None = None

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def period(self) -> int:
        return len(self.layer_pattern)

    def attn_cfg(self, window, theta) -> AttnConfig:
        return AttnConfig(self.d_model, self.n_heads, self.n_kv, self.hd,
                          qkv_bias=self.qkv_bias, rope_theta=theta,
                          window=window, use_rope=(self.pos == "rope"))

    def ssm_cfg(self) -> SSMConfig:
        return SSMConfig(self.d_model, self.ssm_d_state, self.ssm_headdim,
                         2, self.ssm_n_groups, 4, self.ssm_chunk)

    def moe_cfg(self) -> MoEConfig:
        return MoEConfig(self.d_model, self.moe_d_ff, self.n_experts,
                         self.top_k, self.n_shared, self.capacity_factor)

    @property
    def n_scanned(self) -> int:
        return self.n_layers - self.first_k_dense

    @property
    def n_groups(self) -> int:
        assert self.n_scanned % self.period == 0, (self.n_scanned, self.period)
        return self.n_scanned // self.period

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND roofline math)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        per_attn = d * (self.n_heads + 2 * self.n_kv) * self.hd + self.n_heads * self.hd * d
        if self.mlp_kind in ("swiglu", "geglu"):
            per_mlp = 3 * d * f
        else:
            per_mlp = 2 * d * f
        per_moe = (self.n_experts * 3 * d * self.moe_d_ff
                   + self.n_shared * 3 * d * self.moe_d_ff + d * self.n_experts)
        ssm = self.ssm_cfg()
        per_ssm = d * (2 * ssm.d_inner + 2 * ssm.n_groups * ssm.d_state + ssm.n_heads) \
            + ssm.d_inner * d + ssm.conv_dim * 4
        total = v * d * (1 if self.tie_embeddings else 2)
        for i in range(self.n_layers):
            kind = self.layer_pattern[(max(0, i - self.first_k_dense)) % self.period][0] \
                if i >= self.first_k_dense else ATTN
            if kind == SSM:
                total += per_ssm
            else:
                total += per_attn
                if self.family in ("moe",) and i >= self.first_k_dense:
                    total += per_moe
                elif i < self.first_k_dense:
                    total += 3 * d * (f or 4 * d)
                elif self.d_ff:
                    total += per_mlp
        if self.shared_attn_every:
            total += per_attn + (per_mlp if self.d_ff else 0)
        return total

    def active_param_count(self) -> int:
        """Per-token active params (MoE: top_k + shared experts only)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        per_moe_all = (self.n_experts * 3 * d * self.moe_d_ff
                       + self.n_shared * 3 * d * self.moe_d_ff + d * self.n_experts)
        per_moe_act = ((self.top_k + self.n_shared) * 3 * d * self.moe_d_ff
                       + d * self.n_experts)
        n_moe_layers = self.n_layers - self.first_k_dense
        return self.param_count() - n_moe_layers * (per_moe_all - per_moe_act)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _block_init(key, cfg: LMConfig, kind: str, window, theta, *,
                moe: bool, dtype) -> dict:
    ks = jax.random.split(key, 4)
    if kind == SSM:
        return {"ln1": rmsnorm_init(cfg.d_model, dtype),
                "ssm": ssm_init(ks[0], cfg.ssm_cfg(), dtype)}
    p = {"ln1": rmsnorm_init(cfg.d_model, dtype),
         "attn": attn_init(ks[0], cfg.attn_cfg(window, theta), dtype),
         "ln2": rmsnorm_init(cfg.d_model, dtype)}
    if moe:
        p["moe"] = moe_init(ks[1], cfg.moe_cfg(), dtype)
    elif cfg.d_ff:
        p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_kind, dtype)
    return p


def lm_init(key, cfg: LMConfig) -> dict:
    dtype = cfg.dtype
    keys = iter(jax.random.split(key, cfg.n_layers + 16))
    p: dict[str, Any] = {
        "embed": jax.random.normal(next(keys), (cfg.vocab, cfg.d_model),
                                   dtype) * 0.02,
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(next(keys), cfg.d_model, cfg.vocab, dtype=dtype)
    if cfg.family == "vlm":
        p["vision_proj"] = dense_init(next(keys), cfg.d_vision, cfg.d_model,
                                      bias=True, dtype=dtype)
    for i in range(cfg.first_k_dense):
        # leading dense layers (kimi-k2) — un-scanned, standard attn+mlp
        p[f"dense_{i}"] = {
            "ln1": rmsnorm_init(cfg.d_model, dtype),
            "attn": attn_init(next(keys), cfg.attn_cfg(*cfg.layer_pattern[0][1:]), dtype),
            "ln2": rmsnorm_init(cfg.d_model, dtype),
            "mlp": mlp_init(next(keys), cfg.d_model,
                            cfg.d_ff or 4 * cfg.d_model, cfg.mlp_kind, dtype),
        }
    # scanned stack: one param tree per group position, stacked over groups
    per_pos = []
    for pos_i, (kind, window, theta) in enumerate(cfg.layer_pattern):
        group_keys = jax.random.split(next(keys), cfg.n_groups)
        stacked = jax.vmap(
            lambda k: _block_init(k, cfg, kind, window, theta,
                                  moe=(cfg.family == "moe"), dtype=dtype)
        )(group_keys)
        per_pos.append(stacked)
    p["blocks"] = per_pos  # list of per-position stacks, each leading dim = n_groups
    if cfg.shared_attn_every:
        # Zamba2: one shared transformer block (attn + MLP), reused per group
        p["shared_attn"] = {
            "ln": rmsnorm_init(cfg.d_model, dtype),
            "attn": attn_init(next(keys), cfg.attn_cfg(None, 10_000.0), dtype),
        }
        if cfg.d_ff:
            p["shared_attn"]["ln2"] = rmsnorm_init(cfg.d_model, dtype)
            p["shared_attn"]["mlp"] = mlp_init(next(keys), cfg.d_model,
                                               cfg.d_ff, cfg.mlp_kind, dtype)
    return p


# ---------------------------------------------------------------------------
# forward blocks
# ---------------------------------------------------------------------------


def _moe_block(bp, x, cfg, *, ctx, site):
    fn = moe_apply_ep if cfg.moe_impl == "ep" else moe_apply
    return fn(bp["moe"], x, cfg.moe_cfg(), ctx=ctx, site=site)


def _attn_block(bp, h, cos, sin, acfg, cfg, *, ctx, site):
    x = rmsnorm_apply(bp["ln1"], h)
    x = attn_apply(bp["attn"], x, cos, sin, acfg, q_chunk=cfg.q_chunk,
                   unroll=cfg.unroll, ctx=ctx, site=f"{site}/attn")
    h = h + x
    if "moe" in bp:
        x = rmsnorm_apply(bp["ln2"], h)
        x = _moe_block(bp, x, cfg, ctx=ctx, site=f"{site}/moe")
        h = h + x
    elif "mlp" in bp:
        x = rmsnorm_apply(bp["ln2"], h)
        x = mlp_apply(bp["mlp"], x, cfg.mlp_kind, ctx=ctx, site=f"{site}/mlp")
        h = h + x
    return shard_hint(h, DP, None, None)


def _ssm_block(bp, h, cfg, *, ctx, site):
    x = rmsnorm_apply(bp["ln1"], h)
    x = ssm_apply(bp["ssm"], x, cfg.ssm_cfg(), unroll=cfg.unroll, ctx=ctx,
                  site=f"{site}/ssm")
    return shard_hint(h + x, DP, None, None)


def _shared_attn(sp, h, cos, sin, cfg, *, ctx):
    x = rmsnorm_apply(sp["ln"], h)
    x = attn_apply(sp["attn"], x, cos, sin, cfg.attn_cfg(None, 10_000.0),
                   q_chunk=cfg.q_chunk, unroll=cfg.unroll, ctx=ctx,
                   site="shared_attn")
    h = h + x
    if "mlp" in sp:
        x = rmsnorm_apply(sp["ln2"], h)
        h = h + mlp_apply(sp["mlp"], x, cfg.mlp_kind, ctx=ctx,
                          site="shared_attn/mlp")
    return h


def _rope_tables(cfg: LMConfig, s: int, dtype):
    tables = {}
    for kind, window, theta in cfg.layer_pattern:
        if kind == ATTN and theta not in tables:
            tables[theta] = rope_frequencies(cfg.hd, s, theta, dtype)
    if cfg.first_k_dense or cfg.shared_attn_every:
        theta = cfg.layer_pattern[0][2] if cfg.layer_pattern[0][0] == ATTN else 10_000.0
        if theta not in tables:
            tables[theta] = rope_frequencies(cfg.hd, s, theta, dtype)
    if not tables:
        tables[10_000.0] = (None, None)
    return tables


def _embed_tokens(p, cfg: LMConfig, tokens, extra):
    h = jnp.take(p["embed"], tokens, axis=0).astype(cfg.dtype)
    if cfg.scale_embed:
        h = h * jnp.asarray(jnp.sqrt(cfg.d_model), cfg.dtype)
    if cfg.family == "vlm" and extra is not None:
        img = dense_apply(p["vision_proj"], extra.astype(cfg.dtype))
        h = lax.dynamic_update_slice_in_dim(h, img, 0, axis=1)
    if cfg.pos == "sinusoidal":
        pos = timestep_embedding(jnp.arange(h.shape[1]), cfg.d_model)
        h = h + pos[None].astype(cfg.dtype)
    return shard_hint(h, DP, None, None)


def forward(p: dict, cfg: LMConfig, tokens: jnp.ndarray,
            extra: jnp.ndarray | None = None, ctx=None) -> jnp.ndarray:
    """Full-sequence logits: tokens (B, S) [+ extra (B, n_img, d_vision)]."""
    b, s = tokens.shape
    h = _embed_tokens(p, cfg, tokens, extra)
    tables = _rope_tables(cfg, s, jnp.float32)

    for i in range(cfg.first_k_dense):
        kind, window, theta = cfg.layer_pattern[0]
        cos, sin = tables[theta]
        h = _attn_block(p[f"dense_{i}"], h, cos, sin,
                        cfg.attn_cfg(window, theta), cfg, ctx=ctx,
                        site="dense_block")

    group_idx = {"i": 0}

    def group_body(h, group_params):
        for pos_i, (kind, window, theta) in enumerate(cfg.layer_pattern):
            bp = group_params[pos_i]
            site = f"block_p{pos_i}"
            if kind == SSM:
                h = _ssm_block(bp, h, cfg, ctx=ctx, site=site)
            else:
                cos, sin = tables[theta]
                h = _attn_block(bp, h, cos, sin, cfg.attn_cfg(window, theta),
                                cfg, ctx=ctx, site=site)
        if cfg.shared_attn_every:
            cos, sin = tables[list(tables)[0]]
            h = _shared_attn(p["shared_attn"], h, cos, sin, cfg, ctx=ctx)
        return h

    body = group_body
    if cfg.remat:
        body = jax.checkpoint(group_body,
                              policy=jax.checkpoint_policies.nothing_saveable)

    def scan_fn(h, group_params):
        return body(h, group_params), None

    if cfg.unroll:  # exact-cost dry-run path: no while loops in HLO
        for gi in range(cfg.n_groups):
            h = body(h, jax.tree.map(lambda x: x[gi], p["blocks"]))
    else:
        h, _ = lax.scan(scan_fn, h, p["blocks"])
    h = rmsnorm_apply(p["final_norm"], h)
    if cfg.tie_embeddings:
        logits = h @ p["embed"].T.astype(h.dtype)
    else:
        logits = dense_apply(p["lm_head"], h, ctx=ctx, site="lm_head")
    if cfg.logits_softcap:
        logits = cfg.logits_softcap * jnp.tanh(logits / cfg.logits_softcap)
    return shard_hint(logits, DP, None, MODEL)


def loss_fn(p: dict, cfg: LMConfig, tokens: jnp.ndarray,
            extra: jnp.ndarray | None = None, ctx=None) -> jnp.ndarray:
    """Next-token cross-entropy (mean over tokens)."""
    logits = forward(p, cfg, tokens, extra, ctx=ctx)
    targets = tokens[:, 1:]
    # lse - label_logit form, with the label pick as a one-hot reduction:
    # both reduce over the vocab-sharded axis without gathers/all-gathers
    # (take_along_axis over a sharded dim would force a full all-gather).
    lg = logits[:, :-1].astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lg, axis=-1)
    vocab_iota = jnp.arange(lg.shape[-1], dtype=targets.dtype)
    onehot = (targets[..., None] == vocab_iota).astype(lg.dtype)
    lab = jnp.sum(lg * onehot, axis=-1)
    return (lse - lab).mean()


# ---------------------------------------------------------------------------
# decode (serving)
# ---------------------------------------------------------------------------


def cache_specs(cfg: LMConfig, batch: int, s_max: int) -> dict:
    """ShapeDtypeStruct-compatible cache description for input_specs()."""
    specs: dict[str, Any] = {"blocks": []}
    for kind, window, theta in cfg.layer_pattern:
        if kind == SSM:
            per = ssm_state_spec(batch, cfg.ssm_cfg())
        else:
            s_eff = min(s_max, window) if window else s_max
            per = kv_cache_spec(batch, s_eff, cfg.attn_cfg(window, theta),
                                cfg.kv_dtype)
        # stacked over groups
        specs["blocks"].append({
            k: dict(shape=(cfg.n_groups, *v["shape"]), dtype=v["dtype"])
            for k, v in per.items()})
    for i in range(cfg.first_k_dense):
        specs[f"dense_{i}"] = kv_cache_spec(
            batch, s_max, cfg.attn_cfg(*cfg.layer_pattern[0][1:]), cfg.kv_dtype)
    if cfg.shared_attn_every:
        # Zamba2 shares the attention *weights*, not the caches: one KV
        # cache per group invocation, stacked like the scanned blocks.
        per = kv_cache_spec(batch, s_max, cfg.attn_cfg(None, 10_000.0),
                            cfg.kv_dtype)
        specs["shared"] = {k: dict(shape=(cfg.n_groups, *v["shape"]),
                                   dtype=v["dtype"]) for k, v in per.items()}
    return specs


def init_caches(cfg: LMConfig, batch: int, s_max: int) -> dict:
    def make(spec):
        if isinstance(spec, dict) and "shape" in spec:
            return jnp.zeros(spec["shape"], spec["dtype"])
        if isinstance(spec, dict):
            return {k: make(v) for k, v in spec.items()}
        return [make(s) for s in spec]

    return make(cache_specs(cfg, batch, s_max))


def decode_step(p: dict, cfg: LMConfig, caches: dict, token: jnp.ndarray,
                pos: jnp.ndarray, ctx=None) -> tuple[jnp.ndarray, dict]:
    """One decode step. token: (B, 1) ids; pos: scalar int32 position.

    Returns (logits (B, 1, vocab), updated caches).
    """
    h = jnp.take(p["embed"], token, axis=0).astype(cfg.dtype)
    if cfg.scale_embed:
        h = h * jnp.asarray(jnp.sqrt(cfg.d_model), cfg.dtype)
    if cfg.pos == "sinusoidal":
        h = h + timestep_embedding(pos[None].astype(jnp.float32),
                                   cfg.d_model)[None].astype(cfg.dtype)
    h = shard_hint(h, DP, None, None)

    def rot(theta):
        inv = 1.0 / (theta ** (jnp.arange(0, cfg.hd, 2, dtype=jnp.float32) / cfg.hd))
        ang = pos.astype(jnp.float32) * inv
        return jnp.cos(ang)[None], jnp.sin(ang)[None]

    new_caches = dict(caches)
    for i in range(cfg.first_k_dense):
        kind, window, theta = cfg.layer_pattern[0]
        cos_t, sin_t = rot(theta)
        bp = p[f"dense_{i}"]
        x = rmsnorm_apply(bp["ln1"], h)
        x, c = attn_decode(bp["attn"], x, caches[f"dense_{i}"], pos, pos + 1,
                           cos_t, sin_t, cfg.attn_cfg(window, theta),
                           kv_dtype=cfg.kv_dtype, ctx=ctx, site="dense_block/attn")
        new_caches[f"dense_{i}"] = c
        h = h + x
        x = rmsnorm_apply(bp["ln2"], h)
        h = h + mlp_apply(bp["mlp"], x, cfg.mlp_kind, ctx=ctx,
                          site="dense_block/mlp")

    def group_body(h, xs):
        if cfg.shared_attn_every:
            group_params, group_caches, shared_cache = xs
        else:
            group_params, group_caches = xs
            shared_cache = None
        out_caches = []
        for pos_i, (kind, window, theta) in enumerate(cfg.layer_pattern):
            bp = group_params[pos_i]
            cache = group_caches[pos_i]
            site = f"block_p{pos_i}"
            if kind == SSM:
                x = rmsnorm_apply(bp["ln1"], h)
                x, c = ssm_decode(bp["ssm"], x, cache, cfg.ssm_cfg(), ctx=ctx,
                                  site=f"{site}/ssm")
                h = h + x
            else:
                acfg = cfg.attn_cfg(window, theta)
                # windowed layers keep a ring cache of size `window`
                if window:
                    store_pos = pos % window
                    valid_len = jnp.minimum(pos + 1, window)
                else:
                    store_pos, valid_len = pos, pos + 1
                cos_t, sin_t = rot(theta)
                x = rmsnorm_apply(bp["ln1"], h)
                x, c = attn_decode(bp["attn"], x, cache, store_pos, valid_len,
                                   cos_t, sin_t,
                                   dataclasses.replace(acfg, window=None)
                                   if window else acfg,
                                   kv_dtype=cfg.kv_dtype, ctx=ctx,
                                   site=f"{site}/attn")
                h = h + x
                if "moe" in bp:
                    x = rmsnorm_apply(bp["ln2"], h)
                    h = h + _moe_block(bp, x, cfg, ctx=ctx,
                                       site=f"{site}/moe")
                elif "mlp" in bp:
                    x = rmsnorm_apply(bp["ln2"], h)
                    h = h + mlp_apply(bp["mlp"], x, cfg.mlp_kind, ctx=ctx,
                                      site=f"{site}/mlp")
            out_caches.append(c)
        if cfg.shared_attn_every:
            # Zamba2: shared *weights*, per-group KV cache (threaded as xs/ys)
            cos_t, sin_t = rot(10_000.0)
            sp = p["shared_attn"]
            x = rmsnorm_apply(sp["ln"], h)
            x, shared_cache = attn_decode(
                sp["attn"], x, shared_cache, pos, pos + 1, cos_t, sin_t,
                cfg.attn_cfg(None, 10_000.0), kv_dtype=cfg.kv_dtype, ctx=ctx,
                site="shared_attn")
            h = h + x
            if "mlp" in sp:
                x = rmsnorm_apply(sp["ln2"], h)
                h = h + mlp_apply(sp["mlp"], x, cfg.mlp_kind, ctx=ctx,
                                  site="shared_attn/mlp")
            return h, (out_caches, shared_cache)
        return h, (out_caches, None)

    if cfg.shared_attn_every:
        xs = (p["blocks"], caches["blocks"], caches["shared"])
    else:
        xs = (p["blocks"], caches["blocks"])
    if cfg.unroll:  # exact-cost dry-run path
        ys = []
        for gi in range(cfg.n_groups):
            h, y = group_body(h, jax.tree.map(lambda x: x[gi], xs))
            ys.append(y)
        blk_caches, shared_caches = jax.tree.map(
            lambda *ls: jnp.stack(ls), *ys)
    else:
        h, (blk_caches, shared_caches) = lax.scan(group_body, h, xs)
    new_caches["blocks"] = blk_caches
    if cfg.shared_attn_every:
        new_caches["shared"] = shared_caches

    h = rmsnorm_apply(p["final_norm"], h)
    if cfg.tie_embeddings:
        logits = h @ p["embed"].T.astype(h.dtype)
    else:
        logits = dense_apply(p["lm_head"], h, ctx=ctx, site="lm_head")
    if cfg.logits_softcap:
        logits = cfg.logits_softcap * jnp.tanh(logits / cfg.logits_softcap)
    return shard_hint(logits, DP, None, MODEL), new_caches
