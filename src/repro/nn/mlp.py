"""Transformer MLPs: SwiGLU / GeGLU / plain-GELU, with quantization sites.

The gated variants are where the paper's AALs live in LM-family models: the
``down`` projection consumes ``act(gate) * up`` whose distribution carries
the SiLU/GELU negative-tail compression (min ≈ -0.278 for SiLU, ≈ -0.17 for
GELU) — exactly Fig. 1(b) of the paper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.layers import (ACTIVATIONS, dense_apply, dense_init,
                             resolve_act_qp)


def glu_mlp_init(key, d_model: int, d_ff: int, dtype=jnp.float32) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": dense_init(k1, d_model, d_ff, dtype=dtype),
        "up": dense_init(k2, d_model, d_ff, dtype=dtype),
        "down": dense_init(k3, d_ff, d_model, dtype=dtype),
    }


def glu_mlp_apply(p: dict, x: jnp.ndarray, *, act: str = "silu",
                  ctx=None, site: str | None = None,
                  act_qps=None) -> jnp.ndarray:
    fn = ACTIVATIONS[act]
    g = dense_apply(p["gate"], x, ctx=ctx, site=f"{site}/gate",
                    act_qp=resolve_act_qp(act_qps, f"{site}/gate"))
    u = dense_apply(p["up"], x, ctx=ctx, site=f"{site}/up",
                    act_qp=resolve_act_qp(act_qps, f"{site}/up"))
    # ``down`` consumes act(gate)*up — the AAL site where MSFP picks the
    # unsigned-with-zero-point activation format.
    return dense_apply(p["down"], fn(g) * u, ctx=ctx, site=f"{site}/down",
                       act_qp=resolve_act_qp(act_qps, f"{site}/down"))


def gelu_mlp_init(key, d_model: int, d_ff: int, dtype=jnp.float32) -> dict:
    k1, k2 = jax.random.split(key, 2)
    return {
        "up": dense_init(k1, d_model, d_ff, dtype=dtype),
        "down": dense_init(k2, d_ff, d_model, dtype=dtype),
    }


def gelu_mlp_apply(p: dict, x: jnp.ndarray, *, act: str = "gelu",
                   ctx=None, site: str | None = None,
                   act_qps=None) -> jnp.ndarray:
    fn = ACTIVATIONS[act]
    h = fn(dense_apply(p["up"], x, ctx=ctx, site=f"{site}/up",
                       act_qp=resolve_act_qp(act_qps, f"{site}/up")))
    return dense_apply(p["down"], h, ctx=ctx, site=f"{site}/down",
                       act_qp=resolve_act_qp(act_qps, f"{site}/down"))


def mlp_init(key, d_model, d_ff, kind: str, dtype=jnp.float32) -> dict:
    if kind in ("swiglu", "geglu"):
        return glu_mlp_init(key, d_model, d_ff, dtype)
    return gelu_mlp_init(key, d_model, d_ff, dtype)


def mlp_apply(p, x, kind: str, *, ctx=None, site=None, act_qps=None):
    if kind == "swiglu":
        return glu_mlp_apply(p, x, act="silu", ctx=ctx, site=site,
                             act_qps=act_qps)
    if kind == "geglu":
        return glu_mlp_apply(p, x, act="gelu_tanh", ctx=ctx, site=site,
                             act_qps=act_qps)
    return gelu_mlp_apply(p, x, ctx=ctx, site=site, act_qps=act_qps)
