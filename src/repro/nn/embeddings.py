"""Embedding utilities shared by the UNet, the TALoRA router, and LMs."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def timestep_embedding(t: jnp.ndarray, dim: int,
                       max_period: float = 10_000.0) -> jnp.ndarray:
    """DDPM sinusoidal timestep embedding. t: (...,) int/float -> (..., dim)."""
    half = dim // 2
    freqs = jnp.exp(-np.log(max_period) * jnp.arange(half, dtype=jnp.float32) / half)
    args = t.astype(jnp.float32)[..., None] * freqs
    emb = jnp.concatenate([jnp.cos(args), jnp.sin(args)], axis=-1)
    if dim % 2:
        emb = jnp.pad(emb, [(0, 0)] * (emb.ndim - 1) + [(0, 1)])
    return emb


def rope_frequencies(head_dim: int, max_seq: int, theta: float = 10_000.0,
                     dtype=jnp.float32) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Precomputed RoPE cos/sin tables: (max_seq, head_dim//2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    pos = jnp.arange(max_seq, dtype=jnp.float32)
    ang = pos[:, None] * inv[None, :]
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """Rotate pairs. x: (..., S, H, D); cos/sin: (S, D//2) or (..., S, D//2)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    if cos.ndim == 2:  # (S, D/2) -> broadcast over batch and heads
        cos = cos[:, None, :]
        sin = sin[:, None, :]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    return jnp.concatenate([out1, out2], axis=-1).astype(x.dtype)
