"""GQA attention: RoPE, sliding window, q-chunked prefill, KV-cache decode.

Prefill/train computes attention in query chunks (``lax.scan`` over chunk
index) so the logits tensor never materializes at (S, S) — per-device peak
is (B, H_local, q_chunk, S). Heads shard on the ``model`` mesh axis,
sequence/batch on ``data``.

Decode attends one new token against a preallocated KV cache; the cache
dtype is configurable (bf16 / fp8-e4m3 / packed FP4 with per-token-head
scales — the MSFP-style cache compression evaluated in EXPERIMENTS §Perf).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.nn.embeddings import apply_rope
from repro.nn.layers import dense_apply, dense_init, resolve_act_qp


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    window: int | None = None      # sliding-window size; None = global
    softcap: float | None = None
    use_rope: bool = True


def attn_init(key, cfg: AttnConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * cfg.head_dim,
                         bias=cfg.qkv_bias, dtype=dtype),
        "wk": dense_init(ks[1], cfg.d_model, cfg.n_kv * cfg.head_dim,
                         bias=cfg.qkv_bias, dtype=dtype),
        "wv": dense_init(ks[2], cfg.d_model, cfg.n_kv * cfg.head_dim,
                         bias=cfg.qkv_bias, dtype=dtype),
        "wo": dense_init(ks[3], cfg.n_heads * cfg.head_dim, cfg.d_model,
                         dtype=dtype),
    }


def _qkv(p, x, cfg: AttnConfig, cos, sin, pos_offset=0, *, ctx=None, site=None,
         act_qps=None):
    b, s, _ = x.shape
    g = cfg.n_heads // cfg.n_kv
    q = dense_apply(p["wq"], x, ctx=ctx, site=f"{site}/wq",
                    act_qp=resolve_act_qp(act_qps, f"{site}/wq"))
    k = dense_apply(p["wk"], x, ctx=ctx, site=f"{site}/wk",
                    act_qp=resolve_act_qp(act_qps, f"{site}/wk"))
    v = dense_apply(p["wv"], x, ctx=ctx, site=f"{site}/wv",
                    act_qp=resolve_act_qp(act_qps, f"{site}/wv"))
    q = q.reshape(b, s, cfg.n_kv, g, cfg.head_dim)
    k = k.reshape(b, s, cfg.n_kv, cfg.head_dim)
    v = v.reshape(b, s, cfg.n_kv, cfg.head_dim)
    if cfg.use_rope and cos is not None:
        qr = q.reshape(b, s, cfg.n_kv * g, cfg.head_dim)
        qr = apply_rope(qr, cos, sin)
        q = qr.reshape(b, s, cfg.n_kv, g, cfg.head_dim)
        k = apply_rope(k, cos, sin)
    return q, k, v


def _mask(q_pos, k_pos, window):
    m = k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= (q_pos[:, None] - k_pos[None, :]) < window
    return m


def attn_apply(p: dict, x: jnp.ndarray, cos, sin, cfg: AttnConfig, *,
               q_chunk: int = 512, unroll: bool = False, ctx=None,
               site: str | None = None, act_qps=None) -> jnp.ndarray:
    """Causal (optionally windowed) self-attention over a full sequence."""
    b, s, _ = x.shape
    g = cfg.n_heads // cfg.n_kv
    q, k, v = _qkv(p, x, cfg, cos, sin, ctx=ctx, site=site, act_qps=act_qps)
    scale = cfg.head_dim ** -0.5
    qc = min(q_chunk, s)
    assert s % qc == 0, (s, qc)
    nc = s // qc
    q = q.reshape(b, nc, qc, cfg.n_kv, g, cfg.head_dim)
    k_pos = jnp.arange(s)

    def one_chunk(ci):
        qi = q[:, ci]  # (b, qc, K, G, hd)
        logits = jnp.einsum("bqkgh,bskh->bkgqs", qi, k,
                            preferred_element_type=jnp.float32) * scale
        if cfg.softcap:
            logits = cfg.softcap * jnp.tanh(logits / cfg.softcap)
        q_pos = ci * qc + jnp.arange(qc)
        m = _mask(q_pos, k_pos, cfg.window)
        logits = jnp.where(m[None, None, None], logits, -1e30)
        w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
        o = jnp.einsum("bkgqs,bskh->bqkgh", w, v)
        return o.reshape(b, qc, cfg.n_heads * cfg.head_dim)

    if unroll:  # exact-cost dry-run path: same math, no while loop
        out = jnp.stack([one_chunk(jnp.int32(ci)) for ci in range(nc)])
    else:
        out = lax.map(one_chunk, jnp.arange(nc))      # (nc, b, qc, D)
    out = jnp.moveaxis(out, 0, 1).reshape(b, s, cfg.n_heads * cfg.head_dim)
    return dense_apply(p["wo"], out, ctx=ctx, site=f"{site}/wo",
                       act_qp=resolve_act_qp(act_qps, f"{site}/wo"))


# ---------------------------------------------------------------------------
# KV cache + decode
# ---------------------------------------------------------------------------

KV_DTYPES = ("bf16", "fp8", "fp4")


def kv_cache_spec(batch: int, s_max: int, cfg: AttnConfig, kv_dtype: str = "bf16"):
    """Shape/dtype spec for one layer's cache (used by input_specs)."""
    if kv_dtype == "bf16":
        kv = dict(shape=(batch, s_max, cfg.n_kv, cfg.head_dim), dtype=jnp.bfloat16)
        return {"k": kv, "v": kv}
    if kv_dtype == "fp8":
        kv = dict(shape=(batch, s_max, cfg.n_kv, cfg.head_dim),
                  dtype=jnp.float8_e4m3fn)
        return {"k": kv, "v": kv}
    if kv_dtype == "fp4":
        kv = dict(shape=(batch, s_max, cfg.n_kv, cfg.head_dim // 2), dtype=jnp.uint8)
        sc = dict(shape=(batch, s_max, cfg.n_kv), dtype=jnp.float16)
        return {"k": kv, "v": kv, "k_scale": sc, "v_scale": sc}
    raise ValueError(kv_dtype)


def init_kv_cache(batch, s_max, cfg: AttnConfig, kv_dtype="bf16"):
    spec = kv_cache_spec(batch, s_max, cfg, kv_dtype)
    return {k: jnp.zeros(v["shape"], v["dtype"]) for k, v in spec.items()}


def _kv_store(cache: dict, k_new, v_new, pos, kv_dtype: str):
    """Write one position (B, 1, K, hd) into the cache at ``pos``."""
    if kv_dtype == "bf16":
        k_new, v_new = k_new.astype(jnp.bfloat16), v_new.astype(jnp.bfloat16)
        return {
            "k": lax.dynamic_update_slice_in_dim(cache["k"], k_new, pos, axis=1),
            "v": lax.dynamic_update_slice_in_dim(cache["v"], v_new, pos, axis=1),
        }
    if kv_dtype == "fp8":
        return {
            "k": lax.dynamic_update_slice_in_dim(
                cache["k"], k_new.astype(jnp.float8_e4m3fn), pos, axis=1),
            "v": lax.dynamic_update_slice_in_dim(
                cache["v"], v_new.astype(jnp.float8_e4m3fn), pos, axis=1),
        }
    # fp4: signed E2M1 with per-(token, kv-head) scale (MSFP-style).
    from repro.kernels import ops
    out = dict(cache)
    for name, t in (("k", k_new), ("v", v_new)):
        packed, scale = ops.kv4_encode(t)
        out[name] = lax.dynamic_update_slice_in_dim(cache[name], packed, pos, axis=1)
        out[f"{name}_scale"] = lax.dynamic_update_slice_in_dim(
            cache[f"{name}_scale"], scale, pos, axis=1)
    return out


def _kv_load(cache: dict, kv_dtype: str, dtype=jnp.bfloat16):
    if kv_dtype in ("bf16", "fp8"):
        return cache["k"].astype(dtype), cache["v"].astype(dtype)
    from repro.kernels import ops
    k = ops.kv4_decode(cache["k"], cache["k_scale"], dtype)
    v = ops.kv4_decode(cache["v"], cache["v_scale"], dtype)
    return k, v


def attn_decode(p: dict, x: jnp.ndarray, cache: dict, store_pos, valid_len,
                cos_t, sin_t, cfg: AttnConfig, *, kv_dtype: str = "bf16",
                ctx=None, site: str | None = None,
                act_qps=None) -> tuple[jnp.ndarray, dict]:
    """One-token decode. x: (B, 1, D).

    ``store_pos``: cache slot for the new token (ring index for windowed
    layers, absolute position otherwise). ``valid_len``: number of valid
    cache slots to attend over (= min(pos+1, window or s_max)); ring slots
    hold the most recent ``window`` tokens with their absolute RoPE applied
    at store time, so relative rotation stays correct after wraparound.
    cos_t/sin_t: (1, hd/2) rotation for the *absolute* position.
    """
    b = x.shape[0]
    g = cfg.n_heads // cfg.n_kv
    q, k, v = _qkv(p, x, cfg, cos_t, sin_t, ctx=ctx, site=site,
                   act_qps=act_qps)
    cache = _kv_store(cache, k, v, store_pos, kv_dtype)
    keys, vals = _kv_load(cache, kv_dtype, x.dtype)
    s_max = keys.shape[1]
    scale = cfg.head_dim ** -0.5
    logits = jnp.einsum("bqkgh,bskh->bkgqs", q, keys,
                        preferred_element_type=jnp.float32) * scale
    if cfg.softcap:
        logits = cfg.softcap * jnp.tanh(logits / cfg.softcap)
    valid = jnp.arange(s_max) < valid_len
    logits = jnp.where(valid[None, None, None, None, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(vals.dtype)
    o = jnp.einsum("bkgqs,bskh->bqkgh", w, vals)
    o = o.reshape(b, 1, cfg.n_heads * cfg.head_dim)
    return dense_apply(p["wo"], o, ctx=ctx, site=f"{site}/wo",
                       act_qp=resolve_act_qp(act_qps, f"{site}/wo")), cache
