"""NN substrate: layers, attention, MLPs, SSM, MoE, UNet, embeddings."""
