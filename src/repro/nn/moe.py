"""Token-choice top-k MoE with fixed expert capacity (sort-based dispatch).

Dispatch avoids the GShard (T, E, C) one-hot (which materializes at
65k x 384 x 1700 for kimi-scale inputs): instead we sort the (T*k)
token-expert assignments by expert id, compute each entry's position
within its expert segment with a cummax trick, and scatter into a dense
(E, C, d) buffer. Combine is the inverse gather, weighted by router probs.

Sharding: experts live on the ``model`` mesh axis (expert parallelism);
the scatter/gather across the token <-> expert resharding lowers to
all-to-all-style collectives under GSPMD. Capacity overflows drop (standard
for fixed-capacity MoE); capacity_factor sizes the buffer.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.nn.layers import dense_apply, dense_init
from repro.nn.mlp import ACTIVATIONS


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int                    # per-expert hidden
    n_experts: int
    top_k: int
    n_shared: int = 0            # shared (always-on) experts
    capacity_factor: float = 1.25
    act: str = "silu"
    router_dtype: str = "float32"


def moe_init(key, cfg: MoEConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 5)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    s_in = 1.0 / jnp.sqrt(d)
    s_out = 1.0 / jnp.sqrt(f)
    p = {
        "router": dense_init(ks[0], d, e, dtype=jnp.float32),
        "w_gate": jax.random.normal(ks[1], (e, d, f), dtype) * s_in,
        "w_up": jax.random.normal(ks[2], (e, d, f), dtype) * s_in,
        "w_down": jax.random.normal(ks[3], (e, f, d), dtype) * s_out,
    }
    if cfg.n_shared:
        from repro.nn.mlp import glu_mlp_init
        p["shared"] = glu_mlp_init(ks[4], d, f * cfg.n_shared, dtype)
    return p


def capacity(n_tokens: int, cfg: MoEConfig) -> int:
    c = int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, ((c + 7) // 8) * 8)  # pad to a lane-friendly multiple


def _positions_in_segment(sorted_ids: jnp.ndarray) -> jnp.ndarray:
    """For a sorted id vector, the rank of each entry within its id run."""
    n = sorted_ids.shape[0]
    idx = jnp.arange(n)
    is_start = jnp.concatenate([jnp.ones((1,), bool),
                                sorted_ids[1:] != sorted_ids[:-1]])
    seg_start = jax.lax.cummax(jnp.where(is_start, idx, 0))
    return idx - seg_start


def moe_apply(p: dict, x: jnp.ndarray, cfg: MoEConfig, *, ctx=None,
              site: str | None = None) -> jnp.ndarray:
    """x: (..., d) -> (..., d). Flattens leading dims into tokens."""
    lead = x.shape[:-1]
    d = x.shape[-1]
    xt = x.reshape(-1, d)
    t = xt.shape[0]
    k = cfg.top_k
    e = cfg.n_experts
    c = capacity(t, cfg)

    # Router (fp32 for numerics; kept unquantized like the paper's sensitive layers)
    logits = dense_apply(p["router"], xt.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_ids = jax.lax.top_k(probs, k)                     # (T,k)
    gate_w = (gate_w / jnp.clip(gate_w.sum(-1, keepdims=True), 1e-9)).astype(x.dtype)

    # ---- dispatch: sort (T*k) assignments by expert ----
    flat_e = gate_ids.reshape(-1)                                  # (T*k,)
    flat_w = gate_w.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t), k)
    order = jnp.argsort(flat_e)
    se, sw, st = flat_e[order], flat_w[order], flat_tok[order]
    pos = _positions_in_segment(se)
    keep = pos < c
    dst = jnp.where(keep, se * c + pos, e * c)                     # sentinel row

    xq = ctx.act(f"{site}/experts", xt) if (ctx is not None and site) else xt
    buf = jnp.zeros((e * c + 1, d), xq.dtype).at[dst].set(xq[st])
    hidden = buf[:-1].reshape(e, c, d)

    # ---- expert FFN (batched over experts; experts shard on 'model') ----
    def w(name):
        from repro.core.qmodule import PackedW4, dequant_weight
        wt = p[name]
        if isinstance(wt, PackedW4):  # W4 serving: dequant per expert block
            return dequant_weight(wt, hidden.dtype)
        return wt.astype(hidden.dtype)

    act = ACTIVATIONS[cfg.act]
    g = jnp.einsum("ecd,edf->ecf", hidden, w("w_gate"))
    u = jnp.einsum("ecd,edf->ecf", hidden, w("w_up"))
    h = act(g) * u
    if ctx is not None and site:
        h = ctx.act(f"{site}/down", h)
    out_e = jnp.einsum("ecf,efd->ecd", h, w("w_down"))

    # ---- combine: gather back and weight ----
    flat_out = out_e.reshape(e * c, d)
    gathered = jnp.where(keep[:, None], flat_out[jnp.clip(dst, 0, e * c - 1)], 0)
    contrib = gathered * sw[:, None]
    yt = jnp.zeros((t, d), x.dtype).at[st].add(contrib)

    if "shared" in p:
        from repro.nn.mlp import glu_mlp_apply
        yt = yt + glu_mlp_apply(p["shared"], xt, act=cfg.act, ctx=ctx,
                                site=f"{site}/shared" if site else None)
    return yt.reshape(*lead, d)


def _dispatch_local(xt, probs, cfg: MoEConfig, c: int):
    """Sort-based dispatch of LOCAL tokens into a (E, c, d) buffer.

    Returns (hidden, combine_meta) where combine_meta re-gathers outputs."""
    t, d = xt.shape
    k, e = cfg.top_k, cfg.n_experts
    gate_w, gate_ids = jax.lax.top_k(probs, k)
    gate_w = (gate_w / jnp.clip(gate_w.sum(-1, keepdims=True), 1e-9)).astype(xt.dtype)
    flat_e = gate_ids.reshape(-1)
    flat_w = gate_w.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t), k)
    order = jnp.argsort(flat_e)
    se, sw, st = flat_e[order], flat_w[order], flat_tok[order]
    pos = _positions_in_segment(se)
    keep = pos < c
    dst = jnp.where(keep, se * c + pos, e * c)
    buf = jnp.zeros((e * c + 1, d), xt.dtype).at[dst].set(xt[st])
    return buf[:-1].reshape(e, c, d), (keep, dst, st, sw)


def _combine_local(out_e, meta, t: int, d: int, dtype):
    keep, dst, st, sw = meta
    e_c = out_e.shape[0] * out_e.shape[1]
    flat_out = out_e.reshape(e_c, -1)
    gathered = jnp.where(keep[:, None], flat_out[jnp.clip(dst, 0, e_c - 1)], 0)
    return jnp.zeros((t, d), dtype).at[st].add(gathered * sw[:, None])


def moe_apply_ep(p: dict, x: jnp.ndarray, cfg: MoEConfig, *,
                 model_axis: str = "model", ctx=None,
                 site: str | None = None) -> jnp.ndarray:
    """Expert-parallel MoE via shard_map (the §Perf fix for the baseline's

    global-argsort dispatch, which GSPMD lowers to TB-scale sort
    collectives). Each data shard sorts/buckets its LOCAL tokens, then a
    single tiled all-to-all over the ``model`` axis reshards
    (E, C_local, d) -> (E_local, mp*C_local, d); experts compute locally;
    the inverse all-to-all + local gather combines. Collective volume is
    2x the dispatched activations — the textbook EP lower bound.
    """
    from jax.sharding import PartitionSpec as P

    from repro.common.sharding import ambient_mesh

    mesh = ambient_mesh()
    if mesh is None or model_axis not in mesh.axis_names:
        return moe_apply(p, x, cfg, ctx=ctx, site=site)
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    mp = sizes[model_axis]
    if cfg.n_experts % mp != 0:
        return moe_apply(p, x, cfg, ctx=ctx, site=site)
    dp_axes = tuple(a for a in mesh.axis_names if a != model_axis)

    lead = x.shape[:-1]
    d = x.shape[-1]
    xt = x.reshape(-1, d)
    t = xt.shape[0]
    # Tokens shard over EVERY mesh axis for the dispatch (model included) —
    # dispatching on dp-only shards would replicate the sort/scatter across
    # the model axis (the refuted first attempt in §Perf iteration B1).
    tok_axes = (*dp_axes, model_axis)
    n_shards = 1
    for a in tok_axes:
        n_shards *= sizes[a]
    if t % n_shards != 0:
        return moe_apply(p, x, cfg, ctx=ctx, site=site)
    t_local = t // n_shards
    c_l = capacity(t_local, cfg)

    def local_fn(xt_l, router_w, w_gate_l, w_up_l, w_down_l):
        xt_l = xt_l.reshape(-1, d)  # (T_l, d)
        logits = xt_l.astype(jnp.float32) @ router_w
        probs = jax.nn.softmax(logits, axis=-1)
        hidden, meta = _dispatch_local(xt_l, probs, cfg, c_l)  # (E, c_l, d)
        # (E, c_l, d) -> (E/mp, mp*c_l, d)
        hidden = jax.lax.all_to_all(hidden, model_axis, split_axis=0,
                                    concat_axis=1, tiled=True)
        act = ACTIVATIONS[cfg.act]
        g = jnp.einsum("ecd,edf->ecf", hidden, w_gate_l.astype(hidden.dtype))
        u = jnp.einsum("ecd,edf->ecf", hidden, w_up_l.astype(hidden.dtype))
        out_e = jnp.einsum("ecf,efd->ecd", act(g) * u,
                           w_down_l.astype(hidden.dtype))
        out_e = jax.lax.all_to_all(out_e, model_axis, split_axis=1,
                                   concat_axis=0, tiled=True)  # (E, c_l, d)
        return _combine_local(out_e, meta, xt_l.shape[0], d, xt_l.dtype)

    in_specs = (P(tok_axes, None), P(None, None), P(model_axis, None, None),
                P(model_axis, None, None), P(model_axis, None, None))
    try:
        from jax import shard_map
        sharded = shard_map(local_fn, mesh=mesh, in_specs=in_specs,
                            out_specs=P(tok_axes, None), check_vma=False)
    except (ImportError, TypeError):
        # older JAX: experimental home and/or the check_rep spelling
        from jax.experimental.shard_map import shard_map
        sharded = shard_map(local_fn, mesh=mesh, in_specs=in_specs,
                            out_specs=P(tok_axes, None), check_rep=False)

    yt = sharded(xt, p["router"]["w"].astype(jnp.float32), p["w_gate"],
                 p["w_up"], p["w_down"])

    if "shared" in p:
        from repro.nn.mlp import glu_mlp_apply
        yt = yt + glu_mlp_apply(p["shared"], xt, act=cfg.act, ctx=ctx,
                                site=f"{site}/shared" if site else None)
    return yt.reshape(*lead, d)


def aux_load_balance_loss(logits: jnp.ndarray, gate_ids: jnp.ndarray,
                          cfg: MoEConfig) -> jnp.ndarray:
    """Switch-style load-balance auxiliary loss (used by train recipes)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    me = probs.mean(axis=0)
    one_hot = jax.nn.one_hot(gate_ids[..., 0], cfg.n_experts)
    ce = one_hot.mean(axis=0)
    return cfg.n_experts * jnp.sum(me * ce)
