"""DDPM/LDM-style UNet epsilon-predictor — the paper's model family.

Faithful to the DDIM (CIFAR/CelebA) and LDM (LSUN/ImageNet) backbones:
ResBlocks with timestep-embedding injection, spatial self-attention at
configured resolutions, down/upsampling, optional class conditioning.
Every conv/dense is a quant site; the SiLU between norm and conv is what
creates the paper's AALs.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.nn.embeddings import timestep_embedding
from repro.nn.layers import (conv2d_apply, conv2d_init, dense_apply,
                             dense_init, groupnorm_apply, groupnorm_init, silu)


@dataclasses.dataclass(frozen=True)
class UNetConfig:
    image_size: int = 32
    in_ch: int = 3
    out_ch: int = 3
    ch: int = 128
    ch_mult: tuple = (1, 2, 2, 2)
    num_res_blocks: int = 2
    attn_resolutions: tuple = (16,)
    num_classes: int | None = None
    gn_groups: int = 32

    @property
    def temb_dim(self) -> int:
        return self.ch * 4


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _res_init(key, c_in, c_out, temb_dim, dtype):
    ks = jax.random.split(key, 4)
    p = {
        "norm1": groupnorm_init(c_in, dtype),
        "conv1": conv2d_init(ks[0], c_in, c_out, 3, dtype=dtype),
        "temb": dense_init(ks[1], temb_dim, c_out, bias=True, dtype=dtype),
        "norm2": groupnorm_init(c_out, dtype),
        "conv2": conv2d_init(ks[2], c_out, c_out, 3, dtype=dtype, scale=1e-5),
    }
    if c_in != c_out:
        p["skip"] = conv2d_init(ks[3], c_in, c_out, 1, dtype=dtype)
    return p


def _attn_init(key, c, dtype):
    ks = jax.random.split(key, 4)
    return {
        "norm": groupnorm_init(c, dtype),
        "q": dense_init(ks[0], c, c, bias=True, dtype=dtype),
        "k": dense_init(ks[1], c, c, bias=True, dtype=dtype),
        "v": dense_init(ks[2], c, c, bias=True, dtype=dtype),
        "proj": dense_init(ks[3], c, c, bias=True, dtype=dtype, scale=1e-5),
    }


def unet_init(key, cfg: UNetConfig, dtype=jnp.float32) -> dict:
    keys = iter(jax.random.split(key, 4096))
    p: dict[str, Any] = {
        "temb0": dense_init(next(keys), cfg.ch, cfg.temb_dim, bias=True, dtype=dtype),
        "temb1": dense_init(next(keys), cfg.temb_dim, cfg.temb_dim, bias=True, dtype=dtype),
        "conv_in": conv2d_init(next(keys), cfg.in_ch, cfg.ch, 3, dtype=dtype),
    }
    if cfg.num_classes:
        p["class_emb"] = {"table": jax.random.normal(
            next(keys), (cfg.num_classes, cfg.temb_dim), dtype) * 0.02}

    res = cfg.image_size
    chans = [cfg.ch]
    c_cur = cfg.ch
    for i, mult in enumerate(cfg.ch_mult):
        c_out = cfg.ch * mult
        for j in range(cfg.num_res_blocks):
            p[f"down_{i}.res_{j}"] = _res_init(next(keys), c_cur, c_out,
                                               cfg.temb_dim, dtype)
            c_cur = c_out
            if res in cfg.attn_resolutions:
                p[f"down_{i}.attn_{j}"] = _attn_init(next(keys), c_cur, dtype)
            chans.append(c_cur)
        if i != len(cfg.ch_mult) - 1:
            p[f"down_{i}.downsample"] = conv2d_init(next(keys), c_cur, c_cur, 3,
                                                    dtype=dtype)
            res //= 2
            chans.append(c_cur)

    p["mid.res_0"] = _res_init(next(keys), c_cur, c_cur, cfg.temb_dim, dtype)
    p["mid.attn"] = _attn_init(next(keys), c_cur, dtype)
    p["mid.res_1"] = _res_init(next(keys), c_cur, c_cur, cfg.temb_dim, dtype)

    for i in reversed(range(len(cfg.ch_mult))):
        c_out = cfg.ch * cfg.ch_mult[i]
        for j in range(cfg.num_res_blocks + 1):
            c_skip = chans.pop()
            p[f"up_{i}.res_{j}"] = _res_init(next(keys), c_cur + c_skip, c_out,
                                             cfg.temb_dim, dtype)
            c_cur = c_out
            if res in cfg.attn_resolutions:
                p[f"up_{i}.attn_{j}"] = _attn_init(next(keys), c_cur, dtype)
        if i != 0:
            p[f"up_{i}.upsample"] = conv2d_init(next(keys), c_cur, c_cur, 3,
                                                dtype=dtype)
            res *= 2

    p["norm_out"] = groupnorm_init(c_cur, dtype)
    p["conv_out"] = conv2d_init(next(keys), c_cur, cfg.out_ch, 3, dtype=dtype,
                                scale=1e-5)
    return p


# ---------------------------------------------------------------------------
# apply
# ---------------------------------------------------------------------------


def _res_apply(p, x, temb, cfg, *, ctx, site):
    h = silu(groupnorm_apply(p["norm1"], x, groups=cfg.gn_groups))
    h = conv2d_apply(p["conv1"], h, ctx=ctx, site=f"{site}/conv1")
    h = h + dense_apply(p["temb"], silu(temb), ctx=ctx,
                        site=f"{site}/temb")[:, None, None, :]
    h = silu(groupnorm_apply(p["norm2"], h, groups=cfg.gn_groups))
    h = conv2d_apply(p["conv2"], h, ctx=ctx, site=f"{site}/conv2")
    if "skip" in p:
        x = conv2d_apply(p["skip"], x, ctx=ctx, site=f"{site}/skip")
    return x + h


def _attn_apply(p, x, cfg, *, ctx, site):
    b, hh, ww, c = x.shape
    h = groupnorm_apply(p["norm"], x, groups=cfg.gn_groups).reshape(b, hh * ww, c)
    q = dense_apply(p["q"], h, ctx=ctx, site=f"{site}/q")
    k = dense_apply(p["k"], h, ctx=ctx, site=f"{site}/k")
    v = dense_apply(p["v"], h, ctx=ctx, site=f"{site}/v")
    w = jax.nn.softmax(jnp.einsum("bqc,bkc->bqk", q, k,
                                  preferred_element_type=jnp.float32)
                       * (c ** -0.5), axis=-1).astype(v.dtype)
    o = jnp.einsum("bqk,bkc->bqc", w, v)
    o = dense_apply(p["proj"], o, ctx=ctx, site=f"{site}/proj")
    return x + o.reshape(b, hh, ww, c)


def unet_apply(p: dict, x: jnp.ndarray, t: jnp.ndarray, cfg: UNetConfig, *,
               y: jnp.ndarray | None = None, ctx=None) -> jnp.ndarray:
    """x: (B,H,W,C) noisy image; t: (B,) timesteps -> predicted eps."""
    temb = timestep_embedding(t, cfg.ch)
    temb = dense_apply(p["temb0"], temb, ctx=ctx, site="temb0")
    temb = dense_apply(p["temb1"], silu(temb), ctx=ctx, site="temb1")
    if cfg.num_classes and y is not None:
        temb = temb + jnp.take(p["class_emb"]["table"], y, axis=0)

    h = conv2d_apply(p["conv_in"], x, ctx=ctx, site="conv_in")
    hs = [h]
    res = cfg.image_size
    for i in range(len(cfg.ch_mult)):
        for j in range(cfg.num_res_blocks):
            h = _res_apply(p[f"down_{i}.res_{j}"], h, temb, cfg, ctx=ctx,
                           site=f"down_{i}.res_{j}")
            if f"down_{i}.attn_{j}" in p:
                h = _attn_apply(p[f"down_{i}.attn_{j}"], h, cfg, ctx=ctx,
                                site=f"down_{i}.attn_{j}")
            hs.append(h)
        if i != len(cfg.ch_mult) - 1:
            h = conv2d_apply(p[f"down_{i}.downsample"], h, stride=2, ctx=ctx,
                             site=f"down_{i}.downsample")
            res //= 2
            hs.append(h)

    h = _res_apply(p["mid.res_0"], h, temb, cfg, ctx=ctx, site="mid.res_0")
    h = _attn_apply(p["mid.attn"], h, cfg, ctx=ctx, site="mid.attn")
    h = _res_apply(p["mid.res_1"], h, temb, cfg, ctx=ctx, site="mid.res_1")

    for i in reversed(range(len(cfg.ch_mult))):
        for j in range(cfg.num_res_blocks + 1):
            h = jnp.concatenate([h, hs.pop()], axis=-1)
            h = _res_apply(p[f"up_{i}.res_{j}"], h, temb, cfg, ctx=ctx,
                           site=f"up_{i}.res_{j}")
            if f"up_{i}.attn_{j}" in p:
                h = _attn_apply(p[f"up_{i}.attn_{j}"], h, cfg, ctx=ctx,
                                site=f"up_{i}.attn_{j}")
        if i != 0:
            b, hh, ww, c = h.shape
            h = jax.image.resize(h, (b, hh * 2, ww * 2, c), "nearest")
            h = conv2d_apply(p[f"up_{i}.upsample"], h, ctx=ctx,
                             site=f"up_{i}.upsample")
            res *= 2

    h = silu(groupnorm_apply(p["norm_out"], h, groups=cfg.gn_groups))
    return conv2d_apply(p["conv_out"], h, ctx=ctx, site="conv_out")


def io_sites(p: dict) -> set[str]:
    """Input/output layers the paper keeps at 8-bit."""
    return {"conv_in", "conv_in/w", "conv_out", "conv_out/w"}


def lora_target_sites(p: dict) -> dict[str, tuple[int, int]]:
    """LoRA dims for every conv/dense weight (paper: all quantized layers).

    Keys are '/'-joined weight paths (e.g. 'mid.attn/q/w'); convs use the
    flattened (kh*kw*cin, cout) factorization (see talora.merge_into_tree).
    """
    from repro.common.tree import flatten_paths
    from repro.core.talora import lora_target_dims_from_weights

    flat = {k: v for k, v in flatten_paths(p).items()
            if k.endswith("/w") and v.ndim >= 2}
    return lora_target_dims_from_weights(flat)
