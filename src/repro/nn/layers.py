"""Functional NN layers with explicit param dicts and quantization hooks.

Every layer is an (init, apply) pair over plain dicts — no module framework,
so params are trivially shardable / checkpointable / scannable.

Quantization integrates via two hooks threaded through ``apply``:
  * ``ctx``  — a ``repro.quant.QuantContext``: ``ctx.act(site, x)`` observes
    or fake-quantizes the layer input (site = '/'-joined param path).
  * weights — a dense array (possibly already fake-quantized), or a
    ``PackedW4`` (serving form), dispatched here.
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.qmodule import PackedW4, w4_dense_xla
from repro.quant.calibrate import (QuantContext, OFF,  # noqa: F401
                                   resolve_act_qp)


def _maybe_quant_act(ctx: QuantContext | None, site: str | None, x):
    if ctx is None or site is None:
        return x
    return ctx.act(site, x)


# ---------------------------------------------------------------------------
# Dense
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, *, bias: bool = False,
               dtype=jnp.float32, scale: float | None = None) -> dict:
    scale = scale if scale is not None else 1.0 / jnp.sqrt(d_in)
    p = {"w": jax.random.normal(key, (d_in, d_out), dtype) * scale}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense_apply(p: dict, x: jnp.ndarray, *, ctx: QuantContext | None = None,
                site: str | None = None, act_qp=None) -> jnp.ndarray:
    """``act_qp`` (a ``QuantizerParams``) requests serve-mode activation
    quantization: fused into the packed matmul kernel for PackedW4 weights,
    a standalone ``msfp_quantize`` pass for dense (bf16-fallback) weights —
    so serving matches the fake-quant oracle at every planned act site. A
    serve-mode ``ctx`` can supply it per site when the caller doesn't."""
    x = _maybe_quant_act(ctx, site, x)
    w = p["w"]
    if act_qp is None and ctx is not None:
        act_qp = ctx.serving_qp(site)  # site=None still gets the '*' qp
    if isinstance(w, PackedW4):
        from repro.kernels import ops  # late import; kernels depend on nn types
        y = ops.w4a4_matmul(x, w, act_qp)
    else:
        if act_qp is not None:
            from repro.kernels import ops
            x = ops.msfp_quantize(x, act_qp)
        y = x @ w.astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# Conv2D (NHWC, HWIO) — the UNet's workhorse
# ---------------------------------------------------------------------------


def conv2d_init(key, c_in: int, c_out: int, kernel: int = 3, *,
                bias: bool = True, dtype=jnp.float32,
                scale: float | None = None) -> dict:
    fan_in = c_in * kernel * kernel
    scale = scale if scale is not None else 1.0 / jnp.sqrt(fan_in)
    p = {"w": jax.random.normal(key, (kernel, kernel, c_in, c_out), dtype) * scale}
    if bias:
        p["b"] = jnp.zeros((c_out,), dtype)
    return p


def conv2d_apply(p: dict, x: jnp.ndarray, *, stride: int = 1,
                 padding: str | Sequence = "SAME",
                 ctx: QuantContext | None = None,
                 site: str | None = None, act_qp=None) -> jnp.ndarray:
    """Mirrors ``dense_apply``'s serving contract: PackedW4 weights route
    through the W4A4 conv kernels (implicit GEMM where it fits, im2col
    fallback — never decode-then-XLA-conv; see ``ops.w4a4_conv2d``), and
    ``act_qp`` / serve-mode ``ctx.serving_qp`` quantizes the input either
    inside that kernel or, for dense-fallback weights, in a standalone
    pass — conv sites see the same numerics the fake-quant model did."""
    x = _maybe_quant_act(ctx, site, x)
    w = p["w"]
    if act_qp is None and ctx is not None:
        act_qp = ctx.serving_qp(site)
    if isinstance(w, PackedW4):
        from repro.kernels import ops
        y = ops.w4a4_conv2d(x, w, act_qp, stride=stride, padding=padding)
    else:
        if act_qp is not None:
            from repro.kernels import ops
            x = ops.msfp_quantize(x, act_qp)
        y = lax.conv_general_dilated(
            x, w.astype(x.dtype), window_strides=(stride, stride),
            padding=padding, dimension_numbers=("NHWC", "HWIO", "NHWC"))
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_init(dim: int, dtype=jnp.float32) -> dict:
    return {"g": jnp.ones((dim,), dtype)}


def rmsnorm_apply(p: dict, x: jnp.ndarray, *, eps: float = 1e-6,
                  plus_one: bool = False) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    n = xf * lax.rsqrt(var + eps)
    g = p["g"].astype(jnp.float32)
    g = g + 1.0 if plus_one else g  # gemma convention stores g-1
    return (n * g).astype(x.dtype)


def layernorm_init(dim: int, dtype=jnp.float32) -> dict:
    return {"g": jnp.ones((dim,), dtype), "b": jnp.zeros((dim,), dtype)}


def layernorm_apply(p: dict, x: jnp.ndarray, *, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(axis=-1, keepdims=True)
    n = (xf - mu) * lax.rsqrt(var + eps)
    return (n * p["g"].astype(jnp.float32)
            + p["b"].astype(jnp.float32)).astype(x.dtype)


def groupnorm_init(dim: int, dtype=jnp.float32) -> dict:
    return {"g": jnp.ones((dim,), dtype), "b": jnp.zeros((dim,), dtype)}


def groupnorm_apply(p: dict, x: jnp.ndarray, *, groups: int = 32,
                    eps: float = 1e-5) -> jnp.ndarray:
    """NHWC group norm."""
    b, h, w, c = x.shape
    g = min(groups, c)
    xf = x.astype(jnp.float32).reshape(b, h, w, g, c // g)
    mu = xf.mean(axis=(1, 2, 4), keepdims=True)
    var = ((xf - mu) ** 2).mean(axis=(1, 2, 4), keepdims=True)
    n = ((xf - mu) * lax.rsqrt(var + eps)).reshape(b, h, w, c)
    return (n * p["g"] + p["b"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------


def embed_init(key, vocab: int, dim: int, dtype=jnp.float32) -> dict:
    return {"table": jax.random.normal(key, (vocab, dim), dtype) * 0.02}


def embed_apply(p: dict, ids: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(p["table"], ids, axis=0)


def embed_attend(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    """Tied-readout logits: x @ table.T."""
    return x @ p["table"].T.astype(x.dtype)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------


def silu(x):
    return x * jax.nn.sigmoid(x)


ACTIVATIONS = {
    "silu": silu,
    "gelu": jax.nn.gelu,
    "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True),
    "relu": jax.nn.relu,
}
