"""Mamba2 block — SSD (state-space duality) with chunked scan.

Follows the minimal-SSD algorithm of arXiv:2405.21060 §6 but runs a
``lax.scan`` over chunks (carrying the inter-chunk SSM state) so the
(h, s, s) intra-chunk kernel only ever materializes for one chunk — the
TPU-friendly shape: matmul-dominated within chunks, O(1) memory across.

Decode is the dual recurrence: state' = exp(dt*A) * state + dt * B ⊗ x.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from repro.nn.layers import dense_apply, dense_init, rmsnorm_apply, rmsnorm_init, silu


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_model: int
    d_state: int = 128
    headdim: int = 64
    expand: int = 2
    n_groups: int = 1
    d_conv: int = 4
    chunk: int = 256

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.headdim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.n_groups * self.d_state


def ssm_init(key, cfg: SSMConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 4)
    d_in_proj = 2 * cfg.d_inner + 2 * cfg.n_groups * cfg.d_state + cfg.n_heads
    return {
        "in_proj": dense_init(ks[0], cfg.d_model, d_in_proj, dtype=dtype),
        "conv_w": jax.random.normal(ks[1], (cfg.d_conv, cfg.conv_dim), dtype) * 0.2,
        "conv_b": jnp.zeros((cfg.conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, cfg.n_heads).astype(dtype)),
        "dt_bias": jnp.zeros((cfg.n_heads,), dtype),
        "D": jnp.ones((cfg.n_heads,), dtype),
        "norm": rmsnorm_init(cfg.d_inner, dtype),
        "out_proj": dense_init(ks[2], cfg.d_inner, cfg.d_model, dtype=dtype),
    }


def _split_proj(zxbcdt, cfg: SSMConfig):
    di, g, n, h = cfg.d_inner, cfg.n_groups, cfg.d_state, cfg.n_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + cfg.conv_dim]
    dt = zxbcdt[..., di + cfg.conv_dim:]
    return z, xbc, dt


def _causal_conv(xbc, w, b):
    """Depthwise causal conv over sequence. xbc: (B,S,C); w: (K,C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i] for i in range(k))
    return silu(out + b)


def _segsum(a):
    """(..., s) -> (..., s, s) lower-tri segment sums: sum of a[j+1..i]."""
    s = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]          # sum over (j, i]
    mask = jnp.tril(jnp.ones((s, s), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_scan(x, dt_a, b_mat, c_mat, chunk: int, init_state=None,
             unroll: bool = False):
    """Chunked SSD. x: (B,S,H,P) (already dt-scaled), dt_a: (B,S,H) log-decay,
    b_mat/c_mat: (B,S,H,N) (groups pre-broadcast to heads).
    Returns y: (B,S,H,P), final state (B,H,P,N)."""
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    def to_chunks(t):
        # (B, S, ...) -> (nc, B, chunk, ...): scan iterates the leading axis.
        return jnp.swapaxes(t.reshape(bsz, nc, chunk, *t.shape[2:]), 0, 1)

    xc, ac, bc, cc = map(to_chunks, (x, dt_a, b_mat, c_mat))
    state0 = (jnp.zeros((bsz, h, p, n), jnp.float32)
              if init_state is None else init_state.astype(jnp.float32))

    def step(state, inp):
        xk, ak, bk, ck = inp                          # (B,chunk,H,*)
        a_t = jnp.moveaxis(ak, -1, 1).astype(jnp.float32)  # (B,H,chunk)
        cum = jnp.cumsum(a_t, axis=-1)
        li = jnp.exp(_segsum(a_t))                    # (B,H,s,s)
        y_diag = jnp.einsum("blhn,bshn,bhls,bshp->blhp", ck, bk,
                            li.astype(ck.dtype), xk)
        decay_states = jnp.exp(cum[..., -1:] - cum)   # (B,H,s)
        chunk_state = jnp.einsum("bshn,bhs,bshp->bhpn", bk,
                                 decay_states.astype(bk.dtype), xk)
        out_decay = jnp.exp(cum).astype(ck.dtype)     # (B,H,s)
        y_off = jnp.einsum("blhn,bhpn,bhl->blhp", ck,
                           state.astype(ck.dtype), out_decay)
        new_state = (jnp.exp(cum[..., -1])[..., None, None] * state
                     + chunk_state.astype(jnp.float32))
        return new_state, y_diag + y_off

    if unroll:  # exact-cost dry-run path
        state, ys_l = state0, []
        for i in range(nc):
            state, yi = step(state, (xc[i], ac[i], bc[i], cc[i]))
            ys_l.append(yi)
        final, ys = state, jnp.stack(ys_l)
    else:
        final, ys = lax.scan(step, state0, (xc, ac, bc, cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, s, h, p)
    return y, final


def ssm_apply(p: dict, x: jnp.ndarray, cfg: SSMConfig, *, ctx=None,
              unroll: bool = False, site: str | None = None) -> jnp.ndarray:
    """Full-sequence Mamba2 block. x: (B,S,D) -> (B,S,D)."""
    bsz, s, _ = x.shape
    zxbcdt = dense_apply(p["in_proj"], x, ctx=ctx, site=f"{site}/in_proj")
    z, xbc, dt = _split_proj(zxbcdt, cfg)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    di, g, n, h = cfg.d_inner, cfg.n_groups, cfg.d_state, cfg.n_heads
    xs = xbc[..., :di].reshape(bsz, s, h, cfg.headdim)
    b_mat = xbc[..., di:di + g * n].reshape(bsz, s, g, n)
    c_mat = xbc[..., di + g * n:].reshape(bsz, s, g, n)
    rep = h // g
    b_mat = jnp.repeat(b_mat, rep, axis=2)
    c_mat = jnp.repeat(c_mat, rep, axis=2)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,S,H)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))                  # (H,)
    y, _ = ssd_scan(xs * dt[..., None].astype(xs.dtype), dt * a,
                    b_mat, c_mat, cfg.chunk, unroll=unroll)
    y = y + xs * p["D"][None, None, :, None].astype(xs.dtype)
    y = y.reshape(bsz, s, di) * silu(z)
    y = rmsnorm_apply(p["norm"], y)
    return dense_apply(p["out_proj"], y, ctx=ctx, site=f"{site}/out_proj")


# ---------------------------------------------------------------------------
# Decode: recurrent state + rolling conv buffer
# ---------------------------------------------------------------------------


def ssm_state_spec(batch: int, cfg: SSMConfig):
    return {
        "state": dict(shape=(batch, cfg.n_heads, cfg.headdim, cfg.d_state),
                      dtype=jnp.float32),
        "conv": dict(shape=(batch, cfg.d_conv - 1, cfg.conv_dim),
                     dtype=jnp.bfloat16),
    }


def init_ssm_state(batch: int, cfg: SSMConfig):
    return {k: jnp.zeros(v["shape"], v["dtype"])
            for k, v in ssm_state_spec(batch, cfg).items()}


def ssm_decode(p: dict, x: jnp.ndarray, cache: dict, cfg: SSMConfig, *,
               ctx=None, site: str | None = None):
    """One-token decode. x: (B,1,D) -> (B,1,D), updated cache."""
    bsz = x.shape[0]
    zxbcdt = dense_apply(p["in_proj"], x[:, 0], ctx=ctx, site=f"{site}/in_proj")
    z, xbc, dt = _split_proj(zxbcdt, cfg)
    # Rolling causal conv: window = [conv buffer ; xbc]
    win = jnp.concatenate([cache["conv"].astype(xbc.dtype), xbc[:, None]], axis=1)
    conv_out = silu(jnp.einsum("bkc,kc->bc", win, p["conv_w"].astype(xbc.dtype))
                    + p["conv_b"].astype(xbc.dtype))
    new_conv = win[:, 1:].astype(jnp.bfloat16)
    di, g, n, h = cfg.d_inner, cfg.n_groups, cfg.d_state, cfg.n_heads
    xs = conv_out[..., :di].reshape(bsz, h, cfg.headdim)
    b_mat = conv_out[..., di:di + g * n].reshape(bsz, g, n)
    c_mat = conv_out[..., di + g * n:].reshape(bsz, g, n)
    rep = h // g
    b_mat = jnp.repeat(b_mat, rep, axis=1)                        # (B,H,N)
    c_mat = jnp.repeat(c_mat, rep, axis=1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,H)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt * a)[..., None, None]                      # (B,H,1,1)
    incr = jnp.einsum("bhp,bhn->bhpn", (xs * dt[..., None].astype(xs.dtype)),
                      b_mat).astype(jnp.float32)
    state = cache["state"] * decay + incr
    y = jnp.einsum("bhpn,bhn->bhp", state.astype(xs.dtype), c_mat)
    y = y + xs * p["D"][None, :, None].astype(xs.dtype)
    y = y.reshape(bsz, di) * silu(z)
    y = rmsnorm_apply(p["norm"], y)
    out = dense_apply(p["out_proj"], y, ctx=ctx, site=f"{site}/out_proj")
    return out[:, None], {"state": state, "conv": new_conv}
