"""checkpoint substrate."""
