"""Distributed checkpointing: npz shards + manifest, atomic, keep-k, resume.

Design goals for fleet-scale runs:
  * **Atomic**: writes land in ``step_N.tmp`` then ``rename`` to ``step_N``
    — a preempted save never corrupts the latest checkpoint.
  * **Mesh-independent restore**: leaves are stored by logical path name,
    gathered to host; restore re-shards onto whatever mesh the new job
    runs (elastic resize: save on 4 hosts, restore on 2 — tested).
  * **Integrity**: manifest.json records shapes/dtypes + a cheap checksum
    per leaf; restore verifies before handing params to the trainer.
  * **Background save**: ``save_async`` snapshots to host then writes on a
    thread so the train loop only blocks for the device->host copy.
  * **keep-k GC** with the newest always retained.

On a real fleet each host writes only its addressable shards; here the
single-process path gathers fully (jax.device_get handles sharded arrays).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.tree import flatten_paths, unflatten_paths

_MANIFEST = "manifest.json"
_DATA = "arrays.npz"


def _checksum(a: np.ndarray) -> str:
    # cheap but order-sensitive: hash of strided subsample + shape
    sub = a.reshape(-1)[:: max(1, a.size // 4096)]
    h = hashlib.sha256()
    h.update(str(a.shape).encode())
    h.update(str(a.dtype).encode())
    h.update(np.ascontiguousarray(sub).tobytes())
    return h.hexdigest()[:16]


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # -- paths ------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:010d}")

    def steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, d, _MANIFEST)):
                    out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: dict | None = None) -> str:
        host_tree = jax.device_get(tree)
        return self._write(step, host_tree, extra or {})

    def save_async(self, step: int, tree: Any, extra: dict | None = None):
        """Snapshot to host synchronously, write on a background thread."""
        host_tree = jax.device_get(tree)
        self.wait()
        self._thread = threading.Thread(
            target=self._write, args=(step, host_tree, extra or {}))
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree: Any, extra: dict) -> str:
        flat = flatten_paths(host_tree)
        arrays = {}
        manifest = {"step": step, "extra": extra, "time": time.time(),
                    "leaves": {}}
        for i, (path, leaf) in enumerate(sorted(flat.items())):
            arr = np.asarray(leaf)
            key = f"a{i}"
            arrays[key] = arr
            manifest["leaves"][path] = {
                "key": key, "shape": list(arr.shape), "dtype": str(arr.dtype),
                "checksum": _checksum(arr),
            }
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, _DATA), **arrays)
        with open(os.path.join(tmp, _MANIFEST), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()
        return final

    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- restore ------------------------------------------------------------
    def restore(self, step: int | None = None, *, verify: bool = True,
                shardings: Any = None) -> tuple[int, Any, dict]:
        """Returns (step, tree, extra). ``shardings``: optional pytree of

        NamedShardings (same structure) to place leaves onto a new mesh."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self._step_dir(step)
        with open(os.path.join(d, _MANIFEST)) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, _DATA))
        flat = {}
        for path, meta in manifest["leaves"].items():
            arr = data[meta["key"]]
            if verify and _checksum(arr) != meta["checksum"]:
                raise IOError(f"checksum mismatch at {path} in step {step}")
            flat[path] = arr
        tree = unflatten_paths(flat)
        if shardings is not None:
            flat_s = flatten_paths(shardings)
            flat_t = flatten_paths(tree)
            placed = {p: jax.device_put(v, flat_s[p]) if p in flat_s else v
                      for p, v in flat_t.items()}
            tree = unflatten_paths(placed)
        else:
            tree = jax.tree.map(jnp.asarray, tree)
        return step, tree, manifest.get("extra", {})
