"""train substrate."""
