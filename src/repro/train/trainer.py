"""Fault-tolerant training loop.

Production behaviors implemented (and unit-tested on-box):
  * auto-restore: on (re)start the trainer resumes from the newest intact
    checkpoint; the step fn is deterministic in (state, batch, rng) so a
    restart replays bit-exactly from the last save.
  * periodic + preemption checkpointing: background-thread saves every
    ``ckpt_every``; a SIGTERM-style ``request_stop()`` triggers a final
    synchronous save (the k8s/Borg preemption hook).
  * crash containment: a failing step (device error, data corruption,
    injected fault) is caught, the run restores from the last checkpoint
    and continues — bounded by ``max_restarts``.
  * straggler watchdog: per-step wall time is tracked with an EMA; steps
    slower than ``straggler_factor`` x EMA are logged with a flag. On a
    real fleet this signal feeds the supervisor that drains/replaces slow
    hosts; on-box we record + expose it (tested via injected sleep).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterator

import jax

from repro.checkpoint.ckpt import CheckpointManager


@dataclasses.dataclass
class TrainerConfig:
    max_steps: int = 1000
    ckpt_every: int = 100
    keep_ckpts: int = 3
    log_every: int = 50
    max_restarts: int = 3
    straggler_factor: float = 3.0
    ema_beta: float = 0.9


@dataclasses.dataclass
class StepRecord:
    step: int
    wall: float
    is_straggler: bool
    metrics: dict


class Trainer:
    def __init__(self, cfg: TrainerConfig, ckpt: CheckpointManager,
                 step_fn: Callable[[Any, Any], tuple[Any, dict]],
                 *, fault_hook: Callable[[int], None] | None = None):
        """``step_fn(state, batch) -> (state, metrics)`` must be pure.

        ``fault_hook(step)`` (tests only) may raise to simulate crashes."""
        self.cfg = cfg
        self.ckpt = ckpt
        self.step_fn = step_fn
        self.fault_hook = fault_hook
        self.history: list[StepRecord] = []
        self.restarts = 0
        self._stop = False

    def request_stop(self):
        """Preemption signal: save-and-exit at the next step boundary."""
        self._stop = True

    def _restore_or(self, state: Any) -> tuple[int, Any]:
        latest = self.ckpt.latest_step()
        if latest is None:
            return 0, state
        step, tree, _ = self.ckpt.restore(latest)
        return step, tree

    def run(self, state: Any, data: Iterator) -> tuple[Any, list[StepRecord]]:
        step, state = self._restore_or(state)
        ema_wall = None
        while step < self.cfg.max_steps and not self._stop:
            batch = next(data)
            t0 = time.perf_counter()
            try:
                if self.fault_hook is not None:
                    self.fault_hook(step)
                state, metrics = self.step_fn(state, batch)
                jax.block_until_ready(jax.tree.leaves(state)[0])
            except Exception as e:  # crash containment -> restore & retry
                self.restarts += 1
                if self.restarts > self.cfg.max_restarts:
                    raise RuntimeError(
                        f"exceeded max_restarts={self.cfg.max_restarts}") from e
                self.ckpt.wait()
                step, state = self._restore_or(state)
                continue
            wall = time.perf_counter() - t0
            is_straggler = (ema_wall is not None
                            and wall > self.cfg.straggler_factor * ema_wall)
            ema_wall = (wall if ema_wall is None
                        else self.cfg.ema_beta * ema_wall
                        + (1 - self.cfg.ema_beta) * wall)
            step += 1
            self.history.append(StepRecord(step, wall, is_straggler,
                                           {k: float(v) for k, v in
                                            metrics.items()}))
            if step % self.cfg.ckpt_every == 0:
                self.ckpt.save_async(step, state)
        # final (preemption or completion) save — synchronous
        self.ckpt.wait()
        self.ckpt.save(step, state)
        return state, self.history

    def straggler_steps(self) -> list[int]:
        return [r.step for r in self.history if r.is_straggler]
