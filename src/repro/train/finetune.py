"""TALoRA + DFA fine-tuning of a quantized diffusion model (paper §4.2/4.3).

EfficientDM-style trajectory distillation: walk the FP teacher's DDIM
trajectory; at each timestep t the quantized student (TALoRA merged for
that t) matches the teacher's eps prediction under the DFA-weighted loss
(Eq. 9). Only the LoRA hubs and the router train; the quantized base and
the searched quantizers stay frozen.

``loss_mode``: 'dfa' (Eq. 9) | 'plain' (Eq. 7 baseline for the ablation).
``router_mode``: 'learned' (TALoRA) | 'single' (h=1 baseline) |
'split' / 'random' (Table 1's dual-LoRA allocation strategies).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dfa, talora
from repro.diffusion.pipeline import QuantizedDiffusion
from repro.diffusion.samplers import ddim_step
from repro.diffusion.schedule import sample_timesteps
from repro.nn.unet import unet_apply
from repro.optim.adam import AdamConfig, adam_init, adam_update
from repro.quant.calibrate import QuantContext
from repro.core import msfp


@dataclasses.dataclass
class FinetuneConfig:
    steps_per_epoch: int = 20      # DDIM trajectory length during tuning
    epochs: int = 4
    batch: int = 8
    lr: float = 1e-4
    loss_mode: str = "dfa"         # dfa | plain
    router_mode: str = "learned"   # learned | single | split | random
    eta: float = 0.0
    seed: int = 0


def _select_fixed(mode: str, t_frac: float, h: int, key) -> jnp.ndarray:
    """Non-learned allocation baselines from Table 1."""
    if mode == "single" or h == 1:
        return jax.nn.one_hot(0, h)
    if mode == "split":  # first/second half of the trajectory
        return jax.nn.one_hot(jnp.where(t_frac > 0.5, 0, 1), h)
    if mode == "random":
        return jax.nn.one_hot(jax.random.randint(key, (), 0, h), h)
    raise ValueError(mode)


def make_student_eps(bundle: QuantizedDiffusion, ft: FinetuneConfig):
    """(hubs, router, x, t_batch, key) -> eps with the right LoRA routing."""
    tcfg = bundle.talora_cfg
    names = sorted(bundle.hubs)
    qctx = QuantContext("quantize", plan=bundle.plan,
                        act_fn=msfp.quantize_act)

    def eps_fn(hubs, router, x, tb, key, t_frac):
        t_scalar = tb.reshape(-1)[0]
        if ft.router_mode == "learned":
            sels = talora.route(router, t_scalar, names, tcfg)
        else:
            sel = _select_fixed(ft.router_mode, t_frac, tcfg.hub_size, key)
            sels = {n: sel for n in names}
        params = talora.merge_into_tree(bundle.q_params, hubs, sels, tcfg)
        return unet_apply(params, x, tb, bundle.cfg, ctx=qctx)

    return eps_fn


def finetune(bundle: QuantizedDiffusion, ft: FinetuneConfig,
             *, log: Callable[[str], None] | None = None
             ) -> tuple[QuantizedDiffusion, list[dict]]:
    """Runs the fine-tune; returns the bundle with trained hubs/router."""
    assert bundle.hubs is not None, "bundle needs TALoRA attached"
    sched = bundle.sched
    cfg = bundle.cfg
    seq = sample_timesteps(sched.T, ft.steps_per_epoch)
    gammas = np.asarray(sched.gamma())
    acfg = AdamConfig(lr=ft.lr, clip_norm=1.0)
    eps_fn = make_student_eps(bundle, ft)

    trainable = {"hubs": bundle.hubs, "router": bundle.router}
    opt = adam_init(trainable, acfg)
    teacher = jax.jit(lambda x, t: unet_apply(bundle.fp_params, x, t, cfg))

    @partial(jax.jit, static_argnames=("t_frac_key",))
    def train_step(tr, opt, x, tb, gamma_t, key, t_frac_key):
        t_frac = jnp.float32(t_frac_key)

        def loss(tr):
            eps_t = jax.lax.stop_gradient(teacher(x, tb))
            eps_s = eps_fn(tr["hubs"], tr["router"], x, tb, key, t_frac)
            if ft.loss_mode == "dfa":
                return dfa.dfa_loss(eps_t, eps_s, gamma_t)
            return dfa.plain_loss(eps_t, eps_s)

        l, g = jax.value_and_grad(loss)(tr)
        tr, opt, metrics = adam_update(g, opt, tr, acfg)
        return tr, opt, l, metrics

    key = jax.random.PRNGKey(ft.seed)
    logs = []
    for epoch in range(ft.epochs):
        key, k0 = jax.random.split(key)
        shape = (ft.batch, cfg.image_size, cfg.image_size, cfg.in_ch)
        x = jax.random.normal(k0, shape)
        ep_losses = []
        for i, t in enumerate(seq):
            tb = jnp.full((ft.batch,), float(t), jnp.float32)
            gamma_t = jnp.full((ft.batch,), gammas[int(t)], jnp.float32)
            key, k1 = jax.random.split(key)
            t_frac = float(t) / sched.T
            trainable, opt, l, m = train_step(trainable, opt, x, tb, gamma_t,
                                              k1, t_frac)
            ep_losses.append(float(l))
            # advance the trajectory with the TEACHER's prediction (the
            # student input distribution follows the FP trajectory)
            eps_t = teacher(x, tb)
            t_prev = int(seq[i + 1]) if i + 1 < len(seq) else -1
            x = ddim_step(sched, x, int(t), t_prev, eps_t, ft.eta)
        logs.append({"epoch": epoch, "loss": float(np.mean(ep_losses))})
        if log:
            log(f"epoch {epoch}: loss={np.mean(ep_losses):.5f}")
    bundle.hubs = trainable["hubs"]
    bundle.router = trainable["router"]
    return bundle, logs


def eval_denoising_gap(bundle: QuantizedDiffusion, ft: FinetuneConfig,
                       key, *, steps: int = 20, batch: int = 8
                       ) -> dict[str, float]:
    """Paper Fig. 3 metric: per-step MSE(x_{t-1}^fp, x_{t-1}^quant) along

    the FP trajectory + final-image MSE (the FID proxy used on-box)."""
    sched, cfg = bundle.sched, bundle.cfg
    seq = sample_timesteps(sched.T, steps)
    teacher = jax.jit(lambda x, t: unet_apply(bundle.fp_params, x, t, cfg))
    eps_fn = make_student_eps(bundle, ft)
    sfn = jax.jit(lambda x, tb, k, tf: eps_fn(bundle.hubs, bundle.router,
                                              x, tb, k, tf))
    shape = (batch, cfg.image_size, cfg.image_size, cfg.in_ch)
    key, k0 = jax.random.split(key)
    x_fp = jax.random.normal(k0, shape)
    x_q = x_fp
    gaps, eps_mses = [], []
    for i, t in enumerate(seq):
        tb = jnp.full((batch,), float(t), jnp.float32)
        key, k1 = jax.random.split(key)
        e_fp = teacher(x_fp, tb)
        e_q = sfn(x_fp, tb, k1, float(t) / sched.T)  # teacher-forced input
        eps_mses.append(float(jnp.mean((e_fp - e_q) ** 2)))
        t_prev = int(seq[i + 1]) if i + 1 < len(seq) else -1
        x_next_fp = ddim_step(sched, x_fp, int(t), t_prev, e_fp)
        x_next_q = ddim_step(sched, x_fp, int(t), t_prev, e_q)
        gaps.append(float(jnp.mean((x_next_fp - x_next_q) ** 2)))
        # full-trajectory divergence for the final-image metric
        e_q_traj = sfn(x_q, tb, k1, float(t) / sched.T)
        x_q = ddim_step(sched, x_q, int(t), t_prev, e_q_traj)
        x_fp = x_next_fp
    final_mse = float(jnp.mean((x_fp - x_q) ** 2))
    return {"final_image_mse": final_mse,
            "mean_step_gap": float(np.mean(gaps)),
            "mean_eps_mse": float(np.mean(eps_mses)),
            "step_gaps": gaps, "eps_mses": eps_mses}
