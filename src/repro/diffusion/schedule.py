"""Noise schedules: betas, alpha-bars, the DFA denoising factor gamma_t."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class NoiseSchedule:
    betas: jnp.ndarray        # (T,)
    alphas: jnp.ndarray       # (T,)
    alpha_bars: jnp.ndarray   # (T,) cumulative products

    @property
    def T(self) -> int:
        return self.betas.shape[0]

    def gamma(self) -> jnp.ndarray:
        """DFA denoising factor (paper Eq. 4) for every t."""
        from repro.core.dfa import denoising_factor
        return denoising_factor(self.alphas, self.alpha_bars)

    def q_sample(self, x0, t, eps):
        """Forward process Eq. 1: x_t = sqrt(abar) x0 + sqrt(1-abar) eps."""
        ab = self.alpha_bars[t]
        shape = (-1,) + (1,) * (x0.ndim - 1)
        return (jnp.sqrt(ab).reshape(shape) * x0
                + jnp.sqrt(1.0 - ab).reshape(shape) * eps)

    def pred_x0(self, x_t, t, eps):
        ab = self.alpha_bars[t]
        shape = (-1,) + (1,) * (x_t.ndim - 1)
        return ((x_t - jnp.sqrt(1.0 - ab).reshape(shape) * eps)
                / jnp.sqrt(ab).reshape(shape))


def make_schedule(kind: str = "linear", T: int = 1000, *,
                  beta_start: float = 1e-4, beta_end: float = 0.02
                  ) -> NoiseSchedule:
    if kind == "linear":
        betas = np.linspace(beta_start, beta_end, T, dtype=np.float64)
    elif kind == "quad":  # DDIM paper's CelebA schedule
        betas = np.linspace(beta_start**0.5, beta_end**0.5, T,
                            dtype=np.float64) ** 2
    elif kind == "cosine":
        s = 0.008
        ts = np.arange(T + 1, dtype=np.float64) / T
        f = np.cos((ts + s) / (1 + s) * np.pi / 2) ** 2
        ab = f / f[0]
        betas = np.clip(1 - ab[1:] / ab[:-1], 0, 0.999)
    else:
        raise ValueError(kind)
    alphas = 1.0 - betas
    alpha_bars = np.cumprod(alphas)
    return NoiseSchedule(jnp.asarray(betas, jnp.float32),
                         jnp.asarray(alphas, jnp.float32),
                         jnp.asarray(alpha_bars, jnp.float32))


def sample_timesteps(T: int, steps: int) -> np.ndarray:
    """DDIM uniform-stride timestep subsequence, descending."""
    seq = np.linspace(0, T - 1, steps).round().astype(np.int64)
    return np.unique(seq)[::-1].copy()
