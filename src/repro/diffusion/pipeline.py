"""Diffusion quantization pipeline: calibrate -> plan -> finetune -> sample.

Glue between the paper's stages:
  1. Build a Q-Diffusion-style calibration set: intermediate x_t states
     collected along FP-teacher DDIM trajectories (uniform over timesteps).
  2. Record per-site activations through the FP model, classify AAL/NAL,
     run the MSFP search (core.msfp).
  3. Fake-quantize the weights, attach TALoRA, fine-tune (train.finetune).
  4. Sample with the quantized + TALoRA-merged model.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.tree import flatten_paths, unflatten_paths
from repro.core import msfp, talora
from repro.diffusion.samplers import ddim_sample
from repro.diffusion.schedule import NoiseSchedule
from repro.nn.unet import UNetConfig, io_sites, unet_apply
from repro.quant.calibrate import CalibrationDB, QuantContext


@dataclasses.dataclass
class QuantizedDiffusion:
    """Everything needed to run / fine-tune the quantized model."""
    cfg: UNetConfig
    sched: NoiseSchedule
    fp_params: dict
    q_params: dict              # weights fake-quantized under `plan`
    plan: msfp.QuantPlan
    talora_cfg: talora.TALoRAConfig | None = None
    hubs: dict | None = None
    router: dict | None = None

    def teacher_eps(self, x, t, y=None):
        return unet_apply(self.fp_params, x, t, self.cfg, y=y)

    def student_eps(self, x, t, y=None, hubs=None, router=None):
        """Quantized forward; TALoRA merged per distinct batch timestep.

        The router selects adapters per *timestep*, so a batch mixing
        timesteps cannot share one merged weight set. Concrete mixed-``t``
        batches are routed per-t group (merge + forward per group,
        scattered back in order); under tracing the values are invisible,
        so batches larger than one raise instead of silently merging for
        ``t[0]`` (the serving engine batches per routing segment and is
        the jit-friendly path).
        """
        hubs = hubs if hubs is not None else self.hubs
        router = router if router is not None else self.router
        ctx = QuantContext("quantize", plan=self.plan,
                          act_fn=msfp.quantize_act)
        if hubs is None or router is None:
            return unet_apply(self.q_params, x, t, self.cfg, y=y, ctx=ctx)

        names = sorted(hubs)
        t_flat = jnp.reshape(jnp.asarray(t), (-1,))

        def merged_for(t_scalar):
            sels = talora.route(router, t_scalar, names, self.talora_cfg)
            return talora.merge_into_tree(self.q_params, hubs, sels,
                                          self.talora_cfg)

        if isinstance(t_flat, jax.core.Tracer):
            if t_flat.shape[0] > 1:
                raise ValueError(
                    "student_eps under jit cannot verify that a batched t "
                    "is single-timestep; trace with batch size 1 or serve "
                    "mixed timesteps through repro.serving (per-segment "
                    "weight bank)")
            return unet_apply(merged_for(t_flat[0]), x, t, self.cfg, y=y,
                              ctx=ctx)

        t_vals = np.asarray(t_flat)
        uniq = np.unique(t_vals)
        if uniq.size <= 1:
            return unet_apply(merged_for(t_flat[0]), x, t, self.cfg, y=y,
                              ctx=ctx)
        out = None
        for tv in uniq:
            idx = np.nonzero(t_vals == tv)[0]
            eps = unet_apply(merged_for(jnp.float32(tv)), x[idx], t_flat[idx],
                             self.cfg, y=None if y is None else y[idx],
                             ctx=ctx)
            out = jnp.zeros((x.shape[0],) + eps.shape[1:], eps.dtype) \
                if out is None else out
            out = out.at[idx].set(eps)
        return out


def build_calibration_set(fp_params, cfg: UNetConfig, sched: NoiseSchedule,
                          key, *, n_samples: int = 32, steps: int = 20,
                          batch: int = 8) -> list[tuple[int, np.ndarray]]:
    """Q-Diffusion calibration: (t, x_t) states from FP DDIM trajectories."""
    taps: list[tuple[int, np.ndarray]] = []
    eps_fn = jax.jit(lambda x, t: unet_apply(fp_params, x, t, cfg))
    n_batches = max(1, n_samples // batch)
    for b in range(n_batches):
        key, k = jax.random.split(key)
        _, tp = ddim_sample(eps_fn, sched, (batch, cfg.image_size,
                                            cfg.image_size, cfg.in_ch), k,
                            steps=steps, collect_every=1)
        taps.extend(tp)
    return taps


def calibrate_activations(fp_params, cfg: UNetConfig,
                          calib: list[tuple[int, np.ndarray]],
                          max_batches: int = 8) -> CalibrationDB:
    db = CalibrationDB()
    ctx = QuantContext("collect", db=db)
    for t, x in calib[:max_batches]:
        tb = jnp.full((x.shape[0],), t, jnp.float32)
        unet_apply(fp_params, jnp.asarray(x), tb, cfg, ctx=ctx)
    return db


def quantize_diffusion(fp_params, cfg: UNetConfig, sched: NoiseSchedule, key,
                       *, bits_w: int = 4, bits_a: int = 4,
                       mode: str = "msfp",
                       calib: list | None = None,
                       talora_cfg: talora.TALoRAConfig | None = None
                       ) -> QuantizedDiffusion:
    """Stages 1-3 (without the fine-tune loop): returns a ready bundle."""
    if calib is None:
        calib = build_calibration_set(fp_params, cfg, sched, key)
    db = calibrate_activations(fp_params, cfg, calib)
    weights = {k: v for k, v in flatten_paths(fp_params).items()
               if k.endswith("/w")}
    plan = msfp.build_mixed_plan(weights, db, bits_w=bits_w, bits_a=bits_a,
                                 mode=mode, io_sites=io_sites(fp_params))
    qw = msfp.quantize_weight_tree(weights, plan)
    flat = dict(flatten_paths(fp_params))
    flat.update(qw)
    q_params = unflatten_paths(flat)
    bundle = QuantizedDiffusion(cfg, sched, fp_params, q_params, plan)
    if talora_cfg is not None:
        dims = talora.lora_target_dims_from_weights(
            {k: v for k, v in qw.items() if v.ndim >= 2})
        k1, k2 = jax.random.split(key)
        bundle.talora_cfg = talora_cfg
        bundle.hubs = talora.init_lora_hub(k1, dims, talora_cfg)
        bundle.router = talora.init_router(k2, len(dims), talora_cfg)
    return bundle


def sample_quantized(bundle: QuantizedDiffusion, key, *, n: int = 8,
                     steps: int = 20, eta: float = 0.0):
    cfg = bundle.cfg
    eps_fn = lambda x, t: bundle.student_eps(x, t)
    x0, _ = ddim_sample(eps_fn, bundle.sched,
                        (n, cfg.image_size, cfg.image_size, cfg.in_ch), key,
                        steps=steps, eta=eta)
    return x0
