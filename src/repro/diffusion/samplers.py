"""Samplers: DDIM (the paper's main solver), PLMS and DPM-Solver-2 (App. F).

All samplers take ``eps_fn(x_t, t) -> eps`` so the same code drives the FP
teacher, the fake-quant student, and the TALoRA-merged student (the
pipeline builds the eps_fn closure per configuration).

Two equivalent surfaces:

  * Loop samplers (``ddim_sample`` / ``plms_sample`` / ``dpm_solver2_sample``)
    own the denoising loop — the classic offline API.
  * The step-wise API (``sampler_init`` / ``sampler_needed_t`` /
    ``sampler_advance``) inverts control: a ``SamplerState`` is an
    eps-request machine that announces the timestep it needs evaluated
    next (``sampler_needed_t``), exposes the state to evaluate at
    (``state.eval_x`` — for DPM-Solver-2's midpoint this is the
    intermediate ``u``, not ``x``), and consumes the result
    (``sampler_advance``). The serving engine owns the loop and batches
    many requests' eps evaluations into one model forward; the loop
    samplers here are thin drivers over the same machine, so both paths
    produce bit-identical outputs.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.diffusion.schedule import NoiseSchedule, sample_timesteps

EpsFn = Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]


def ddim_step(sched: NoiseSchedule, x_t, t: int, t_prev: int, eps,
              eta: float = 0.0, noise=None):
    """One DDIM update x_t -> x_{t_prev} (t_prev < t; t_prev = -1 -> x0)."""
    ab_t = sched.alpha_bars[t]
    ab_p = sched.alpha_bars[t_prev] if t_prev >= 0 else jnp.float32(1.0)
    x0 = (x_t - jnp.sqrt(1 - ab_t) * eps) / jnp.sqrt(ab_t)
    sigma = eta * jnp.sqrt((1 - ab_p) / (1 - ab_t)) * jnp.sqrt(1 - ab_t / ab_p)
    dir_xt = jnp.sqrt(jnp.clip(1 - ab_p - sigma**2, 0.0)) * eps
    x_prev = jnp.sqrt(ab_p) * x0 + dir_xt
    if eta > 0 and noise is not None:
        x_prev = x_prev + sigma * noise
    return x_prev


# ---------------------------------------------------------------------------
# Step-wise API: an eps-request state machine per generation.
# ---------------------------------------------------------------------------

# DPM-Solver-2 phases: eps needed at (x, seq[i]) / at the midpoint (u, t_mid)
# / the final DDIM step to x0 at (x, seq[-1]).
_DPM_T, _DPM_MID, _DPM_FINAL = 0, 1, 2


@dataclasses.dataclass
class SamplerState:
    """One request's denoising trajectory, advanced one eps at a time."""

    kind: str                      # 'ddim' | 'plms' | 'dpm_solver2'
    sched: NoiseSchedule
    seq: np.ndarray                # descending timestep subsequence
    x: jnp.ndarray                 # current latent (B, H, W, C)
    key: jax.Array
    eta: float = 0.0
    i: int = 0                     # next seq index
    done: bool = False
    old_eps: list = dataclasses.field(default_factory=list)   # PLMS history
    # DPM-Solver-2 scratch: hoisted log-SNR table + mid-step carry.
    lams: jnp.ndarray | None = None
    phase: int = _DPM_T
    t_mid: int = -1
    u: jnp.ndarray | None = None
    h: jnp.ndarray | None = None

    @property
    def eval_x(self) -> jnp.ndarray:
        """The state the next eps evaluation runs on."""
        if self.kind == "dpm_solver2" and self.phase == _DPM_MID:
            return self.u
        return self.x

    @property
    def steps_left(self) -> int:
        return 0 if self.done else len(self.seq) - self.i


def sampler_init(kind: str, sched: NoiseSchedule, shape, key, *,
                 steps: int = 50, eta: float = 0.0) -> SamplerState:
    """Draw x_T and build the request machine (kind in SAMPLERS)."""
    assert kind in STEP_SAMPLERS, kind
    seq = sample_timesteps(sched.T, steps)
    key, k0 = jax.random.split(key)
    x = jax.random.normal(k0, shape)
    st = SamplerState(kind, sched, seq, x, key, eta=eta)
    if kind == "dpm_solver2":
        # Hoisted out of the per-step loop: the full-schedule log-SNR/2
        # table used to invert lambda -> nearest discrete timestep.
        st.lams = 0.5 * jnp.log(sched.alpha_bars / (1 - sched.alpha_bars))
        if len(seq) == 1:
            st.phase = _DPM_FINAL
    return st


def sampler_needed_t(st: SamplerState) -> int:
    """Timestep the next eps evaluation must run at (engine batching key)."""
    assert not st.done
    if st.kind == "dpm_solver2":
        if st.phase == _DPM_MID:
            return st.t_mid
        if st.phase == _DPM_FINAL:
            return int(st.seq[-1])
    return int(st.seq[st.i])


def _coeffs(sched: NoiseSchedule, t: int):
    ab = sched.alpha_bars[t]
    return jnp.sqrt(ab), jnp.sqrt(1 - ab)  # alpha_t, sigma_t


def _advance_ddim(st: SamplerState, eps) -> None:
    t = int(st.seq[st.i])
    t_prev = int(st.seq[st.i + 1]) if st.i + 1 < len(st.seq) else -1
    st.key, kn = jax.random.split(st.key)
    noise = jax.random.normal(kn, st.x.shape) if st.eta > 0 else None
    st.x = ddim_step(st.sched, st.x, t, t_prev, eps, st.eta, noise)
    st.i += 1
    st.done = st.i >= len(st.seq)


def _advance_plms(st: SamplerState, eps) -> None:
    t = int(st.seq[st.i])
    t_prev = int(st.seq[st.i + 1]) if st.i + 1 < len(st.seq) else -1
    old = st.old_eps
    if len(old) == 0:
        eps_prime = eps
    elif len(old) == 1:
        eps_prime = (3 * eps - old[-1]) / 2
    elif len(old) == 2:
        eps_prime = (23 * eps - 16 * old[-1] + 5 * old[-2]) / 12
    else:
        eps_prime = (55 * eps - 59 * old[-1] + 37 * old[-2] - 9 * old[-3]) / 24
    st.old_eps = (old + [eps])[-3:]
    st.x = ddim_step(st.sched, st.x, t, t_prev, eps_prime)
    st.i += 1
    st.done = st.i >= len(st.seq)


def _advance_dpm(st: SamplerState, eps) -> None:
    if st.phase == _DPM_FINAL:
        st.x = ddim_step(st.sched, st.x, int(st.seq[-1]), -1, eps)
        st.done = True
        return
    t, t_next = int(st.seq[st.i]), int(st.seq[st.i + 1])
    if st.phase == _DPM_T:
        l_t, l_n = st.lams[t], st.lams[t_next]
        h = l_n - l_t
        l_mid = l_t + 0.5 * h
        st.t_mid = int(jnp.argmin(jnp.abs(st.lams - l_mid)))
        a_t, _ = _coeffs(st.sched, t)
        a_m, s_m = _coeffs(st.sched, st.t_mid)
        st.u = (a_m / a_t) * st.x - s_m * jnp.expm1(0.5 * h) * eps
        st.h = h
        st.phase = _DPM_MID
        return
    # _DPM_MID: consume the midpoint eps, complete the solver step.
    a_t, _ = _coeffs(st.sched, t)
    a_n, s_n = _coeffs(st.sched, t_next)
    st.x = (a_n / a_t) * st.x - s_n * jnp.expm1(st.h) * eps
    st.u = None
    st.i += 1
    st.phase = _DPM_T if st.i < len(st.seq) - 1 else _DPM_FINAL


_ADVANCE = {"ddim": _advance_ddim, "plms": _advance_plms,
            "dpm_solver2": _advance_dpm}


def sampler_advance(st: SamplerState, eps) -> SamplerState:
    """Consume the eps evaluated at (st.eval_x, sampler_needed_t(st))."""
    assert not st.done, "sampler already finished"
    _ADVANCE[st.kind](st, eps)
    return st


STEP_SAMPLERS = ("ddim", "plms", "dpm_solver2")


# ---------------------------------------------------------------------------
# Loop samplers — thin drivers over the step machine (same bits).
# ---------------------------------------------------------------------------


def _eps_batch(eps_fn: EpsFn, st: SamplerState, t: int) -> jnp.ndarray:
    tb = jnp.full((st.x.shape[0],), t, jnp.float32)
    return eps_fn(st.eval_x, tb)


def ddim_sample(eps_fn: EpsFn, sched: NoiseSchedule, shape, key, *,
                steps: int = 50, eta: float = 0.0,
                collect_every: int = 0):
    """Full DDIM sampling loop. Returns (x0, taps) where taps is a list of

    (t, x_t) pairs when collect_every > 0 (Q-Diffusion calibration sets)."""
    st = sampler_init("ddim", sched, shape, key, steps=steps, eta=eta)
    taps = []
    while not st.done:
        t = sampler_needed_t(st)
        eps = _eps_batch(eps_fn, st, t)
        if collect_every and (st.i % collect_every == 0):
            taps.append((t, np.asarray(st.x)))
        sampler_advance(st, eps)
    return st.x, taps


def plms_sample(eps_fn: EpsFn, sched: NoiseSchedule, shape, key, *,
                steps: int = 50):
    """Pseudo Linear Multi-Step (PLMS/PNDM) sampler, 4th-order AB corrector."""
    st = sampler_init("plms", sched, shape, key, steps=steps)
    while not st.done:
        sampler_advance(st, _eps_batch(eps_fn, st, sampler_needed_t(st)))
    return st.x


def dpm_solver2_sample(eps_fn: EpsFn, sched: NoiseSchedule, shape, key, *,
                       steps: int = 20):
    """DPM-Solver-2 (midpoint) in log-SNR time (Lu et al. 2022)."""
    st = sampler_init("dpm_solver2", sched, shape, key, steps=steps)
    while not st.done:
        sampler_advance(st, _eps_batch(eps_fn, st, sampler_needed_t(st)))
    return st.x


SAMPLERS = {"ddim": ddim_sample, "plms": plms_sample,
            "dpm_solver2": dpm_solver2_sample}
