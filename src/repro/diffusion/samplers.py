"""Samplers: DDIM (the paper's main solver), PLMS and DPM-Solver-2 (App. F).

All samplers take ``eps_fn(x_t, t) -> eps`` so the same code drives the FP
teacher, the fake-quant student, and the TALoRA-merged student (the
pipeline builds the eps_fn closure per configuration).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.diffusion.schedule import NoiseSchedule, sample_timesteps

EpsFn = Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]


def ddim_step(sched: NoiseSchedule, x_t, t: int, t_prev: int, eps,
              eta: float = 0.0, noise=None):
    """One DDIM update x_t -> x_{t_prev} (t_prev < t; t_prev = -1 -> x0)."""
    ab_t = sched.alpha_bars[t]
    ab_p = sched.alpha_bars[t_prev] if t_prev >= 0 else jnp.float32(1.0)
    x0 = (x_t - jnp.sqrt(1 - ab_t) * eps) / jnp.sqrt(ab_t)
    sigma = eta * jnp.sqrt((1 - ab_p) / (1 - ab_t)) * jnp.sqrt(1 - ab_t / ab_p)
    dir_xt = jnp.sqrt(jnp.clip(1 - ab_p - sigma**2, 0.0)) * eps
    x_prev = jnp.sqrt(ab_p) * x0 + dir_xt
    if eta > 0 and noise is not None:
        x_prev = x_prev + sigma * noise
    return x_prev


def ddim_sample(eps_fn: EpsFn, sched: NoiseSchedule, shape, key, *,
                steps: int = 50, eta: float = 0.0,
                collect_every: int = 0):
    """Full DDIM sampling loop. Returns (x0, taps) where taps is a list of

    (t, x_t) pairs when collect_every > 0 (Q-Diffusion calibration sets)."""
    seq = sample_timesteps(sched.T, steps)
    key, k0 = jax.random.split(key)
    x = jax.random.normal(k0, shape)
    taps = []
    for i, t in enumerate(seq):
        t_prev = int(seq[i + 1]) if i + 1 < len(seq) else -1
        tb = jnp.full((shape[0],), t, jnp.float32)
        eps = eps_fn(x, tb)
        if collect_every and (i % collect_every == 0):
            taps.append((int(t), np.asarray(x)))
        key, kn = jax.random.split(key)
        noise = jax.random.normal(kn, shape) if eta > 0 else None
        x = ddim_step(sched, x, int(t), t_prev, eps, eta, noise)
    return x, taps


def plms_sample(eps_fn: EpsFn, sched: NoiseSchedule, shape, key, *,
                steps: int = 50):
    """Pseudo Linear Multi-Step (PLMS/PNDM) sampler, 4th-order AB corrector."""
    seq = sample_timesteps(sched.T, steps)
    key, k0 = jax.random.split(key)
    x = jax.random.normal(k0, shape)
    old_eps: list = []
    for i, t in enumerate(seq):
        t_prev = int(seq[i + 1]) if i + 1 < len(seq) else -1
        tb = jnp.full((shape[0],), t, jnp.float32)
        eps = eps_fn(x, tb)
        if len(old_eps) == 0:
            eps_prime = eps
        elif len(old_eps) == 1:
            eps_prime = (3 * eps - old_eps[-1]) / 2
        elif len(old_eps) == 2:
            eps_prime = (23 * eps - 16 * old_eps[-1] + 5 * old_eps[-2]) / 12
        else:
            eps_prime = (55 * eps - 59 * old_eps[-1] + 37 * old_eps[-2]
                         - 9 * old_eps[-3]) / 24
        old_eps = (old_eps + [eps])[-3:]
        x = ddim_step(sched, x, int(t), t_prev, eps_prime)
    return x


def dpm_solver2_sample(eps_fn: EpsFn, sched: NoiseSchedule, shape, key, *,
                       steps: int = 20):
    """DPM-Solver-2 (midpoint) in log-SNR time (Lu et al. 2022)."""
    seq = sample_timesteps(sched.T, steps)
    key, k0 = jax.random.split(key)
    x = jax.random.normal(k0, shape)

    def lam(t):  # log-SNR/2
        ab = sched.alpha_bars[t]
        return 0.5 * jnp.log(ab / (1 - ab))

    def coeffs(t):
        ab = sched.alpha_bars[t]
        return jnp.sqrt(ab), jnp.sqrt(1 - ab)  # alpha_t, sigma_t

    for i in range(len(seq) - 1):
        t, t_next = int(seq[i]), int(seq[i + 1])
        l_t, l_n = lam(t), lam(t_next)
        h = l_n - l_t
        # midpoint timestep in lambda space
        l_mid = l_t + 0.5 * h
        # invert lambda -> nearest discrete timestep
        lams = 0.5 * jnp.log(sched.alpha_bars / (1 - sched.alpha_bars))
        t_mid = int(jnp.argmin(jnp.abs(lams - l_mid)))
        a_t, s_t = coeffs(t)
        a_m, s_m = coeffs(t_mid)
        a_n, s_n = coeffs(t_next)
        tb = jnp.full((shape[0],), t, jnp.float32)
        eps1 = eps_fn(x, tb)
        u = (a_m / a_t) * x - s_m * jnp.expm1(0.5 * h) * eps1
        tbm = jnp.full((shape[0],), t_mid, jnp.float32)
        eps2 = eps_fn(u, tbm)
        x = (a_n / a_t) * x - s_n * jnp.expm1(h) * eps2
    # final step to x0 with DDIM
    t_last = int(seq[-1])
    tb = jnp.full((shape[0],), t_last, jnp.float32)
    x = ddim_step(sched, x, t_last, -1, eps_fn(x, tb))
    return x


SAMPLERS = {"ddim": ddim_sample, "plms": plms_sample,
            "dpm_solver2": dpm_solver2_sample}
