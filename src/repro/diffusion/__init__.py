"""Diffusion substrate: schedules, samplers, quantization pipeline."""
from repro.diffusion.schedule import NoiseSchedule, make_schedule, sample_timesteps
from repro.diffusion.samplers import (ddim_sample, ddim_step, plms_sample,
                                      dpm_solver2_sample, SAMPLERS,
                                      SamplerState, sampler_init,
                                      sampler_needed_t, sampler_advance,
                                      STEP_SAMPLERS)
