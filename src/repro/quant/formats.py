"""Floating-point quantization formats (ExMy grids), signed and unsigned.

The paper (Eq. 6/8/10) parameterizes an FP quantizer by a format ``ExMy``
(x exponent bits, y mantissa bits), a sign bit ``s`` (1 = signed, 0 =
unsigned), a bias ``b`` that acts as the scale/threshold (equivalently the
grid maximum ``maxval``), and — for unsigned quantizers only — a zero-point
``z`` shifting the whole grid.

We represent the *base* (unscaled) grid with bias fixed so the smallest
normal octave is ``[1, 2)``:

  exponent field p in [0, 2^e - 1]
    p = 0  -> subnormal:  v = m / 2^M                     (step 2^-M, covers [0, 1))
    p >= 1 -> normal:     v = 2^(p-1) * (1 + m / 2^M)     (octave [2^(p-1), 2^p))

  base_max = 2^(2^e - 2) * (2 - 2^-M)      (e >= 1)
  e = 0    -> pure fixed point: v = m / 2^M, base_max = (2^M - 1) / 2^M

A quantizer with grid maximum ``maxval`` is the base grid scaled by
``maxval / base_max`` — this is the continuous-bias view the paper uses
("maxval and b are directly correlated").

E2M1 sanity check: {0, .5, 1, 1.5, 2, 3, 4, 6} — the standard FP4 grid.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True, order=True)
class FPFormat:
    """An ExMy floating-point format, signed or unsigned."""

    exp_bits: int
    man_bits: int
    signed: bool

    @property
    def bits(self) -> int:
        return self.exp_bits + self.man_bits + (1 if self.signed else 0)

    @property
    def base_max(self) -> float:
        if self.exp_bits == 0:
            return (2**self.man_bits - 1) / 2**self.man_bits
        return float(2 ** (2**self.exp_bits - 2) * (2.0 - 2.0**-self.man_bits))

    @property
    def name(self) -> str:
        return f"{'s' if self.signed else 'u'}E{self.exp_bits}M{self.man_bits}"

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        return self.name


def signed_formats(bits: int) -> tuple[FPFormat, ...]:
    """The paper's signed search space for a bit-width (Table 6 / App. B)."""
    if bits == 4:
        ems = [(3, 0), (2, 1), (1, 2), (0, 3)]
    elif bits == 6:
        ems = [(4, 1), (3, 2), (2, 3), (1, 4)]
    elif bits == 8:
        ems = [(5, 2), (4, 3), (3, 4), (2, 5)]
    else:  # generic: every split with e+m = bits-1
        ems = [(e, bits - 1 - e) for e in range(bits - 1, -1, -1)]
    return tuple(FPFormat(e, m, True) for e, m in ems)


def unsigned_formats(bits: int) -> tuple[FPFormat, ...]:
    """All ExMy splits with x + y = bits (App. B: 'all possible formats')."""
    # E>=6 grids span 2^62 dynamic range — numerically pointless for
    # activations; cap exponent bits at 5 like the signed spaces do.
    return tuple(
        FPFormat(e, bits - e, False) for e in range(min(bits, 5), -1, -1)
    )


def enumerate_grid(fmt: FPFormat) -> np.ndarray:
    """Every representable base-grid value, sorted ascending (test oracle)."""
    vals = set()
    m_range = range(2**fmt.man_bits)
    if fmt.exp_bits == 0:
        for m in m_range:
            vals.add(m / 2**fmt.man_bits)
    else:
        for p in range(2**fmt.exp_bits):
            for m in m_range:
                if p == 0:
                    vals.add(m / 2**fmt.man_bits)
                else:
                    vals.add(2.0 ** (p - 1) * (1 + m / 2**fmt.man_bits))
    out = sorted(vals)
    if fmt.signed:
        out = sorted({-v for v in out} | set(out))
    return np.asarray(out, dtype=np.float64)


def snap_to_base_grid(y: jnp.ndarray, fmt: FPFormat) -> jnp.ndarray:
    """Round |y| (y >= 0) to the nearest base-grid point, clamped to base_max.

    Arithmetic snap (no LUT/gather — VPU friendly, reused verbatim by the
    Pallas kernel): pick the octave via floor(log2 y), quantize the mantissa
    at that octave's step with round-to-nearest-even.
    """
    man = fmt.man_bits
    if fmt.exp_bits == 0:
        step = 2.0**-man
        q = jnp.round(y / step) * step
        return jnp.minimum(q, fmt.base_max)
    max_oct = 2**fmt.exp_bits - 2  # exponent of the top octave
    # Octave index; y < 1 (subnormal) shares the first octave's step 2^-M.
    safe = jnp.maximum(y, 2.0**-40)
    oct_ = jnp.clip(jnp.floor(jnp.log2(safe)), 0, max_oct)
    step = jnp.exp2(oct_ - man)
    q = jnp.round(y / step) * step
    return jnp.minimum(q, fmt.base_max)


def quant_codes(fmt: FPFormat) -> np.ndarray:
    """Map 4-bit (or n-bit) integer codes -> base-grid values.

    Code layout (unsigned part): p = code >> man_bits, m = code & (2^man-1).
    Signed formats put the sign in the top bit. Used for packing weights.
    """
    n_mag = 2 ** (fmt.exp_bits + fmt.man_bits)
    mags = np.zeros(n_mag)
    for c in range(n_mag):
        p, m = c >> fmt.man_bits, c & (2**fmt.man_bits - 1)
        if fmt.exp_bits == 0 or p == 0:
            mags[c] = m / 2**fmt.man_bits
        else:
            mags[c] = 2.0 ** (p - 1) * (1 + m / 2**fmt.man_bits)
    if not fmt.signed:
        return mags
    return np.concatenate([mags, -mags])  # sign bit = MSB


def encode_to_codes(x: np.ndarray, fmt: FPFormat, maxval: float) -> np.ndarray:
    """Encode values to integer codes (numpy, offline packing path)."""
    lut = quant_codes(fmt) * (maxval / fmt.base_max)
    # nearest-value encode (offline only; packing runs once per checkpoint)
    d = np.abs(x[..., None] - lut[None, :])
    return np.argmin(d, axis=-1).astype(np.uint8)


FORMAT_BY_NAME: dict[str, FPFormat] = {}
for _b in (3, 4, 5, 6, 8):
    for _f in signed_formats(_b) + unsigned_formats(_b):
        FORMAT_BY_NAME[_f.name] = _f


def format_list_names(fmts: Sequence[FPFormat]) -> list[str]:
    return [f.name for f in fmts]
