"""Fake quantization (quantize-dequantize) with straight-through gradients.

``QuantizerParams`` is the runtime artifact produced by the MSE search
(Alg. 1): a format, a grid maximum, and (unsigned only) a zero-point. The
same struct drives the XLA path here, the Pallas kernel in
``repro.kernels``, and the W4 packing in ``repro.core.qmodule``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.quant.formats import FPFormat, snap_to_base_grid

# Quantizer kinds.
KIND_FP_SIGNED = 0
KIND_FP_UNSIGNED = 1  # unsigned FP + zero-point (the paper's Eq. 8)
KIND_INT_AFFINE = 2  # INT baseline


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class QuantizerParams:
    """Searched quantization parameters for one site (layer weight or act)."""

    kind: int = dataclasses.field(metadata=dict(static=True))
    exp_bits: int = dataclasses.field(metadata=dict(static=True))
    man_bits: int = dataclasses.field(metadata=dict(static=True))
    bits: int = dataclasses.field(metadata=dict(static=True))
    maxval: jnp.ndarray = dataclasses.field(default_factory=lambda: jnp.float32(1.0))
    zero_point: jnp.ndarray = dataclasses.field(default_factory=lambda: jnp.float32(0.0))

    @property
    def fmt(self) -> FPFormat:
        return FPFormat(self.exp_bits, self.man_bits, self.kind == KIND_FP_SIGNED)

    @property
    def is_unsigned(self) -> bool:
        return self.kind == KIND_FP_UNSIGNED


def fp_qdq(x: jnp.ndarray, fmt: FPFormat, maxval: jnp.ndarray,
           zero_point: jnp.ndarray | float = 0.0) -> jnp.ndarray:
    """Quantize-dequantize onto the scaled ExMy grid (no gradient handling).

    Signed:   snap(|x|) * sign(x), clipped to [-maxval, maxval].
    Unsigned: snap(x - z) on the non-negative grid, + z  (Eq. 8); inputs
              below z round to the grid zero (i.e. to z itself).
    """
    dtype = x.dtype
    x = x.astype(jnp.float32)
    maxval = jnp.asarray(maxval, jnp.float32)
    scale = maxval / fmt.base_max
    inv = jnp.where(scale > 0, 1.0 / jnp.maximum(scale, 1e-30), 0.0)
    if fmt.signed:
        y = jnp.abs(x) * inv
        q = snap_to_base_grid(y, fmt) * scale
        out = jnp.sign(x) * q
    else:
        z = jnp.asarray(zero_point, jnp.float32)
        y = jnp.clip((x - z) * inv, 0.0, None)
        out = snap_to_base_grid(y, fmt) * scale + z
    return out.astype(dtype)


def int_qdq(x: jnp.ndarray, bits: int, maxval: jnp.ndarray,
            zero_point: jnp.ndarray | float = 0.0,
            symmetric: bool = True) -> jnp.ndarray:
    """Affine INT quantize-dequantize (Q-Diffusion-style baseline, Eq. 5)."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    if symmetric:
        qmax = 2 ** (bits - 1) - 1
        s = jnp.maximum(jnp.asarray(maxval, jnp.float32), 1e-30) / qmax
        q = jnp.clip(jnp.round(x / s), -qmax - 1, qmax)
        out = q * s
    else:
        qmax = 2**bits - 1
        z = jnp.asarray(zero_point, jnp.float32)
        s = jnp.maximum(jnp.asarray(maxval, jnp.float32) - z, 1e-30) / qmax
        q = jnp.clip(jnp.round((x - z) / s), 0, qmax)
        out = q * s + z
    return out.astype(dtype)


def apply_qdq(x: jnp.ndarray, qp: QuantizerParams) -> jnp.ndarray:
    """Dispatch on quantizer kind (static)."""
    if qp.kind == KIND_INT_AFFINE:
        return int_qdq(x, qp.bits, qp.maxval, qp.zero_point, symmetric=False)
    return fp_qdq(x, qp.fmt, qp.maxval, qp.zero_point)


# ---------------------------------------------------------------------------
# Straight-through estimator: identity gradient inside the representable
# range, zero outside (clipped STE). Used for activation fake-quant during
# TALoRA fine-tuning so gradients flow to the LoRA branches.
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def ste_qdq(x: jnp.ndarray, qp: QuantizerParams) -> jnp.ndarray:
    return apply_qdq(x, qp)


def _ste_fwd(x, qp):
    lo = qp.zero_point if qp.is_unsigned else -qp.maxval
    hi = qp.maxval + (qp.zero_point if qp.is_unsigned else 0.0)
    mask = (x >= lo) & (x <= hi)
    return apply_qdq(x, qp), mask


def _ste_bwd(qp, mask, g):
    return (g * mask.astype(g.dtype),)


ste_qdq.defvjp(_ste_fwd, _ste_bwd)


def quantizer_range(qp: QuantizerParams) -> tuple[Any, Any]:
    """(lo, hi) of representable values."""
    if qp.is_unsigned:
        return qp.zero_point, qp.maxval + qp.zero_point
    return -qp.maxval, qp.maxval
