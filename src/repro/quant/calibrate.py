"""Calibration: collect per-site activation samples and classify AAL/NAL.

The paper builds a Q-Diffusion-style calibration set (intermediate x_t
states across timesteps), runs it through the FP model, and records the
input activation of every quantized layer. A layer whose input distribution
carries the SiLU signature — negative tail compressed into ~[-0.278, 0) —
is an AAL (anomalous-activation-distribution layer); the rest are NALs.

Models in this repo thread a ``QuantContext`` through their forward pass;
in ``collect`` mode every quant site deposits a subsample of its input here.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class SiteStats:
    samples: np.ndarray  # strided subsample of observed values
    x_min: float
    x_max: float
    n_seen: int

    @property
    def asymmetry(self) -> float:
        """|min| / max — near 0 for SiLU-fed (half-normal-ish) activations."""
        if self.x_max <= 0:
            return float("inf")
        return abs(min(self.x_min, 0.0)) / self.x_max


@dataclasses.dataclass
class AALConfig:
    """AAL classifier. A site is an AAL when its negative tail is both

    shallow (bounded like SiLU's -0.278 * gamma) and small relative to the
    positive range. Panel (b)/(c) of Fig. 1.
    """

    max_asymmetry: float = 0.30   # |min|/max below this -> asymmetric
    min_floor: float = -0.45      # negative tail shallower than this


class CalibrationDB:
    """Accumulates activation samples per site across calibration batches."""

    def __init__(self, sample_cap: int = 1 << 15):
        self.sites: dict[str, SiteStats] = {}
        self.sample_cap = sample_cap

    def record(self, name: str, x) -> None:
        arr = np.asarray(jnp.ravel(x), dtype=np.float32)
        stride = max(1, arr.size // self.sample_cap)
        sub = arr[::stride][: self.sample_cap]
        if name in self.sites:
            s = self.sites[name]
            merged = np.concatenate([s.samples, sub])
            if merged.size > self.sample_cap:
                merged = merged[:: max(1, merged.size // self.sample_cap)]
            self.sites[name] = SiteStats(
                merged, min(s.x_min, float(arr.min())),
                max(s.x_max, float(arr.max())), s.n_seen + arr.size)
        else:
            self.sites[name] = SiteStats(sub, float(arr.min()), float(arr.max()),
                                         arr.size)

    def is_aal(self, name: str, cfg: AALConfig | None = None) -> bool:
        cfg = cfg or AALConfig()
        s = self.sites[name]
        return (s.x_min >= cfg.min_floor and s.x_min < 0.0
                and s.asymmetry <= cfg.max_asymmetry)

    def classify(self, cfg: AALConfig | None = None) -> dict[str, bool]:
        return {n: self.is_aal(n, cfg) for n in self.sites}

    def summary(self) -> dict[str, dict]:
        return {
            n: dict(min=s.x_min, max=s.x_max, asym=s.asymmetry, n=s.n_seen)
            for n, s in self.sites.items()
        }


class QuantContext:
    """Threaded through model forwards; behavior depends on mode.

    mode='off'      : identity at every quant site (full-precision run).
    mode='collect'  : record activation samples into a CalibrationDB.
    mode='quantize' : apply the searched fake-quantizers (from a QuantPlan).
    mode='serve'    : activation quant happens *inside* the fused W4A4
                      Pallas kernel — ``act`` is identity here, and packed
                      dense layers fetch their per-site QuantizerParams via
                      ``serving_qp``. ``act_qps`` maps site -> params; the
                      key ``"*"`` is the fallback for unlisted sites.
    """

    def __init__(self, mode: str = "off", db: CalibrationDB | None = None,
                 plan=None, act_fn: Callable | None = None,
                 act_qps: dict | None = None):
        assert mode in ("off", "collect", "quantize", "serve")
        self.mode = mode
        self.db = db
        self.plan = plan
        self.act_qps = act_qps or {}
        self._act_fn = act_fn  # injected by core.msfp to avoid cyclic import

    def act(self, name: str, x):
        if self.mode == "collect":
            self.db.record(name, x)
            return x
        if self.mode == "quantize" and self.plan is not None:
            return self._act_fn(name, x, self.plan)
        return x

    def serving_qp(self, name: str):
        """Per-site activation quantizer for the fused serving kernel."""
        if self.mode != "serve":
            return None
        return resolve_act_qp(self.act_qps, name)


def resolve_act_qp(act_qps, name: str | None):
    """Site lookup in an ``act_qps`` mapping; ``"*"`` is the wildcard
    default. Shared by QuantContext.serving_qp and the explicit ``act_qps``
    threading through the nn layers."""
    if not act_qps:
        return None
    if name is None:
        return act_qps.get("*")
    return act_qps.get(name, act_qps.get("*"))


OFF = QuantContext("off")
