"""FP/INT quantization substrate: formats, fake-quant, MSE search, calibration."""
from repro.quant.formats import (FPFormat, signed_formats, unsigned_formats,
                                 enumerate_grid, quant_codes, FORMAT_BY_NAME)
from repro.quant.fakequant import (QuantizerParams, fp_qdq, int_qdq, apply_qdq,
                                   ste_qdq, quantizer_range,
                                   KIND_FP_SIGNED, KIND_FP_UNSIGNED,
                                   KIND_INT_AFFINE)
from repro.quant.search import (SearchResult, search_signed_fp,
                                search_unsigned_fp, search_int_affine,
                                search_weight_params, search_activation_params)
from repro.quant.calibrate import (CalibrationDB, QuantContext, AALConfig,
                                   SiteStats, OFF)
