"""MSE-minimizing search for quantizer parameters (paper Algorithm 1).

The paper's Alg. 1 is a Python triple loop over (format, maxval, zp). On
TPU/CPU we vectorize the entire candidate grid with ``vmap`` and evaluate it
in one jitted pass per format — same result, ~1000x fewer dispatches.

Search spaces follow App. B / C / Table 6:
  weights      maxval in [lo_frac * maxval_0, 2 * maxval_0]   (lo_frac 0.8@4b, 0.9@6/8b)
               formats = paper's signed sets
  activations  maxval in linspace(0, maxval_0, 100)[1:]
               formats = all ExMy of the bit-width
               zp in linspace(-0.3, 0, 6) for unsigned candidates (SiLU min
               is -0.278, the paper's justification for this range)
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.quant import formats as F
from repro.quant.fakequant import (
    KIND_FP_SIGNED,
    KIND_FP_UNSIGNED,
    KIND_INT_AFFINE,
    QuantizerParams,
    fp_qdq,
    int_qdq,
)


@dataclasses.dataclass(frozen=True)
class SearchResult:
    params: QuantizerParams
    mse: float
    # Diagnostics for EXPERIMENTS / Fig. 4-style analysis.
    per_format: dict[str, float]


def _subsample(x: np.ndarray | jnp.ndarray, cap: int = 1 << 16) -> jnp.ndarray:
    """Deterministic strided subsample so the search cost is bounded."""
    flat = jnp.ravel(jnp.asarray(x)).astype(jnp.float32)
    n = flat.shape[0]
    if n <= cap:
        return flat
    stride = int(np.ceil(n / cap))
    return flat[::stride]


@partial(jax.jit, static_argnums=(1,))
def _mse_signed_grid(x: jnp.ndarray, fmt: F.FPFormat, maxvals: jnp.ndarray) -> jnp.ndarray:
    def one(mv):
        return jnp.mean((x - fp_qdq(x, fmt, mv)) ** 2)

    return jax.vmap(one)(maxvals)


@partial(jax.jit, static_argnums=(1,))
def _mse_unsigned_grid(x: jnp.ndarray, fmt: F.FPFormat, maxvals: jnp.ndarray,
                       zps: jnp.ndarray) -> jnp.ndarray:
    def one(mv, zp):
        return jnp.mean((x - fp_qdq(x, fmt, mv, zp)) ** 2)

    mv_g, zp_g = jnp.meshgrid(maxvals, zps, indexing="ij")
    return jax.vmap(one)(mv_g.ravel(), zp_g.ravel()).reshape(mv_g.shape)


def search_signed_fp(x, bits: int, *, formats: Sequence[F.FPFormat] | None = None,
                     maxval_grid: np.ndarray | None = None,
                     lo_frac: float | None = None) -> SearchResult:
    """Stage-1 search: signed FP over (format, maxval)."""
    xs = _subsample(x)
    maxval_0 = float(jnp.max(jnp.abs(xs)))
    maxval_0 = max(maxval_0, 1e-8)
    if formats is None:
        formats = F.signed_formats(bits)
    if maxval_grid is None:
        if lo_frac is None:
            lo_frac = 0.8 if bits <= 4 else 0.9
        maxval_grid = np.linspace(lo_frac * maxval_0, 2.0 * maxval_0, 100)
    grid = jnp.asarray(maxval_grid, jnp.float32)

    best = None
    per_format = {}
    for fmt in formats:
        mses = np.asarray(_mse_signed_grid(xs, fmt, grid))
        i = int(np.argmin(mses))
        per_format[fmt.name] = float(mses[i])
        if best is None or mses[i] < best[0]:
            best = (float(mses[i]), fmt, float(maxval_grid[i]))
    mse, fmt, mv = best
    qp = QuantizerParams(KIND_FP_SIGNED, fmt.exp_bits, fmt.man_bits, bits,
                         jnp.float32(mv), jnp.float32(0.0))
    return SearchResult(qp, mse, per_format)


def search_unsigned_fp(x, bits: int, *, formats: Sequence[F.FPFormat] | None = None,
                       maxval_grid: np.ndarray | None = None,
                       zp_grid: np.ndarray | None = None,
                       with_zero_point: bool = True) -> SearchResult:
    """Stage-2 search: unsigned FP (+ zero-point) over (format, maxval, zp)."""
    xs = _subsample(x)
    maxval_0 = float(jnp.max(xs))
    maxval_0 = max(maxval_0, 1e-8)
    if formats is None:
        formats = F.unsigned_formats(bits)
    if maxval_grid is None:
        maxval_grid = np.linspace(0.0, maxval_0, 100)[1:]
    if zp_grid is None:
        zp_grid = np.linspace(-0.3, 0.0, 6) if with_zero_point else np.zeros(1)
    grid = jnp.asarray(maxval_grid, jnp.float32)
    zgrid = jnp.asarray(zp_grid, jnp.float32)

    best = None
    per_format = {}
    for fmt in formats:
        mses = np.asarray(_mse_unsigned_grid(xs, fmt, grid, zgrid))
        i, j = np.unravel_index(int(np.argmin(mses)), mses.shape)
        per_format[fmt.name] = float(mses[i, j])
        if best is None or mses[i, j] < best[0]:
            best = (float(mses[i, j]), fmt, float(maxval_grid[i]), float(zp_grid[j]))
    mse, fmt, mv, zp = best
    qp = QuantizerParams(KIND_FP_UNSIGNED, fmt.exp_bits, fmt.man_bits, bits,
                         jnp.float32(mv), jnp.float32(zp))
    return SearchResult(qp, mse, per_format)


def search_int_affine(x, bits: int, *, symmetric: bool = False,
                      n_grid: int = 80) -> SearchResult:
    """INT-affine baseline search (Q-Diffusion-style min/max + MSE refine)."""
    xs = _subsample(x)
    x_min = float(jnp.min(xs))
    x_max = float(jnp.max(xs))
    if symmetric:
        m0 = max(abs(x_min), abs(x_max), 1e-8)
        cands = np.linspace(0.5 * m0, 1.0 * m0, n_grid)

        @jax.jit
        def mses_fn(c):
            return jax.vmap(lambda mv: jnp.mean((xs - int_qdq(xs, bits, mv)) ** 2))(c)

        mses = np.asarray(mses_fn(jnp.asarray(cands, jnp.float32)))
        i = int(np.argmin(mses))
        qp = QuantizerParams(KIND_INT_AFFINE, 0, 0, bits,
                             jnp.float32(cands[i]), jnp.float32(0.0))
        return SearchResult(qp, float(mses[i]), {"int_sym": float(mses[i])})
    # Affine: shrink the (min, max) window jointly.
    fracs = np.linspace(0.6, 1.0, n_grid)

    @jax.jit
    def mses_fn(fr):
        def one(f):
            lo = x_min * f
            hi = x_max * f
            return jnp.mean((xs - int_qdq(xs, bits, hi, lo, symmetric=False)) ** 2)

        return jax.vmap(one)(fr)

    mses = np.asarray(mses_fn(jnp.asarray(fracs, jnp.float32)))
    i = int(np.argmin(mses))
    qp = QuantizerParams(KIND_INT_AFFINE, 0, 0, bits,
                         jnp.float32(x_max * fracs[i]), jnp.float32(x_min * fracs[i]))
    return SearchResult(qp, float(mses[i]), {"int_affine": float(mses[i])})


def search_weight_params(w, bits: int) -> SearchResult:
    """Weights ~ normal (paper Fig. 8) -> signed FP with Table 6 spaces."""
    return search_signed_fp(w, bits)


def search_activation_params(x, bits: int, *, allow_unsigned: bool,
                             with_zero_point: bool = True) -> SearchResult:
    """Alg. 1 for one activation site.

    Stage 1 (always): signed FP. Stage 2 (AALs only): unsigned FP (+zp);
    keep whichever minimizes MSE — the 'mixup-sign' selection.
    """
    res_s = search_signed_fp(x, bits, maxval_grid=np.linspace(
        0.0, max(float(jnp.max(jnp.abs(_subsample(x)))), 1e-8), 100)[1:])
    if not allow_unsigned:
        return res_s
    res_u = search_unsigned_fp(x, bits, with_zero_point=with_zero_point)
    if res_u.mse < res_s.mse:
        return SearchResult(res_u.params, res_u.mse,
                            {**res_s.per_format, **res_u.per_format})
    return SearchResult(res_s.params, res_s.mse,
                        {**res_s.per_format, **res_u.per_format})
