"""Wall-clock for the static-analysis gate itself.

The repolint CI job is budgeted at <60s total; this row keeps the lint
pass honest as rules and the tree grow. Runs the same in-process path
CI uses (`--all-files` discovery + every rule + baseline split) and
reports one row: total wall seconds, with file/violation counts in the
derived column. Deliberately jax-free — the gate must stay cheap enough
to run on every push.
"""
from __future__ import annotations

import os
import time


def rows(log=print) -> list[dict]:
    from tools.analysis.framework import (baseline_split, collect_files,
                                          load_config, run_files)
    root = os.getcwd()
    config = load_config(root)
    t0 = time.perf_counter()
    files = collect_files(root, config)
    result = run_files(files, root, config)
    new, baselined, stale = baseline_split(result, config)
    wall_s = time.perf_counter() - t0
    row = {"name": "repolint_all_files_wall_s",
           "wall_s": round(wall_s, 3),
           "derived": {"files": result.files,
                       "files_per_s": round(result.files / wall_s, 1),
                       "errors": len([v for v in new
                                      if v.severity == "error"]),
                       "baselined": len(baselined),
                       "stale": len(stale),
                       "suppressed": result.suppressed}}
    log(f"repolint_all_files_wall_s,{row['wall_s']},{row['derived']}")
    return [row]
