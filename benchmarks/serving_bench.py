"""Serving-engine benchmark: continuous-batched denoising on packed W4A4.

Replays a burst of concurrent generation requests through the diffusion
serving engine (tiny UNet, XLA packed path on CPU) and emits rows under
the kernel-bench JSON conventions (name, us_per_call, derived) — the
derived column carries throughput and segment-cache hit rate, plus a
cold-vs-warm row for the weight bank's merge+pack build, plus one
``traffic_<scenario>`` row per registry scenario (open-loop arrival
shapes, the closed-loop think-time workload, and the deadline/priority
mix) so the perf trajectory has traffic-level numbers to regress
against.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from benchmarks.common import timer
from repro.core import talora
from repro.configs.diffusion_presets import tiny_ddim
from repro.diffusion.schedule import make_schedule
from repro.nn.unet import io_sites, unet_init
from repro.quant.fakequant import KIND_FP_SIGNED, QuantizerParams
from repro.serving import (DiffusionServingEngine, WeightBank,
                           absmax_talora_setup)
from repro.serving.traffic import SimClock, get_scenario, run_scenario

IMG = 8
T = 50
N_REQ = 6
STEPS = 4

# scenarios shrunk to bench scale: 4-6 requests, 2-3 sampler steps each
BENCH_SCENARIOS = ("steady", "burst", "diurnal", "heavy_tail",
                   "closed_loop", "deadline_mix", "tight_deadlines")


def _bench_scale(scn):
    mix = dataclasses.replace(scn.mix, steps=2, steps_jitter=1)
    return dataclasses.replace(scn, mix=mix, n_requests=4, n_users=2,
                               requests_per_user=2, think_mean_s=0.05)


def _setup(key):
    cfg = tiny_ddim(IMG)
    sched = make_schedule("linear", T)
    params = unet_init(key, cfg)
    tcfg = talora.TALoRAConfig(hub_size=2, rank=4, t_emb_dim=32,
                               router_hidden=16)
    plan, hubs, router = absmax_talora_setup(params, tcfg, key,
                                             io_sites=io_sites(params))
    return cfg, sched, params, plan, hubs, router, tcfg


def rows(log=print) -> list[dict]:
    out = []
    key = jax.random.PRNGKey(0)
    cfg, sched, params, plan, hubs, router, tcfg = _setup(key)

    # weight bank build: cold merge+pack vs warm LRU hit
    bank = WeightBank(params, plan, hubs, router, tcfg, T, max_cached=4)
    t0 = time.perf_counter()
    jax.block_until_ready(jax.tree.leaves(bank.params_for_segment(0)))
    cold_us = (time.perf_counter() - t0) * 1e6
    warm_us = timer(lambda: bank.params_for_segment(0))
    out.append({"name": f"weight_bank_build_seg_{len(plan.sites)}sites",
                "us_per_call": cold_us,
                "derived": f"warm LRU hit {warm_us:.0f}us "
                           f"({cold_us / max(warm_us, 1e-9):.0f}x); "
                           f"{bank.n_segments} segments"})

    # continuous-batched serving: N concurrent requests, mixed steps
    bank = WeightBank(params, plan, hubs, router, tcfg, T,
                      max_cached=bank.n_segments)  # perf run: no evictions
    act_qp = QuantizerParams(KIND_FP_SIGNED, 2, 1, 4, jnp.float32(6.0))
    engine = DiffusionServingEngine(cfg, sched, bank,
                                    act_qps={"*": act_qp}, max_batch=N_REQ)
    for i in range(N_REQ):
        engine.submit(steps=STEPS + i % 2, seed=i,
                      sampler="ddim" if i % 2 == 0 else "plms")
    t0 = time.perf_counter()
    results = engine.run()
    wall = time.perf_counter() - t0
    s = engine.stats()
    evals = sum(rs.n_evals for rs in results.values())
    out.append({"name": f"serving_engine_{N_REQ}req_tiny_ddim{IMG}",
                "us_per_call": wall * 1e6 / max(evals, 1),
                "derived": f"{N_REQ / wall:.2f} req/s; segment-cache "
                           f"hit-rate {s['bank_hit_rate']:.2f}; mean batch "
                           f"{s['mean_batch']:.2f}; {s['forwards']} fwd"})

    # single-request baseline (no batching win, same packed path)
    bank1 = WeightBank(params, plan, hubs, router, tcfg, T,
                       max_cached=bank.n_segments)
    eng1 = DiffusionServingEngine(cfg, sched, bank1, act_qps={"*": act_qp},
                                  max_batch=1)
    eng1.submit(steps=STEPS, seed=0)
    t0 = time.perf_counter()
    res1 = eng1.run()
    wall1 = time.perf_counter() - t0
    evals1 = sum(rs.n_evals for rs in res1.values())
    out.append({"name": "serving_engine_1req_tiny_ddim8_ref",
                "us_per_call": wall1 * 1e6 / max(evals1, 1),
                "derived": "per-eval baseline (batch=1)"})

    # policy comparison: fifo (largest-group-wins) vs slo (slack-aware
    # EDF + preemption) on the deadline scenarios, under the traffic
    # subsystem's deterministic simulated service clock (`SimClock`:
    # each forward costs base + per-padded-row, charged inside the tick
    # so completions pay for their own forward) — the goodput gap is a
    # property of the *policy*, not of this machine's wall-clock speed.
    # (scenario, max_batch, tight-tier override): pressure points where
    # selection — not admission — decides who meets the deadline
    for name, comp_mb, comp_dl in (("deadline_mix", 4, (0.6, 10.0, None)),
                                   ("tight_deadlines", 8, None)):
        mix = dataclasses.replace(get_scenario(name).mix,
                                  steps=5, steps_jitter=1)
        if comp_dl is not None:
            mix = dataclasses.replace(mix, deadline_s=comp_dl)
        scn = dataclasses.replace(get_scenario(name), n_requests=12,
                                  max_batch=comp_mb, mix=mix)
        goodput = {}
        for policy in ("fifo", "slo"):
            clock = SimClock()
            bank_p = WeightBank(params, plan, hubs, router, tcfg, T,
                                max_cached=bank.n_segments)
            eng = DiffusionServingEngine(
                cfg, sched, bank_p, act_qps={"*": act_qp},
                max_batch=scn.max_batch, policy=policy,
                now_fn=clock.now, max_idle_sleep=0.0)
            clock.attach(eng)
            summary = run_scenario(scn, eng, seed=0)
            goodput[policy] = summary["goodput_frac"]
            s = eng.stats()
            out.append({
                "name": f"traffic_{name}_{policy}",
                "us_per_call": summary["wall_s"] * 1e6
                / max(sum(rs.n_evals for rs in eng.results.values()), 1),
                "goodput_frac": summary["goodput_frac"],
                # structured scheduler/bank counters (folded into the
                # collector summary) so regressions diff on fields, not
                # on parsing the derived string
                "preemptions": summary["preemptions"],
                "deadline_saves": summary["deadline_saves"],
                "bank_builds": summary["bank_builds"],
                "bank_build_joins": summary["bank_build_joins"],
                "prefetch_hits": summary["prefetch_hits"],
                "derived": f"goodput {summary['goodput_frac']:.2f} "
                           f"({summary['deadline_misses']} misses, "
                           f"{summary['expired']} expired); "
                           f"{s['preemptions']} preemptions, "
                           f"{s['deadline_saves']} saves; sim-clock "
                           f"{clock.tick_base_s}+{clock.sample_s}/row"})
        log(f"  # policy gap [{name}]: slo goodput {goodput['slo']:.2f} "
            f"vs fifo {goodput['fifo']:.2f}")

    # traffic scenarios: one row per registry entry (arrival shape x SLO)
    for name in BENCH_SCENARIOS:
        scn = _bench_scale(get_scenario(name))
        bank_s = WeightBank(params, plan, hubs, router, tcfg, T,
                            max_cached=bank.n_segments)
        eng = DiffusionServingEngine(cfg, sched, bank_s,
                                     act_qps={"*": act_qp},
                                     max_batch=scn.max_batch)
        summary = run_scenario(scn, eng, seed=0)
        evals = sum(rs.n_evals for rs in eng.results.values())
        slo = summary["slo"]
        verdict = ("no-slo" if not slo["checks"]
                   else "slo-pass" if slo["passed"] else "slo-FAIL")
        out.append({
            "name": f"traffic_{name}",
            "us_per_call": summary["wall_s"] * 1e6 / max(evals, 1),
            "preemptions": summary["preemptions"],
            "deadline_saves": summary["deadline_saves"],
            "bank_builds": summary["bank_builds"],
            "bank_build_joins": summary["bank_build_joins"],
            "prefetch_hits": summary["prefetch_hits"],
            "derived": f"{summary['throughput_rps']:.2f} req/s; "
                       f"p95 {summary['p95_s']:.2f}s; goodput "
                       f"{summary['goodput_frac']:.2f} "
                       f"({summary['expired']} expired); {verdict}; "
                       f"hit-rate {eng.stats()['bank_hit_rate']:.2f}; "
                       f"{eng.stats()['prefetch_hits']} prefetch hits"})

    for r in out:
        log(f"  {r['name']},{r['us_per_call']:.0f}us,{r['derived']}")

    # observability overhead: same deadline_mix/SimClock run obs on vs
    # off — the row pins the disabled-path cost, the derived column the
    # enabled ratio and the outcome-identity check (see obs_overhead)
    from benchmarks import obs_overhead
    out.extend(obs_overhead.rows(log=log, iters=2))
    return out
