"""Roofline report: turn experiments/dryrun/*.json into the §Roofline table.

Hardware model (TPU v5e):
  peak_flops  = 197e12 FLOP/s bf16 per chip
  hbm_bw      = 819e9  B/s per chip
  ici_bw      = 50e9   B/s per link (collective term uses per-device
                collective bytes / link bw — a 1-link serialization bound;
                all-reduce payloads already carry the 2x factor)

Terms (per device, per step):
  compute    = extrap.flops / peak_flops
  memory     = extrap['bytes accessed'] / hbm_bw
  collective = extrap.collective_bytes / ici_bw

MODEL_FLOPS: 6*N*D for dense train (N params, D tokens), 6*N_active*D for
MoE; 2*N*B per decode step (B new tokens); 2*N*D prefill. The ratio
MODEL/HLO flags remat + replication waste.
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

SHAPE_TOKENS = {"train_4k": (4096, 256), "prefill_32k": (32768, 32),
                "decode_32k": (32768, 128), "long_500k": (524288, 1)}


def model_flops(rec: dict) -> float:
    seq, gb = SHAPE_TOKENS[rec["shape"]]
    n = rec["active_params"]
    if rec["kind"] == "train":
        return 6.0 * n * seq * gb
    if rec["kind"] == "prefill":
        return 2.0 * n * seq * gb
    return 2.0 * n * gb  # decode: one token per sequence


def analyze(rec: dict) -> dict:
    chips = rec["chips"]
    fl = rec["extrap"]["cost"].get("flops", 0.0)
    by = rec["extrap"]["cost"].get("bytes accessed", 0.0)
    co = rec["extrap"]["collective_bytes"]
    t_c = fl / PEAK_FLOPS
    t_m = by / HBM_BW
    t_x = co / ICI_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])[0]
    mf = model_flops(rec)
    t_ideal = mf / chips / PEAK_FLOPS
    t_bound = max(t_c, t_m, t_x)
    return {
        "cell": f"{rec['arch']}/{rec['shape']}",
        "mesh": rec["mesh"], "quant": rec.get("quant", "bf16"),
        "kv": rec.get("kv", "bf16"),
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dom,
        "model_flops": mf,
        "hlo_flops_total": fl * chips,
        "useful_ratio": mf / (fl * chips) if fl else 0.0,
        "roofline_frac": t_ideal / t_bound if t_bound else 0.0,
        "hbm_gb_per_dev": rec.get("memory", {}).get("temp_size_in_bytes", 0)
        / 1e9,
    }


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.0f}us"


def report(dirpath: str = "experiments/dryrun", mesh: str = "single",
           quant: str | None = None, kv: str | None = None,
           log=print) -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        rec = json.load(open(path))
        if not rec.get("ok"):
            continue
        if rec["mesh"] != mesh:
            continue
        if quant is not None and rec.get("quant", "bf16") != quant:
            continue
        if kv is not None and rec.get("kv", "bf16") != kv:
            continue
        rows.append(analyze(rec))
    rows.sort(key=lambda r: r["cell"])
    log(f"| cell | dom | compute | memory | collective | useful(6ND/HLO) "
        f"| roofline-frac | HBM GB/dev |")
    log("|---|---|---|---|---|---|---|---|")
    for r in rows:
        log(f"| {r['cell']} | {r['dominant'][:4]} | {fmt_s(r['compute_s'])} "
            f"| {fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} "
            f"| {r['useful_ratio']:.3f} | {r['roofline_frac']:.3f} "
            f"| {r['hbm_gb_per_dev']:.1f} |")
    return rows


if __name__ == "__main__":
    import sys
    report(mesh=sys.argv[1] if len(sys.argv) > 1 else "single")
