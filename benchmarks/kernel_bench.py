"""Kernel microbenchmarks: us/call on this host (XLA path; Pallas targets

TPU and is validated in interpret mode — wall-clock here measures the XLA
fallback numerics, the bytes ratios are the hardware-independent part)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

import repro.kernels.ops as ops
from benchmarks.common import timer
from repro.core.qmodule import dequant_weight, pack_weight
from repro.quant.fakequant import KIND_FP_SIGNED, QuantizerParams


def _w4_hbm_bytes(m, k, n, fused: bool) -> int:
    """Serving-path HBM bytes for one W4(A4) matmul: read bf16 x + packed
    weight, write bf16 out. The unfused pipeline round-trips the quantized
    activations (write + re-read of x) before the matmul."""
    x_bytes = m * k * 2
    packed = k * n // 2
    out = m * n * 2
    if fused:
        return x_bytes + packed + out
    return 3 * x_bytes + packed + out  # qdq: read x, write xq; matmul: read xq


def rows(log=print) -> list[dict]:
    out = []
    key = jax.random.PRNGKey(0)
    qp = QuantizerParams(KIND_FP_SIGNED, 2, 1, 4, jnp.float32(2.0))

    x = jax.random.normal(key, (1024, 1024), jnp.float32)
    f = jax.jit(lambda x: ops.msfp_quantize(x, qp))
    us = timer(f, x)
    out.append({"name": "msfp_qdq_1Mx", "us_per_call": us,
                "derived": f"{x.size * 8 / us / 1e3:.2f}GB/s eff"})

    k, n, m = 2048, 2048, 256
    w = jax.random.normal(key, (k, n), jnp.float32)
    pw = pack_weight(w, qp)
    xb = jax.random.normal(key, (m, k), jnp.bfloat16)
    f_w4 = jax.jit(lambda x: ops.w4_matmul(x, pw))
    us_w4 = timer(f_w4, xb)
    wd = w.astype(jnp.bfloat16)
    f_bf = jax.jit(lambda x: x @ wd)
    us_bf = timer(f_bf, xb)
    out.append({"name": "w4_matmul_256x2048x2048", "us_per_call": us_w4,
                "derived": f"weight bytes 4x smaller; bf16 dense={us_bf:.0f}us"})
    out.append({"name": "dense_bf16_matmul_ref", "us_per_call": us_bf,
                "derived": "baseline"})

    # per-output-channel scale (vector-scale PackedW4, same Pallas path)
    mv_pc = jnp.maximum(jnp.max(jnp.abs(w), axis=0), 1e-8).astype(jnp.float32)
    qp_pc = QuantizerParams(KIND_FP_SIGNED, 2, 1, 4, mv_pc)
    pw_pc = pack_weight(w, qp_pc)
    f_pc = jax.jit(lambda x: ops.w4_matmul(x, pw_pc))
    us_pc = timer(f_pc, xb)
    out.append({"name": "w4_matmul_perchannel_256x2048x2048",
                "us_per_call": us_pc,
                "derived": f"scale bytes {n * 4}B vs 4B scalar"})

    # fused W4A4 vs qdq-then-matmul: same math, one fewer HBM round-trip
    act_qp = QuantizerParams(KIND_FP_SIGNED, 2, 1, 4, jnp.float32(4.0))
    f_fused = jax.jit(lambda x: ops.w4a4_matmul(x, pw, act_qp))
    us_fused = timer(f_fused, xb)
    f_2pass = jax.jit(lambda x: ops.w4_matmul(ops.msfp_quantize(x, act_qp),
                                              pw))
    us_2pass = timer(f_2pass, xb)
    b_fused = _w4_hbm_bytes(m, k, n, fused=True)
    b_2pass = _w4_hbm_bytes(m, k, n, fused=False)
    out.append({"name": "w4a4_matmul_fused_256x2048x2048",
                "us_per_call": us_fused,
                "derived": f"HBM {b_fused / 1e6:.2f}MB vs "
                           f"{b_2pass / 1e6:.2f}MB qdq-then-matmul "
                           f"({b_2pass / b_fused:.2f}x)"})
    out.append({"name": "w4a4_matmul_qdq_then_matmul_ref",
                "us_per_call": us_2pass,
                "derived": f"HBM {b_2pass / 1e6:.2f}MB"})

    # im2col W4A4 conv route vs decode-then-XLA-conv (today's fallback).
    # Mid-block diffusion shape: small spatial, wide channels — the weight
    # bytes dominate, which is exactly where the packed route wins (the
    # patch matrix round-trip is the route's known cost; see kernels/README).
    bq, hq, cinq, coutq, kk = 1, 8, 256, 256, 3
    xc = jax.random.normal(key, (bq, hq, hq, cinq), jnp.bfloat16)
    wc = jax.random.normal(key, (kk, kk, cinq, coutq), jnp.float32) * 0.05
    qp_c = QuantizerParams(KIND_FP_SIGNED, 2, 1, 4,
                           jnp.maximum(jnp.max(jnp.abs(wc)), 1e-6))
    pw_c = pack_weight(wc, qp_c)
    act_qp_c = QuantizerParams(KIND_FP_SIGNED, 2, 1, 4, jnp.float32(4.0))
    f_conv = jax.jit(lambda x: ops.w4a4_conv2d(x, pw_c, act_qp_c))
    us_conv = timer(f_conv, xc)

    def _decode_then_conv(x):
        w = dequant_weight(pw_c, jnp.bfloat16)
        return jax.lax.conv_general_dilated(
            x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))

    f_dec = jax.jit(_decode_then_conv)
    us_dec = timer(f_dec, xc)
    mq = bq * hq * hq                      # stride-1 SAME: OH*OW = H*W
    kq = kk * kk * cinq
    x_b = xc.size * 2
    p_b = kq * coutq // 2                  # packed nibbles
    o_b = mq * coutq * 2
    b_conv = x_b + 2 * mq * kq * 2 + p_b + o_b     # + patch write/read
    b_dec = x_b + p_b + 2 * (kq * coutq * 2) + o_b  # + bf16 W write/read
    out.append({"name": f"w4a4_conv2d_im2col_{hq}x{hq}x{cinq}x{coutq}k{kk}",
                "us_per_call": us_conv,
                "derived": f"HBM {b_conv / 1e6:.2f}MB vs "
                           f"{b_dec / 1e6:.2f}MB decode-then-conv "
                           f"({b_dec / b_conv:.2f}x)"})
    out.append({"name": "conv2d_dequant_then_conv_ref",
                "us_per_call": us_dec,
                "derived": f"HBM {b_dec / 1e6:.2f}MB (bf16 weight "
                           f"round-trip each step)"})

    t = jax.random.normal(key, (128, 32, 8, 128), jnp.bfloat16)
    f_enc = jax.jit(lambda t: ops.kv4_encode(t))
    us_e = timer(f_enc, t)
    packed, scale = f_enc(t)
    f_dec = jax.jit(lambda p, s: ops.kv4_decode(p, s))
    us_d = timer(f_dec, packed, scale)
    ratio = t.size * 2 / (packed.size + scale.size * 2)
    out.append({"name": "kv4_encode_4Mv", "us_per_call": us_e,
                "derived": f"cache bytes /{ratio:.2f}"})
    out.append({"name": "kv4_decode_4Mv", "us_per_call": us_d,
                "derived": ""})
    for r in out:
        log(f"  {r['name']},{r['us_per_call']:.0f}us,{r['derived']}")
    return out
