"""Kernel microbenchmarks: us/call on this host.

Wall-clock measures the path the dispatcher actually serves on this
backend (off-TPU: the fast XLA serving path in ``kernels.xla_serve``;
the Pallas kernels target TPU and are validated in interpret mode).

Weights are *runtime operands* of every timed function, exactly as the
engine passes params to its jitted steps. Closing over them instead —
what this benchmark used to do — lets XLA constant-fold both the packed
route's nibble decode and the bf16 route's weight upconvert, collapsing
the comparison to "same GEMM + qdq overhead": the quantized rows could
only lose, and the serving costs being compared never ran.

Rows that get compared are timed *interleaved* (``timer_interleaved``),
so their ratios survive host-load drift; each quantized row's
``derived`` records the kernel tile sizes and a ``speedup_vs_ref``
ratio against the reference row from the same interleaved group.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

import repro.kernels.ops as ops
import repro.kernels.ref as kref
from benchmarks.common import timer, timer_interleaved
from repro.core.qmodule import dequant_weight, pack_weight
from repro.kernels.w4_matmul import pick_tiles
from repro.quant.fakequant import KIND_FP_SIGNED, QuantizerParams


def _w4_hbm_bytes(m, k, n, fused: bool) -> int:
    """Serving-path HBM bytes for one W4(A4) matmul: read bf16 x + packed
    weight, write bf16 out. The unfused pipeline round-trips the quantized
    activations (write + re-read of x) before the matmul."""
    x_bytes = m * k * 2
    packed = k * n // 2
    out = m * n * 2
    if fused:
        return x_bytes + packed + out
    return 3 * x_bytes + packed + out  # qdq: read x, write xq; matmul: read xq


def rows(log=print) -> list[dict]:
    out = []
    key = jax.random.PRNGKey(0)
    qp = QuantizerParams(KIND_FP_SIGNED, 2, 1, 4, jnp.float32(2.0))

    # --- fused fake-quant: bitcast-octave serving snap vs the
    # transcendental oracle (floor(log2) + exp2), same numerics.
    x = jax.random.normal(key, (1024, 1024), jnp.float32)
    f = jax.jit(lambda x: ops.msfp_quantize(x, qp))
    f_oracle = jax.jit(lambda x: kref.ref_msfp_qdq(x, qp))
    us, us_oracle = timer_interleaved([f, f_oracle], [(x,), (x,)])
    out.append({"name": "msfp_qdq_1Mx", "us_per_call": us,
                "derived": {"note": f"{x.size * 8 / us / 1e3:.2f}GB/s eff; "
                                    "bitcast-octave snap",
                            "speedup_vs_ref": round(us_oracle / us, 3)}})

    # --- matmul family at the serving shape, one interleaved group so
    # every ratio (incl. the acceptance fused-vs-dense one) is apples to
    # apples on this host.
    k, n, m = 2048, 2048, 256
    w = jax.random.normal(key, (k, n), jnp.float32)
    pw = pack_weight(w, qp)
    xb = jax.random.normal(key, (m, k), jnp.bfloat16)
    wd = w.astype(jnp.bfloat16)
    mv_pc = jnp.maximum(jnp.max(jnp.abs(w), axis=0), 1e-8).astype(jnp.float32)
    pw_pc = pack_weight(w, QuantizerParams(KIND_FP_SIGNED, 2, 1, 4, mv_pc))
    act_qp = QuantizerParams(KIND_FP_SIGNED, 2, 1, 4, jnp.float32(4.0))

    f_w4 = jax.jit(lambda x, p: ops.w4_matmul(x, p))
    f_bf = jax.jit(lambda x, w: x @ w)
    f_fused = jax.jit(lambda x, p: ops.w4a4_matmul(x, p, act_qp))
    f_2pass = jax.jit(
        lambda x, p: ops.w4_matmul(ops.msfp_quantize(x, act_qp), p))
    us_w4, us_bf, us_pc, us_fused, us_2pass = timer_interleaved(
        [f_w4, f_bf, f_w4, f_fused, f_2pass],
        [(xb, pw), (xb, wd), (xb, pw_pc), (xb, pw), (xb, pw)], iters=30)
    tiles = pick_tiles(m, k, n)
    b_fused = _w4_hbm_bytes(m, k, n, fused=True)
    b_2pass = _w4_hbm_bytes(m, k, n, fused=False)
    out.append({"name": "w4_matmul_256x2048x2048", "us_per_call": us_w4,
                "derived": {"note": "weight bytes 4x smaller than bf16",
                            "tiles": tiles,
                            "speedup_vs_ref": round(us_bf / us_w4, 3)}})
    out.append({"name": "dense_bf16_matmul_ref", "us_per_call": us_bf,
                "derived": {"note": "baseline, weight a runtime operand "
                                    "like every row (engine params are "
                                    "jit args); interleaved with the "
                                    "quantized rows"}})
    out.append({"name": "w4_matmul_perchannel_256x2048x2048",
                "us_per_call": us_pc,
                "derived": {"note": f"scale bytes {n * 4}B vs 4B scalar",
                            "tiles": tiles,
                            "speedup_vs_ref": round(us_bf / us_pc, 3)}})
    out.append({"name": "w4a4_matmul_fused_256x2048x2048",
                "us_per_call": us_fused,
                "derived": {"note": f"HBM {b_fused / 1e6:.2f}MB vs "
                                    f"{b_2pass / 1e6:.2f}MB qdq-then-matmul "
                                    f"({b_2pass / b_fused:.2f}x); ref = the "
                                    "bf16 dense path it replaces (which "
                                    "re-converts a 2x bigger weight per "
                                    "call; nibble decode is cheaper)",
                            "tiles": tiles,
                            "speedup_vs_ref": round(us_bf / us_fused, 3),
                            "speedup_vs_2pass": round(us_2pass / us_fused,
                                                      3)}})
    out.append({"name": "w4a4_matmul_qdq_then_matmul_ref",
                "us_per_call": us_2pass,
                "derived": {"note": f"HBM {b_2pass / 1e6:.2f}MB"}})

    # --- conv routes at the mid-block diffusion shape (small spatial,
    # wide channels). Implicit GEMM (the serving route) never builds the
    # patch matrix; the previous im2col-route fallback and the
    # decode-then-conv reference ride in the same interleaved group.
    bq, hq, cinq, coutq, kk = 1, 8, 256, 256, 3
    xc = jax.random.normal(key, (bq, hq, hq, cinq), jnp.bfloat16)
    wc = jax.random.normal(key, (kk, kk, cinq, coutq), jnp.float32) * 0.05
    qp_c = QuantizerParams(KIND_FP_SIGNED, 2, 1, 4,
                           jnp.maximum(jnp.max(jnp.abs(wc)), 1e-6))
    pw_c = pack_weight(wc, qp_c)
    act_qp_c = QuantizerParams(KIND_FP_SIGNED, 2, 1, 4, jnp.float32(4.0))

    f_conv = jax.jit(lambda x, p: ops.w4a4_conv2d(x, p, act_qp_c))
    f_prev = jax.jit(lambda x, p: kref.ref_w4a4_conv2d(x, p, act_qp_c,
                                                       dtype=x.dtype))

    def _decode_then_conv(x, p):
        w = dequant_weight(p, jnp.bfloat16)
        return jax.lax.conv_general_dilated(
            x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))

    f_dec = jax.jit(_decode_then_conv)
    us_impl, us_prev, us_dec = timer_interleaved(
        [f_conv, f_prev, f_dec], [(xc, pw_c)] * 3, iters=30)
    mq = bq * hq * hq                      # stride-1 SAME: OH*OW = H*W
    kq = kk * kk * cinq
    x_b = xc.size * 2
    p_b = kq * coutq // 2                  # packed nibbles
    o_b = mq * coutq * 2
    b_impl = x_b + p_b + o_b                        # no patch matrix
    b_im2col = x_b + 2 * mq * kq * 2 + p_b + o_b    # + patch write/read
    b_dec = x_b + p_b + 2 * (kq * coutq * 2) + o_b  # + bf16 W write/read
    ctiles = {"bc": min(128, cinq), "bn": min(128, coutq // 2)}
    out.append({"name": f"w4a4_conv2d_implicit_{hq}x{hq}x{cinq}x{coutq}k{kk}",
                "us_per_call": us_impl,
                "derived": {"note": f"HBM {b_impl / 1e6:.2f}MB vs "
                                    f"{b_dec / 1e6:.2f}MB decode-then-conv "
                                    f"({b_dec / b_impl:.2f}x); unfold folded "
                                    "into the index maps / tap loop",
                            "tiles": ctiles,
                            "speedup_vs_ref": round(us_dec / us_impl, 3)}})
    out.append({"name": f"w4a4_conv2d_im2col_{hq}x{hq}x{cinq}x{coutq}k{kk}",
                "us_per_call": us_prev,
                "derived": {"note": f"previous route (HBM "
                                    f"{b_im2col / 1e6:.2f}MB patch-matrix "
                                    "round-trip on TPU; qdq + decode + XLA "
                                    "conv here)",
                            "speedup_vs_ref": round(us_dec / us_prev, 3)}})
    out.append({"name": "conv2d_dequant_then_conv_ref",
                "us_per_call": us_dec,
                "derived": {"note": f"HBM {b_dec / 1e6:.2f}MB (bf16 weight "
                                    "round-trip each step)"}})

    t = jax.random.normal(key, (128, 32, 8, 128), jnp.bfloat16)
    f_enc = jax.jit(lambda t: ops.kv4_encode(t))
    us_e = timer(f_enc, t)
    packed, scale = f_enc(t)
    f_kvd = jax.jit(lambda p, s: ops.kv4_decode(p, s))
    us_d = timer(f_kvd, packed, scale)
    ratio = t.size * 2 / (packed.size + scale.size * 2)
    out.append({"name": "kv4_encode_4Mv", "us_per_call": us_e,
                "derived": {"note": f"cache bytes /{ratio:.2f}"}})
    out.append({"name": "kv4_decode_4Mv", "us_per_call": us_d,
                "derived": {"note": ""}})
    for r in out:
        log(f"  {r['name']},{r['us_per_call']:.0f}us,{r['derived']}")
    return out
