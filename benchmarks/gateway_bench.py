"""Gateway benchmark: mixed-model traffic through the serving gateway.

Runs the two multi-model scenarios (``mixed_model``, ``per_model_slo``)
at bench scale through a real two-model gateway — the tiny diffusion
preset plus the smoke LM, each quantized through its own weight bank —
under a shared ``SimClock`` so per-model goodput is machine-independent.
Rows follow the kernel-bench conventions (name, us_per_call, derived):
``us_per_call`` is wall time per served request; ``derived`` carries the
per-model goodput split, the per-bank hit rates, and the cross-model
build totals (the contention signal: two banks building on one clock).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

from repro.launch.serve_diffusion import outcome_digest
from repro.launch.serve_gateway import build_gateway
from repro.serving.traffic import (MetricsCollector, get_scenario,
                                   run_scenario)

MODELS = ["tiny-ddim", "smollm-135m"]
BENCH_SCENARIOS = ("mixed_model", "per_model_slo")


def _args():
    """The launcher-arg surface ``build_gateway`` consumes, bench-shaped."""
    return argparse.Namespace(clock="sim", image_size=8, T=50, seed=0,
                              bank_cap=None, policy="fifo",
                              gateway_max_batch=4)


def _bench_scale(scn):
    mix = dataclasses.replace(scn.mix, steps=2, steps_jitter=1)
    return dataclasses.replace(scn, mix=mix, n_requests=6)


def rows(log=print) -> list[dict]:
    out = []
    for name in BENCH_SCENARIOS:
        scn = _bench_scale(get_scenario(name))
        gw, _sim = build_gateway(MODELS, _args())
        collector = MetricsCollector()
        t0 = time.perf_counter()
        summary = run_scenario(scn, gw, seed=0, collector=collector)
        wall_us = (time.perf_counter() - t0) * 1e6
        served = max(summary["requests"] + summary["expired"], 1)
        gs = gw.stats()
        goodput = {m: round(gs["per_model"][m]["summary"]["goodput_frac"], 3)
                   for m in gw.list_models()}
        banks = {m: gw.engine(m).bank for m in gw.list_models()}
        for m, b in banks.items():
            assert (b.builds + b.build_failures
                    == b.misses + b.prefetches), f"bank mismatch: {m}"
        derived = (
            f"goodput {goodput}; "
            f"{summary['expired']} expired; "
            "banks "
            + ", ".join(f"{m}: hit {b.hit_rate:.2f} ({b.builds} builds)"
                        for m, b in banks.items())
            + f"; sim duration {summary['duration_s']:.2f}s"
            + f"; digest {outcome_digest(gw.results)}")
        row = {"name": f"gateway_{name}",
               "us_per_call": wall_us / served,
               "derived": derived}
        log(f"{row['name']},{row['us_per_call']:.0f},{derived}")
        out.append(row)
    return out
