"""Benchmarks: one per paper table/figure + kernel microbench + roofline."""
