"""Benchmark orchestrator — one section per paper table/figure + kernels.

``python -m benchmarks.run [--only t4,...] [--retrain]``
Prints `name,value,derived` CSV lines per section and writes
experiments/bench_results.json.
"""
from __future__ import annotations

import argparse
import json
import os
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: t1,t4,t5,t7,fig3,fig4,kernels,serving,"
                         "gateway,fleet,analysis")
    ap.add_argument("--retrain", action="store_true")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    def want(k):
        return only is None or k in only

    results = {}
    t0 = time.time()

    if want("analysis"):
        from benchmarks import analysis_bench
        print("## analysis (name,wall_s,derived)")
        results["analysis"] = analysis_bench.rows()

    # every remaining section needs the trained fixture (and jax); an
    # `--only analysis` run must stay dependency-light and sub-minute,
    # and the gateway/fleet sections quantize from init (no trained
    # fixture)
    if only is None or (only - {"analysis", "gateway", "fleet"}):
        from benchmarks.common import get_tiny_ddim
        get_tiny_ddim(retrain=args.retrain)  # build/reuse trained fixture
        print(f"# fixture ready ({time.time() - t0:.0f}s)")

        from benchmarks import kernel_bench, paper_tables

    if want("kernels"):
        print("## kernels (name,us_per_call,derived)")
        results["kernels"] = kernel_bench.rows()
    if want("serving"):
        from benchmarks import serving_bench
        print("## serving (name,us_per_call,derived)")
        results["serving"] = serving_bench.rows()
    if want("gateway"):
        from benchmarks import gateway_bench
        print("## gateway (name,us_per_call,derived)")
        results["gateway"] = gateway_bench.rows()
    if want("fleet"):
        from benchmarks import fleet_bench
        print("## fleet (name,us_per_call,derived)")
        results["fleet"] = fleet_bench.rows()
    if want("fig4"):
        print("## fig4: AAL strategies (paper: unsigned+zp improves >95%)")
        results["fig4"] = paper_tables.fig4_aal_strategies()
    if want("fig3"):
        print("## fig3: loss alignment (DFA should correlate with true gap)")
        results["fig3"] = paper_tables.fig3_loss_alignment()
    if want("t5"):
        print("## table5: weight maxval search spaces")
        results["table5"] = paper_tables.table5_search_space()
    if want("t7"):
        print("## table7: FP vs INT PTQ (no finetune)")
        results["table7"] = paper_tables.table7_fp_vs_int()
    if want("t1"):
        print("## table1: LoRA allocation strategies")
        results["table1"] = paper_tables.table1_lora_alloc()
    if want("t4"):
        print("## table4: ablation (MSFP / TALoRA / DFA)")
        results["table4"] = paper_tables.table4_ablation()

    os.makedirs("experiments", exist_ok=True)
    # merge into existing results so `--only <section>` runs don't drop the
    # other sections' rows
    path = "experiments/bench_results.json"
    merged = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                merged = json.load(f)
        except (json.JSONDecodeError, OSError):
            merged = {}
    merged.update(results)
    with open(path, "w") as f:
        json.dump(merged, f, indent=1, default=float)
    print(f"# total {time.time() - t0:.0f}s -> experiments/bench_results.json")


if __name__ == "__main__":
    main()
