"""Observability overhead: the deadline_mix SimClock run, obs on vs off.

The serving engine's obs layer promises (a) determinism — tracing reads
the engine clock and engine state but never perturbs either, so the
per-request outcome digest is identical with obs on or off — and (b)
near-zero disabled overhead — ``NULL_OBS`` costs one branch per
instrumentation point. This module measures both on the same workload
the policy-comparison bench rows use (deadline_mix, 12 requests,
slack-aware policy, deterministic simulated service clock) and turns
them into a CI gate (``python -m benchmarks.obs_overhead --gate``):

  1. obs-on and obs-off runs produce byte-identical outcome digests and
     identical summary counters (exact — the sim is deterministic);
  2. the obs-off run's deterministic fields (goodput, misses, expired,
     preemptions) exactly match the committed baseline row in
     ``experiments/bench_results.json`` — a 0%-tolerance regression
     check on everything the sim pins down;
  3. the obs-on / obs-off wall ratio (interleaved, best-of-N) stays
     under ``--ratio-tol``.

The obs-off wall-per-eval vs the committed baseline ``us_per_call`` is
*reported but never gated*: that number includes jit compile time and
the baseline was recorded by whatever machine last ran
``benchmarks.run``, so a wall gate against it would flake on shared
runners (observed cross-process drift is >100% with zero code delta).
The "disabled obs regresses <2%" claim is instead carried by check 3 in
its strongest same-process form: even the *enabled* run — which does
strictly more work per instrumentation point than the disabled branch —
stays within the ratio tolerance of the disabled run, measured
interleaved in one process.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.launch.serve_diffusion import outcome_digest
from repro.quant.fakequant import KIND_FP_SIGNED, QuantizerParams
from repro.serving import DiffusionServingEngine, WeightBank
from repro.serving.obs import NULL_OBS, Observability
from repro.serving.traffic import SimClock, get_scenario, run_scenario

BASELINE = os.path.join("experiments", "bench_results.json")
BASELINE_ROW = "traffic_deadline_mix_slo"

# deterministic summary fields every run of this sim must reproduce
EXACT_FIELDS = ("requests", "expired", "deadline_misses", "goodput_frac",
                "preemptions", "deadline_saves")


def _scenario():
    """The deadline_mix pressure config from the policy-comparison bench
    rows (kept in sync with serving_bench: tight tier 0.6s, 12 req)."""
    base = get_scenario("deadline_mix")
    mix = dataclasses.replace(base.mix, steps=5, steps_jitter=1,
                              deadline_s=(0.6, 10.0, None))
    return dataclasses.replace(base, n_requests=12, max_batch=4, mix=mix)


def run_once(obs_on: bool) -> dict:
    """One SimClock deadline_mix run; returns summary + digest + wall."""
    from benchmarks.serving_bench import T, _setup
    key = jax.random.PRNGKey(0)
    cfg, sched, params, plan, hubs, router, tcfg = _setup(key)
    scn = _scenario()
    act_qp = QuantizerParams(KIND_FP_SIGNED, 2, 1, 4, jnp.float32(6.0))
    clock = SimClock()
    bank = WeightBank(params, plan, hubs, router, tcfg, T, max_cached=8)
    obs = Observability() if obs_on else NULL_OBS
    obs.install_kernels()
    try:
        eng = DiffusionServingEngine(cfg, sched, bank, act_qps={"*": act_qp},
                                     max_batch=scn.max_batch, policy="slo",
                                     now_fn=clock.now, max_idle_sleep=0.0,
                                     obs=obs)
        clock.attach(eng)
        t0 = time.perf_counter()
        summary = run_scenario(scn, eng, seed=0)
        wall = time.perf_counter() - t0
    finally:
        obs.uninstall_kernels()
    evals = sum(rs.n_evals for rs in eng.results.values())
    return {"summary": summary, "digest": outcome_digest(eng.results),
            "wall_s": wall, "evals": evals,
            "trace_events": len(obs.tracer.events())}


def measure(iters: int = 3) -> dict:
    """Interleaved obs-off/obs-on runs; best-of-``iters`` walls plus the
    (deterministic) outcome comparison from the last pair."""
    off = on = None
    off_walls, on_walls = [], []
    for _ in range(iters):
        off = run_once(False)
        on = run_once(True)
        off_walls.append(off["wall_s"])
        on_walls.append(on["wall_s"])
    mismatched = [f for f in EXACT_FIELDS
                  if off["summary"][f] != on["summary"][f]]
    return {"off": off, "on": on,
            "off_wall_s": min(off_walls), "on_wall_s": min(on_walls),
            "ratio": min(on_walls) / max(min(off_walls), 1e-9),
            "outcomes_identical": (off["digest"] == on["digest"]
                                   and not mismatched),
            "mismatched_fields": mismatched}


def rows(log=print, iters: int = 3) -> list[dict]:
    m = measure(iters=iters)
    off = m["off"]
    row = {"name": "serving_obs_overhead_deadline_mix",
           "us_per_call": m["off_wall_s"] * 1e6 / max(off["evals"], 1),
           "goodput_frac": off["summary"]["goodput_frac"],
           "derived": f"obs-on/off wall ratio {m['ratio']:.2f}; outcomes "
                      f"{'identical' if m['outcomes_identical'] else 'DIVERGED'}"
                      f"; {m['on']['trace_events']} trace events when on"}
    log(f"  {row['name']},{row['us_per_call']:.0f}us,{row['derived']}")
    return [row]


def _baseline_row() -> dict | None:
    try:
        with open(BASELINE) as f:
            data = json.load(f)
    except OSError:
        return None
    for r in data.get("serving", []):
        if r["name"] == BASELINE_ROW:
            return r
    return None


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--gate", action="store_true",
                    help="exit non-zero on any failed check (CI mode)")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--ratio-tol", type=float, default=1.25,
                    help="max obs-on / obs-off wall ratio")
    args = ap.parse_args(argv)

    m = measure(iters=args.iters)
    off = m["off"]
    us = m["off_wall_s"] * 1e6 / max(off["evals"], 1)
    print(f"obs-off: {us:.0f}us/eval (best of {args.iters}), "
          f"digest {off['digest']}")
    print(f"obs-on : ratio {m['ratio']:.2f}x, "
          f"{m['on']['trace_events']} trace events, "
          f"digest {m['on']['digest']}")

    failures = []
    if not m["outcomes_identical"]:
        failures.append("obs-on outcomes diverged from obs-off: "
                        f"digest {m['on']['digest']} vs {off['digest']}, "
                        f"fields {m['mismatched_fields']}")
    base = _baseline_row()
    if base is None:
        print(f"note: no committed baseline row {BASELINE_ROW!r}; "
              "skipping baseline checks")
    else:
        s = off["summary"]
        if base.get("goodput_frac") is not None \
                and abs(s["goodput_frac"] - base["goodput_frac"]) > 1e-12:
            failures.append(
                f"deterministic goodput drifted vs baseline: "
                f"{s['goodput_frac']:.4f} vs {base['goodput_frac']:.4f}")
        drift = us / base["us_per_call"] - 1.0
        print(f"wall vs committed baseline: {drift:+.1%} "
              "(report-only — cross-process/machine, not gated)")
    if m["ratio"] > args.ratio_tol:
        failures.append(f"obs-on wall ratio {m['ratio']:.2f} > "
                        f"tol {args.ratio_tol:.2f}")

    for f in failures:
        print(f"FAIL: {f}")
    if not failures:
        print("obs overhead gate: PASS")
    return 1 if (failures and args.gate) else 0


if __name__ == "__main__":
    raise SystemExit(main())
