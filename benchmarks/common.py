"""Shared benchmark fixtures: a *trained* tiny DDIM (cached to disk).

Quantization benchmarks on a random network measure noise; the paper's
tables quantize trained models. We train the reduced DDIM (16x16 UNet)
on the synthetic Gaussian-bump distribution for a few hundred steps once
and cache the params — every table benchmark reuses it.
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.tree import flatten_paths, unflatten_paths
from repro.configs.diffusion_presets import tiny_ddim
from repro.data.synthetic import gaussian_bump_images
from repro.diffusion.schedule import make_schedule
from repro.nn.unet import unet_apply, unet_init
from repro.optim.adam import AdamConfig, adam_init, adam_update

CACHE = os.path.join(os.path.dirname(__file__), "..", "experiments",
                     "tiny_ddim_params.npz")
IMG = 16
T = 200


def train_tiny_ddim(steps: int = 400, batch: int = 16, lr: float = 2e-3,
                    log=print) -> dict:
    cfg = tiny_ddim(IMG)
    sched = make_schedule("linear", T)
    key = jax.random.PRNGKey(0)
    params = unet_init(key, cfg)
    acfg = AdamConfig(lr=lr, clip_norm=1.0)
    opt = adam_init(params, acfg)

    @jax.jit
    def step(params, opt, key):
        k1, k2, k3 = jax.random.split(key, 3)
        x0 = gaussian_bump_images(k1, batch, IMG)
        t = jax.random.randint(k2, (batch,), 0, T)
        eps = jax.random.normal(k3, x0.shape)
        xt = sched.q_sample(x0, t, eps)

        def loss(p):
            pred = unet_apply(p, xt, t.astype(jnp.float32), cfg)
            return jnp.mean((pred - eps) ** 2)

        l, g = jax.value_and_grad(loss)(params)
        params_, opt_, _ = adam_update(g, opt, params, acfg)
        return params_, opt_, l

    t0 = time.time()
    for i in range(steps):
        key, k = jax.random.split(key)
        params, opt, l = step(params, opt, k)
        if i % 100 == 0:
            log(f"  ddim-train step {i}: loss={float(l):.4f} "
                f"({time.time() - t0:.0f}s)")
    log(f"  ddim-train done: loss={float(l):.4f}")
    return params


def get_tiny_ddim(retrain: bool = False, steps: int = 400, log=print):
    """Returns (params, cfg, sched); trains + caches on first call."""
    cfg = tiny_ddim(IMG)
    sched = make_schedule("linear", T)
    if not retrain and os.path.exists(CACHE):
        data = np.load(CACHE)
        flat = {k: jnp.asarray(v) for k, v in data.items()}
        return unflatten_paths(flat), cfg, sched
    params = train_tiny_ddim(steps=steps, log=log)
    os.makedirs(os.path.dirname(CACHE), exist_ok=True)
    np.savez(CACHE, **{k: np.asarray(v)
                       for k, v in flatten_paths(params).items()})
    return params, cfg, sched


def timer(fn, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median wall-time per call in microseconds (blocks on results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)


def timer_interleaved(fns, argss, warmup: int = 2,
                      iters: int = 20) -> list[float]:
    """Best wall-time (us) per function, measured round-robin.

    Each iteration times every function back to back, so host-load drift
    lands on all of them equally and the *ratios* between the returned
    values are meaningful — rows timed minutes apart by ``timer`` are
    not comparable at the couple-percent level on a shared host.

    The per-slot *minimum* is reported: wall-clock can only be inflated
    by interference, never deflated, so the fastest of N round-robin
    iterations is the estimate of uncontended cost least distorted by
    the load spikes a shared host mixes into medians.
    """
    for fn, args in zip(fns, argss):
        for _ in range(warmup):
            jax.block_until_ready(fn(*args))
    times = [[] for _ in fns]
    for _ in range(iters):
        for slot, (fn, args) in enumerate(zip(fns, argss)):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            times[slot].append(time.perf_counter() - t0)
    return [float(np.min(t) * 1e6) for t in times]
