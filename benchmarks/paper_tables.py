"""Paper-table benchmarks (Tables 1/2/4/5/7, Figs. 3/4) at reduced scale.

No FID on-box (no datasets / inception net); the quality proxy is the
**final-image MSE vs the FP model** plus the per-step denoising gap —
the exact quantities Fig. 3 defines and the fine-tuning optimizes. Each
function returns rows of (name, value, derived-info) and asserts the
paper's *direction* where it claims one.
"""
from __future__ import annotations

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import get_tiny_ddim
from repro.core import msfp
from repro.core.talora import TALoRAConfig
from repro.diffusion.pipeline import (build_calibration_set,
                                      quantize_diffusion)
from repro.quant.search import (search_int_affine, search_signed_fp,
                                search_signed_fp as _ss,
                                search_unsigned_fp)
from repro.train.finetune import FinetuneConfig, eval_denoising_gap, finetune

TALORA = TALoRAConfig(hub_size=2, rank=8, t_emb_dim=128, router_hidden=64)
KEY = jax.random.PRNGKey(42)


def _bundle(params, cfg, sched, calib, mode, bits=4):
    return quantize_diffusion(params, cfg, sched, KEY, bits_w=bits,
                              bits_a=bits, mode=mode, calib=calib,
                              talora_cfg=TALORA)


def _ft(bundle, *, loss_mode="dfa", router_mode="learned", epochs=6):
    ft = FinetuneConfig(steps_per_epoch=10, epochs=epochs, batch=8,
                        loss_mode=loss_mode, router_mode=router_mode)
    bundle, _ = finetune(bundle, ft)
    return eval_denoising_gap(bundle, ft, jax.random.PRNGKey(9), steps=10)


def table4_ablation(log=print) -> list[dict]:
    """Table 4: baseline -> +MSFP -> +TALoRA -> +DFA -> all (FID proxy)."""
    params, cfg, sched = get_tiny_ddim(log=log)
    calib = build_calibration_set(params, cfg, sched, KEY, n_samples=8,
                                  steps=10, batch=4)
    rows = []

    def run(name, mode, loss_mode, router_mode):
        b = _bundle(params, cfg, sched, calib, mode)
        m = _ft(b, loss_mode=loss_mode, router_mode=router_mode)
        rows.append({"config": name, "final_image_mse": m["final_image_mse"],
                     "mean_step_gap": m["mean_step_gap"]})
        log(f"  {name:28s} final_mse={m['final_image_mse']:.5f} "
            f"step_gap={m['mean_step_gap']:.6f}")

    run("baseline (signed+1LoRA)", "signed", "plain", "single")
    run("+MSFP", "msfp", "plain", "single")
    run("+TALoRA", "signed", "plain", "learned")
    run("+MSFP+DFA", "msfp", "dfa", "single")
    run("+MSFP+TALoRA", "msfp", "plain", "learned")
    run("+MSFP+TALoRA+DFA (ours)", "msfp", "dfa", "learned")
    return rows


def table1_lora_alloc(log=print) -> list[dict]:
    """Table 1: dual-LoRA allocation strategies (split beats random)."""
    params, cfg, sched = get_tiny_ddim(log=log)
    calib = build_calibration_set(params, cfg, sched, KEY, n_samples=8,
                                  steps=10, batch=4)
    rows = []
    for name, mode in [("single-LoRA", "single"),
                       ("dual-LoRA split-half", "split"),
                       ("dual-LoRA random", "random"),
                       ("TALoRA learned router", "learned")]:
        b = _bundle(params, cfg, sched, calib, "msfp")
        m = _ft(b, router_mode=mode)
        rows.append({"alloc": name, "final_image_mse": m["final_image_mse"]})
        log(f"  {name:24s} final_mse={m['final_image_mse']:.5f}")
    return rows


def table7_fp_vs_int(log=print) -> list[dict]:
    """Table 7 / App. D: PTQ-only (no finetune) MSFP vs signed-FP vs INT."""
    params, cfg, sched = get_tiny_ddim(log=log)
    calib = build_calibration_set(params, cfg, sched, KEY, n_samples=8,
                                  steps=10, batch=4)
    rows = []
    for name, mode, bits in [("INT W4A4", "int", 4),
                             ("signed FP W4A4", "signed", 4),
                             ("MSFP W4A4 (ours)", "msfp", 4),
                             ("INT W6A6", "int", 6),
                             ("MSFP W6A6 (ours)", "msfp", 6)]:
        b = _bundle(params, cfg, sched, calib, mode, bits)
        ft = FinetuneConfig(steps_per_epoch=10, epochs=0)
        m = eval_denoising_gap(b, ft, jax.random.PRNGKey(9), steps=10)
        rows.append({"method": name, "final_image_mse": m["final_image_mse"],
                     "mean_eps_mse": m["mean_eps_mse"]})
        log(f"  {name:20s} final_mse={m['final_image_mse']:.5f} "
            f"eps_mse={m['mean_eps_mse']:.6f}")
    return rows


def table5_search_space(log=print) -> list[dict]:
    """Table 5: weight-maxval search-space choices (weight-MSE proxy)."""
    params, cfg, sched = get_tiny_ddim(log=log)
    from repro.common.tree import flatten_paths
    ws = [v for k, v in flatten_paths(params).items()
          if k.endswith("/w")][:12]
    spaces = {"[0, m0]": (0.0, 1.0), "[0, 2m0]": (0.0, 2.0),
              "[0.6m0, 2m0]": (0.6, 2.0), "[0.8m0, 2m0]": (0.8, 2.0),
              "[m0, 2m0]": (1.0, 2.0)}
    rows = []
    for name, (lo, hi) in spaces.items():
        mses = []
        for w in ws:
            m0 = float(jnp.max(jnp.abs(w)))
            grid = np.linspace(max(lo * m0, 1e-6), hi * m0, 60)
            r = search_signed_fp(np.asarray(w), 4, maxval_grid=grid)
            mses.append(r.mse)
        rows.append({"space": name, "mean_weight_mse": float(np.mean(mses))})
        log(f"  {name:14s} mean weight MSE {np.mean(mses):.3e}")
    return rows


def fig3_loss_alignment(log=print) -> dict:
    """Fig. 3: gamma_t-weighted eps-loss tracks the true denoising gap."""
    params, cfg, sched = get_tiny_ddim(log=log)
    calib = build_calibration_set(params, cfg, sched, KEY, n_samples=8,
                                  steps=10, batch=4)
    b = _bundle(params, cfg, sched, calib, "msfp")
    ft = FinetuneConfig(steps_per_epoch=10, epochs=0)
    m = eval_denoising_gap(b, ft, jax.random.PRNGKey(5), steps=10)
    eps_mse = np.asarray(m["eps_mses"])
    gaps = np.asarray(m["step_gaps"])
    from repro.diffusion.schedule import sample_timesteps
    seq = sample_timesteps(sched.T, 10)
    gam = np.asarray(sched.gamma())[seq]
    plain, aligned = eps_mse, eps_mse * gam

    def corr(a, b):
        if a.std() < 1e-12 or b.std() < 1e-12:
            return 0.0
        return float(np.corrcoef(a, b)[0, 1])

    out = {"corr_plain_vs_gap": corr(plain, gaps),
           "corr_dfa_vs_gap": corr(aligned, gaps)}
    log(f"  corr(eps_mse, gap)={out['corr_plain_vs_gap']:.3f}  "
        f"corr(gamma*eps_mse, gap)={out['corr_dfa_vs_gap']:.3f}")
    return out


def fig4_aal_strategies(log=print) -> dict:
    """Fig. 4: per-AAL activation MSE under the four quantizer strategies;

    the paper claims unsigned+zp improves >95% of AALs vs signed."""
    params, cfg, sched = get_tiny_ddim(log=log)
    from repro.diffusion.pipeline import calibrate_activations
    calib = build_calibration_set(params, cfg, sched, KEY, n_samples=8,
                                  steps=10, batch=4)
    db = calibrate_activations(params, cfg, calib)
    classes = db.classify()
    aals = [n for n, a in classes.items() if a]
    improved_u_zp, improved_u, improved_s_zp = 0, 0, 0
    for n in aals:
        x = db.sites[n].samples
        m_s = search_signed_fp(x, 4).mse
        m_u = search_unsigned_fp(x, 4, with_zero_point=False).mse
        m_uz = search_unsigned_fp(x, 4, with_zero_point=True).mse
        best_szp = min(search_signed_fp(x - zp, 4).mse
                       for zp in np.linspace(-0.3, 0, 4))
        improved_u_zp += m_uz < m_s
        improved_u += m_u < m_s
        improved_s_zp += best_szp < m_s
    n = max(len(aals), 1)
    out = {"n_aals": len(aals),
           "frac_improved_unsigned_zp": improved_u_zp / n,
           "frac_improved_unsigned_nozp": improved_u / n,
           "frac_improved_signed_zp": improved_s_zp / n}
    log(f"  AALs={len(aals)}  unsigned+zp improves {out['frac_improved_unsigned_zp']:.0%}"
        f"  unsigned(no zp) {out['frac_improved_unsigned_nozp']:.0%}"
        f"  signed+zp {out['frac_improved_signed_zp']:.0%}")
    return out
