"""Fleet benchmark: placement-policy × replica-count sweep.

Runs ``deadline_mix`` through a ``FleetRouter`` at 1/2/3 replicas under
every placement policy, each replica on its *own* ``SimClock`` service
axis with ``build_s`` charging cold weight-bank builds — the
machine-independent setup where placement quality shows up in pooled
bank hit rate and goodput instead of wall noise. Rows follow the
kernel-bench conventions (name, us_per_call, derived): ``us_per_call``
is wall time per served request (router + scheduler overhead; compute
is stubbed), ``derived`` carries hit rate / goodput / builds / the
placement histogram.

The fixture isolates *placement* dynamics: engines short-circuit the
UNet (the packed-path numerics are pinned elsewhere) and the bank uses
a tiny param tree with an injected per-timestep segmentation — the
adversarial regime for an LRU bank (every denoising step is a segment
switch, the cache cap sits well below a trajectory's working set).
``steps_jitter=4`` gives five step families, coprime with both swept
replica counts, so round-robin cannot partition the families by
accident — what round-robin duplicates across replicas,
segment-affinity amortizes on the replica already holding the segment.
Affinity beats round-robin on BOTH pooled hit rate and goodput at 2 and
3 replicas; the r=1 row is the degenerate baseline every policy
collapses to.

Everything is deterministic: simulated clocks, sync builds, fixed
seeds — two invocations emit identical derived fields.
"""
from __future__ import annotations

import dataclasses
import time

import jax.numpy as jnp
import numpy as np

from repro.common.tree import flatten_paths
from repro.configs.diffusion_presets import tiny_ddim
from repro.diffusion.schedule import make_schedule
from repro.launch.serve_diffusion import outcome_digest
from repro.serving import (DiffusionServingEngine, WeightBank,
                           default_serving_plan)
from repro.serving.fleet import PLACEMENTS, FleetRouter
from repro.serving.traffic import (MetricsCollector, get_scenario,
                                   run_scenario)
from repro.serving.traffic.sim import SimClock

T = 50
BANK_CAP = 6        # well under the ~10-14 segment trajectory working set
BUILD_S = 0.6       # simulated merge+pack charge per cold build
N_REQUESTS = 20
RATE = 6.0
STEPS_JITTER = 4    # 5 step families; coprime with 2 and 3 replicas
REPLICAS = (1, 2, 3)


def _bench_bank():
    """Tiny bank with a *per-timestep* segmentation injected through the
    WeightBank signatures seam: 50 segments over [0, 50) so every
    denoising step is a segment switch — maximal LRU pressure."""
    params = {"l0": {"w": jnp.ones((4, 4))}}
    plan = default_serving_plan(flatten_paths(params))
    return WeightBank(params, plan, {}, None, None, T, max_cached=BANK_CAP,
                      signatures=np.arange(T, dtype=np.int32)[:, None])


def _fleet(placement: str, n_replicas: int) -> FleetRouter:
    sched = make_schedule("linear", T)
    fleet = FleetRouter(placement=placement, max_idle_sleep=0.0)
    for _ in range(n_replicas):
        sim = SimClock(build_s=BUILD_S)
        engine = DiffusionServingEngine(
            tiny_ddim(4), sched, _bench_bank(), max_batch=4,
            apply_fn=lambda params, x, tb, y, ctx: 0.1 * x,
            now_fn=sim.now, max_idle_sleep=0.0)
        sim.attach(engine)
        fleet.add_replica(engine)
    return fleet


def _scenario():
    scn = get_scenario("deadline_mix")
    return dataclasses.replace(
        scn, n_requests=N_REQUESTS, max_batch=4,
        mix=dataclasses.replace(scn.mix, steps_jitter=STEPS_JITTER),
        gen_kw=(("rate", RATE),))


def rows(log=print) -> list[dict]:
    out = []
    scn = _scenario()
    for n_replicas in REPLICAS:
        # one replica degenerates every policy to the same placement —
        # a single baseline row instead of three identical ones
        policies = PLACEMENTS if n_replicas > 1 else ("round_robin",)
        scores = {}
        for placement in policies:
            fleet = _fleet(placement, n_replicas)
            collector = MetricsCollector()
            t0 = time.perf_counter()
            summary = run_scenario(scn, fleet, seed=0, collector=collector)
            wall_us = (time.perf_counter() - t0) * 1e6
            served = max(summary["requests"] + summary["expired"], 1)
            agg = fleet.stats()["aggregate"]
            for rep in fleet.replicas:
                b = rep.bank
                assert (b.builds + b.build_failures
                        == b.misses + b.prefetches), rep.name
            scores[placement] = (agg["bank_hit_rate"],
                                 summary["goodput_frac"])
            derived = (
                f"hit_rate {agg['bank_hit_rate']:.3f}; "
                f"goodput {summary['goodput_frac']:.3f}; "
                f"{agg['bank_builds']} builds, "
                f"{summary['expired']} expired; "
                f"placements {agg['placements']}; "
                f"reasons {agg['placement_reasons']}; "
                f"sim duration {summary['duration_s']:.2f}s; "
                f"digest {outcome_digest(fleet.results)}")
            row = {"name": f"fleet_{scn.name}_{placement}_r{n_replicas}",
                   "us_per_call": wall_us / served,
                   "derived": derived}
            log(f"{row['name']},{row['us_per_call']:.0f},{derived}")
            out.append(row)
        if n_replicas > 1:
            # the reason this subsystem exists — fail loudly if the
            # regime regresses rather than publishing stale claims
            aff, rr = scores["segment_affinity"], scores["round_robin"]
            assert aff[0] > rr[0] and aff[1] > rr[1], (
                f"segment_affinity {aff} does not beat round_robin {rr} "
                f"at r={n_replicas}")
    return out
