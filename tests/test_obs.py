"""Observability layer: tracer invariants, registry, determinism, threads.

What this suite pins down, in order:

  * the metrics registry's instrument semantics (get-or-create by
    (name, labels), kind collisions rejected, exposition format),
  * span tracer invariants — nesting, deterministic clock-bound
    timestamps, ring-buffer overflow accounting, export round-trips,
  * the engine integration: a traced run emits the full span taxonomy
    (request lifecycle, ticks with scheduler decisions, bank builds,
    forwards) and — the core contract — the per-request outcomes are
    bit-identical with obs on and off (tracing reads, never perturbs),
  * thread safety: bank-build spans arriving from 4 churning threads
    never tear the buffer and reconcile with the bank's build counter,
  * kernel-route profiling: per-route counts reconcile with the ops
    dispatch rules the route-forcing tests in test_kernels pin,
  * MetricsCollector retention: capped buffers compact instead of drop —
    summary totals stay exact, and the scheduler/bank counters ride in.
"""
import json
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests._serving_fixtures import (SCHED, T,
                                     multi_segment_bank as
                                     _multi_segment_bank,
                                     single_segment_bank as
                                     _single_segment_bank)

from repro.configs.diffusion_presets import tiny_ddim
from repro.core.qmodule import pack_weight
from repro.kernels import ops
from repro.quant.fakequant import KIND_FP_SIGNED, QuantizerParams
from repro.serving import DiffusionServingEngine, VirtualClock
from repro.serving.obs import NULL_OBS, Observability, SpanTracer
from repro.serving.obs.metrics import MetricsRegistry
from repro.serving.traffic import load_trace, submit_trace
from repro.serving.traffic.metrics import MetricsCollector, _Event
from repro.serving.traffic.scenarios import resolve_trace_path

GOLDEN = "tests/data/golden_trace.jsonl"


def _engine(obs=None, bank=None, **kw):
    return DiffusionServingEngine(
        tiny_ddim(4), SCHED, bank or _single_segment_bank(),
        apply_fn=lambda params, x, tb, y, ctx: 0.1 * x, obs=obs, **kw)


# ---------------------------------------------------------------------------
# Metrics registry.
# ---------------------------------------------------------------------------


def test_registry_instruments_and_labels():
    m = MetricsRegistry()
    c = m.counter("requests_total", help="n requests", route="a")
    c.inc()
    c.inc(2)
    assert m.counter("requests_total", route="a") is c      # get-or-create
    assert m.counter("requests_total", route="b") is not c  # new label set
    m.set("queue_depth", 7)
    h = m.histogram("lat_s")
    h.observe(0.5)
    h.observe(1.5)
    snap = m.snapshot()
    assert snap['requests_total{route="a"}'] == 3
    assert snap['requests_total{route="b"}'] == 0
    assert snap["queue_depth"] == 7
    assert snap["lat_s_count"] == 2
    assert snap["lat_s_sum"] == pytest.approx(2.0)
    assert snap["lat_s_mean"] == pytest.approx(1.0)


def test_registry_rejects_kind_collisions():
    m = MetricsRegistry()
    m.counter("x")
    with pytest.raises(ValueError):
        m.gauge("x")


def test_registry_text_exposition():
    m = MetricsRegistry()
    m.counter("calls_total", help="total calls", op="mm").inc(4)
    m.set("depth", 2)
    h = m.histogram("dur_s", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    h.observe(5.0)                 # overflow bucket
    text = m.to_text()
    assert "# TYPE calls_total counter" in text
    assert '# HELP calls_total total calls' in text
    assert 'calls_total{op="mm"} 4' in text
    assert 'dur_s_bucket{le="0.1"} 1' in text
    assert 'dur_s_bucket{le="1.0"} 2' in text      # cumulative
    assert 'dur_s_bucket{le="+Inf"} 3' in text
    assert "dur_s_count 3" in text


# ---------------------------------------------------------------------------
# Span tracer.
# ---------------------------------------------------------------------------


def test_tracer_nesting_and_deterministic_clock():
    t = [0.0]
    tr = SpanTracer(clock=lambda: t[0])
    outer = tr.begin("tick", args={"n": 1})
    t[0] = 1.0
    with tr.span("forward", cat="engine") as sp:
        sp.set("rows", 4)
        t[0] = 3.0
    t[0] = 5.0
    tr.end(outer)
    evs = tr.events()
    assert [e["name"] for e in evs] == ["forward", "tick"]  # inner ends first
    fwd, tick = evs
    assert tick["ts"] == 0.0 and tick["dur"] == 5e6         # us
    assert fwd["ts"] == 1e6 and fwd["dur"] == 2e6
    assert fwd["args"]["rows"] == 4
    # nested span lies inside its parent
    assert (tick["ts"] <= fwd["ts"]
            and fwd["ts"] + fwd["dur"] <= tick["ts"] + tick["dur"])


def test_tracer_end_tolerates_leaked_inner_span():
    tr = SpanTracer(clock=lambda: 0.0)
    outer = tr.begin("outer")
    tr.begin("leaked")              # never ended (error path)
    tr.end(outer)                   # must not corrupt later nesting
    nxt = tr.begin("next")
    tr.end(nxt)
    assert [e["name"] for e in tr.events()] == ["outer", "next"]


def test_tracer_ring_buffer_drops_oldest():
    tr = SpanTracer(clock=lambda: 0.0, max_events=3)
    for i in range(5):
        tr.instant(f"i{i}")
    assert tr.dropped == 2
    assert [e["name"] for e in tr.events()] == ["i2", "i3", "i4"]


def test_tracer_export_round_trips(tmp_path):
    tr = SpanTracer(clock=lambda: 1.0)
    tr.async_begin("request", 7, args={"steps": 3})
    tr.instant("admit", cat="sched")
    tr.counter("queue", {"pending": 2})
    tr.async_end("request", 7)
    chrome = tmp_path / "t.json"
    jsonl = tmp_path / "t.jsonl"
    n1 = tr.export(str(chrome))
    n2 = tr.export(str(jsonl))
    doc = json.loads(chrome.read_text())
    assert {e["ph"] for e in doc["traceEvents"]} == {"M", "b", "i", "C", "e"}
    lines = [json.loads(ln) for ln in jsonl.read_text().splitlines()]
    assert n1 == n2 == len(lines) == len(doc["traceEvents"])
    b = next(e for e in lines if e["ph"] == "b")
    assert b["id"] == "7" and b["args"]["steps"] == 3


def test_null_obs_is_inert():
    assert not NULL_OBS.enabled and not NULL_OBS.tracer.enabled
    assert NULL_OBS.tracer.begin("x") is None
    NULL_OBS.tracer.end(None)
    NULL_OBS.tracer.instant("x")
    NULL_OBS.tracer.async_begin("x", 1)
    assert NULL_OBS.tracer.events() == []
    assert NULL_OBS.kernel_profiler is None


# ---------------------------------------------------------------------------
# Engine integration: taxonomy + digest invariance.
# ---------------------------------------------------------------------------


def _replay_golden(obs):
    reqs, _ = load_trace(resolve_trace_path(GOLDEN))
    eng = _engine(obs=obs, bank=_multi_segment_bank(), max_batch=2,
                  clock=VirtualClock())
    submit_trace(eng, reqs)
    res = eng.run()
    return eng, {rid: (rs.n_evals, np.asarray(rs.x0).tobytes())
                 for rid, rs in res.items()}


def test_traced_golden_replay_has_full_taxonomy_and_identical_outcomes():
    obs = Observability()
    eng, traced_out = _replay_golden(obs)
    _, plain_out = _replay_golden(None)
    assert traced_out == plain_out        # tracing never perturbs outcomes

    evs = obs.tracer.events()
    by_name = {}
    for e in evs:
        by_name.setdefault(e["name"], []).append(e)
    # request lifecycle: one async begin + end per request, eval instants
    begins = [e for e in by_name["request"] if e["ph"] == "b"]
    ends = [e for e in by_name["request"] if e["ph"] == "e"]
    assert len(begins) == len(ends) == len(traced_out)
    assert {e["id"] for e in begins} == {str(r) for r in traced_out}
    assert all(e["args"]["outcome"] == "complete" for e in ends)
    assert len(by_name["eval"]) == sum(n for n, _ in traced_out.values())
    # engine ticks carry the scheduler decision annotations
    ticks = by_name["tick"]
    busy = [e for e in ticks if not e["args"].get("idle")]
    assert busy and all("seg" in e["args"] and "members" in e["args"]
                        and e["args"]["policy"] == "fifo" for e in busy)
    assert eng.tick_count == len(ticks)
    # ticks on the engine thread never overlap, and each forward /
    # bank_fetch nests inside some tick
    spans = sorted((e for e in ticks), key=lambda e: e["ts"])
    for a, b in zip(spans, spans[1:]):
        assert a["ts"] + a["dur"] <= b["ts"]
    for name in ("forward", "bank_fetch"):
        for e in by_name[name]:
            assert any(t["ts"] <= e["ts"]
                       and e["ts"] + e["dur"] <= t["ts"] + t["dur"]
                       for t in ticks), f"{name} span outside every tick"
    # bank builds + scheduler selects + counter tracks present
    assert len(by_name["bank_build"]) == eng.bank.builds > 0
    assert len(by_name["select"]) == len(busy)
    assert {e["cat"] for e in evs} >= {"request", "engine", "bank",
                                       "sched", "metrics"}
    # virtual clock => deterministic timestamps: replay again, same trace
    obs2 = Observability()
    _replay_golden(obs2)
    strip = [dict(e) for e in obs2.tracer.events()]
    assert strip == evs


def test_obs_registry_tracks_engine_counters():
    obs = Observability()
    eng, _ = _replay_golden(obs)
    obs.finalize(eng)
    snap = obs.metrics.snapshot()
    assert snap["engine_ticks"] == eng.tick_count
    assert snap["engine_finished"] == eng.n_finished
    assert snap["bank_builds"] == eng.bank.builds
    assert snap["sched_preemptions"] == eng.batcher.preemptions
    assert snap["engine_forward_seconds_count"] > 0
    assert snap["trace_events"] == len(obs.tracer.events())
    text = obs.metrics.to_text()
    assert "engine_ticks" in text and "bank_builds" in text


# ---------------------------------------------------------------------------
# Thread safety: spans from the prefetch worker under churn.
# ---------------------------------------------------------------------------


def test_bank_spans_from_threaded_churn_reconcile():
    # The churn runs with lockcheck's order-tracking locks installed in
    # both the bank and the whole obs stack: beyond "no torn spans",
    # this pins that no thread ever held bank._lock while taking a
    # tracer/metrics lock (the deadlock precondition), not just that the
    # deadlock didn't happen to fire.
    from tools.analysis.lockcheck import LockMonitor, serving_discipline
    mon = serving_discipline(LockMonitor())
    bank = _multi_segment_bank(lock_factory=mon)
    bank.max_cached = bank.n_segments
    obs = Observability(lock_factory=mon)
    bank.obs = obs
    segs = list(range(bank.n_segments))
    errs = []

    def worker(wid):
        rng = np.random.default_rng(wid)
        try:
            for _ in range(30):
                seg = int(rng.choice(segs))
                if rng.random() < 0.5:
                    bank.prefetch(seg, block=bool(rng.random() < 0.3))
                else:
                    bank.params_for_segment(seg)
        except Exception as e:
            errs.append(e)

    threads = [threading.Thread(target=worker, args=(w,)) for w in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    bank.drain()
    assert not errs
    evs = obs.tracer.events()
    builds = [e for e in evs if e["name"] == "bank_build"]
    # one completed build span per counted build, none torn
    assert len(builds) == bank.builds == len(segs)
    for e in builds:
        assert e["ph"] == "X" and e["dur"] >= 0 and "seg" in e["args"]
        json.dumps(e)                      # fully serializable, not torn
    # spans arrived from >1 thread; metadata names every tid
    tids = {e["tid"] for e in evs}
    assert len(tids) >= 2
    meta = {m["tid"] for m in obs.tracer._metadata_events()}
    assert tids <= meta
    # the instrumented locks actually saw the churn, and the order
    # discipline held throughout
    counts = mon.acquire_counts()
    assert counts.get("bank._lock", 0) > 0
    assert counts.get("tracer._lock", 0) > 0
    mon.assert_clean()


# ---------------------------------------------------------------------------
# Kernel-route profiling reconciles with ops dispatch.
# ---------------------------------------------------------------------------


@pytest.fixture
def clean_force():
    old = ops.FORCE
    yield
    ops.FORCE = old


def _packed(rng):
    w = jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)
    qp = QuantizerParams(KIND_FP_SIGNED, 2, 1, 4,
                         jnp.float32(jnp.abs(w).max()))
    return pack_weight(w, qp)


def test_kernel_route_counts_reconcile_with_dispatch(clean_force):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((4, 8)), jnp.float32)
    qp = QuantizerParams(KIND_FP_SIGNED, 2, 1, 4, jnp.float32(3.0))
    pw = _packed(rng)
    obs = Observability()
    with obs.kernel_profiler:
        ops.FORCE = "interpret"            # pallas kernels, interpret mode
        ops.msfp_quantize(x, qp)
        ops.w4_matmul(x, pw)
        ops.FORCE = "xla"                  # pure reference oracles
        ops.msfp_quantize(x, qp)
        ops.FORCE = None                   # CPU default: fast XLA serving
        ops.msfp_quantize(x, qp)
    assert ops.PROFILER is None            # context manager uninstalls
    counts = obs.kernel_profiler.route_counts()
    assert counts == {"msfp_quantize:interpret": 1,
                      "w4_matmul:interpret": 1,
                      "msfp_quantize:ref": 1,
                      "msfp_quantize:xla_fast": 1}
    snap = obs.metrics.snapshot()
    # eager calls are timed into the per-route histogram
    key = 'kernel_call_seconds{op="msfp_quantize",route="interpret"}_count'
    assert snap[key] == 1
    assert snap['kernel_calls_total{mode="eager",op="msfp_quantize",'
                'route="xla_fast"}'] == 1


def test_kernel_profiler_counts_traced_calls_once_per_compile(clean_force):
    ops.FORCE = "xla"
    qp = QuantizerParams(KIND_FP_SIGNED, 2, 1, 4, jnp.float32(3.0))
    x = jnp.ones((4, 8), jnp.float32)
    obs = Observability()
    with obs.kernel_profiler:
        f = jax.jit(lambda v: ops.msfp_quantize(v, qp))
        f(x)
        f(x)                               # cache hit: no re-trace
    assert obs.kernel_profiler.route_counts() == {"msfp_quantize:ref": 1}
    snap = obs.metrics.snapshot()
    assert snap['kernel_calls_total{mode="traced",op="msfp_quantize",'
                'route="ref"}'] == 1
    # traced calls are marked, not timed (timing a trace is meaningless)
    assert not any(k.startswith("kernel_call_seconds") for k in snap)
    marks = [e for e in obs.tracer.events() if e["cat"] == "kernel"]
    assert len(marks) == 1 and marks[0]["args"]["traced"]


# ---------------------------------------------------------------------------
# MetricsCollector retention + folded counters.
# ---------------------------------------------------------------------------


def _feed(col, n):
    for i in range(n):
        col.events.append(_Event(arrival=float(i), finished=i + 0.5,
                                 latency=0.5, met_deadline=(i % 3 != 0),
                                 expired=(i % 7 == 0)))
        col.ticks.append((float(i), i % 5, i % 3, 0, 0))


def test_retention_cap_keeps_summary_totals_exact():
    capped = MetricsCollector(max_events=6, max_ticks=4)
    unbounded = MetricsCollector(max_events=None, max_ticks=None)
    _feed(capped, 20)
    _feed(unbounded, 20)
    assert len(capped.events) == 6 and len(capped.ticks) == 4
    s_c, s_u = capped.summary(), unbounded.summary()
    for k in ("requests", "expired", "deadline_misses", "duration_s",
              "throughput_rps", "goodput_rps", "goodput_frac",
              "peak_queue_depth", "mean_inflight"):
        assert s_c[k] == pytest.approx(s_u[k]), k
    assert s_c["compacted_events"] == 14 and s_c["compacted_ticks"] == 16
    assert s_u["compacted_events"] == 0
    # percentiles are windowed — still well-formed over the retained tail
    assert s_c["p95_s"] == 0.5


def test_summary_folds_scheduler_and_bank_counters():
    col = MetricsCollector()
    s = col.summary()                      # unattached: zero defaults
    assert (s["preemptions"], s["deadline_saves"], s["bank_builds"],
            s["bank_build_joins"], s["prefetch_hits"]) == (0, 0, 0, 0, 0)

    eng = _engine(bank=_multi_segment_bank(), max_batch=2)
    col.attach(eng)
    for i in range(3):
        eng.submit(steps=3 + i % 2, seed=i)
    eng.run()
    s = col.summary()
    assert s["bank_builds"] == eng.bank.builds > 0
    assert s["prefetch_hits"] == eng.bank.prefetch_hits
    assert s["preemptions"] == eng.batcher.preemptions
    assert s["requests"] == 3
