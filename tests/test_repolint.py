"""repolint: every rule fires on a violating fixture, every allowlist
mechanism silences it, and the committed baseline tracks reality.

Structure per rule: one minimal snippet that MUST produce exactly the
expected violation (negative fixture — proves the rule can fire at all,
so a rule broken into a no-op fails here, not silently in CI), one
snippet where the violation is allowlisted inline, and clean variants
that must NOT fire (precision — the rule earns its place only if the
sanctioned patterns stay unflagged).

The suite ends with the two repo-level gates: the committed
``repolint.toml`` baseline must match ``--all-files`` output *exactly*
(new debt and paid-off debt both fail), and the CLI module must exit 0
on the tree as committed.
"""
import subprocess
import sys
import textwrap
from pathlib import Path

from tools.analysis.framework import (Config, baseline_split, collect_files,
                                      lint_source, load_config,
                                      parse_toml_subset, run_files,
                                      scan_disables)

REPO_ROOT = Path(__file__).resolve().parents[1]


def _lint(src, path, rule=None, config=None):
    res = lint_source(textwrap.dedent(src), path, config)
    if rule is None:
        return res.violations
    return [v for v in res.violations if v.rule == rule]


# ---------------------------------------------------------------------------
# clock-discipline
# ---------------------------------------------------------------------------


def test_clock_discipline_fires():
    vs = _lint("""
        import time
        def admit(self):
            return time.time()
        """, "src/repro/serving/foo.py", "clock-discipline")
    assert len(vs) == 1 and "time.time" in vs[0].message
    assert vs[0].severity == "error"


def test_clock_discipline_argless_datetime_fires():
    vs = _lint("""
        import datetime
        def stamp():
            return datetime.datetime.now()
        """, "src/repro/serving/foo.py", "clock-discipline")
    assert len(vs) == 1


def test_clock_discipline_allowlisted_inline():
    vs = _lint("""
        import time
        def admit(self):
            return time.time()  # repolint: disable=clock-discipline
        """, "src/repro/serving/foo.py", "clock-discipline")
    assert vs == []


def test_clock_discipline_exempts_clock_classes_and_monotonic():
    vs = _lint("""
        import time
        class VirtualClock:
            def now(self):
                return time.time()
        def tick(self):
            return time.monotonic()
        """, "src/repro/serving/engine.py", "clock-discipline")
    assert vs == []


def test_clock_discipline_out_of_scope_path_ignored():
    vs = _lint("import time\nt = time.time()\n",
               "src/repro/train/loop.py", "clock-discipline")
    assert vs == []


# ---------------------------------------------------------------------------
# lock-discipline
# ---------------------------------------------------------------------------


def test_lock_discipline_fires_on_span_under_lock():
    vs = _lint("""
        class Bank:
            def build(self):
                with self._lock:
                    self.obs.tracer.instant("bank_build")
        """, "src/repro/serving/weight_bank.py", "lock-discipline")
    assert len(vs) == 1 and "_lock" in vs[0].message


def test_lock_discipline_fires_on_callback_under_lock():
    vs = _lint("""
        class Bank:
            def pop(self, cb):
                with self._lock:
                    cb(self.item)
        """, "src/repro/serving/weight_bank.py", "lock-discipline")
    assert len(vs) == 1


def test_lock_discipline_allowlisted_inline():
    vs = _lint("""
        class Bank:
            def build(self):
                with self._lock:
                    # repolint: disable=lock-discipline
                    self.obs.tracer.instant("bank_build")
        """, "src/repro/serving/weight_bank.py", "lock-discipline")
    assert vs == []


def test_lock_discipline_allows_deferred_and_after_release():
    vs = _lint("""
        class Bank:
            def build(self):
                with self._lock:
                    self._executor.submit(lambda: self.obs.tracer.end(sp))
                    item = self.cache.pop()
                self.obs.tracer.instant("bank_build")
        """, "src/repro/serving/weight_bank.py", "lock-discipline")
    assert vs == []


# ---------------------------------------------------------------------------
# import-layering
# ---------------------------------------------------------------------------

_LAYER_CFG = Config({"layers": {"kernels": ["core", "quant"],
                                "serving": ["kernels"]}})


def test_import_layering_fires():
    vs = _lint("from repro.serving.engine import DiffusionServingEngine\n",
               "src/repro/kernels/foo.py", "import-layering", _LAYER_CFG)
    assert len(vs) == 1 and "'serving'" in vs[0].message


def test_import_layering_allowlisted_by_comment_block():
    vs = _lint("""
        # repolint: disable=import-layering — sanctioned upward edge,
        # see the layering note in repolint.toml.
        from repro.serving.engine import DiffusionServingEngine
        """, "src/repro/kernels/foo.py", "import-layering", _LAYER_CFG)
    assert vs == []


def test_import_layering_allows_declared_edges_and_self():
    vs = _lint("""
        from repro.core.qmodule import pack_weight
        from repro.quant.fakequant import QuantizerParams
        from repro.kernels import ref
        import numpy as np
        """, "src/repro/kernels/foo.py", "import-layering", _LAYER_CFG)
    assert vs == []


def test_import_layering_sublayer_resolution():
    cfg = Config({"layers": {"serving.obs": ["kernels"],
                             "serving": ["kernels", "serving.obs"]}})
    bad = _lint("from repro.serving.engine import x\n",
                "src/repro/serving/obs/tracer.py", "import-layering", cfg)
    assert len(bad) == 1  # obs must never grow an engine dependency
    ok = _lint("from repro.serving.obs import NULL_OBS\n",
               "src/repro/serving/engine.py", "import-layering", cfg)
    assert ok == []


def test_import_layering_gateway_sublayer():
    cfg = Config({"layers": {
        "serving.gateway": ["serving", "serving.traffic"],
        "serving": []}})
    # gateway sits above the engine: importing it is a declared edge...
    ok = _lint("from repro.serving.engine import x\n",
               "src/repro/serving/gateway/gateway.py", "import-layering",
               cfg)
    assert ok == []
    # ...but nothing below may import the gateway back
    bad = _lint("from repro.serving.gateway import ServingGateway\n",
                "src/repro/serving/engine.py", "import-layering", cfg)
    assert len(bad) == 1 and "serving.gateway" in bad[0].message


# ---------------------------------------------------------------------------
# tracer-purity
# ---------------------------------------------------------------------------


def test_tracer_purity_fires_in_kernel_body():
    vs = _lint("""
        def _matmul_kernel(x_ref, o_ref):
            a = x_ref[...]
            n = int(a[0, 0])
            o_ref[...] = a * n
        """, "src/repro/kernels/foo.py", "tracer-purity")
    assert len(vs) == 1 and "int()" in vs[0].message


def test_tracer_purity_fires_in_blockspec_index_map():
    vs = _lint("""
        import jax.experimental.pallas as pl
        def build(bm):
            return pl.BlockSpec((bm, 8), lambda i, j: (int(i), j))
        """, "src/repro/kernels/foo.py", "tracer-purity")
    assert len(vs) == 1 and "index map" in vs[0].message


def test_tracer_purity_allowlisted_inline():
    vs = _lint("""
        def _matmul_kernel(x_ref, o_ref):
            n = int(x_ref[0, 0])  # repolint: disable=tracer-purity
            o_ref[...] = n
        """, "src/repro/kernels/foo.py", "tracer-purity")
    assert vs == []


def test_tracer_purity_ignores_host_side_int():
    # static-shape math outside kernel bodies (conv padding etc.) and
    # untainted values inside them stay unflagged
    vs = _lint("""
        def _normalize_padding(x, pad):
            return int(pad[0]), int(x.shape[1])
        def _conv_kernel(x_ref, o_ref, *, bn):
            k = int(bn)
            o_ref[...] = x_ref[...] * k
        """, "src/repro/kernels/conv.py", "tracer-purity")
    assert vs == []


# ---------------------------------------------------------------------------
# bench-operand
# ---------------------------------------------------------------------------


def test_bench_operand_fires_on_closed_over_array():
    vs = _lint("""
        import jax
        import jax.numpy as jnp
        w = jnp.ones((128, 128))
        f = jax.jit(lambda x: x @ w)
        """, "benchmarks/foo.py", "bench-operand")
    assert len(vs) == 1 and "'w'" in vs[0].message


def test_bench_operand_fires_on_decorated_def():
    vs = _lint("""
        import jax
        import jax.numpy as jnp
        def bench():
            w = jnp.ones((8, 8)).astype(jnp.bfloat16)
            @jax.jit
            def step(x):
                return x @ w
            return step
        """, "benchmarks/foo.py", "bench-operand")
    assert len(vs) == 1


def test_bench_operand_allowlisted_inline():
    vs = _lint("""
        import jax
        import jax.numpy as jnp
        w = jnp.ones((8, 8))
        f = jax.jit(lambda x: x @ w)  # repolint: disable=bench-operand
        """, "benchmarks/foo.py", "bench-operand")
    assert vs == []


def test_bench_operand_allows_operands_and_scalar_config():
    vs = _lint("""
        import jax
        import jax.numpy as jnp
        w = jnp.ones((8, 8))
        cfg = QuantizerParams(2, 1)
        f = jax.jit(lambda x, w: (x @ w) * cfg.scale)
        out = f(jnp.zeros((8, 8)), w)
        """, "benchmarks/foo.py", "bench-operand")
    assert vs == []


# ---------------------------------------------------------------------------
# seeded-rng
# ---------------------------------------------------------------------------


def test_seeded_rng_fires_on_global_numpy():
    vs = _lint("""
        import numpy as np
        def noise(n):
            return np.random.rand(n)
        """, "src/repro/data/foo.py", "seeded-rng")
    assert len(vs) == 1 and "default_rng" in vs[0].message


def test_seeded_rng_fires_on_global_stdlib():
    vs = _lint("""
        import random
        def jitter():
            return random.random()
        """, "src/repro/data/foo.py", "seeded-rng")
    assert len(vs) == 1


def test_seeded_rng_allowlisted_inline():
    vs = _lint("""
        import numpy as np
        def noise(n):
            return np.random.rand(n)  # repolint: disable=seeded-rng
        """, "src/repro/data/foo.py", "seeded-rng")
    assert vs == []


def test_seeded_rng_allows_generators():
    vs = _lint("""
        import numpy as np
        def noise(n, seed):
            rng = np.random.default_rng(seed)
            return rng.standard_normal(n)
        """, "src/repro/data/foo.py", "seeded-rng")
    assert vs == []


# ---------------------------------------------------------------------------
# no-silent-fallback
# ---------------------------------------------------------------------------


def test_no_silent_fallback_fires():
    vs = _lint("""
        from repro.kernels import ref as _ref
        def w4_matmul(x, p):
            return _ref.w4_matmul(x, p)
        """, "src/repro/kernels/ops.py", "no-silent-fallback")
    assert len(vs) == 1 and "_dispatch" in vs[0].message


def test_no_silent_fallback_allowlisted_inline():
    vs = _lint("""
        from repro.kernels import ref as _ref
        def w4_matmul(x, p):
            return _ref.w4_matmul(x, p)  # repolint: disable=no-silent-fallback
        """, "src/repro/kernels/ops.py", "no-silent-fallback")
    assert vs == []


def test_no_silent_fallback_allows_dispatched_calls():
    vs = _lint("""
        from repro.kernels import ref as _ref
        def w4_matmul(x, p):
            return _dispatch("w4_matmul", "ref",
                             lambda: _ref.w4_matmul(x, p), x)
        """, "src/repro/kernels/ops.py", "no-silent-fallback")
    assert vs == []


# ---------------------------------------------------------------------------
# framework mechanics
# ---------------------------------------------------------------------------


def test_disable_file_silences_whole_module():
    vs = _lint("""
        # repolint: disable-file=clock-discipline
        import time
        a = time.time()
        b = time.time()
        """, "src/repro/serving/foo.py", "clock-discipline")
    assert vs == []


def test_scan_disables_trailing_and_block():
    per_line, per_file = scan_disables(
        "x = 1  # repolint: disable=rule-a\n"
        "# repolint: disable=rule-b\n"
        "# more justification text\n"
        "\n"
        "y = 2\n"
        "# repolint: disable-file=rule-c\n")
    assert per_line[1] == {"rule-a"}
    assert per_line[5] == {"rule-b"}   # carried through comments + blank
    assert per_file == {"rule-c"}


def test_toml_subset_parser():
    d = parse_toml_subset("""
        # comment
        [rules]
        clock-discipline = "warning"
        n = 3
        flag = true
        [layers]
        "serving.obs" = ["kernels",
                         "common"]  # multiline array
        """)
    assert d["rules"]["clock-discipline"] == "warning"
    assert d["rules"]["n"] == 3 and d["rules"]["flag"] is True
    assert d["layers"]["serving.obs"] == ["kernels", "common"]


def test_severity_override_and_off():
    cfg = Config({"rules": {"clock-discipline": "warning"}})
    vs = _lint("import time\nt = time.time()\n",
               "src/repro/serving/foo.py", "clock-discipline", cfg)
    assert len(vs) == 1 and vs[0].severity == "warning"
    off = Config({"rules": {"clock-discipline": "off"}})
    assert _lint("import time\nt = time.time()\n",
                 "src/repro/serving/foo.py", "clock-discipline", off) == []


def test_baseline_split_detects_drift_both_ways():
    src = "import time\nt = time.time()\n"
    res = lint_source(src, "src/repro/serving/foo.py")
    key = next(v for v in res.violations
               if v.rule == "clock-discipline").key
    # exact match: clean
    cfg = Config({"baseline": {"entries": [key]}})
    new, baselined, stale = baseline_split(res, cfg)
    assert [v.key for v in baselined] == [key] and not stale
    assert all(v.rule != "clock-discipline" for v in new)
    # stale entry (violation fixed but ledger kept): flagged
    cfg2 = Config({"baseline": {"entries": [key, "clock-discipline:gone.py:1"]}})
    _, _, stale2 = baseline_split(res, cfg2)
    assert stale2 == ["clock-discipline:gone.py:1"]
    # new violation (not in ledger): reported
    new3, _, _ = baseline_split(res, Config())
    assert key in {v.key for v in new3}


# ---------------------------------------------------------------------------
# the repo itself is clean — committed baseline matches --all-files exactly
# ---------------------------------------------------------------------------


def test_committed_baseline_matches_all_files_exactly():
    config = load_config(str(REPO_ROOT))
    files = collect_files(str(REPO_ROOT), config)
    assert len(files) > 50  # discovery actually found the tree
    result = run_files(files, str(REPO_ROOT), config)
    new, baselined, stale = baseline_split(result, config)
    errors = [v for v in new if v.severity == "error"]
    assert not errors, ("unbaselined repolint errors:\n"
                        + "\n".join(v.format() for v in errors))
    assert not stale, (f"stale baseline entries (fix landed but ledger "
                       f"kept): {stale}")


def test_cli_all_files_exits_zero():
    proc = subprocess.run(
        [sys.executable, "-m", "tools.analysis", "--all-files"],
        cwd=REPO_ROOT, capture_output=True, text=True, timeout=120)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "0 error(s)" in proc.stdout
