"""Per-arch smoke tests + the decode-vs-forward consistency invariant."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_config
from repro.models.lm import (decode_step, forward, init_caches, lm_init,
                             loss_fn, LMConfig, ATTN, SSM)

KEY = jax.random.PRNGKey(0)
B, S = 2, 16


def _inputs(cfg):
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    extra = (jax.random.normal(KEY, (B, cfg.n_img_tokens, cfg.d_vision))
             if cfg.family == "vlm" else None)
    return toks, extra


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_decode(arch):
    """(f) reduced config: one forward + one decode, shapes + no NaNs."""
    cfg = get_config(arch, smoke=True)
    p = lm_init(KEY, cfg)
    toks, extra = _inputs(cfg)
    logits = forward(p, cfg, toks, extra)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    l = loss_fn(p, cfg, toks, extra)
    assert bool(jnp.isfinite(l))
    caches = init_caches(cfg, B, S)
    lg, caches2 = decode_step(p, cfg, caches, toks[:, :1], jnp.int32(0))
    assert lg.shape == (B, 1, cfg.vocab)
    assert bool(jnp.isfinite(lg).all())
    assert jax.tree.structure(caches) == jax.tree.structure(caches2)


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "gemma3-27b", "mamba2-370m",
                                  "zamba2-2.7b", "kimi-k2-1t-a32b"])
@pytest.mark.slow
def test_arch_train_step_decreases_loss(arch):
    from repro.optim.adam import AdamConfig, adam_init, adam_update
    cfg = get_config(arch, smoke=True)
    p = lm_init(KEY, cfg)
    toks, extra = _inputs(cfg)
    acfg = AdamConfig(lr=5e-3)
    opt = adam_init(p, acfg)

    @jax.jit
    def step(p, opt):
        l, g = jax.value_and_grad(lambda p: loss_fn(p, cfg, toks, extra))(p)
        p, opt, _ = adam_update(g, opt, p, acfg)
        return p, opt, l

    losses = []
    for _ in range(5):
        p, opt, l = step(p, opt)
        losses.append(float(l))
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("name,cfg", [
    ("dense", LMConfig("t", n_layers=3, d_model=64, n_heads=4, n_kv=2,
                       d_ff=128, vocab=97, qkv_bias=True, dtype=jnp.float32,
                       q_chunk=4)),
    ("swa", LMConfig("t", n_layers=4, d_model=64, n_heads=4, n_kv=2,
                     d_ff=128, vocab=97, dtype=jnp.float32, q_chunk=4,
                     layer_pattern=((ATTN, 4, 10_000.0), (ATTN, 4, 10_000.0),
                                    (ATTN, None, 10_000.0),
                                    (ATTN, 4, 10_000.0)))),
    ("ssm", LMConfig("t", n_layers=4, d_model=64, n_heads=4, n_kv=4, d_ff=0,
                     vocab=97, family="ssm", ssm_d_state=16, ssm_headdim=16,
                     ssm_chunk=4, layer_pattern=((SSM, None, 10_000.0),),
                     dtype=jnp.float32)),
    ("hybrid", LMConfig("t", n_layers=4, d_model=64, n_heads=4, n_kv=4,
                        d_ff=64, vocab=97, family="hybrid", ssm_d_state=16,
                        ssm_headdim=16, ssm_chunk=4, mlp_kind="gelu",
                        layer_pattern=((SSM, None, 10_000.0),) * 2,
                        shared_attn_every=2, dtype=jnp.float32, q_chunk=4)),
    ("moe", LMConfig("t", n_layers=2, d_model=64, n_heads=4, n_kv=2,
                     d_ff=128, vocab=97, family="moe", n_experts=4, top_k=2,
                     moe_d_ff=32, capacity_factor=4.0, dtype=jnp.float32,
                     q_chunk=4)),
])
@pytest.mark.slow
def test_decode_matches_forward(name, cfg):
    """The strongest invariant: stepwise decode == full causal forward."""
    p = lm_init(KEY, cfg)
    toks = jax.random.randint(jax.random.PRNGKey(7), (B, 12), 0, cfg.vocab)
    full = forward(p, cfg, toks)
    caches = init_caches(cfg, B, 12)
    step = jax.jit(lambda c, t, i: decode_step(p, cfg, c, t, i))
    outs = []
    for i in range(12):
        lg, caches = step(caches, toks[:, i:i + 1], jnp.int32(i))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    err = float(jnp.abs(full - dec).max() / (jnp.abs(full).max() + 1e-9))
    assert err < 2e-2, err


@pytest.mark.slow
def test_unroll_mode_matches_scan():
    import dataclasses
    cfg = LMConfig("t", n_layers=4, d_model=32, n_heads=4, n_kv=2, d_ff=64,
                   vocab=64, dtype=jnp.float32, q_chunk=4)
    p = lm_init(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 8), 0, cfg.vocab)
    a = forward(p, cfg, toks)
    b = forward(p, dataclasses.replace(cfg, unroll=True), toks)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


@pytest.mark.slow
def test_ring_buffer_window_decode_long_context():
    """Windowed layer decoding past the window: ring cache still matches

    a full forward with the same sliding-window mask."""
    cfg = LMConfig("t", n_layers=2, d_model=32, n_heads=2, n_kv=2, d_ff=64,
                   vocab=64, dtype=jnp.float32, q_chunk=4,
                   layer_pattern=((ATTN, 4, 10_000.0),))
    p = lm_init(KEY, cfg)
    s = 12
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, s), 0, cfg.vocab)
    full = forward(p, cfg, toks)
    caches = init_caches(cfg, 1, s)  # ring size = window = 4
    step = jax.jit(lambda c, t, i: decode_step(p, cfg, c, t, i))
    outs = []
    for i in range(s):
        lg, caches = step(caches, toks[:, i:i + 1], jnp.int32(i))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    err = float(jnp.abs(full - dec).max() / (jnp.abs(full).max() + 1e-9))
    assert err < 2e-2, err


def test_param_counts_close_to_published():
    expected = {"mamba2-370m": 0.37e9, "qwen1.5-0.5b": 0.46e9,
                "gemma3-27b": 28e9, "smollm-135m": 0.135e9,
                "kimi-k2-1t-a32b": 1.03e12,
                "llava-next-mistral-7b": 7.2e9, "zamba2-2.7b": 2.4e9}
    for arch, want in expected.items():
        got = get_config(arch).param_count()
        assert abs(got - want) / want < 0.12, (arch, got, want)
