"""Serving subsystem: step-wise samplers, weight bank, batching engine.

The bit-exactness tests pin the step-wise sampler refactor against inline
copies of the pre-refactor loops (the loop samplers are now thin drivers
over the eps-request state machine, so any drift here is a real change).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.tree import flatten_paths
from repro.configs.diffusion_presets import tiny_ddim
from repro.core import talora
from repro.core.qmodule import PackedW4, dequant_weight
from repro.diffusion.samplers import (ddim_sample, ddim_step,
                                      dpm_solver2_sample, plms_sample,
                                      sampler_advance, sampler_init,
                                      sampler_needed_t)
from repro.diffusion.schedule import make_schedule, sample_timesteps
from repro.nn.unet import io_sites, unet_apply, unet_init
from repro.quant.fakequant import (KIND_FP_SIGNED, KIND_FP_UNSIGNED,
                                   QuantizerParams)
from repro.serving import (DiffusionServingEngine, VirtualClock, WeightBank,
                           act_qps_from_plan, default_serving_plan,
                           segments_of)
from repro.serving.scheduler import ContinuousBatcher, GenRequest, RequestState

KEY = jax.random.PRNGKey(0)


def toy_eps_fn(x, tb):
    return 0.1 * x + 0.01 * jnp.sin(tb)[:, None, None, None]


# ---------------------------------------------------------------------------
# Step-wise sampler API reproduces the pre-refactor loops bit-exactly.
# (Reference implementations below are verbatim copies of the old loops.)
# ---------------------------------------------------------------------------


def _ref_ddim(eps_fn, sched, shape, key, *, steps, eta=0.0, collect_every=0):
    seq = sample_timesteps(sched.T, steps)
    key, k0 = jax.random.split(key)
    x = jax.random.normal(k0, shape)
    taps = []
    for i, t in enumerate(seq):
        t_prev = int(seq[i + 1]) if i + 1 < len(seq) else -1
        tb = jnp.full((shape[0],), t, jnp.float32)
        eps = eps_fn(x, tb)
        if collect_every and (i % collect_every == 0):
            taps.append((int(t), np.asarray(x)))
        key, kn = jax.random.split(key)
        noise = jax.random.normal(kn, shape) if eta > 0 else None
        x = ddim_step(sched, x, int(t), t_prev, eps, eta, noise)
    return x, taps


def _ref_plms(eps_fn, sched, shape, key, *, steps):
    seq = sample_timesteps(sched.T, steps)
    key, k0 = jax.random.split(key)
    x = jax.random.normal(k0, shape)
    old_eps = []
    for i, t in enumerate(seq):
        t_prev = int(seq[i + 1]) if i + 1 < len(seq) else -1
        tb = jnp.full((shape[0],), t, jnp.float32)
        eps = eps_fn(x, tb)
        if len(old_eps) == 0:
            eps_prime = eps
        elif len(old_eps) == 1:
            eps_prime = (3 * eps - old_eps[-1]) / 2
        elif len(old_eps) == 2:
            eps_prime = (23 * eps - 16 * old_eps[-1] + 5 * old_eps[-2]) / 12
        else:
            eps_prime = (55 * eps - 59 * old_eps[-1] + 37 * old_eps[-2]
                         - 9 * old_eps[-3]) / 24
        old_eps = (old_eps + [eps])[-3:]
        x = ddim_step(sched, x, int(t), t_prev, eps_prime)
    return x


def _ref_dpm(eps_fn, sched, shape, key, *, steps):
    seq = sample_timesteps(sched.T, steps)
    key, k0 = jax.random.split(key)
    x = jax.random.normal(k0, shape)

    def lam(t):
        ab = sched.alpha_bars[t]
        return 0.5 * jnp.log(ab / (1 - ab))

    def coeffs(t):
        ab = sched.alpha_bars[t]
        return jnp.sqrt(ab), jnp.sqrt(1 - ab)

    for i in range(len(seq) - 1):
        t, t_next = int(seq[i]), int(seq[i + 1])
        l_t, l_n = lam(t), lam(t_next)
        h = l_n - l_t
        l_mid = l_t + 0.5 * h
        lams = 0.5 * jnp.log(sched.alpha_bars / (1 - sched.alpha_bars))
        t_mid = int(jnp.argmin(jnp.abs(lams - l_mid)))
        a_t, s_t = coeffs(t)
        a_m, s_m = coeffs(t_mid)
        a_n, s_n = coeffs(t_next)
        tb = jnp.full((shape[0],), t, jnp.float32)
        eps1 = eps_fn(x, tb)
        u = (a_m / a_t) * x - s_m * jnp.expm1(0.5 * h) * eps1
        tbm = jnp.full((shape[0],), t_mid, jnp.float32)
        eps2 = eps_fn(u, tbm)
        x = (a_n / a_t) * x - s_n * jnp.expm1(h) * eps2
    t_last = int(seq[-1])
    tb = jnp.full((shape[0],), t_last, jnp.float32)
    x = ddim_step(sched, x, t_last, -1, eps_fn(x, tb))
    return x


@pytest.mark.parametrize("steps", [1, 7])
@pytest.mark.parametrize("eta", [0.0, 0.7])
def test_stepwise_ddim_bitexact(steps, eta):
    sched = make_schedule("linear", 100)
    shape = (2, 4, 4, 3)
    want, taps_w = _ref_ddim(toy_eps_fn, sched, shape, KEY, steps=steps,
                             eta=eta, collect_every=1)
    got, taps_g = ddim_sample(toy_eps_fn, sched, shape, KEY, steps=steps,
                              eta=eta, collect_every=1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert [t for t, _ in taps_g] == [t for t, _ in taps_w]
    for (_, a), (_, b) in zip(taps_g, taps_w):
        np.testing.assert_array_equal(a, b)


def test_stepwise_plms_bitexact():
    sched = make_schedule("linear", 100)
    shape = (2, 4, 4, 3)
    want = _ref_plms(toy_eps_fn, sched, shape, KEY, steps=7)
    got = plms_sample(toy_eps_fn, sched, shape, KEY, steps=7)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("steps", [1, 2, 6])
def test_stepwise_dpm_bitexact(steps):
    sched = make_schedule("linear", 100)
    shape = (2, 4, 4, 3)
    want = _ref_dpm(toy_eps_fn, sched, shape, KEY, steps=steps)
    got = dpm_solver2_sample(toy_eps_fn, sched, shape, KEY, steps=steps)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_step_machine_engine_drive_matches_loop():
    """Driving the state machine externally (engine-style) == loop driver."""
    sched = make_schedule("linear", 100)
    shape = (1, 4, 4, 3)
    st = sampler_init("dpm_solver2", sched, shape, KEY, steps=5)
    while not st.done:
        t = sampler_needed_t(st)
        tb = jnp.full((shape[0],), t, jnp.float32)
        sampler_advance(st, toy_eps_fn(st.eval_x, tb))
    want = dpm_solver2_sample(toy_eps_fn, sched, shape, KEY, steps=5)
    np.testing.assert_array_equal(np.asarray(st.x), np.asarray(want))


# ---------------------------------------------------------------------------
# Weight bank: segments, merge+pack, LRU.
# ---------------------------------------------------------------------------

T = 40


def _toy_bank(max_cached=4, lora_scale=0.1):
    key = jax.random.PRNGKey(1)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    params = {"l0": {"w": jax.random.normal(k1, (8, 8))},
              "l1": {"w": jax.random.normal(k2, (8, 6))}}
    weights = {k: v for k, v in flatten_paths(params).items()}
    plan = default_serving_plan(weights)
    tcfg = talora.TALoRAConfig(hub_size=2, rank=2, t_emb_dim=16,
                               router_hidden=8)
    hubs = talora.init_lora_hub(k3, talora.lora_target_dims_from_weights(
        weights), tcfg)
    # randomize B so the merged delta is nonzero and differs per slot
    for name in hubs:
        hubs[name]["B"] = jax.random.normal(
            k4, hubs[name]["B"].shape) * lora_scale
    router = talora.init_router(k4, len(weights), tcfg)
    bank = WeightBank(params, plan, hubs, router, tcfg, T,
                      max_cached=max_cached)
    return bank, params, plan, hubs, router, tcfg


def test_segments_partition_schedule():
    bank, *_ = _toy_bank()
    assert bank.segments[0].t_lo == 0
    assert bank.segments[-1].t_hi == T - 1
    for a, b in zip(bank.segments, bank.segments[1:]):
        assert b.t_lo == a.t_hi + 1
        assert a.slots != b.slots  # maximal runs: adjacent segments differ
    for s in bank.segments:
        for t in range(s.t_lo, s.t_hi + 1):
            assert bank.segment_of(t) == s.index
            assert tuple(bank.signatures[t].tolist()) == s.slots


def test_segment_boundaries_match_allocation_histogram():
    """Fig. 7/9 histogram is constant inside every bank segment and equals
    the per-layer one-hot mean of the segment signature."""
    bank, params, plan, hubs, router, tcfg = _toy_bank()
    names = sorted(hubs)
    hist = np.asarray(talora.allocation_histogram(
        router, jnp.arange(T, dtype=jnp.float32), names, tcfg))
    for s in bank.segments:
        want = np.zeros((tcfg.hub_size,))
        for slot in s.slots:
            want[slot] += 1.0 / len(s.slots)
        for t in range(s.t_lo, s.t_hi + 1):
            np.testing.assert_allclose(hist[t], want, atol=1e-6)


def test_weight_bank_merges_and_packs_per_segment():
    bank, params, plan, hubs, router, tcfg = _toy_bank()
    p0 = bank.params_for_segment(0)
    flat0 = flatten_paths(p0)
    assert isinstance(flat0["l0/w"], PackedW4)
    assert isinstance(flat0["l1/w"], PackedW4)
    # decode ~= TALoRA-merged weight (within FP4 grid error)
    names = sorted(hubs)
    sels = {n: jax.nn.one_hot(bank.segments[0].slots[i], tcfg.hub_size)
            for i, n in enumerate(names)}
    merged = flatten_paths(talora.merge_into_tree(params, hubs, sels, tcfg))
    w = np.asarray(merged["l0/w"], np.float32)
    dq = np.asarray(dequant_weight(flat0["l0/w"], jnp.float32))
    scale = float(plan.sites["l0/w"].qp.maxval)
    assert np.abs(w.clip(-scale, scale) - dq).max() <= scale / 4  # E2M1 step
    # a segment with different routing packs different bytes
    other = next((s for s in bank.segments if s.slots != bank.segments[0].slots),
                 None)
    assert other is not None, "toy router collapsed to one signature"
    po = flatten_paths(bank.params_for_segment(other.index))
    assert not np.array_equal(np.asarray(flat0["l0/w"].packed),
                              np.asarray(po["l0/w"].packed))


def test_weight_bank_lru_and_stats():
    bank, *_ = _toy_bank(max_cached=1)
    assert bank.n_segments >= 2, "toy router should produce several segments"
    bank.params_for_segment(0)
    bank.params_for_segment(0)
    assert (bank.hits, bank.misses) == (1, 1)
    bank.params_for_segment(1)          # evicts 0 (cap 1)
    assert bank.evictions == 1
    bank.params_for_segment(0)          # rebuilt -> miss
    assert (bank.hits, bank.misses) == (1, 3)
    assert 0.0 < bank.hit_rate < 1.0


def test_default_plan_and_act_qps_filter():
    w = {"a/w": jnp.ones((4, 4)), "io/w": jnp.ones((4, 4))}
    plan = default_serving_plan(w, io_sites={"io/w"})
    assert plan.sites["a/w"].qp.bits == 4
    assert plan.sites["io/w"].qp.bits == 8
    # act_qps: only per-tensor FP 4-bit activation sites pass the filter
    from repro.core.msfp import SiteInfo
    plan.sites["act_ok"] = SiteInfo(
        QuantizerParams(KIND_FP_UNSIGNED, 2, 1, 4, jnp.float32(3.0)),
        False, True, 0.0)
    plan.sites["act_vec"] = SiteInfo(
        QuantizerParams(KIND_FP_SIGNED, 2, 1, 4, jnp.ones((4,))),
        False, False, 0.0)
    qps = act_qps_from_plan(plan)
    assert set(qps) == {"act_ok"}


def test_pack_param_tree_conv_layout_and_odd_width_fallback():
    key1, key2, key3 = jax.random.split(KEY, 3)
    params = {"c": {"w": jax.random.normal(key1, (3, 3, 4, 8))},
              "odd": {"w": jax.random.normal(key2, (3, 3, 4, 7))},
              "d": {"w": jax.random.normal(key3, (8, 8))}}
    from repro.serving.weight_bank import pack_param_tree
    plan = default_serving_plan(dict(flatten_paths(params)))
    tree, stats = pack_param_tree(params, plan)
    flat = flatten_paths(tree)
    # conv weights pack as (kh*kw*cin, cout/2) GEMM nibbles, HWIO shape kept
    assert isinstance(flat["c/w"], PackedW4)
    assert flat["c/w"].packed.shape == (36, 4)
    assert flat["c/w"].shape == (3, 3, 4, 8)
    assert dequant_weight(flat["c/w"], jnp.float32).shape == (3, 3, 4, 8)
    assert sorted(stats["packed"]) == ["c/w", "d/w"]
    # odd output width cannot nibble-pack -> bf16 fallback, forward stays total
    assert stats["fallback"] == ["odd/w"]
    assert flat["odd/w"].dtype == jnp.bfloat16


@pytest.mark.slow
def test_serve_forward_matches_fakequant_oracle_at_conv_sites(monkeypatch):
    """Packed serve-mode tiny-UNet forward == the fake-quant reference
    (FP4-grid weights + qdq acts at every planned site), with no PackedW4
    conv weight float-dequantized on the dispatch path.

    Regression: the pre-im2col serve path decoded conv packs to float and
    never quantized conv activations, so it matched the *unquantized*
    model at conv sites instead of the fake-quant one that calibration and
    TALoRA fine-tuning validated.
    """
    import repro.kernels.ops as ops
    from repro.common.tree import unflatten_paths
    from repro.quant.calibrate import QuantContext
    from repro.serving.weight_bank import pack_param_tree

    cfg = tiny_ddim(8)
    params = unet_init(KEY, cfg)
    weights = {k: v for k, v in flatten_paths(params).items()
               if k.endswith("/w") and v.ndim >= 2}
    plan = default_serving_plan(weights, io_sites=io_sites(params))
    packed, stats = pack_param_tree(params, plan)

    conv_sites = [k for k, v in flatten_paths(params).items()
                  if k.endswith("/w") and v.ndim == 4]
    non_io = sorted(set(conv_sites) - io_sites(params))
    assert non_io, "tiny UNet must have quantized conv sites"
    assert set(non_io) <= set(stats["packed"])
    assert set(conv_sites) & set(stats["fallback"]) <= io_sites(params)
    flat_packed = dict(flatten_paths(packed))
    assert all(flat_packed[k].packed.ndim == 2 for k in non_io)

    act_qp = QuantizerParams(KIND_FP_SIGNED, 2, 1, 4, jnp.float32(6.0))
    ctx = QuantContext("serve", act_qps={"*": act_qp})
    # Oracle: identical serve ctx over the *dequantized* dense weights —
    # i.e. fake-quant numerics (FP4-grid weights, qdq at every act site).
    dense = unflatten_paths({
        k: (dequant_weight(v, jnp.float32) if isinstance(v, PackedW4) else v)
        for k, v in flat_packed.items()})
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 8, 8, 3))
    t = jnp.asarray([3.0, 17.0], jnp.float32)

    old = ops.FORCE
    ops.FORCE = "interpret"
    try:
        want = np.asarray(unet_apply(dense, x, t, cfg, ctx=ctx))

        def boom(*a, **k):
            raise AssertionError("packed serve forward decoded a conv "
                                 "weight / fell back to XLA")

        monkeypatch.setattr(ops._ref, "ref_w4a4_conv2d", boom)
        monkeypatch.setattr(ops._ref, "ref_w4_matmul", boom)
        monkeypatch.setattr(ops._ref, "ref_w4a4_matmul", boom)
        got = np.asarray(unet_apply(packed, x, t, cfg, ctx=ctx))
        monkeypatch.undo()

        plain = np.asarray(unet_apply(dense, x, t, cfg))  # no act quant
    finally:
        ops.FORCE = old
    np.testing.assert_allclose(got, want, atol=5e-4, rtol=5e-3)
    # act quant is a real numerics effect: the silent full-precision-act
    # path (today's conv behavior) is measurably different
    assert not np.allclose(plain, want, atol=1e-4)


# ---------------------------------------------------------------------------
# Engine: admission/retirement, determinism, starvation guard.
# ---------------------------------------------------------------------------


def _stub_engine(max_batch, sched, bank, **kw):
    cfg = tiny_ddim(4)
    return DiffusionServingEngine(
        cfg, sched, bank, max_batch=max_batch,
        apply_fn=lambda params, x, tb, y, ctx: 0.1 * x, **kw)


def _single_segment_bank():
    params = {"l0": {"w": jnp.ones((4, 4))}}
    plan = default_serving_plan(flatten_paths(params))
    return WeightBank(params, plan, {}, None, None, T)


def test_engine_admission_and_retirement_order():
    sched = make_schedule("linear", T)
    bank = _single_segment_bank()
    assert bank.n_segments == 1
    eng = _stub_engine(2, sched, bank)
    for steps in (2, 2, 4, 1):
        eng.submit(steps=steps, seed=0)
    res = eng.run()
    # FIFO admission into 2 slots: rid 0,1 first; 2,3 only after both retire
    a = {rid: rs.admitted_at for rid, rs in res.items()}
    assert max(a[0], a[1]) <= min(a[2], a[3])
    # retirement order follows remaining work: 0,1 (2 evals) then 3 (1) then 2
    assert list(res.keys()) == [0, 1, 3, 2]
    assert [res[r].n_evals for r in (0, 1, 3, 2)] == [2, 2, 1, 4]


def test_engine_determinism_under_fixed_seeds():
    sched = make_schedule("linear", T)

    def run_once():
        bank, *_ = _toy_bank()
        eng = _stub_engine(3, sched, bank)
        for i in range(4):
            eng.submit(steps=3 + i % 2, seed=i, eta=0.5 * (i % 2),
                       sampler=("ddim", "plms")[i % 2])
        return {rid: np.asarray(rs.x0) for rid, rs in eng.run().items()}

    r1, r2 = run_once(), run_once()
    assert sorted(r1) == sorted(r2)
    for rid in r1:
        np.testing.assert_array_equal(r1[rid], r2[rid])


def test_scheduler_starvation_guard_and_grouping():
    sched = make_schedule("linear", T)
    b = ContinuousBatcher(max_batch=4, starvation_ticks=3)

    def mk(rid, tick):
        st = sampler_init("ddim", sched, (1, 2, 2, 3), KEY, steps=2)
        rs = RequestState(GenRequest(rid), st)
        rs.admitted_at = 0.0
        rs.last_advance_tick = tick
        b.inflight.append(rs)
        return rs

    a0, a1 = mk(0, tick=10), mk(1, tick=10)
    lone = mk(2, tick=5)   # hasn't advanced for 5 ticks
    groups = {7: [a0, a1], 9: [lone]}
    # starved request promotes its (smaller) group
    seg, members = b.select(groups, tick=10)
    assert seg == 9 and members == [lone]
    # without starvation the largest group wins
    lone.last_advance_tick = 10
    seg, members = b.select(groups, tick=10)
    assert seg == 7 and members == [a0, a1]


def test_engine_cfg_guidance_pairs_cond_uncond():
    sched = make_schedule("linear", T)
    bank = _single_segment_bank()
    cfg = dataclasses.replace(tiny_ddim(4), num_classes=5)
    calls = []

    def apply_fn(params, x, tb, y, ctx):
        calls.append((x.shape[0], y is not None))
        base = 0.1 * x
        if y is not None:
            base = base + 0.01 * y[:, None, None, None].astype(x.dtype)
        return base

    eng = DiffusionServingEngine(cfg, sched, bank, max_batch=4,
                                 apply_fn=apply_fn)
    eng.submit(steps=2, seed=0, y=3, guidance_scale=2.0)
    eng.submit(steps=2, seed=1)              # unconditional rider
    res = eng.run()
    assert len(res) == 2
    # each tick ran one uncond forward (guided pair + plain) and one cond
    sizes = sorted(c[0] for c in calls[:2])
    assert sizes == [1, 2]
    with pytest.raises(ValueError):
        eng.submit(steps=2, guidance_scale=1.0)   # guidance without label


def test_engine_buckets_pad_to_pow2_and_share_jit():
    """Distinct in-flight counts must share a power-of-two jit bucket
    (padded inputs, outputs masked by slicing) so the jit cache stays
    bounded under churny traffic."""
    sched = make_schedule("linear", T)
    bank = _single_segment_bank()
    sizes = []

    def apply_fn(params, x, tb, y, ctx):
        sizes.append(x.shape[0])
        return 0.1 * x + 0.01 * tb[:, None, None, None]

    cfg = tiny_ddim(4)
    eng = DiffusionServingEngine(cfg, sched, bank, max_batch=4,
                                 apply_fn=apply_fn)
    for steps in (3, 3, 3, 1):
        eng.submit(steps=steps, seed=0)
    res = eng.run()
    assert len(res) == 4
    # tick 1 runs all 4; ticks 2-3 run the remaining 3, padded into the
    # same 4-bucket. apply_fn runs under jit, so `sizes` records traces:
    # exactly one, at the padded bucket size — not one per batch size.
    assert sizes == [4]
    s = eng.stats()
    assert s["forwards"] == 3
    assert s["compiled_forwards"] == 1
    assert s["buckets"] == [4]
    assert s["padded_samples"] == 2
    assert [res[r].n_evals for r in range(4)] == [3, 3, 3, 1]


def test_engine_run_sleeps_to_arrival_instead_of_busy_polling():
    """While idle before the next arrival the driver sleeps once (up to
    the arrival, capped), not a 2 ms poll loop — and trace replay still
    admits strictly in arrival order."""
    sched = make_schedule("linear", T)
    bank = _single_segment_bank()
    eng = _stub_engine(2, sched, bank)
    arrivals = {0: 0.0, 1: 0.05, 2: 0.10}
    for rid, arr in arrivals.items():
        assert eng.submit(steps=1, seed=rid, arrival=arr) == rid
    res = eng.run()
    assert len(res) == 3
    admits = [res[r].admitted_at for r in (0, 1, 2)]
    assert admits == sorted(admits)
    for rid in (1, 2):
        assert res[rid].admitted_at >= arrivals[rid]
    # steps=1 requests retire instantly, so each inter-arrival gap is at
    # most one idle sleep (zero if a slow first jit eats the gap); the old
    # 2 ms busy-poll would have slept dozens of times
    assert eng.n_idle_sleeps <= 4
    assert eng.stats()["idle_sleeps"] == eng.n_idle_sleeps


def test_engine_idle_sleep_cap_zero_never_sleeps():
    """Regression: ``max_idle_sleep=0`` used to call ``time.sleep(0)``
    in a hot loop (wait capped at zero still entered the sleep branch,
    counting a bogus idle sleep per spin). A zero cap must mean "poll,
    never sleep" — the run completes and counts zero idle sleeps."""
    sched = make_schedule("linear", T)
    eng = _stub_engine(2, sched, _single_segment_bank())
    for rid, arr in enumerate((0.0, 0.02, 0.04)):
        eng.submit(steps=1, seed=rid, arrival=arr)
    res = eng.run(max_idle_sleep=0.0)
    assert len(res) == 3
    assert eng.n_idle_sleeps == 0
    assert eng.stats()["idle_sleeps"] == 0


def test_request_latency_none_for_expired():
    """Expired requests never ran: ``latency`` must stay None (keeping
    them out of completion percentiles) and ``expired_after_s`` records
    how long past arrival the scheduler held them before refusing."""
    sched = make_schedule("linear", T)
    eng = _stub_engine(2, sched, _single_segment_bank(),
                       clock=VirtualClock())
    dead = eng.submit(steps=1, seed=0, arrival=0.0, deadline=-1.0)
    ok = eng.submit(steps=1, seed=1, arrival=0.0)
    res = eng.run()
    assert res[dead].expired
    assert res[dead].latency is None
    assert res[dead].expired_after_s is not None
    assert res[dead].expired_after_s >= 0.0
    assert not res[ok].expired
    assert isinstance(res[ok].latency, float) and res[ok].latency >= 0.0
    assert res[ok].expired_after_s is None


# ---------------------------------------------------------------------------
# student_eps mixed-timestep guard (regression for t.reshape(-1)[0]).
# ---------------------------------------------------------------------------


def _tiny_bundle():
    from repro.diffusion.pipeline import QuantizedDiffusion

    cfg = tiny_ddim(8)
    params = unet_init(KEY, cfg)
    weights = {k: v for k, v in flatten_paths(params).items()
               if k.endswith("/w") and v.ndim >= 2}
    plan = default_serving_plan(weights, io_sites=io_sites(params))
    tcfg = talora.TALoRAConfig(hub_size=2, rank=2, t_emb_dim=16,
                               router_hidden=8)
    k1, k2, k3 = jax.random.split(KEY, 3)
    hubs = talora.init_lora_hub(k1, talora.lora_target_dims_from_weights(
        weights), tcfg)
    for name in hubs:
        hubs[name]["B"] = jax.random.normal(k3, hubs[name]["B"].shape) * 0.05
    router = talora.init_router(k2, len(weights), tcfg)
    sched = make_schedule("linear", T)
    return QuantizedDiffusion(cfg, sched, params, params, plan,
                              talora_cfg=tcfg, hubs=hubs, router=router)


@pytest.mark.slow
def test_student_eps_mixed_timesteps_routes_per_group():
    bundle = _tiny_bundle()
    x = jax.random.normal(KEY, (2, 8, 8, 3))
    # pick two timesteps with different routing signatures
    sig = np.asarray(talora.routing_signatures(
        bundle.router, jnp.arange(T), sorted(bundle.hubs),
        bundle.talora_cfg))
    t1 = 0
    t2 = next(t for t in range(1, T) if not np.array_equal(sig[t], sig[t1]))
    mixed = bundle.student_eps(x, jnp.asarray([t1, t2], jnp.float32))
    one = bundle.student_eps(x[:1], jnp.asarray([t1], jnp.float32))
    two = bundle.student_eps(x[1:], jnp.asarray([t2], jnp.float32))
    np.testing.assert_allclose(np.asarray(mixed[0]), np.asarray(one[0]),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(mixed[1]), np.asarray(two[0]),
                               rtol=2e-4, atol=2e-5)
    # the old behavior (route everything for t[0]) is measurably different
    sels = talora.route(bundle.router, jnp.float32(t1),
                        sorted(bundle.hubs), bundle.talora_cfg)
    old = unet_apply(talora.merge_into_tree(bundle.q_params, bundle.hubs,
                                            sels, bundle.talora_cfg),
                     x, jnp.asarray([t1, t2], jnp.float32), bundle.cfg)
    assert not np.allclose(np.asarray(mixed[1]), np.asarray(old[1]),
                           atol=1e-6)


@pytest.mark.slow
def test_student_eps_traced_mixed_batch_raises():
    bundle = _tiny_bundle()
    x = jax.random.normal(KEY, (2, 8, 8, 3))
    with pytest.raises(ValueError, match="serving"):
        jax.jit(lambda x, t: bundle.student_eps(x, t))(
            x, jnp.asarray([1.0, 2.0]))
    # batch-1 tracing stays supported (scalar routing is unambiguous)
    out = jax.jit(lambda x, t: bundle.student_eps(x, t))(
        x[:1], jnp.asarray([1.0]))
    assert bool(jnp.isfinite(out).all())


# ---------------------------------------------------------------------------
# End-to-end acceptance: concurrent packed-path serving == single-request.
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_engine_end_to_end_packed_concurrent_matches_single():
    cfg = tiny_ddim(8)
    params = unet_init(KEY, cfg)
    weights = {k: v for k, v in flatten_paths(params).items()
               if k.endswith("/w") and v.ndim >= 2}
    plan = default_serving_plan(weights, io_sites=io_sites(params))
    tcfg = talora.TALoRAConfig(hub_size=2, rank=2, t_emb_dim=16,
                               router_hidden=8)
    k1, k2, k3 = jax.random.split(KEY, 3)
    hubs = talora.init_lora_hub(k1, talora.lora_target_dims_from_weights(
        weights), tcfg)
    for name in hubs:
        hubs[name]["B"] = jax.random.normal(k3, hubs[name]["B"].shape) * 0.05
    router = talora.init_router(k2, len(weights), tcfg)
    sched = make_schedule("linear", T)
    act_qp = QuantizerParams(KIND_FP_SIGNED, 2, 1, 4, jnp.float32(6.0))

    def make_engine(max_batch):
        bank = WeightBank(params, plan, hubs, router, tcfg, T,
                          max_cached=8)
        return DiffusionServingEngine(cfg, sched, bank,
                                      act_qps={"*": act_qp},
                                      max_batch=max_batch)

    jobs = [dict(steps=3, seed=0, sampler="ddim"),
            dict(steps=4, seed=1, sampler="ddim", eta=0.8),
            dict(steps=3, seed=2, sampler="plms"),
            dict(steps=2, seed=3, sampler="dpm_solver2")]
    eng = make_engine(max_batch=4)
    assert eng.ctx.mode == "serve"   # no fake-quant ctx on the serve path
    for j in jobs:
        eng.submit(**j)
    res = eng.run()
    assert len(res) == 4
    # forward really ran on packed integer weights
    flat = flatten_paths(eng.bank.params_for_segment(0))
    assert sum(isinstance(v, PackedW4) for v in flat.values()) > 20
    assert eng.stats()["bank_hit_rate"] > 0.0

    for rid, j in enumerate(jobs):
        single = make_engine(max_batch=1)
        single.submit(**j)
        ref = single.run()[0]
        assert res[rid].n_evals == ref.n_evals
        np.testing.assert_allclose(np.asarray(res[rid].x0),
                                   np.asarray(ref.x0),
                                   rtol=1e-4, atol=1e-4)
