"""Quant substrate: formats, fake-quant, search (incl. hypothesis props)."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.quant import (FPFormat, QuantizerParams, KIND_FP_SIGNED,
                         KIND_FP_UNSIGNED, fp_qdq, int_qdq,
                         search_signed_fp, search_unsigned_fp,
                         search_int_affine, search_activation_params,
                         signed_formats, unsigned_formats, enumerate_grid)
from repro.quant.formats import snap_to_base_grid

ALL_4BIT = list(signed_formats(4)) + list(unsigned_formats(4))


def test_e2m1_grid_is_standard_fp4():
    g = enumerate_grid(FPFormat(2, 1, False))
    assert np.allclose(g, [0, 0.5, 1, 1.5, 2, 3, 4, 6])


@pytest.mark.parametrize("fmt", ALL_4BIT, ids=lambda f: f.name)
def test_snap_matches_bruteforce_nearest(fmt, rng):
    grid = enumerate_grid(FPFormat(fmt.exp_bits, fmt.man_bits, False))
    x = np.abs(rng.normal(size=500)).astype(np.float32) * 2
    snapped = np.asarray(snap_to_base_grid(jnp.asarray(x), fmt))
    bf = grid[np.argmin(np.abs(x[:, None] - grid[None]), axis=1)]
    err_s = np.abs(x - np.clip(snapped, 0, fmt.base_max))
    err_b = np.abs(x - np.clip(bf, 0, fmt.base_max))
    np.testing.assert_allclose(err_s, err_b, atol=1e-6)


@pytest.mark.slow
@settings(max_examples=40, deadline=None)
@given(e=st.integers(0, 3), m=st.integers(0, 3),
       signed=st.booleans(),
       maxval=st.floats(0.1, 50.0),
       data=st.lists(st.floats(-100, 100, allow_nan=False), min_size=1,
                     max_size=64))
def test_qdq_properties(e, m, signed, maxval, data):
    """Idempotence, range clipping, grid membership (hypothesis)."""
    if e + m == 0:
        return
    fmt = FPFormat(e, m, signed)
    x = jnp.asarray(np.asarray(data, np.float32))
    mv = jnp.float32(maxval)
    q = fp_qdq(x, fmt, mv)
    # idempotent
    np.testing.assert_allclose(np.asarray(fp_qdq(q, fmt, mv)), np.asarray(q),
                               atol=1e-5, rtol=1e-5)
    # clipped to representable range
    lo = -maxval if signed else 0.0
    assert np.all(np.asarray(q) <= maxval * (1 + 1e-5))
    assert np.all(np.asarray(q) >= lo - maxval * 1e-5)
    # grid membership (scaled)
    grid = enumerate_grid(fmt) * maxval / fmt.base_max
    d = np.min(np.abs(np.asarray(q)[:, None] - grid[None]), axis=1)
    assert np.all(d <= 1e-4 * max(1.0, maxval))


@settings(max_examples=30, deadline=None)
@given(zp=st.floats(-0.3, 0.0), maxval=st.floats(0.2, 5.0))
def test_unsigned_zp_recovers_negative_tail(zp, maxval):
    """Eq. 8: grid+z represents values down to z (the SiLU tail)."""
    fmt = FPFormat(2, 2, False)
    x = jnp.asarray(np.linspace(zp, maxval, 64, dtype=np.float32))
    q = np.asarray(fp_qdq(x, fmt, jnp.float32(maxval), jnp.float32(zp)))
    assert q.min() >= zp - 1e-5
    # zero-point value itself is exactly representable
    np.testing.assert_allclose(
        np.asarray(fp_qdq(jnp.float32(zp), fmt, jnp.float32(maxval),
                          jnp.float32(zp))), zp, atol=1e-6)


def test_monotonicity(rng):
    fmt = FPFormat(2, 1, True)
    x = jnp.asarray(np.sort(rng.normal(size=256)).astype(np.float32))
    q = np.asarray(fp_qdq(x, fmt, jnp.float32(2.0)))
    assert np.all(np.diff(q) >= -1e-6)


def test_search_silu_prefers_unsigned(rng):
    """The paper's Observation 1 at the tensor level."""
    x = rng.normal(size=20000).astype(np.float32)
    silu = x / (1 + np.exp(-x))
    rs = search_signed_fp(silu, 4)
    ru = search_unsigned_fp(silu, 4)
    assert ru.mse < rs.mse, (ru.mse, rs.mse)
    assert ru.params.kind == KIND_FP_UNSIGNED
    assert float(ru.params.zero_point) < 0  # recovered the negative tail
    # mixup-sign selection keeps the better candidate
    mix = search_activation_params(silu, 4, allow_unsigned=True)
    assert mix.params.kind == KIND_FP_UNSIGNED


def test_search_symmetric_prefers_signed(rng):
    x = rng.normal(size=20000).astype(np.float32)
    rs = search_signed_fp(x, 4)
    ru = search_unsigned_fp(x, 4)
    assert rs.mse <= ru.mse
    mix = search_activation_params(x, 4, allow_unsigned=True)
    assert mix.params.kind == KIND_FP_SIGNED


def test_fp_beats_int_on_heavy_tailed_data(rng):
    """App. D direction: FP's log-spaced grid fits heavy-tailed activation

    distributions (outliers + dense near-zero mass) better than uniform INT.
    (On pure Gaussians at 4-bit the two are within noise — the paper's
    advantage comes from real activation shapes.)"""
    x = rng.laplace(scale=1.0, size=30000).astype(np.float32)
    fp = search_signed_fp(x, 4)
    it = search_int_affine(x, 4, symmetric=True)
    assert fp.mse < it.mse
    fp6 = search_signed_fp(x, 6)
    it6 = search_int_affine(x, 6, symmetric=True)
    assert fp6.mse < it6.mse


def test_weight_search_spaces_follow_table6(rng):
    w = rng.normal(size=8000).astype(np.float32)
    r = search_weight_params(w := jnp.asarray(w), 4)
    assert float(r.params.maxval) >= 0.8 * float(jnp.max(jnp.abs(w))) - 1e-5
    assert float(r.params.maxval) <= 2.0 * float(jnp.max(jnp.abs(w))) + 1e-5


from repro.quant.search import search_weight_params  # noqa: E402


def test_int_qdq_roundtrip_range():
    x = jnp.asarray(np.linspace(-3, 3, 100, dtype=np.float32))
    q = np.asarray(int_qdq(x, 4, jnp.float32(2.0)))
    assert q.max() <= 2.0 + 1e-6 and q.min() >= -2.0 * (8 / 7) - 1e-5
