"""Lock-order race detector: unit semantics + the bank/obs integration.

Unit tier: the instrumented lock still mutually excludes, a consistent
global order stays clean, an AB/BA inversion is caught as a cycle *even
when the deadlock never fires*, forbidden pairs and same-thread
re-acquire are caught, and assert_clean raises a readable report.

Integration tier (the satellite this suite exists for): the full bank +
obs lock population — ``bank._lock``, ``tracer._lock``,
``metrics._lock``, per-instrument metrics locks, the profiler lock —
under concurrent prefetch churn, per-tick ``obs.sample``, and
``metrics.to_text()`` readers, with ``serving_discipline`` armed. The
PR 7 reconciliation invariants must hold *with instrumented locks
installed* (the instrumentation itself may not perturb the counters).
"""
import threading
from types import SimpleNamespace

import numpy as np
import pytest

from tests._serving_fixtures import multi_segment_bank

from repro.serving.obs import Observability
from tools.analysis.lockcheck import (InstrumentedLock, LockMonitor,
                                      LockOrderError, serving_discipline)


# ---------------------------------------------------------------------------
# unit: the wrapper is still a lock
# ---------------------------------------------------------------------------


def test_instrumented_lock_mutually_excludes():
    mon = LockMonitor(capture_stacks=False)
    lock = mon.lock("x")
    state = {"n": 0}

    def bump():
        for _ in range(2000):
            with lock:
                v = state["n"]
                state["n"] = v + 1

    ts = [threading.Thread(target=bump) for _ in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert state["n"] == 8000
    assert mon.acquire_counts()["x"] == 8000
    mon.assert_clean()


def test_try_acquire_and_locked():
    mon = LockMonitor()
    lock = mon.lock("x")
    assert lock.acquire(blocking=False)
    assert lock.locked()
    lock.release()
    assert not lock.locked()
    mon.assert_clean()


# ---------------------------------------------------------------------------
# unit: order graph
# ---------------------------------------------------------------------------


def test_consistent_order_is_clean():
    mon = LockMonitor()
    a, b, c = mon.lock("a"), mon.lock("b"), mon.lock("c")
    for _ in range(5):
        with a:
            with b:
                with c:
                    pass
    assert ("a", "b") in mon.edges() and ("b", "c") in mon.edges()
    mon.assert_clean()


def test_ab_ba_cycle_detected_without_deadlock_firing():
    # one thread, sequential: A->B then B->A. No deadlock ever happens,
    # but the *precondition* exists and must be reported.
    mon = LockMonitor()
    a, b = mon.lock("a"), mon.lock("b")
    with a:
        with b:
            pass
    with b:
        with a:
            pass
    kinds = [v.kind for v in mon.violations()]
    assert "cycle" in kinds
    with pytest.raises(LockOrderError, match="cycle"):
        mon.assert_clean()


def test_transitive_cycle_detected():
    mon = LockMonitor()
    a, b, c = mon.lock("a"), mon.lock("b"), mon.lock("c")
    with a:
        with b:
            pass
    with b:
        with c:
            pass
    with c:
        with a:
            pass   # closes a -> b -> c -> a
    assert any(v.kind == "cycle" for v in mon.violations())


def test_cross_thread_inversion_detected():
    mon = LockMonitor()
    a, b = mon.lock("a"), mon.lock("b")
    barrier = threading.Barrier(2)

    def t1():
        with a:
            barrier.wait()
        barrier.wait()
        # after t2 released b, take b->a (inverted) without contention
        with b:
            with a:
                pass

    def t2():
        with b:
            barrier.wait()
        barrier.wait()

    ts = [threading.Thread(target=t1), threading.Thread(target=t2)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    # t1 recorded a->b? No — it recorded b->a only; seed the other side
    with a:
        with b:
            pass
    assert any(v.kind == "cycle" for v in mon.violations())


def test_forbidden_pair_detected():
    mon = LockMonitor()
    mon.forbid("bank._lock", "tracer", "spans under the bank lock")
    bank = mon.lock("bank._lock")
    tr = mon.lock("tracer._lock")
    with bank:
        with tr:
            pass
    vs = mon.violations()
    assert len(vs) == 1 and vs[0].kind == "forbidden"
    assert "spans under the bank lock" in vs[0].reason
    with pytest.raises(LockOrderError, match="bank._lock -> tracer._lock"):
        mon.assert_clean()


def test_leaf_policy_empty_inner_prefix_matches_any():
    mon = LockMonitor()
    mon.forbid("tracer._lock", "", "tracer lock is a leaf")
    tr, other = mon.lock("tracer._lock"), mon.lock("anything")
    with tr:
        with other:
            pass
    assert [v.kind for v in mon.violations()] == ["forbidden"]


def test_self_deadlock_raises_instead_of_hanging():
    mon = LockMonitor()
    lock = mon.lock("x")
    lock.acquire()
    with pytest.raises(LockOrderError, match="self-deadlock"):
        lock.acquire()
    lock.release()
    assert any(v.kind == "self-deadlock" for v in mon.violations())


def test_same_name_siblings_carry_no_order_edge():
    # every Counter of one family shares a name; holding two distinct
    # objects of the same name is not an inversion (and no self-edge)
    mon = LockMonitor()
    l1, l2 = mon.lock("metrics.kcalls"), mon.lock("metrics.kcalls")
    with l1:
        with l2:
            pass
    assert mon.edges() == set()
    mon.assert_clean()


def test_report_mentions_counts_and_violation():
    mon = LockMonitor()
    mon.forbid("a", "b", "because")
    with mon.lock("a"):
        with mon.lock("b"):
            pass
    rep = mon.report()
    assert "violation" in rep and "because" in rep and "acquires" in rep


# ---------------------------------------------------------------------------
# integration: bank._lock x tracer/metrics locks under concurrent load
# ---------------------------------------------------------------------------


def _fake_engine(bank):
    """The attribute surface obs.sample() reads, wired to a real bank."""
    batcher = SimpleNamespace(pending=[], inflight=[], preemptions=0,
                              deadline_saves=0,
                              cost=SimpleNamespace(sample_s=0.0,
                                                   switch_s=0.0))
    return SimpleNamespace(batcher=batcher, bank=bank, tick_count=0,
                           n_forwards=0, n_finished=0, n_expired=0,
                           n_padded_samples=0, _jit={})


def test_bank_obs_lock_population_under_concurrent_load():
    mon = serving_discipline(LockMonitor())
    bank = multi_segment_bank(lock_factory=mon)
    bank.max_cached = bank.n_segments
    obs = Observability(lock_factory=mon)
    bank.obs = obs
    eng = _fake_engine(bank)
    segs = list(range(bank.n_segments))
    errs = []
    stop = threading.Event()

    def churn(wid):
        rng = np.random.default_rng(wid)
        try:
            for _ in range(40):
                seg = int(rng.choice(segs))
                if rng.random() < 0.5:
                    bank.prefetch(seg, block=bool(rng.random() < 0.3))
                else:
                    bank.params_for_segment(seg)
        except Exception as e:   # pragma: no cover - surfaced below
            errs.append(e)

    def sampler():
        try:
            while not stop.is_set():
                obs.sample(eng)
                with obs.tracer.span("tick", cat="engine") as sp:
                    sp.set("pending", 0)
        except Exception as e:   # pragma: no cover
            errs.append(e)

    def reader():
        try:
            while not stop.is_set():
                obs.metrics.to_text()
                obs.metrics.snapshot()
                obs.tracer.events()
        except Exception as e:   # pragma: no cover
            errs.append(e)

    workers = [threading.Thread(target=churn, args=(w,)) for w in range(2)]
    aux = [threading.Thread(target=sampler), threading.Thread(target=reader)]
    for t in workers + aux:
        t.start()
    for t in workers:
        t.join()
    bank.drain()
    stop.set()
    for t in aux:
        t.join()
    assert not errs

    # the run exercised the full lock population from >= 4 threads...
    counts = mon.acquire_counts()
    for name in ("bank._lock", "tracer._lock", "metrics._lock"):
        assert counts.get(name, 0) > 0, (name, counts)
    assert any(n.startswith("metrics.") and n != "metrics._lock"
               for n in counts), counts
    # ...the ordering discipline held throughout (no span/metrics call
    # ever nested under bank._lock, tracer/profiler stayed leaves)...
    mon.assert_clean()
    # ...and the PR 7 reconciliation invariants survive instrumentation:
    assert bank.builds + bank.build_failures == bank.misses + bank.prefetches
    build_spans = [e for e in obs.tracer.events()
                   if e["name"] == "bank_build"]
    assert len(build_spans) == bank.builds == len(segs)
    # registry gauges sampled concurrently converged to the bank's final
    # counters once the churn drained
    obs.sample(eng)
    snap = obs.metrics.snapshot()
    assert snap["bank_builds"] == bank.builds
    assert snap["bank_misses"] == bank.misses
    assert snap["bank_prefetches"] == bank.prefetches
