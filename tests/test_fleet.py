"""Fleet router: placement policies, gid identity, determinism, clocks.

Replica engines here are stubs (``apply_fn`` short-circuits the UNet) —
the packed-path numerics are pinned in test_serving, and the full-stack
fleet digest checks live in CI via ``launch.serve_fleet``. What this
suite pins is the routing layer: placement decisions per policy,
``rs.replica``/``rs.gid`` annotations and their pop_result cleanup, the
1-replica golden identity against a bare engine, and deterministic
replay under shared-virtual and per-replica-sim clock topologies.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.common.tree import flatten_paths
from repro.configs.diffusion_presets import tiny_ddim
from repro.diffusion.schedule import make_schedule, sample_timesteps
from repro.launch.serve_diffusion import outcome_digest
from repro.serving import (DiffusionServingEngine, VirtualClock, WeightBank,
                           default_serving_plan)
from repro.serving.fleet import PLACEMENTS, EngineReplica, FleetRouter
from repro.serving.obs import Observability
from repro.serving.traffic import (MetricsCollector, RequestMix,
                                   open_loop_trace, submit_trace)
from repro.serving.traffic.sim import SimClock

T = 40


def _bank(*, per_timestep=False, max_cached=8):
    """Toy single-tensor bank; ``per_timestep`` injects a T-segment
    routing signature through the WeightBank seam so every timestep is
    its own segment (affinity tests need >1 segment to say anything)."""
    params = {"l0": {"w": jnp.ones((4, 4))}}
    plan = default_serving_plan(flatten_paths(params))
    sig = np.arange(T, dtype=np.int32)[:, None] if per_timestep else None
    return WeightBank(params, plan, {}, None, None, T,
                      max_cached=max_cached, signatures=sig)


def _stub_engine(max_batch=3, scale=0.1, per_timestep=False, **kw):
    sched = make_schedule("linear", T)
    return DiffusionServingEngine(
        tiny_ddim(4), sched, _bank(per_timestep=per_timestep),
        max_batch=max_batch,
        apply_fn=lambda params, x, tb, y, ctx, s=scale: s * x, **kw)


def _fleet(n=2, placement="round_robin", clock=None, per_timestep=False,
           **eng_kw):
    fleet = FleetRouter(placement=placement, clock=clock)
    kw = dict(eng_kw)
    if clock is not None:
        kw["clock"] = clock
    for _ in range(n):
        fleet.add_replica(_stub_engine(per_timestep=per_timestep, **kw))
    return fleet


def _seg0(steps=2):
    """The first routing segment every request shares: samplers start at
    the top of their subsequence, so seg0 = segment_of(T - 1)."""
    return int(sample_timesteps(T, steps)[0])


# ---------------------------------------------------------------------------
# Placement policies.
# ---------------------------------------------------------------------------


def test_fleet_round_robin_cycles_replicas():
    fleet = _fleet(2, "round_robin", clock=VirtualClock())
    gids = [fleet.submit(steps=1, seed=i) for i in range(4)]
    res = fleet.run()
    assert set(res) == set(gids)
    names = [fleet.route[g][0] for g in gids]
    assert names == ["r0", "r1", "r0", "r1"]
    s = fleet.stats()["aggregate"]
    assert s["placements"] == {"r0": 2, "r1": 2}
    assert s["placement_reasons"] == {"rr": 4}
    for gid, rs in res.items():
        assert rs.gid == gid
        assert rs.replica == fleet.route[gid][0]


def test_fleet_least_loaded_avoids_busy_replica():
    fleet = _fleet(2, "least_loaded", clock=VirtualClock())
    # load r0 directly (bypassing the router is allowed; such requests
    # just never get gids) so the policy has an imbalance to react to
    r0 = fleet.replica("r0")
    for i in range(3):
        r0.engine.submit(steps=1, seed=10 + i)
    assert r0.load == 3 and fleet.replica("r1").load == 0
    g0 = fleet.submit(steps=1, seed=0)
    g1 = fleet.submit(steps=1, seed=1)
    res = fleet.run()
    # only routed requests surface fleet-side
    assert set(res) == {g0, g1}
    assert fleet.route[g0][0] == "r1" and fleet.route[g1][0] == "r1"
    assert fleet.stats()["aggregate"]["placement_reasons"] == \
        {"least_loaded": 2}


def test_fleet_segment_affinity_routes_to_warm_bank():
    fleet = _fleet(2, "segment_affinity", clock=VirtualClock(),
                   per_timestep=True)
    r1 = fleet.replica("r1")
    seg = r1.bank.segment_of(_seg0())
    r1.bank.prefetch(seg, block=True)
    assert r1.holds(seg) == "cached"
    assert fleet.replica("r0").holds(seg) is None
    g = fleet.submit(steps=2, seed=0)
    fleet.run()
    assert fleet.route[g][0] == "r1"
    assert fleet.stats()["aggregate"]["placement_reasons"]["affinity_hit"] \
        == 1


def test_fleet_segment_affinity_universal_miss_falls_back():
    fleet = _fleet(2, "segment_affinity", clock=VirtualClock(),
                   per_timestep=True)
    g = fleet.submit(steps=2, seed=0)
    fleet.run()
    assert fleet.route[g][0] == "r0"     # least-loaded tiebreak by index
    reasons = fleet.stats()["aggregate"]["placement_reasons"]
    assert reasons["affinity_miss"] >= 1


def test_fleet_segment_affinity_ready_beats_building_beats_load():
    fleet = _fleet(3, "segment_affinity", clock=VirtualClock(),
                   per_timestep=True)
    from repro.serving.fleet.fleet import _Queued
    q = _Queued(gid=0, arrival=0.0, kw={}, seg0=7)
    # monkeypatch holds() so the ranking is tested without racing real
    # background builds
    states = {"r0": "building", "r1": "cached", "r2": "cached"}
    for rep in fleet.replicas:
        rep.holds = lambda seg, s=states[rep.name]: s
    fleet.replica("r2").engine.submit(steps=1, seed=0)   # r2 heavier
    i, reason = fleet._choose(q)
    assert fleet.replicas[i].name == "r1" and reason == "affinity_hit"
    states["r1"] = states["r2"] = None
    for rep in fleet.replicas:
        rep.holds = lambda seg, s=states[rep.name]: s
    i, reason = fleet._choose(q)
    assert fleet.replicas[i].name == "r0" and reason == "affinity_building"


def test_fleet_stub_bank_degrades_affinity_gracefully():
    # the single-segment toy bank can't answer segment_of for steps
    # beyond its schedule? it can — but a bank with no schedule at all
    # (seg0 None) must fall back to least-loaded instead of raising
    fleet = _fleet(2, "segment_affinity", clock=VirtualClock())
    q_seg = fleet._first_segment({"steps": 2})
    assert q_seg == 0      # single-segment bank: everything is segment 0
    g = fleet.submit(steps=2, seed=0)
    res = fleet.run()
    assert g in res


# ---------------------------------------------------------------------------
# Registration + submit surface.
# ---------------------------------------------------------------------------


def test_fleet_rejects_model_routing_duplicates_and_busy_engines():
    with pytest.raises(RuntimeError, match="no replicas"):
        FleetRouter().submit(steps=1)
    with pytest.raises(ValueError, match="placement"):
        FleetRouter(placement="sticky")
    fleet = FleetRouter()
    fleet.add_replica(_stub_engine(), name="a")
    with pytest.raises(ValueError, match="already registered"):
        fleet.add_replica(_stub_engine(), name="a")
    busy = _stub_engine()
    busy.submit(steps=1)
    with pytest.raises(ValueError, match="already has requests"):
        fleet.add_replica(busy)
    with pytest.raises(ValueError, match="gateway"):
        fleet.submit(model="tiny-ddim", steps=1)
    with pytest.raises(KeyError, match="unknown replica"):
        fleet.replica("zzz")
    assert isinstance(fleet.replica("a"), EngineReplica)


def test_fleet_pop_result_prunes_all_bookkeeping():
    fleet = _fleet(2, "round_robin", clock=VirtualClock())
    gids = [fleet.submit(steps=1, seed=i) for i in range(4)]
    res = fleet.run()
    assert len(res) == 4
    for g in gids:
        rs = fleet.pop_result(g)
        assert rs.gid == g
    assert fleet.results == {} and fleet.route == {}
    for rep in fleet.replicas:
        assert rep.gid_of == {}
        assert rep.engine.results == {}
    with pytest.raises(KeyError):
        fleet.pop_result(gids[0])


# ---------------------------------------------------------------------------
# Determinism + the 1-replica golden identity.
# ---------------------------------------------------------------------------


def _trace():
    mix = RequestMix(samplers=("ddim", "plms"), steps=2, steps_jitter=1,
                     priorities=(1, 0))
    return open_loop_trace("poisson", 6, seed=4, mix=mix, rate=30.0)


def test_fleet_one_replica_round_robin_is_bare_engine():
    """The whole point of the run() driver's advance condition: at N=1
    the fleet adds zero behavior — identical digest to engine.run()."""
    reqs = _trace()
    eng = _stub_engine(max_batch=2, clock=VirtualClock())
    submit_trace(eng, reqs)
    direct = outcome_digest(eng.run())

    fleet = _fleet(1, "round_robin", clock=VirtualClock(), max_batch=2)
    submit_trace(fleet, reqs)
    assert outcome_digest(fleet.run()) == direct


@pytest.mark.parametrize("placement", PLACEMENTS)
def test_fleet_replay_is_deterministic(placement):
    reqs = _trace()

    def once():
        fleet = _fleet(2, placement, clock=VirtualClock(), max_batch=2,
                       per_timestep=True)
        collector = MetricsCollector()
        collector.attach(fleet)
        submit_trace(fleet, reqs)
        res = fleet.run()
        for rep in fleet.replicas:
            b = rep.bank
            assert (b.builds + b.build_failures
                    == b.misses + b.prefetches), rep.name
        return (outcome_digest(res), fleet.stats()["aggregate"],
                collector.summary()["goodput_frac"])

    d1, a1, g1 = once()
    d2, a2, g2 = once()
    assert d1 == d2
    assert a1["placements"] == a2["placements"]
    assert a1["placement_reasons"] == a2["placement_reasons"]
    assert a1["bank_hit_rate"] == a2["bank_hit_rate"]
    assert g1 == g2
    assert a1["requests"] + a1["expired"] == 6


def test_fleet_per_replica_sim_clocks_drain():
    """Each replica on its own SimClock axis (parallel hosts): the fleet
    clock is their minimum, the run drains, and per-replica stats
    reconcile with the aggregate."""
    fleet = FleetRouter(placement="round_robin", max_idle_sleep=0.0)
    sims = []
    for _ in range(2):
        sim = SimClock(tick_base_s=0.01, sample_s=0.005)
        eng = _stub_engine(max_batch=2, now_fn=sim.now, max_idle_sleep=0.0)
        sim.attach(eng)
        fleet.add_replica(eng)
        sims.append(sim)
    mix = RequestMix(steps=1, steps_jitter=0)
    submit_trace(fleet, open_loop_trace("poisson", 4, seed=3, mix=mix,
                                        rate=50.0))
    res = fleet.run()
    assert len(res) == 4
    assert all(sim.now() > 0.0 for sim in sims)
    s = fleet.stats()
    assert s["aggregate"]["requests"] == 4
    assert sum(s["aggregate"]["placements"].values()) == 4
    assert sum(p["engine"]["requests"]
               for p in s["per_replica"].values()) == 4


def test_fleet_route_instants_and_replica_labels():
    obs = Observability()
    clock = VirtualClock()
    fleet = FleetRouter(placement="round_robin", clock=clock, obs=obs)
    for _ in range(2):
        fleet.add_replica(_stub_engine(max_batch=2, clock=clock, obs=obs))
    gids = [fleet.submit(steps=1, seed=i) for i in range(3)]
    fleet.run()
    routes = [e for e in obs.tracer.events()
              if e.get("ph") == "i" and e["name"] == "route"]
    assert len(routes) == 3
    assert {e["args"]["gid"] for e in routes} == set(gids)
    assert {e["args"]["replica"] for e in routes} == {"r0", "r1"}
    for e in routes:
        assert e["cat"] == "fleet"
        assert e["args"]["placement"] == "round_robin"
        assert e["args"]["reason"] == "rr"
