"""Launch-layer units: input specs, abstract quantization, grad accum."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.configs.shapes import SHAPES, ShapeSpec, cells, LONG_OK
from repro.core.qmodule import PackedW4
from repro.common.tree import flatten_paths
from repro.launch.steps import (abstract_params, input_specs,
                                make_train_step, quantize_abstract)
from repro.launch.dryrun import with_depth
from repro.models.lm import lm_init
from repro.optim.adam import AdamConfig, adam_init

KEY = jax.random.PRNGKey(0)


def test_cells_cover_40_minus_long_skips():
    from repro.configs.registry import ARCH_IDS
    cs = cells(ARCH_IDS)
    assert len(cs) == 10 * 4 - (10 - len(LONG_OK))
    assert ("mamba2-370m", "long_500k") in cs
    assert ("qwen1.5-0.5b", "long_500k") not in cs


def test_input_specs_shapes():
    cfg = get_config("llava-next-mistral-7b")
    sp = input_specs(cfg, SHAPES["prefill_32k"])
    assert sp["batch"]["tokens"].shape == (32, 32768)
    assert sp["batch"]["extra"].shape == (32, 576, 1024)
    spd = input_specs(cfg, SHAPES["decode_32k"])
    assert spd["token"].shape == (128, 1)
    # llava caches: (groups, B, S, kv, hd)
    k = spd["caches"]["blocks"][0]["k"]
    assert k.shape == (32, 128, 32768, 8, 128)


def test_decode_specs_windowed_cache_is_ring_sized():
    cfg = get_config("gemma3-27b")
    spd = input_specs(cfg, SHAPES["long_500k"])
    local_k = spd["caches"]["blocks"][0]["k"]      # window=1024 ring
    global_k = spd["caches"]["blocks"][5]["k"]     # global layer
    assert local_k.shape[2] == 1024
    assert global_k.shape[2] == 524288


def test_quantize_abstract_marks_only_big_weights():
    cfg = get_config("qwen1.5-0.5b", smoke=True)
    ap = abstract_params(cfg)
    qt = quantize_abstract(ap)
    flat = jax.tree_util.tree_flatten_with_path(qt)[0]
    kinds = {type(l).__name__ for _, l in flat}
    # embeddings stay dense (io convention); matmuls become packed
    has_packed = any(isinstance(l, jax.ShapeDtypeStruct) is False
                     for _, l in flat)
    from repro.common.tree import flatten_paths as fp
    # embed stays a ShapeDtypeStruct
    assert isinstance(qt["embed"], jax.ShapeDtypeStruct)


def test_quantize_for_serving_per_channel_scales():
    """per_channel=True must produce channel-resolved scales for both 2D
    and stacked (scanned) weights, and stay numerically close to dense."""
    from repro.launch.steps import quantize_lm_for_serving

    key = jax.random.PRNGKey(0)
    w2d = jax.random.normal(key, (16, 8))
    w3d = jax.random.normal(key, (3, 16, 8))  # (groups, in, out)
    params = {"attn": {"wq": {"w": w2d}}, "blocks": [{"mlp": {"down": {"w": w3d}}}]}
    q = quantize_lm_for_serving(params, searched=False, per_channel=True)
    pq = q["attn"]["wq"]["w"]
    assert isinstance(pq, PackedW4) and pq.scale.shape == (8,)
    ps = q["blocks"][0]["mlp"]["down"]["w"]
    assert isinstance(ps, PackedW4) and ps.scale.shape == (3, 1, 8)
    # per-channel dequant error <= per-tensor dequant error (same format)
    from repro.core.qmodule import dequant_weight
    qt = quantize_lm_for_serving(params, searched=False, per_channel=False)
    err_pc = float(jnp.mean((dequant_weight(ps, jnp.float32) - w3d) ** 2))
    err_pt = float(jnp.mean((dequant_weight(
        qt["blocks"][0]["mlp"]["down"]["w"], jnp.float32) - w3d) ** 2))
    assert err_pc <= err_pt + 1e-9


def test_with_depth_preserves_period():
    cfg = get_config("gemma3-27b")
    c1 = with_depth(cfg, 1)
    assert c1.n_groups == 1 and c1.first_k_dense == cfg.first_k_dense
    assert c1.n_layers == cfg.first_k_dense + cfg.period


@pytest.mark.slow
def test_grad_accum_matches_single_step():
    cfg = get_config("smollm-135m", smoke=True)
    p = lm_init(KEY, cfg)
    acfg = AdamConfig(lr=1e-3, clip_norm=None)
    opt = adam_init(p, acfg)
    toks = jax.random.randint(KEY, (4, 16), 0, cfg.vocab)
    batch = {"tokens": toks}
    s1 = make_train_step(cfg, acfg, grad_accum=1)
    s2 = make_train_step(cfg, acfg, grad_accum=2)
    p1, _, m1 = jax.jit(s1)(p, opt, batch)
    p2, _, m2 = jax.jit(s2)(p, opt, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=2e-2)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))), p1, p2)
    assert max(jax.tree.leaves(d)) < 5e-2
