"""SLO-aware scheduling: slack-aware selection, preemption, async prefetch.

Covers the deadline path's edges (mid-flight deadline pass, preemption at
the exact tick boundary, all-expired ticks) under both policies, the
fifo-vs-slo goodput discriminator on the ``tight_deadlines`` scenario,
and the threading contract of the weight bank's background prefetch
(digest-identical replay, single-build guarantee, counter
reconciliation). Engine runs use a stub ``apply_fn`` and a simulated
clock — the numerics are test_serving's job; what matters here is who
runs when.
"""
import dataclasses
import threading

import jax
import numpy as np
import pytest

from tests._serving_fixtures import (SCHED, mk_inflight as _mk_inflight,
                                     multi_segment_bank as
                                     _multi_segment_bank,
                                     single_segment_bank as
                                     _single_segment_bank)

from repro.configs.diffusion_presets import tiny_ddim
from repro.diffusion.samplers import sampler_init
from repro.serving import (ContinuousBatcher, DiffusionServingEngine,
                           GenRequest, RequestState, VirtualClock)
from repro.serving.scheduler import CostModel, bucket_of, remaining_evals
from repro.serving.traffic import (MetricsCollector, SimClock, get_scenario,
                                   load_trace, run_scenario, submit_trace)
from repro.serving.traffic.scenarios import resolve_trace_path

GOLDEN = "tests/data/golden_trace.jsonl"


def _stub_engine(max_batch=3, bank=None, **kw):
    return DiffusionServingEngine(
        tiny_ddim(4), SCHED, bank or _single_segment_bank(),
        max_batch=max_batch,
        apply_fn=lambda params, x, tb, y, ctx: 0.1 * x, **kw)


# ---------------------------------------------------------------------------
# Slack-aware selection.
# ---------------------------------------------------------------------------


def test_cost_model_ewma_and_buckets():
    cm = CostModel(alpha=0.5)
    assert cm.eval_s(5) == 0.0           # unobserved: pure EDF slack
    cm.observe_eval(0.8, 8)              # 8 padded rows -> 0.1/row
    assert cm.sample_s == pytest.approx(0.1)
    cm.observe_eval(0.0, 4)              # zero-duration ignored (virtual)
    assert cm.sample_s == pytest.approx(0.1)
    cm.observe_eval(0.2, 1)              # ewma toward 0.2
    assert cm.sample_s == pytest.approx(0.15)
    assert cm.eval_s(3) == pytest.approx(0.15 * 4)   # pads to bucket 4
    cm.observe_switch(0.5)
    cm.observe_switch(0.3)
    assert cm.switch_s == pytest.approx(0.4)
    assert bucket_of(1) == 1 and bucket_of(5) == 8


def test_slo_select_prefers_urgent_group_over_largest():
    b = ContinuousBatcher(max_batch=8, starvation_ticks=10, policy="slo")
    big = [_mk_inflight(b, i) for i in range(3)]           # no deadlines
    urgent = [_mk_inflight(b, 9, deadline=0.5)]
    groups = {0: big, 1: urgent}
    seg, members = b.select(groups, tick=1, now=0.0)
    assert seg == 1 and members == urgent
    # fifo picks the big group in the same state
    b.policy = "fifo"
    seg, members = b.select(groups, tick=1, now=0.0)
    assert seg == 0 and members == big


def test_slo_select_stays_on_current_segment_without_pressure():
    """No deadline pressure: the switch penalty keeps the scheduler on
    the current (or warm) bank segment even against a bigger group."""
    b = ContinuousBatcher(max_batch=8, starvation_ticks=10, policy="slo")
    b.cost.switch_s = 5.0
    cur = [_mk_inflight(b, 0)]
    big = [_mk_inflight(b, i) for i in (1, 2)]
    groups = {3: cur, 4: big}
    b.current_seg = 3
    seg, _ = b.select(groups, tick=1, now=0.0)
    assert seg == 3
    # a warm segment pays no penalty -> the bigger group wins again
    b.segment_warm = lambda s: True
    seg, _ = b.select(groups, tick=1, now=0.0)
    assert seg == 4
    # and with no cost estimate yet, size breaks the tie as before
    b.segment_warm = None
    b.cost.switch_s = 0.0
    seg, _ = b.select(groups, tick=1, now=0.0)
    assert seg == 4


def test_slo_select_ignores_already_missed_deadlines():
    """A member whose deadline has already passed is a guaranteed miss:
    it must exert no EDF pressure (its group scores like a deadline-free
    one), so still-savable groups are not starved by a lost cause."""
    b = ContinuousBatcher(max_batch=8, starvation_ticks=10, policy="slo")
    doomed = [_mk_inflight(b, 0, steps=5, deadline=0.5)]   # now=2.0: past
    big = [_mk_inflight(b, i) for i in (1, 2)]             # no deadlines
    savable = [_mk_inflight(b, 9, deadline=2.4)]           # 0.4s slack
    seg, members = b.select({0: doomed, 1: big, 2: savable}, tick=1,
                            now=2.0)
    assert seg == 2 and members == savable
    # without the savable group, the doomed one ties at the horizon and
    # the larger group wins
    seg, _ = b.select({0: doomed, 1: big}, tick=1, now=2.0)
    assert seg == 1


def test_slack_and_splits_price_cfg_pairs_per_partition():
    """A guided request contributes a row to BOTH class-conditioning
    partitions, each padded to its own bucket, so group cost — and
    therefore split decisions — must sum per-partition buckets."""
    b = ContinuousBatcher(max_batch=8, starvation_ticks=10, policy="slo")
    b.cost.sample_s = 0.1
    tight = _mk_inflight(b, 0, steps=1, deadline=0.29)
    guided = _mk_inflight(b, 1, steps=1, guidance_scale=2.0)
    # partitions: y=None holds the uncond row (bucket 1), y-labeled
    # holds tight + cond (bucket 2) -> 3 padded rows -> 0.3s > 0.29:
    # tight only because the CFG pair spills into both partitions (two
    # plain labeled members would cost bucket 2 = 0.2s and meet); alone
    # (1 row) it meets -> split
    seg, members = b.select({0: [tight, guided]}, tick=1, now=0.0)
    assert members == [tight] and b.preemptions == 1


def test_slo_select_starvation_backstop_overrides_urgency():
    b = ContinuousBatcher(max_batch=8, starvation_ticks=3, policy="slo")
    urgent = [_mk_inflight(b, 0, deadline=0.1, last_tick=9)]
    starved = [_mk_inflight(b, 1, last_tick=2)]
    groups = {0: urgent, 1: starved}
    seg, members = b.select(groups, tick=9, now=0.0)
    assert seg == 1 and members == starved


# ---------------------------------------------------------------------------
# Preemption (group splits).
# ---------------------------------------------------------------------------


def test_preemption_splits_group_and_counts_saves():
    b = ContinuousBatcher(max_batch=8, starvation_ticks=10, policy="slo")
    b.cost.sample_s = 0.1                 # eval cost 0.1 * bucket
    tight = _mk_inflight(b, 0, steps=1, deadline=0.39)
    loose = [_mk_inflight(b, i, steps=1) for i in (1, 2)]
    groups = {0: [tight] + loose}
    # full group pads to bucket 4 -> 0.4s > deadline; alone (bucket 1)
    # the tight request still makes it -> split
    seg, members = b.select(groups, tick=1, now=0.0)
    assert seg == 0 and members == [tight]
    assert b.preemptions == 2             # two deferred members
    # the save is only booked when the tight request retires in time
    assert b.deadline_saves == 0
    tight.finished_at = 0.2
    b.retire(tight)
    assert b.deadline_saves == 1


def test_preemption_exact_tick_boundary_is_a_meet_not_a_split():
    """slack == 0 at the full bucket means the deadline is met exactly;
    the group must NOT split (strict inequality)."""
    b = ContinuousBatcher(max_batch=8, starvation_ticks=10, policy="slo")
    b.cost.sample_s = 0.1
    tight = _mk_inflight(b, 0, steps=1, deadline=0.4)   # 0.1 * bucket(4)
    loose = [_mk_inflight(b, i, steps=1) for i in (1, 2)]
    groups = {0: [tight] + loose}
    seg, members = b.select(groups, tick=1, now=0.0)
    assert members == [tight] + loose and b.preemptions == 0
    # one epsilon past the boundary it splits
    b2 = ContinuousBatcher(max_batch=8, starvation_ticks=10, policy="slo")
    b2.cost.sample_s = 0.1
    tight2 = _mk_inflight(b2, 0, steps=1, deadline=0.4 - 1e-6)
    loose2 = [_mk_inflight(b2, i, steps=1) for i in (1, 2)]
    _, members2 = b2.select({0: [tight2] + loose2}, tick=1, now=0.0)
    assert members2 == [tight2] and b2.preemptions == 2


def test_preemption_split_always_runs_the_saved_tight_member():
    """A merely-low-slack member that would still meet its deadline at
    the full bucket must not displace the tight member whose save
    justified the split (regression: the run prefix was ordered by raw
    slack over all members)."""
    b = ContinuousBatcher(max_batch=8, starvation_ticks=10, policy="slo")
    b.cost.sample_s = 0.1
    # A: tight at bucket 4 (10 evals -> needs 4.0s, deadline 2.0), saved
    # at bucket 1 (1.0s); B: NOT tight (0.1s spare at bucket 4) but lower
    # slack than A at bucket 1; C: no deadline
    a = _mk_inflight(b, 0, steps=10, deadline=2.0)
    _mk_inflight(b, 1, steps=1, deadline=0.5)
    _mk_inflight(b, 2, steps=1)
    seg, members = b.select({0: b.inflight}, tick=1, now=0.0)
    assert members == [a]
    assert b.preemptions == 2


def test_preemption_never_defers_doomed_or_starving_members():
    b = ContinuousBatcher(max_batch=8, starvation_ticks=10, policy="slo")
    b.cost.sample_s = 0.1
    # everyone tight -> splitting cannot save anyone -> no split
    m = [_mk_inflight(b, i, steps=1, deadline=0.39) for i in range(3)]
    _, members = b.select({0: m}, tick=1, now=0.0)
    assert len(members) == 3 and b.preemptions == 0
    # a member one tick from the starvation backstop blocks the split
    b2 = ContinuousBatcher(max_batch=8, starvation_ticks=4, policy="slo")
    b2.cost.sample_s = 0.1
    tight = _mk_inflight(b2, 0, steps=1, deadline=0.39, last_tick=9)
    aging = _mk_inflight(b2, 1, steps=1, last_tick=6)   # gap 3 == starve-1
    fresh = _mk_inflight(b2, 2, steps=1, last_tick=9)
    _, members2 = b2.select({0: [tight, aging, fresh]}, tick=9, now=0.0)
    assert len(members2) == 3 and b2.preemptions == 0


def test_split_ignores_doomed_members_when_sizing_the_bucket():
    """An already-missed member must not count as tight: it would
    inflate the small bucket and cancel a split that saves a
    still-reachable groupmate."""
    b = ContinuousBatcher(max_batch=8, starvation_ticks=10, policy="slo")
    b.cost.sample_s = 0.1
    doomed = _mk_inflight(b, 0, steps=1, deadline=0.1,
                          guidance_scale=2.0)          # 2 rows, past due
    savable = _mk_inflight(b, 1, steps=1, deadline=1.35)
    loose = _mk_inflight(b, 2, steps=1)
    # now=1.0: full bucket = bucket_of(4 rows) -> 0.4s; savable misses at
    # the full bucket (1.35 < 1.4) but meets alone (1.1 <= 1.35). If the
    # doomed member counted as tight, small bucket would equal the full
    # one and the split would be cancelled.
    seg, members = b.select({0: [doomed, savable, loose]}, tick=1, now=1.0)
    assert members == [savable]
    assert b.preemptions == 2


def test_split_spare_capacity_prefers_savable_over_doomed():
    """When a split leaves spare bucket rows, a still-savable member
    must take them ahead of an already-missed one (whose hugely negative
    raw slack would otherwise rank it most urgent)."""
    b = ContinuousBatcher(max_batch=8, starvation_ticks=10, policy="slo")
    b.cost.sample_s = 0.1
    now = 1.0
    tight = [_mk_inflight(b, i, steps=1, deadline=now + 0.45)
             for i in range(3)]
    doomed = _mk_inflight(b, 3, steps=1, deadline=0.5)     # already past
    savable = _mk_inflight(b, 4, steps=1, deadline=now + 0.85)
    # full bucket: 5 rows -> 8 -> 0.8s (tight members miss, savable just
    # meets); small bucket: 3 rows -> 4 -> 0.4s with one spare row
    seg, members = b.select({0: tight + [doomed, savable]}, tick=1,
                            now=now)
    assert members == tight + [savable]    # spare row goes to the live one
    assert b.preemptions == 1              # doomed deferred


def test_fifo_policy_never_preempts_and_rejects_unknown_policy():
    b = ContinuousBatcher(max_batch=4, policy="fifo")
    b.cost.sample_s = 0.1
    tight = _mk_inflight(b, 0, steps=1, deadline=0.01)
    loose = [_mk_inflight(b, i, steps=1) for i in (1, 2)]
    _, members = b.select({0: [tight] + loose}, tick=1, now=0.0)
    assert len(members) == 3 and b.preemptions == 0
    with pytest.raises(AssertionError, match="policy"):
        ContinuousBatcher(policy="edf")


def test_remaining_evals_counts_dpm_double():
    st = sampler_init("ddim", SCHED, (1, 2, 2, 3), jax.random.PRNGKey(0),
                      steps=3)
    assert remaining_evals(RequestState(GenRequest(0, steps=3), st)) == 3
    st2 = sampler_init("dpm_solver2", SCHED, (1, 2, 2, 3),
                       jax.random.PRNGKey(0), steps=3)
    assert remaining_evals(RequestState(GenRequest(1, steps=3), st2)) == 6


# ---------------------------------------------------------------------------
# Deadline-path edges through the engine.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", ["fifo", "slo"])
def test_deadline_passing_mid_flight_completes_as_miss(policy):
    """A request whose deadline passes while in flight must run to
    completion and score as a deadline miss — never as an expiry."""
    clock = [0.0]
    eng = _stub_engine(max_batch=2, policy=policy, now_fn=lambda: clock[0])
    col = MetricsCollector().attach(eng)
    eng.submit(steps=3, arrival=0.0, deadline=0.5)
    eng.on_tick_end.append(lambda e: clock.__setitem__(0, clock[0] + 0.4))
    res = eng.run()
    rs = res[0]
    assert not rs.expired and rs.state.done and rs.x0 is not None
    assert rs.n_evals == 3
    assert rs.finished_at > rs.req.deadline        # finished late...
    s = col.summary()
    assert s["requests"] == 1 and s["expired"] == 0
    assert s["deadline_misses"] == 1               # ...and scored as a miss
    assert eng.stats()["expired"] == 0


@pytest.mark.parametrize("policy", ["fifo", "slo"])
def test_all_expired_admission_wave_is_safe(policy):
    """A tick whose whole admission wave expires must produce an empty
    group set without reaching selection (no crash, callbacks fire)."""
    clock = [10.0]
    eng = _stub_engine(policy=policy, now_fn=lambda: clock[0])
    ticks = []
    eng.on_tick_end.append(lambda e: ticks.append(e.tick_count))
    for i in range(3):
        eng.submit(steps=1, arrival=0.0, deadline=1.0 + i)
    res = eng.run()
    assert len(res) == 3 and all(rs.expired for rs in res.values())
    assert eng.n_expired == 3 and eng.n_finished == 0
    assert ticks, "on_tick_end must fire even on empty ticks"
    for rs in res.values():
        assert rs.finished_at > rs.req.deadline


def test_expiry_boundary_is_strict():
    """At now == deadline a request is still admissible (expiry needs
    now strictly past the deadline)."""
    clock = [1.0]
    eng = _stub_engine(now_fn=lambda: clock[0])
    eng.submit(steps=1, arrival=0.0, deadline=1.0)
    res = eng.run()
    assert not res[0].expired and res[0].n_evals == 1


# ---------------------------------------------------------------------------
# fifo vs slo: the tight_deadlines discriminator.
# ---------------------------------------------------------------------------


def _run_policy(policy, scn, *, tick_base=0.02, sample_s=0.015):
    clock = SimClock(tick_base_s=tick_base, sample_s=sample_s)
    eng = _stub_engine(max_batch=scn.max_batch, bank=_multi_segment_bank(),
                       policy=policy, now_fn=clock.now, max_idle_sleep=0.0)
    clock.attach(eng)
    summary = run_scenario(scn, eng, seed=0)
    return summary, eng


def test_sim_clock_charges_the_forward_before_completion_stamps():
    """A completion must pay for its own tick: deadline verdicts at the
    exact service cost are misses, not one-tick-early meets."""
    cost = 0.02 + 0.015 * 1               # base + one padded row
    for deadline, met in ((cost - 1e-3, False), (cost + 1e-3, True)):
        clock = SimClock()
        eng = _stub_engine(max_batch=1, now_fn=clock.now,
                           max_idle_sleep=0.0)
        clock.attach(eng)
        eng.submit(steps=1, arrival=0.0, deadline=deadline)
        res = eng.run()
        rs = res[0]
        assert not rs.expired
        assert rs.finished_at == pytest.approx(cost)
        assert (rs.finished_at <= deadline) is met


def test_compile_ticks_do_not_poison_the_cost_ewma():
    """A tick that traced+compiled a new (bucket, has_y) forward must
    not feed its (compile-inflated) duration into sample_s."""
    clock = SimClock()
    eng = _stub_engine(max_batch=1, now_fn=clock.now, max_idle_sleep=0.0)
    clock.attach(eng)                     # primes sample_s = 0.015
    eng.submit(steps=1, arrival=0.0)
    eng.run()
    # single tick, fresh jit entry -> observation skipped, prime intact
    assert eng.batcher.cost.sample_s == clock.sample_s
    eng.submit(steps=1, arrival=0.0)      # same bucket: now observed
    eng.run()
    assert eng.batcher.cost.sample_s != clock.sample_s


def test_tight_deadlines_scenario_slo_beats_fifo():
    """The registry's fifo-vs-slo discriminator: largest-group-wins
    demonstrably fails the tight tier that slack-aware selection meets,
    on the same deterministic simulated clock."""
    scn = get_scenario("tight_deadlines")
    scn = dataclasses.replace(
        scn, n_requests=12, max_batch=8,
        mix=dataclasses.replace(scn.mix, steps=5, steps_jitter=1))
    sum_f, eng_f = _run_policy("fifo", scn)
    sum_s, eng_s = _run_policy("slo", scn)
    # both serve every request...
    assert sum_f["requests"] + sum_f["expired"] == 12
    assert sum_s["requests"] + sum_s["expired"] == 12
    # ...but only the slack-aware policy meets the tight tier
    assert sum_s["goodput_frac"] > sum_f["goodput_frac"]
    assert eng_f.stats()["preemptions"] == 0
    # determinism: the whole comparison replays bit-identically
    sum_f2, _ = _run_policy("fifo", scn)
    sum_s2, _ = _run_policy("slo", scn)
    assert sum_f2["goodput_frac"] == sum_f["goodput_frac"]
    assert sum_s2["goodput_frac"] == sum_s["goodput_frac"]


# ---------------------------------------------------------------------------
# Async prefetch: determinism + threading contract.
# ---------------------------------------------------------------------------


def test_golden_replay_digest_identical_with_prefetch_on_off():
    reqs, _ = load_trace(resolve_trace_path(GOLDEN))

    def replay(prefetch):
        eng = _stub_engine(max_batch=2, bank=_multi_segment_bank(),
                           clock=VirtualClock(), prefetch=prefetch)
        assert not eng.async_prefetch     # virtual clock forces sync builds
        submit_trace(eng, reqs)
        res = eng.run()
        return {rid: (rs.n_evals, np.asarray(rs.x0).tobytes())
                for rid, rs in res.items()}

    assert replay(True) == replay(False)


def test_async_prefetch_overlaps_and_matches_sync_outputs():
    def run(async_prefetch):
        bank = _multi_segment_bank()
        eng = _stub_engine(max_batch=2, bank=bank,
                           async_prefetch=async_prefetch)
        for i in range(4):                # churn: staggered submit/retire
            eng.submit(steps=5 + i % 3, seed=i)
        res = eng.run()
        return bank, {r: np.asarray(rs.x0).tobytes()
                      for r, rs in res.items()}

    bank_a, out_a = run(True)
    bank_s, out_s = run(False)
    assert out_a == out_s                  # threading never changes outputs
    for bank in (bank_a, bank_s):
        assert not bank._building          # run() drains
        assert bank.builds == bank.misses + bank.prefetches
    assert bank_a.prefetches >= 1


def test_threaded_churn_never_builds_a_segment_twice():
    bank = _multi_segment_bank()
    bank.max_cached = bank.n_segments      # no evictions -> one build each
    n_built = {}
    built_lock = threading.Lock()
    orig_build = bank._build

    def counting_build(seg):
        with built_lock:
            n_built[seg.index] = n_built.get(seg.index, 0) + 1
        return orig_build(seg)

    bank._build = counting_build
    segs = list(range(bank.n_segments))
    errs = []

    def worker(wid):
        rng = np.random.default_rng(wid)
        try:
            for _ in range(30):
                seg = int(rng.choice(segs))
                if rng.random() < 0.5:
                    bank.prefetch(seg, block=bool(rng.random() < 0.3))
                else:
                    bank.params_for_segment(seg)
        except Exception as e:             # surface from the thread
            errs.append(e)

    threads = [threading.Thread(target=worker, args=(w,)) for w in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    bank.drain()
    assert not errs
    assert set(n_built) == set(segs)
    assert all(n == 1 for n in n_built.values()), n_built
    # counter reconciliation: every build was either a miss or a prefetch
    assert bank.builds == bank.misses + bank.prefetches == len(segs)
    d = bank.describe()
    assert d["builds"] == len(segs) and d["build_joins"] == bank.build_joins


def test_failed_background_build_counts_and_keeps_reconciliation():
    """A prefetch build that raises on the worker thread must not
    silently break builds + build_failures == misses + prefetches, and
    the segment must remain buildable afterwards."""
    bank = _multi_segment_bank()
    orig_build = bank._build
    bank._build = lambda seg: (_ for _ in ()).throw(RuntimeError("boom"))
    assert bank.prefetch(0, block=False)
    bank.drain()                            # swallows the ownerless error
    assert bank.build_failures == 1 and bank.builds == 0
    assert bank.builds + bank.build_failures == (bank.misses
                                                 + bank.prefetches)
    assert not bank.is_cached(0)
    bank._build = orig_build                # segment recovers on retry
    bank.params_for_segment(0)
    assert bank.is_cached(0)
    assert bank.builds + bank.build_failures == (bank.misses
                                                 + bank.prefetches) == 2
    assert bank.describe()["build_failures"] == 1


def test_prefetch_nonblocking_returns_false_while_building():
    bank = _multi_segment_bank()
    started = bank.prefetch(0, block=False)
    again = bank.prefetch(0, block=False)  # already building or cached
    bank.drain()
    assert started and not again
    assert bank.builds == 1 and bank.prefetches == 1
    assert bank.is_cached(0)
