"""Randomized scheduler-invariant suite (seeded; both selection policies).

Each property drives the full engine (stub ``apply_fn`` — numerics are
test_serving's job) over a random trace under a deterministic simulated
clock and checks the contracts the serving layer is built on:

  * liveness   — every admitted request eventually completes; every
    submitted request ends up in ``results`` exactly once,
  * starvation — no in-flight request goes more than
    ``starvation_ticks + max_batch`` compute ticks without advancing
    (the backstop promotes the oldest starved request's group, and a
    preemptive split may never defer a member the backstop protects),
  * admission  — each admission wave orders by (priority desc, arrival,
    rid) and only due requests are admitted,
  * expiry     — only already-due requests whose deadline has passed are
    expired, and expired requests never ran.

The randomized sweeps come from ``tests/_hypothesis_compat`` (real
hypothesis when installed, a seeded deterministic fallback otherwise),
so failures reproduce by seed.
"""
import jax
import numpy as np

from tests._hypothesis_compat import given, settings, strategies as st
from tests._serving_fixtures import (SCHED, mk_inflight as _mk_inflight_fx,
                                     multi_segment_bank as
                                     _multi_segment_bank)

from repro.configs.diffusion_presets import tiny_ddim
from repro.diffusion.samplers import sampler_init
from repro.serving import (ContinuousBatcher, DiffusionServingEngine,
                           GenRequest, RequestState)

POLICIES = ("fifo", "slo")


def _random_engine(rng, policy):
    """(engine, clock, trace_params) with a per-tick simulated clock."""
    max_batch = int(rng.integers(1, 5))
    starve = int(rng.integers(2, 5))
    clock = [0.0]
    eng = DiffusionServingEngine(
        tiny_ddim(4), SCHED, _multi_segment_bank(),
        max_batch=max_batch, starvation_ticks=starve, policy=policy,
        apply_fn=lambda p, x, tb, y, ctx: 0.1 * x,
        now_fn=lambda: clock[0], max_idle_sleep=0.0)
    eng.on_tick_end.append(lambda e: clock.__setitem__(0, clock[0] + 0.05))
    # prime the cost model so the slo slack / preemption paths are live
    # (sim compute takes zero clock time, so nothing is observed)
    eng.batcher.cost.sample_s = 0.01
    eng.batcher.cost.switch_s = 0.02
    return eng, clock


def _random_trace(rng, eng, n):
    for i in range(n):
        arrival = float(rng.uniform(0.0, 0.6))
        deadline = (None if rng.random() < 0.4
                    else arrival + float(rng.uniform(0.05, 1.5)))
        eng.submit(steps=int(rng.integers(1, 4)),
                   seed=i,
                   sampler=str(rng.choice(["ddim", "plms", "dpm_solver2"])),
                   arrival=arrival, deadline=deadline,
                   priority=int(rng.integers(0, 4)))


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from(POLICIES))
def test_engine_random_trace_invariants(seed, policy):
    rng = np.random.default_rng(seed)
    eng, clock = _random_engine(rng, policy)
    n = int(rng.integers(2, 9))

    starve = eng.batcher.starvation_ticks
    max_batch = eng.batcher.max_batch
    gap_violations = []

    def watch_starvation(e):
        for rs in e.batcher.inflight:
            if rs.last_advance_tick < 0:
                continue
            gap = e.tick_count - rs.last_advance_tick
            if gap > starve + max_batch:
                gap_violations.append((rs.req.rid, gap, e.tick_count))

    eng.on_tick_end.append(watch_starvation)

    waves = []
    orig_admit = eng.batcher.admit

    def recording_admit(now, tick):
        admitted, expired = orig_admit(now, tick)
        waves.append((now, [(r.req.priority, r.req.arrival, r.req.rid)
                            for r in admitted]))
        return admitted, expired

    eng.batcher.admit = recording_admit

    _random_trace(rng, eng, n)
    res = eng.run()

    # liveness: every submitted request resolves exactly once
    assert sorted(res) == list(range(n))
    for rid, rs in res.items():
        if rs.expired:
            # expiry only ever happens to an already-due request past its
            # deadline, and an expired request never ran
            assert rs.req.deadline is not None
            assert rs.finished_at > rs.req.deadline
            assert rs.finished_at >= rs.req.arrival
            assert rs.n_evals == 0 and rs.x0 is None
        else:
            assert rs.state.done and rs.x0 is not None
            assert rs.n_evals >= rs.req.steps  # dpm runs extra mid evals
            assert rs.finished_at is not None

    # starvation bound holds at every tick
    assert not gap_violations, gap_violations

    # each admission wave orders by (priority desc, arrival, rid) and
    # admits only due requests
    for now, wave in waves:
        assert wave == sorted(wave, key=lambda k: (-k[0], k[1], k[2]))
        assert all(arr <= now for _, arr, _ in wave)

    # scheduler accounting is consistent
    assert eng.n_finished + eng.n_expired == n
    assert not eng.batcher.inflight and not eng.batcher.pending
    if policy == "fifo":
        assert eng.batcher.preemptions == 0


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_batcher_admit_respects_priority_arrival_rid(seed):
    """Direct ContinuousBatcher.admit property: slots, order, expiry."""
    rng = np.random.default_rng(seed)
    max_batch = int(rng.integers(1, 5))
    b = ContinuousBatcher(max_batch=max_batch,
                          policy=str(rng.choice(POLICIES)))
    n = int(rng.integers(1, 10))
    key = jax.random.PRNGKey(0)
    for rid in range(n):
        arrival = float(rng.uniform(0.0, 2.0))
        deadline = (None if rng.random() < 0.5
                    else arrival + float(rng.uniform(-0.5, 1.0)))
        st_ = sampler_init("ddim", SCHED, (1, 2, 2, 3), key, steps=1)
        b.submit(RequestState(
            GenRequest(rid, steps=1, arrival=arrival, deadline=deadline,
                       priority=int(rng.integers(0, 3))), st_))
    now = float(rng.uniform(0.0, 2.5))
    admitted, expired = b.admit(now, tick=0)

    keys = [(-r.req.priority, r.req.arrival, r.req.rid) for r in admitted]
    assert keys == sorted(keys)
    assert len(b.inflight) <= max_batch
    for rs in admitted:
        assert rs.req.arrival <= now and not rs.expired
        assert rs.admitted_at == now
    for rs in expired:
        assert rs.expired
        assert rs.req.arrival <= now
        assert rs.req.deadline is not None and now > rs.req.deadline
    # nothing admitted or expired stays pending; everything else does
    leftover = {r.req.rid for r in b.pending}
    taken = {r.req.rid for r in admitted} | {r.req.rid for r in expired}
    assert leftover.isdisjoint(taken)
    assert leftover | taken == set(range(n))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_batcher_pending_order_independent_of_submit_order(seed):
    """Regression for the insort submit: a shuffled trace must leave
    ``pending`` in exactly the (arrival, rid) order an in-order ingest
    produces — admission waves can't depend on ingest order."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 12))
    key = jax.random.PRNGKey(0)
    reqs = [(rid, float(rng.uniform(0.0, 2.0))) for rid in range(n)]
    # duplicate arrivals exercise the rid tiebreak
    if n >= 4:
        reqs[1] = (1, reqs[0][1])

    def ingest(order):
        b = ContinuousBatcher(max_batch=4)
        for rid, arrival in order:
            st_ = sampler_init("ddim", SCHED, (1, 2, 2, 3), key, steps=1)
            b.submit(RequestState(
                GenRequest(rid, steps=1, arrival=arrival), st_))
        return [(r.req.arrival, r.req.rid) for r in b.pending]

    in_order = ingest(sorted(reqs, key=lambda x: (x[1], x[0])))
    shuffled = list(reqs)
    rng.shuffle(shuffled)
    assert ingest(shuffled) == in_order == sorted(in_order)


def _mk_inflight(b, rid, *, deadline=None, last_tick=0):
    return _mk_inflight_fx(b, rid, steps=2, deadline=deadline,
                           last_tick=last_tick)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from(POLICIES))
def test_select_is_deterministic_and_serves_from_groups(seed, policy):
    """select() is a pure function of (groups, tick, now) given fixed
    scheduler state, and always returns a non-empty subset of one group."""
    rng = np.random.default_rng(seed)
    b = ContinuousBatcher(max_batch=8, starvation_ticks=3, policy=policy)
    b.cost.sample_s = 0.01
    groups = {}
    rid = 0
    for seg in range(int(rng.integers(1, 4))):
        members = []
        for _ in range(int(rng.integers(1, 4))):
            deadline = (None if rng.random() < 0.5
                        else float(rng.uniform(0.0, 1.0)))
            members.append(_mk_inflight(b, rid, deadline=deadline,
                                        last_tick=int(rng.integers(0, 6))))
            rid += 1
        groups[seg] = members
    now = float(rng.uniform(0.0, 1.0))
    seg1, mem1 = b.select(groups, tick=6, now=now)
    seg2, mem2 = b.select(groups, tick=6, now=now)
    assert seg1 == seg2 and mem1 == mem2
    assert mem1
    assert {id(rs) for rs in mem1} <= {id(rs) for rs in groups[seg1]}
    # starvation backstop: the oldest starved request's group always wins
    starved = [rs for rs in b.inflight if 6 - rs.last_advance_tick >= 3]
    if starved:
        oldest = min(starved, key=lambda r: (r.last_advance_tick, r.req.rid))
        assert oldest in mem1
