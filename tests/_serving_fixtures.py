"""Shared fixtures for the scheduler/serving test suites.

One place to build the toy weight banks and scheduler request states the
SLO-scheduling and invariant suites drive, so WeightBank/RequestState
constructor changes land in a single helper instead of drifting across
test files. All helpers are deterministic (fixed keys/seeds).
"""
import jax
import jax.numpy as jnp

from repro.common.tree import flatten_paths
from repro.core import talora
from repro.diffusion.samplers import sampler_init
from repro.diffusion.schedule import make_schedule
from repro.serving import (GenRequest, RequestState, WeightBank,
                           default_serving_plan)

T = 40
SCHED = make_schedule("linear", T)


def single_segment_bank():
    """Trivial bank: one segment, no TALoRA routing."""
    params = {"l0": {"w": jnp.ones((4, 4))}}
    plan = default_serving_plan(flatten_paths(params))
    return WeightBank(params, plan, {}, None, None, T)


def multi_segment_bank(max_cached=8, lock_factory=None):
    """Toy TALoRA bank whose untrained router fragments [0, T) into
    several routing segments (the suites assert >= 2). ``lock_factory``
    passes through to WeightBank — the lockcheck suites install
    order-tracking locks through it."""
    key = jax.random.PRNGKey(1)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    params = {"l0": {"w": jax.random.normal(k1, (8, 8))},
              "l1": {"w": jax.random.normal(k2, (8, 6))}}
    weights = dict(flatten_paths(params))
    plan = default_serving_plan(weights)
    tcfg = talora.TALoRAConfig(hub_size=2, rank=2, t_emb_dim=16,
                               router_hidden=8)
    hubs = talora.init_lora_hub(k3, talora.lora_target_dims_from_weights(
        weights), tcfg)
    router = talora.init_router(k4, len(weights), tcfg)
    return WeightBank(params, plan, hubs, router, tcfg, T,
                      max_cached=max_cached, lock_factory=lock_factory)


def mk_inflight(b, rid, *, steps=1, deadline=None, last_tick=0,
                guidance_scale=0.0):
    """Append a ready-to-schedule RequestState to batcher ``b``."""
    st = sampler_init("ddim", SCHED, (1, 2, 2, 3), jax.random.PRNGKey(rid),
                      steps=steps)
    rs = RequestState(GenRequest(rid, steps=steps, deadline=deadline,
                                 guidance_scale=guidance_scale, y=0), st)
    rs.admitted_at = 0.0
    rs.last_advance_tick = last_tick
    b.inflight.append(rs)
    return rs
