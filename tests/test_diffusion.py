"""Diffusion substrate: schedules, samplers, quantization pipeline, UNet."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.diffusion_presets import tiny_ddim
from repro.diffusion import (SAMPLERS, ddim_sample, make_schedule,
                             sample_timesteps)
from repro.diffusion.samplers import ddim_step, dpm_solver2_sample, plms_sample
from repro.nn.unet import unet_init, unet_apply, lora_target_sites

KEY = jax.random.PRNGKey(0)


def test_schedule_invariants():
    for kind in ("linear", "quad", "cosine"):
        s = make_schedule(kind, 100)
        ab = np.asarray(s.alpha_bars)
        assert np.all(np.diff(ab) < 0) and ab[0] < 1.0 and ab[-1] > 0.0
        g = np.asarray(s.gamma())
        assert np.all(g > 0)


def test_q_sample_and_pred_x0_inverse():
    s = make_schedule("linear", 50)
    x0 = jax.random.normal(KEY, (4, 8, 8, 3))
    eps = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 8, 3))
    t = jnp.asarray([0, 10, 30, 49])
    xt = s.q_sample(x0, t, eps)
    back = s.pred_x0(xt, t, eps)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x0), atol=1e-4)


def test_ddim_step_noiseless_identity_direction():
    s = make_schedule("linear", 100)
    x = jax.random.normal(KEY, (2, 4, 4, 3))
    eps = jnp.zeros_like(x)
    out = ddim_step(s, x, 50, 40, eps)
    # with eps=0, x0 = x/sqrt(ab_t), x_prev = sqrt(ab_prev) x0
    want = jnp.sqrt(s.alpha_bars[40] / s.alpha_bars[50]) * x
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5)


def test_sample_timesteps_descending_unique():
    seq = sample_timesteps(1000, 20)
    assert len(seq) == 20 and np.all(np.diff(seq) < 0)


@pytest.mark.slow
@pytest.mark.parametrize("sampler", ["ddim", "plms", "dpm_solver2"])
def test_samplers_run_on_tiny_unet(sampler):
    cfg = tiny_ddim(8)
    p = unet_init(KEY, cfg)
    s = make_schedule("linear", 100)
    eps_fn = jax.jit(lambda x, t: unet_apply(p, x, t, cfg))
    fn = SAMPLERS[sampler]
    if sampler == "ddim":
        x, _ = fn(eps_fn, s, (2, 8, 8, 3), KEY, steps=5)
    else:
        x = fn(eps_fn, s, (2, 8, 8, 3), KEY, steps=5)
    assert x.shape == (2, 8, 8, 3) and bool(jnp.isfinite(x).all())


@pytest.mark.slow
def test_unet_class_conditional():
    cfg = tiny_ddim(8)
    import dataclasses
    cfg = dataclasses.replace(cfg, num_classes=5)
    p = unet_init(KEY, cfg)
    x = jax.random.normal(KEY, (2, 8, 8, 3))
    out = unet_apply(p, x, jnp.asarray([1.0, 2.0]), cfg,
                     y=jnp.asarray([0, 3]))
    assert out.shape == x.shape and bool(jnp.isfinite(out).all())


def test_lora_target_sites_cover_all_weights():
    cfg = tiny_ddim(8)
    p = unet_init(KEY, cfg)
    sites = lora_target_sites(p)
    assert all(k.endswith("/w") for k in sites)
    assert len(sites) > 20


@pytest.mark.slow
def test_quantize_diffusion_pipeline_end_to_end():
    """calibrate -> plan -> fake-quant -> TALoRA bundle -> sample."""
    from repro.core.talora import TALoRAConfig
    from repro.diffusion.pipeline import (build_calibration_set,
                                          quantize_diffusion,
                                          sample_quantized)
    from repro.diffusion.schedule import make_schedule

    cfg = tiny_ddim(8)
    p = unet_init(KEY, cfg)
    sched = make_schedule("linear", 50)
    calib = build_calibration_set(p, cfg, sched, KEY, n_samples=4, steps=4,
                                  batch=2)
    assert len(calib) >= 4
    bundle = quantize_diffusion(
        p, cfg, sched, KEY, bits_w=4, bits_a=4, calib=calib,
        talora_cfg=TALoRAConfig(hub_size=2, rank=2, t_emb_dim=16,
                                router_hidden=8))
    assert bundle.plan.summary()["sites"] > 0
    assert bundle.hubs is not None
    x = sample_quantized(bundle, KEY, n=1, steps=3)
    assert x.shape == (1, 8, 8, 3) and bool(jnp.isfinite(x).all())
