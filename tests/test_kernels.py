"""Per-kernel allclose sweeps (interpret mode) vs the pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels.ops as ops
from repro.core.qmodule import pack_weight
from repro.kernels import ref
from repro.quant.fakequant import (KIND_FP_SIGNED, KIND_FP_UNSIGNED,
                                   QuantizerParams)


@pytest.fixture(autouse=True)
def force_interpret():
    old = ops.FORCE
    ops.FORCE = "interpret"
    yield
    ops.FORCE = old


QDQ_CASES = [(KIND_FP_SIGNED, 2, 1), (KIND_FP_SIGNED, 1, 2),
             (KIND_FP_SIGNED, 3, 0), (KIND_FP_SIGNED, 0, 3),
             (KIND_FP_UNSIGNED, 2, 2), (KIND_FP_UNSIGNED, 3, 1),
             (KIND_FP_UNSIGNED, 1, 3)]
SHAPES = [(8, 32), (100, 300), (1, 128), (257, 511), (4, 7, 64)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("kind,e,m", QDQ_CASES)
@pytest.mark.parametrize("shape", SHAPES, ids=str)
def test_msfp_qdq_kernel_matches_ref(kind, e, m, shape, rng):
    qp = QuantizerParams(kind, e, m, 4, jnp.float32(2.3),
                         jnp.float32(-0.15 if kind == KIND_FP_UNSIGNED else 0.0))
    x = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    out = ops.msfp_quantize(x, qp)
    want = ref.ref_msfp_qdq(x, qp)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-6)


@pytest.mark.parametrize("dtype", DTYPES, ids=str)
def test_msfp_qdq_kernel_dtypes(dtype, rng):
    qp = QuantizerParams(KIND_FP_SIGNED, 2, 1, 4, jnp.float32(1.7))
    x = jnp.asarray(rng.normal(size=(64, 128)).astype(np.float32)).astype(dtype)
    out = ops.msfp_quantize(x, qp)
    want = ref.ref_msfp_qdq(x, qp)
    assert out.dtype == dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=1e-2)


@pytest.mark.parametrize("m,k,n", [(7, 96, 64), (128, 256, 128), (1, 64, 32),
                                   (33, 130, 66)])
@pytest.mark.parametrize("fmt", [(2, 1), (1, 2), (3, 0)], ids=str)
def test_w4_matmul_kernel_matches_ref(m, k, n, fmt, rng):
    e, mm = fmt
    qp = QuantizerParams(KIND_FP_SIGNED, e, mm, 4, jnp.float32(2.5))
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    pw = pack_weight(w, qp)
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32)).astype(jnp.bfloat16)
    out = ops.w4_matmul(x, pw)
    want = ref.ref_w4_matmul(x, pw, jnp.bfloat16)
    assert out.shape == (m, n)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=2e-1, rtol=2e-2)


# ---------------------------------------------------------------------------
# full-format-space W4 paths: per-channel scale, unsigned+zp, fused W4A4
# ---------------------------------------------------------------------------


def _pack_per_channel(w, e, m, rng):
    mv = jnp.maximum(jnp.max(jnp.abs(w), axis=0), 1e-6).astype(jnp.float32)
    qp = QuantizerParams(KIND_FP_SIGNED, e, m, 4, mv)
    return pack_weight(w, qp)


def _pack_unsigned(w, e, m, zp=-0.15):
    mv = jnp.float32(float(jnp.max(w - zp)))
    qp = QuantizerParams(KIND_FP_UNSIGNED, e, m, 4, mv, jnp.float32(zp))
    return pack_weight(w, qp)


@pytest.mark.parametrize("m,k,n", [(7, 96, 64), (33, 130, 66), (257, 511, 64),
                                   (33, 257, 514)])
@pytest.mark.parametrize("fmt", [(2, 1), (1, 2)], ids=str)
def test_w4_matmul_per_channel_matches_ref(m, k, n, fmt, rng):
    e, mm = fmt
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    pw = _pack_per_channel(w, e, mm, rng)
    assert pw.scale.shape == (n,)
    # small-magnitude x keeps f32 dot-reassociation noise under the atol
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32)) * 0.02
    out = ops.w4_matmul(x, pw)
    want = ref.ref_w4_matmul(x, pw, jnp.float32)
    assert out.shape == (m, n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-6, rtol=5e-4)


@pytest.mark.parametrize("m,k,n", [(7, 96, 64), (33, 130, 66), (257, 511, 64)])
@pytest.mark.parametrize("fmt", [(2, 2), (1, 3), (0, 4)], ids=str)
def test_w4_matmul_unsigned_zp_matches_ref(m, k, n, fmt, rng):
    e, mm = fmt
    # SiLU-like AAL weights: mostly positive with a shallow negative tail.
    w = jnp.asarray(np.abs(rng.normal(size=(k, n))).astype(np.float32) - 0.15)
    pw = _pack_unsigned(w, e, mm)
    assert not pw.signed
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32)) * 0.02
    out = ops.w4_matmul(x, pw)
    want = ref.ref_w4_matmul(x, pw, jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-6, rtol=5e-4)


@pytest.mark.parametrize("m,k,n", [(7, 96, 64), (33, 130, 66), (257, 511, 64)])
@pytest.mark.parametrize("act_kind,act_e,act_m",
                         [(KIND_FP_SIGNED, 2, 1), (KIND_FP_UNSIGNED, 2, 2)])
def test_w4a4_fused_matches_qdq_then_matmul(m, k, n, act_kind, act_e, act_m,
                                            rng):
    qp = QuantizerParams(KIND_FP_SIGNED, 2, 1, 4, jnp.float32(2.5))
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    pw = pack_weight(w, qp)
    act_qp = QuantizerParams(
        act_kind, act_e, act_m, 4, jnp.float32(2.3),
        jnp.float32(-0.15 if act_kind == KIND_FP_UNSIGNED else 0.0))
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32)) * 0.02
    out = ops.w4a4_matmul(x, pw, act_qp)
    want = ref.ref_w4a4_matmul(x, pw, act_qp, jnp.float32)
    assert out.shape == (m, n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-6, rtol=5e-4)


def test_w4a4_fused_unsigned_act_with_padded_k(rng):
    """K > bk-multiple forces zero-padding of x; unsigned act quant maps
    those zeros to qdq(0) != 0, which must not leak into the dot or the
    weight zero-point rowsum correction (regression)."""
    m, k, n = 5, 600, 32  # bk=512 -> padded to 1024: 424 phantom K rows
    wu = jnp.abs(jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))) - 0.15
    pw = _pack_unsigned(wu, 2, 2)
    act_qp = QuantizerParams(KIND_FP_UNSIGNED, 2, 2, 4, jnp.float32(2.3),
                             jnp.float32(-0.15))
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32)) * 0.02
    out = ops.w4a4_matmul(x, pw, act_qp)
    want = ref.ref_w4a4_matmul(x, pw, act_qp, jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-5, rtol=5e-4)


def test_w4a4_fused_per_channel_unsigned_weight_bf16(rng):
    """The full stack at once: unsigned per-channel weights, fused act
    quant, bf16 activations, odd/padded shapes."""
    k, n = 130, 66
    # O(1)-scaled data keeps outputs within bf16 ulp ~4e-3 of the oracle.
    w = jnp.asarray(np.abs(rng.normal(size=(k, n))).astype(np.float32)
                    * 0.1 - 0.01)
    mv = jnp.maximum(jnp.max(w + 0.01, axis=0), 1e-6).astype(jnp.float32)
    qp = QuantizerParams(KIND_FP_UNSIGNED, 2, 2, 4, mv,
                         jnp.broadcast_to(jnp.float32(-0.01), mv.shape))
    pw = pack_weight(w, qp)
    act_qp = QuantizerParams(KIND_FP_SIGNED, 2, 1, 4, jnp.float32(1.0))
    x = jnp.asarray(rng.normal(size=(29, k)).astype(np.float32) * 0.3
                    ).astype(jnp.bfloat16)
    out = ops.w4a4_matmul(x, pw, act_qp)
    want = ref.ref_w4a4_matmul(x, pw, act_qp, jnp.bfloat16)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=1e-2, rtol=2e-2)


@pytest.mark.parametrize("dtype", DTYPES, ids=str)
def test_w4_matmul_per_channel_dtypes(dtype, rng):
    w = jnp.asarray(rng.normal(size=(96, 64)).astype(np.float32)) * 0.1
    pw = _pack_per_channel(w, 2, 1, rng)
    x = jnp.asarray(rng.normal(size=(17, 96)).astype(np.float32)
                    * 0.3).astype(dtype)
    out = ops.w4_matmul(x, pw)
    want = ref.ref_w4_matmul(x, pw, dtype)
    assert out.dtype == dtype
    atol = 1e-2 if dtype == jnp.bfloat16 else 1e-6
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=atol, rtol=2e-2)


def test_w4_dispatch_covers_full_format_space(monkeypatch, rng):
    """Vector-scale and unsigned PackedW4 must hit the Pallas kernel, not
    the XLA decode-then-dot fallback."""

    def boom(*a, **k):
        raise AssertionError("w4_matmul fell back to the XLA path")

    monkeypatch.setattr(ops._ref, "ref_w4_matmul", boom)
    monkeypatch.setattr(ops._ref, "ref_w4a4_matmul", boom)
    x = jnp.asarray(rng.normal(size=(4, 32)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(32, 16)).astype(np.float32))

    pc = _pack_per_channel(w, 2, 1, rng)
    assert ops.w4_matmul(x, pc).shape == (4, 16)

    un = _pack_unsigned(jnp.abs(w) - 0.1, 2, 2)
    assert ops.w4_matmul(x, un).shape == (4, 16)

    act_qp = QuantizerParams(KIND_FP_SIGNED, 2, 1, 4, jnp.float32(2.0))
    assert ops.w4a4_matmul(x, pc, act_qp).shape == (4, 16)

    # stacked packs (scanned layers) are the documented remaining fallback
    monkeypatch.undo()
    from repro.core.qmodule import PackedW4
    stacked = PackedW4(jnp.zeros((2, 32, 8), jnp.uint8),
                       jnp.ones((2, 1, 1)), jnp.zeros((2, 1, 1)),
                       2, 1, True, (2, 32, 16))
    assert not ops._pallas_w4_ok(stacked)


def test_dense_apply_serve_ctx_routes_to_fused_kernel(monkeypatch, rng):
    """A serve-mode QuantContext must hand packed dense layers their
    activation params so they take the fused W4A4 path."""
    from repro.nn.layers import dense_apply
    from repro.quant.calibrate import QuantContext

    qp = QuantizerParams(KIND_FP_SIGNED, 2, 1, 4, jnp.float32(2.0))
    w = jnp.asarray(rng.normal(size=(32, 16)).astype(np.float32))
    pw = pack_weight(w, QuantizerParams(KIND_FP_SIGNED, 2, 1, 4,
                                        jnp.float32(2.5)))
    x = jnp.asarray(rng.normal(size=(4, 32)).astype(np.float32))
    seen = {}
    real = ops.w4a4_matmul

    def spy(x_, pw_, act_qp_):
        seen["act_qp"] = act_qp_
        return real(x_, pw_, act_qp_)

    monkeypatch.setattr(ops, "w4a4_matmul", spy)
    ctx = QuantContext("serve", act_qps={"*": qp})
    out = dense_apply({"w": pw}, x, ctx=ctx, site="mlp/down")
    assert out.shape == (4, 16)
    assert seen["act_qp"] is qp
    # off-mode ctx leaves act_qp unset -> plain w4 path
    seen.clear()
    dense_apply({"w": pw}, x, ctx=QuantContext("off"), site="mlp/down")
    assert seen["act_qp"] is None


def test_mlp_apply_act_qps_threading(monkeypatch, rng):
    """Explicit act_qps mapping (site-keyed with '*' fallback) reaches the
    fused kernel through mlp_apply's dense call sites."""
    from repro.nn.mlp import mlp_apply

    d, f = 16, 32
    qp_down = QuantizerParams(KIND_FP_UNSIGNED, 2, 2, 4, jnp.float32(2.0),
                              jnp.float32(-0.15))
    qp_any = QuantizerParams(KIND_FP_SIGNED, 2, 1, 4, jnp.float32(3.0))
    wqp = QuantizerParams(KIND_FP_SIGNED, 2, 1, 4, jnp.float32(2.0))
    p = {name: {"w": pack_weight(
            jnp.asarray(rng.normal(size=shape).astype(np.float32)), wqp)}
         for name, shape in (("gate", (d, f)), ("up", (d, f)),
                             ("down", (f, d)))}
    calls = []
    real = ops.w4a4_matmul

    def spy(x_, pw_, act_qp_):
        calls.append(act_qp_)
        return real(x_, pw_, act_qp_)

    monkeypatch.setattr(ops, "w4a4_matmul", spy)
    x = jnp.asarray(rng.normal(size=(3, d)).astype(np.float32))
    out = mlp_apply(p, x, "swiglu", site="mlp",
                    act_qps={"mlp/down": qp_down, "*": qp_any})
    assert out.shape == (3, d)
    assert calls == [qp_any, qp_any, qp_down]  # gate, up, down


# ---------------------------------------------------------------------------
# im2col conv route: packed HWIO convs through the fused W4A4 matmul
# ---------------------------------------------------------------------------


def _pack_conv(w4d, e=2, m=1):
    mv = jnp.maximum(jnp.max(jnp.abs(w4d)).astype(jnp.float32), 1e-6)
    return pack_weight(w4d, QuantizerParams(KIND_FP_SIGNED, e, m, 4, mv))


@pytest.mark.parametrize("kernel,stride,padding",
                         [(3, 1, "SAME"), (3, 2, "SAME"), (1, 1, "SAME"),
                          (1, 2, "SAME"), (3, 1, "VALID"), (3, 2, "VALID")])
def test_w4a4_conv2d_matches_ref_and_xla_conv(kernel, stride, padding, rng):
    """Interpret-mode conv route vs the jnp oracle AND vs lax.conv on the
    dequantized (reshaped-back-to-HWIO) weights."""
    from jax import lax

    from repro.core.qmodule import dequant_weight
    from repro.quant.fakequant import apply_qdq

    cin, cout = 6, 10
    w = jnp.asarray(rng.normal(size=(kernel, kernel, cin, cout))
                    .astype(np.float32))
    pw = _pack_conv(w)
    # conv weights pack as their 2D GEMM flattening, original shape kept
    assert pw.packed.shape == (kernel * kernel * cin, cout // 2)
    assert pw.shape == w.shape
    x = jnp.asarray(rng.normal(size=(2, 9, 9, cin)).astype(np.float32)) * 0.3
    act_qp = QuantizerParams(KIND_FP_SIGNED, 2, 1, 4, jnp.float32(1.0))
    out = ops.w4a4_conv2d(x, pw, act_qp, stride=stride, padding=padding)
    want = ref.ref_w4a4_conv2d(x, pw, act_qp, stride=(stride, stride),
                               padding=padding, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=5e-4)
    want_xla = lax.conv_general_dilated(
        apply_qdq(x, act_qp), dequant_weight(pw, jnp.float32),
        (stride, stride), padding, dimension_numbers=("NHWC", "HWIO", "NHWC"))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want_xla),
                               atol=2e-5, rtol=5e-4)


def test_w4a4_conv2d_unsigned_act_same_padding(rng):
    """Unsigned act grids map 0 to the zero-point, so the dispatcher must
    pre-quantize x (quantize-then-pad order) rather than snap the zero-
    padded patch entries in-kernel — SAME padding is the regression."""
    w = jnp.asarray(np.abs(rng.normal(size=(3, 3, 6, 8))).astype(np.float32))
    pw = _pack_conv(w)
    x = jnp.asarray(rng.normal(size=(1, 7, 7, 6)).astype(np.float32)) * 0.3
    act_qp = QuantizerParams(KIND_FP_UNSIGNED, 2, 2, 4, jnp.float32(1.5),
                             jnp.float32(-0.15))
    out = ops.w4a4_conv2d(x, pw, act_qp, stride=1, padding="SAME")
    want = ref.ref_w4a4_conv2d(x, pw, act_qp, stride=(1, 1), padding="SAME",
                               dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=5e-4)


def test_w4a4_conv2d_vector_act_maxval_falls_back(rng):
    """A per-channel (vector-maxval) act quantizer can't ride the per-
    tensor Pallas snap; the pre-quantize pass must degrade to the XLA
    ref instead of crashing (regression: msfp_quantize Pallas gating)."""
    w = jnp.asarray(rng.normal(size=(3, 3, 4, 8)).astype(np.float32))
    pw = _pack_conv(w)
    x = jnp.asarray(rng.normal(size=(1, 5, 5, 4)).astype(np.float32)) * 0.3
    act_qp = QuantizerParams(KIND_FP_SIGNED, 2, 1, 4,
                             jnp.full((4,), 1.0, jnp.float32))
    out = ops.w4a4_conv2d(x, pw, act_qp, stride=1, padding="SAME")
    want = ref.ref_w4a4_conv2d(x, pw, act_qp, stride=(1, 1), padding="SAME",
                               dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=5e-4)


def test_w4a4_conv2d_per_channel_scale_and_bf16(rng):
    w = jnp.asarray(rng.normal(size=(3, 3, 4, 6)).astype(np.float32)) * 0.1
    mv = jnp.maximum(jnp.max(jnp.abs(w), axis=(0, 1, 2)), 1e-6)
    pw = pack_weight(w, QuantizerParams(KIND_FP_SIGNED, 2, 1, 4, mv))
    assert pw.scale.shape == (6,)
    x = jnp.asarray(rng.normal(size=(2, 5, 5, 4)).astype(np.float32)
                    * 0.3).astype(jnp.bfloat16)
    act_qp = QuantizerParams(KIND_FP_SIGNED, 2, 1, 4, jnp.float32(1.0))
    out = ops.w4a4_conv2d(x, pw, act_qp, stride=1, padding="SAME")
    want = ref.ref_w4a4_conv2d(x, pw, act_qp, stride=(1, 1), padding="SAME",
                               dtype=jnp.bfloat16)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=1e-2, rtol=2e-2)


def test_w4a4_conv2d_dispatch_never_decodes(monkeypatch, rng):
    """Packed conv weights (scalar or per-channel scale, signed act fused
    or None) must hit the Pallas im2col route, not the decode-then-conv
    oracle fallback."""

    def boom(*a, **k):
        raise AssertionError("w4a4_conv2d fell back to decode-then-conv")

    monkeypatch.setattr(ops._ref, "ref_w4a4_conv2d", boom)
    w = jnp.asarray(rng.normal(size=(3, 3, 4, 8)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(1, 6, 6, 4)).astype(np.float32))
    act_qp = QuantizerParams(KIND_FP_SIGNED, 2, 1, 4, jnp.float32(2.0))
    pw = _pack_conv(w)
    assert ops.w4a4_conv2d(x, pw, act_qp).shape == (1, 6, 6, 8)
    assert ops.w4a4_conv2d(x, pw, None, stride=2).shape == (1, 3, 3, 8)
    mv = jnp.maximum(jnp.max(jnp.abs(w), axis=(0, 1, 2)), 1e-6)
    pc = pack_weight(w, QuantizerParams(KIND_FP_SIGNED, 2, 1, 4, mv))
    assert ops.w4a4_conv2d(x, pc, act_qp).shape == (1, 6, 6, 8)


def test_conv2d_apply_serve_ctx_routes_to_conv_kernel(monkeypatch, rng):
    """A serve-mode QuantContext hands packed conv layers their act params
    and routes through ops.w4a4_conv2d — never dequant + XLA conv."""
    from repro.nn.layers import conv2d_apply
    from repro.quant.calibrate import QuantContext

    qp = QuantizerParams(KIND_FP_SIGNED, 2, 1, 4, jnp.float32(2.0))
    w = jnp.asarray(rng.normal(size=(3, 3, 4, 8)).astype(np.float32))
    pw = _pack_conv(w)
    x = jnp.asarray(rng.normal(size=(2, 6, 6, 4)).astype(np.float32))
    seen = {}
    real = ops.w4a4_conv2d

    def spy(x_, pw_, act_qp_, **kw):
        seen["act_qp"] = act_qp_
        return real(x_, pw_, act_qp_, **kw)

    monkeypatch.setattr(ops, "w4a4_conv2d", spy)
    ctx = QuantContext("serve", act_qps={"*": qp})
    out = conv2d_apply({"w": pw}, x, ctx=ctx, site="res/conv1")
    assert out.shape == (2, 6, 6, 8)
    assert seen["act_qp"] is qp
    seen.clear()
    conv2d_apply({"w": pw}, x, ctx=QuantContext("off"), site="res/conv1")
    assert seen["act_qp"] is None


def test_unpacked_sites_quantize_acts_in_serve_mode(monkeypatch, rng):
    """bf16-fallback dense/conv sites must still quantize their input in
    serve mode (standalone msfp pass) so serving matches the fake-quant
    oracle at every planned act site (regression: they skipped it)."""
    from repro.nn.layers import conv2d_apply, dense_apply
    from repro.quant.calibrate import QuantContext
    from repro.quant.fakequant import apply_qdq

    qp = QuantizerParams(KIND_FP_SIGNED, 2, 1, 4, jnp.float32(2.0))
    calls = []
    real = ops.msfp_quantize

    def spy(x_, qp_):
        calls.append(qp_)
        return real(x_, qp_)

    monkeypatch.setattr(ops, "msfp_quantize", spy)
    ctx = QuantContext("serve", act_qps={"*": qp})
    xd = jnp.asarray(rng.normal(size=(4, 8)).astype(np.float32))
    wd = jnp.asarray(rng.normal(size=(8, 6)).astype(np.float32))
    out = dense_apply({"w": wd}, xd, ctx=ctx, site="io/head")
    assert calls == [qp]
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(apply_qdq(xd, qp) @ wd),
                               atol=1e-6)
    calls.clear()
    xc = jnp.asarray(rng.normal(size=(1, 5, 5, 3)).astype(np.float32))
    wc = jnp.asarray(rng.normal(size=(3, 3, 3, 7)).astype(np.float32))
    conv2d_apply({"w": wc}, xc, ctx=ctx, site="conv_in")  # odd cout: dense
    assert calls == [qp]
    # no ctx / off mode: the plain unquantized path is untouched
    calls.clear()
    dense_apply({"w": wd}, xd)
    conv2d_apply({"w": wc}, xc, ctx=QuantContext("off"), site="conv_in")
    assert calls == []


def test_w4_matmul_3d_input(rng):
    qp = QuantizerParams(KIND_FP_SIGNED, 2, 1, 4, jnp.float32(1.0))
    w = jnp.asarray(rng.normal(size=(32, 48)).astype(np.float32))
    pw = pack_weight(w, qp)
    x = jnp.asarray(rng.normal(size=(2, 5, 32)).astype(np.float32))
    out = ops.w4_matmul(x, pw)
    assert out.shape == (2, 5, 48)


@pytest.mark.parametrize("shape", [(16, 64), (3, 5, 8, 128), (1, 1, 2, 64)],
                         ids=str)
def test_kv4_roundtrip_and_ref_match(shape, rng):
    t = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    packed, scale = ops.kv4_encode(t)
    back = ops.kv4_decode(packed, scale, jnp.float32)
    pr, sr = ref.ref_kv4_encode(t.reshape(-1, shape[-1]))
    assert bool(jnp.all(packed.reshape(-1, shape[-1] // 2) == pr))
    np.testing.assert_allclose(
        np.asarray(back),
        np.asarray(ref.ref_kv4_decode(pr, sr, jnp.float32)).reshape(shape),
        atol=1e-6)
    # E2M1 with per-head scale: bounded relative error
    rel = float(jnp.max(jnp.abs(back - t)) / jnp.max(jnp.abs(t)))
    assert rel < 0.25


def test_kv4_zero_row():
    t = jnp.zeros((4, 64))
    packed, scale = ops.kv4_encode(t)
    back = ops.kv4_decode(packed, scale, jnp.float32)
    np.testing.assert_allclose(np.asarray(back), 0.0, atol=1e-6)


# ---------------------------------------------------------------------------
# Implicit-GEMM conv kernel (interpret-mode parity for the new index maps)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kernel,stride,padding",
                         [(3, (1, 1), "SAME"), (3, (2, 2), "SAME"),
                          (1, (1, 1), "SAME"), (5, (2, 1), "VALID"),
                          (3, (1, 1), ((2, 1), (0, 3)))])
@pytest.mark.parametrize("act", ["none", "signed", "unsigned"])
def test_implicit_conv_kernel_parity(kernel, stride, padding, act, rng):
    """The implicit-GEMM kernel's index maps (whole-slab gather, tap
    unroll, pad re-masking) vs the jnp oracle on odd shapes, strides,
    SAME/VALID and explicit pad pairs."""
    from repro.kernels.conv import w4a4_conv2d_implicit

    cin, cout = 6, 10
    w = jnp.asarray(rng.normal(size=(kernel, kernel, cin, cout))
                    .astype(np.float32)) * 0.3
    pw = _pack_conv(w)
    x = jnp.asarray(rng.normal(size=(2, 9, 7, cin)).astype(np.float32)) * 0.4
    act_qp = {"none": None,
              "signed": QuantizerParams(KIND_FP_SIGNED, 2, 1, 4,
                                        jnp.float32(1.2)),
              "unsigned": QuantizerParams(KIND_FP_UNSIGNED, 2, 2, 4,
                                          jnp.float32(1.5),
                                          jnp.float32(-0.15))}[act]
    out = w4a4_conv2d_implicit(x, pw, act_qp, stride=stride, padding=padding,
                               interpret=True)
    want = ref.ref_w4a4_conv2d(x, pw, act_qp, stride=stride, padding=padding,
                               dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=5e-4)


def test_implicit_conv_kernel_per_channel_bf16(rng):
    from repro.kernels.conv import w4a4_conv2d_implicit

    w = jnp.asarray(rng.normal(size=(3, 3, 4, 6)).astype(np.float32)) * 0.1
    mv = jnp.maximum(jnp.max(jnp.abs(w), axis=(0, 1, 2)), 1e-6)
    pw = pack_weight(w, QuantizerParams(KIND_FP_SIGNED, 2, 1, 4, mv))
    x = jnp.asarray(rng.normal(size=(2, 5, 5, 4)).astype(np.float32)
                    * 0.3).astype(jnp.bfloat16)
    act_qp = QuantizerParams(KIND_FP_SIGNED, 2, 1, 4, jnp.float32(1.0))
    out = w4a4_conv2d_implicit(x, pw, act_qp, stride=(1, 1), padding="SAME",
                               interpret=True)
    want = ref.ref_w4a4_conv2d(x, pw, act_qp, stride=(1, 1), padding="SAME",
                               dtype=jnp.bfloat16)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=1e-2, rtol=2e-2)


def test_conv_route_forced_implicit_is_used(monkeypatch, rng):
    """CONV_ROUTE="implicit" must run the implicit kernel (and never the
    im2col route or the decode oracle), even in interpret mode."""
    import repro.kernels.conv as conv_mod

    monkeypatch.setattr(ops, "CONV_ROUTE", "implicit")
    monkeypatch.setattr(ops._ref, "ref_w4a4_conv2d",
                        lambda *a, **k: (_ for _ in ()).throw(
                            AssertionError("decode fallback")))
    monkeypatch.setattr(conv_mod, "w4a4_conv2d_im2col",
                        lambda *a, **k: (_ for _ in ()).throw(
                            AssertionError("im2col route")))
    w = jnp.asarray(rng.normal(size=(3, 3, 4, 8)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(1, 6, 6, 4)).astype(np.float32))
    act_qp = QuantizerParams(KIND_FP_SIGNED, 2, 1, 4, jnp.float32(2.0))
    out = ops.w4a4_conv2d(x, _pack_conv(w), act_qp)
    assert out.shape == (1, 6, 6, 8)


def test_conv_route_interpret_default_stays_im2col(monkeypatch, rng):
    """Unforced interpret-mode dispatch keeps the im2col route — the
    golden replay trace's digest is pinned to its accumulation order."""
    import repro.kernels.conv as conv_mod

    called = {}
    real = conv_mod.w4a4_conv2d_im2col

    def spy(*a, **k):
        called["im2col"] = True
        return real(*a, **k)

    monkeypatch.setattr(conv_mod, "w4a4_conv2d_im2col", spy)
    monkeypatch.setattr(conv_mod, "w4a4_conv2d_implicit",
                        lambda *a, **k: (_ for _ in ()).throw(
                            AssertionError("implicit under interpret auto")))
    w = jnp.asarray(rng.normal(size=(3, 3, 4, 8)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(1, 6, 6, 4)).astype(np.float32))
    act_qp = QuantizerParams(KIND_FP_SIGNED, 2, 1, 4, jnp.float32(2.0))
    assert ops.CONV_ROUTE == "auto"
    ops.w4a4_conv2d(x, _pack_conv(w), act_qp)
    assert called.get("im2col")


# ---------------------------------------------------------------------------
# Fused matmul: ragged K with unsigned formats; snap-once re-tiling
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("wfmt", [(2, 2), (3, 1)], ids=str)
@pytest.mark.parametrize("k", [600, 96])
def test_w4_matmul_ragged_k_unsigned_weight(wfmt, k, rng):
    """K % bk != 0 (600 vs the 512 K-tile) with unsigned weight formats:
    the zero-point K-padding correction must count only valid rows."""
    from repro.kernels.w4_matmul import w4_matmul_2d

    e, mm = wfmt
    w = jnp.asarray(np.abs(rng.normal(size=(k, 66))).astype(np.float32))
    qp = QuantizerParams(KIND_FP_UNSIGNED, e, mm, 4, jnp.float32(2.2),
                         jnp.float32(0.4))
    pw = pack_weight(w, qp)
    x = jnp.asarray(rng.normal(size=(33, k)).astype(np.float32))
    out = w4_matmul_2d(x, pw.packed, pw.scale, pw.zero_point,
                       exp_bits=e, man_bits=mm, signed=False, interpret=True)
    want = ref.ref_w4_matmul(x, pw, jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-4, rtol=5e-4)


@pytest.mark.parametrize("act_kind", [KIND_FP_SIGNED, KIND_FP_UNSIGNED])
def test_w4a4_fused_ragged_k_unsigned_act(act_kind, rng):
    from repro.kernels.w4_matmul import w4a4_matmul_2d

    k = 600
    w = jnp.asarray(rng.normal(size=(k, 66)).astype(np.float32))
    qp = QuantizerParams(KIND_FP_SIGNED, 2, 1, 4, jnp.float32(2.5))
    pw = pack_weight(w, qp)
    act_qp = QuantizerParams(act_kind, 2, 1, 4, jnp.float32(3.0),
                             jnp.float32(-0.2))
    x = jnp.asarray(rng.normal(size=(17, k)).astype(np.float32))
    out = w4a4_matmul_2d(
        x, pw.packed, pw.scale, pw.zero_point, act_qp.maxval,
        act_qp.zero_point, exp_bits=2, man_bits=1, signed=True,
        act_exp_bits=2, act_man_bits=1,
        act_signed=(act_kind == KIND_FP_SIGNED), interpret=True)
    want = ref.ref_w4a4_matmul(x, pw, act_qp, jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-4, rtol=5e-4)


def test_snap_once_retiling_matches_per_program_snap(monkeypatch, rng):
    """The persistent-VMEM snap-once path (one snap per (i, k) tile) must
    be bit-identical to snapping in every (h, j) program — same tiles,
    same accumulation order."""
    import repro.kernels.w4_matmul as wm

    k = 600
    w = jnp.asarray(rng.normal(size=(k, 66)).astype(np.float32))
    pw = pack_weight(w, QuantizerParams(KIND_FP_SIGNED, 2, 1, 4,
                                        jnp.float32(2.5)))
    act_qp = QuantizerParams(KIND_FP_UNSIGNED, 2, 1, 4, jnp.float32(3.0),
                             jnp.float32(-0.2))
    x = jnp.asarray(rng.normal(size=(17, k)).astype(np.float32))

    def run():
        return wm.w4a4_matmul_2d(
            x, pw.packed, pw.scale, pw.zero_point, act_qp.maxval,
            act_qp.zero_point, exp_bits=2, man_bits=1, signed=True,
            act_exp_bits=2, act_man_bits=1, act_signed=False, interpret=True)

    snap_once = run()
    monkeypatch.setattr(wm, "XQ_VMEM_BUDGET", 0)   # disable the scratch
    per_program = run()
    assert jnp.array_equal(snap_once, per_program)


# ---------------------------------------------------------------------------
# Fast XLA serving path (kernels.xla_serve)
# ---------------------------------------------------------------------------


XS_FMTS = [(KIND_FP_SIGNED, 2, 1), (KIND_FP_SIGNED, 3, 0),
           (KIND_FP_SIGNED, 1, 2), (KIND_FP_SIGNED, 0, 3),
           (KIND_FP_UNSIGNED, 2, 2), (KIND_FP_UNSIGNED, 3, 1)]


@pytest.mark.parametrize("kind,e,m", XS_FMTS)
def test_fast_qdq_equals_oracle(kind, e, m, rng):
    """Bitcast-octave snap == transcendental oracle, including octave
    boundaries and zeros/huge/tiny, f32 and bf16, scalar + per-channel."""
    from repro.kernels import xla_serve

    qp = QuantizerParams(kind, e, m, 4, jnp.float32(2.3), jnp.float32(-0.15))
    x = jnp.asarray(rng.normal(size=(64, 128)).astype(np.float32)) * 3
    adv = jnp.asarray(np.array(
        [0.0, -0.0, 1.0, np.nextafter(2.0, 0), np.nextafter(2.0, 3),
         -6.0, 6.0, 1e-30, -1e-30, 3.3e38, 0.49999997, 0.5] * 4,
        np.float32)).reshape(4, 12)
    for inp in (x, adv, x.astype(jnp.bfloat16)):
        want = ref.ref_msfp_qdq(inp, qp)
        got = xla_serve.fast_qdq(inp, qp)
        assert got.dtype == inp.dtype
        assert jnp.array_equal(want, got), (kind, e, m, inp.dtype)
    mv = jnp.abs(jnp.asarray(rng.normal(size=(128,)).astype(np.float32))) + .5
    qpc = QuantizerParams(kind, e, m, 4, mv, jnp.float32(0.1))
    assert jnp.array_equal(ref.ref_msfp_qdq(x, qpc),
                           xla_serve.fast_qdq(x, qpc))


def test_fast_qdq_high_exp_formats_fall_back_to_ref(monkeypatch, rng):
    """E4+ octaves hit XLA CPU's inexact exp2 in the *reference*; the
    fast path must route them to the reference, not disagree with it."""
    from repro.kernels import xla_serve

    called = {}
    real = ref.ref_msfp_qdq

    def spy(*a, **k):
        called["ref"] = True
        return real(*a, **k)

    monkeypatch.setattr(xla_serve._ref, "ref_msfp_qdq", spy)
    qp = QuantizerParams(KIND_FP_SIGNED, 4, 0, 5, jnp.float32(2.0e4))
    x = jnp.asarray(rng.normal(size=(32, 32)).astype(np.float32)) * 1e4
    assert jnp.array_equal(xla_serve.fast_qdq(x, qp), real(x, qp))
    assert called.get("ref")


def test_fast_decode_equals_decode_codes():
    from repro.core.qmodule import decode_codes
    from repro.kernels import xla_serve
    from repro.quant.formats import FPFormat

    for e, m, signed in [(2, 1, True), (3, 0, True), (1, 2, True),
                         (0, 3, True), (2, 1, False), (3, 0, False),
                         (2, 2, False), (0, 4, False)]:
        fmt = FPFormat(e, m, signed)
        codes = jnp.arange(2 ** min(e + m + signed, 4), dtype=jnp.uint8)
        for sc in (0.7, 2.0, 1e-3, 137.0):
            want = decode_codes(codes, fmt, jnp.float32(sc), 0.3, jnp.float32)
            got = xla_serve.fast_decode(codes, fmt, jnp.float32(sc), 0.3,
                                        jnp.float32)
            assert jnp.array_equal(want, got), (e, m, signed, sc)


def test_serve_dequant_matches_dequant_weight(rng):
    from repro.core.qmodule import dequant_weight
    from repro.kernels import xla_serve

    w = jnp.asarray(rng.normal(size=(3, 3, 6, 10)).astype(np.float32)) * 0.3
    mv = jnp.maximum(jnp.max(jnp.abs(w), axis=(0, 1, 2)), 1e-6)
    for qp in (QuantizerParams(KIND_FP_SIGNED, 2, 1, 4, jnp.float32(0.9)),
               QuantizerParams(KIND_FP_SIGNED, 2, 1, 4, mv),
               QuantizerParams(KIND_FP_UNSIGNED, 2, 2, 4, jnp.float32(0.9),
                               jnp.float32(-0.4))):
        pw = pack_weight(w, qp)
        assert jnp.array_equal(dequant_weight(pw, jnp.float32),
                               xla_serve.serve_dequant(pw, jnp.float32))


def test_xla_serve_matmuls_bit_identical_for_f32(rng):
    """f32 in, f32 out: same snap, same decode, same per-column
    accumulation order as the oracles — equality, not allclose."""
    from repro.kernels import xla_serve

    k, n = 384, 66
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    act_qp = QuantizerParams(KIND_FP_SIGNED, 2, 1, 4, jnp.float32(4.0))
    for qp in (QuantizerParams(KIND_FP_SIGNED, 2, 1, 4, jnp.float32(2.0)),
               QuantizerParams(KIND_FP_UNSIGNED, 2, 2, 4, jnp.float32(2.0),
                               jnp.float32(-1.0))):
        pw = pack_weight(w, qp)
        x = jnp.asarray(rng.normal(size=(32, k)).astype(np.float32))
        assert jnp.array_equal(xla_serve.w4_matmul(x, pw, jnp.float32),
                               ref.ref_w4_matmul(x, pw, jnp.float32))
        assert jnp.array_equal(
            xla_serve.fused_matmul(x, pw, act_qp, jnp.float32),
            ref.ref_w4a4_matmul(x, pw, act_qp, jnp.float32))


def test_xla_serve_fused_bf16_close_to_oracle(rng):
    """bf16 in: the snapped activation stays f32 through the dot (the
    oracle re-rounds to bf16) — within one bf16 ulp relative."""
    from repro.kernels import xla_serve

    k, n = 384, 66
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    pw = pack_weight(w, QuantizerParams(KIND_FP_SIGNED, 2, 1, 4,
                                        jnp.float32(2.0)))
    act_qp = QuantizerParams(KIND_FP_SIGNED, 2, 1, 4, jnp.float32(4.0))
    x = jnp.asarray(rng.normal(size=(32, k)).astype(np.float32)) \
        .astype(jnp.bfloat16)
    got = xla_serve.fused_matmul(x, pw, act_qp, jnp.bfloat16)
    want = ref.ref_w4a4_matmul(x, pw, act_qp, jnp.bfloat16)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=5e-2, rtol=2e-2)


@pytest.mark.parametrize("stride,padding",
                         [((1, 1), "SAME"), ((2, 2), "SAME"),
                          ((2, 1), "VALID"), ((1, 1), ((2, 1), (0, 3)))])
@pytest.mark.parametrize("act", ["none", "signed", "unsigned"])
def test_xla_serve_implicit_conv_parity(stride, padding, act, rng):
    from repro.kernels import xla_serve

    w = jnp.asarray(rng.normal(size=(3, 3, 6, 10)).astype(np.float32)) * 0.3
    pw = _pack_conv(w)
    x = jnp.asarray(rng.normal(size=(2, 9, 7, 6)).astype(np.float32)) * 0.4
    act_qp = {"none": None,
              "signed": QuantizerParams(KIND_FP_SIGNED, 2, 1, 4,
                                        jnp.float32(1.2)),
              "unsigned": QuantizerParams(KIND_FP_UNSIGNED, 2, 2, 4,
                                          jnp.float32(1.5),
                                          jnp.float32(-0.15))}[act]
    out = xla_serve.implicit_conv(x, pw, act_qp, stride=stride,
                                  padding=padding, dtype=jnp.float32)
    want = ref.ref_w4a4_conv2d(x, pw, act_qp, stride=stride, padding=padding,
                               dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=5e-4)


def test_force_xla_pins_pure_reference(monkeypatch, rng):
    """FORCE="xla" must never touch the fast serving path — it is the
    oracle escape hatch."""
    import repro.kernels.xla_serve as xla_serve

    ops.FORCE = "xla"
    for name in ("fast_qdq", "fused_matmul", "w4_matmul", "implicit_conv"):
        monkeypatch.setattr(xla_serve, name,
                            lambda *a, _n=name, **k: (_ for _ in ()).throw(
                                AssertionError(f"fast path {_n} under xla")))
    w = jnp.asarray(rng.normal(size=(96, 64)).astype(np.float32))
    pw = pack_weight(w, QuantizerParams(KIND_FP_SIGNED, 2, 1, 4,
                                        jnp.float32(2.0)))
    qp = QuantizerParams(KIND_FP_SIGNED, 2, 1, 4, jnp.float32(2.0))
    x = jnp.asarray(rng.normal(size=(8, 96)).astype(np.float32))
    assert jnp.array_equal(ops.msfp_quantize(x, qp), ref.ref_msfp_qdq(x, qp))
    assert jnp.array_equal(ops.w4_matmul(x, pw),
                           ref.ref_w4_matmul(x, pw, x.dtype))
    assert jnp.array_equal(ops.w4a4_matmul(x, pw, qp),
                           ref.ref_w4a4_matmul(x, pw, qp, x.dtype))
    wc = jnp.asarray(rng.normal(size=(3, 3, 4, 8)).astype(np.float32))
    xc = jnp.asarray(rng.normal(size=(1, 6, 6, 4)).astype(np.float32))
    assert jnp.array_equal(
        ops.w4a4_conv2d(xc, _pack_conv(wc), qp),
        ref.ref_w4a4_conv2d(xc, _pack_conv(wc), qp, dtype=xc.dtype))


def test_default_cpu_dispatch_routes_to_fast_path(monkeypatch, rng):
    """Unforced off-TPU dispatch serves via xla_serve (matmul, fused,
    conv, qdq) — the reference oracles are for tests, not serving."""
    import repro.kernels.xla_serve as xla_serve

    ops.FORCE = None
    assert jax.default_backend() != "tpu"
    seen = set()
    for name in ("fast_qdq", "fused_matmul", "w4_matmul", "implicit_conv"):
        real = getattr(xla_serve, name)

        def spy(*a, _n=name, _real=real, **k):
            seen.add(_n)
            return _real(*a, **k)

        monkeypatch.setattr(xla_serve, name, spy)
    w = jnp.asarray(rng.normal(size=(96, 64)).astype(np.float32))
    pw = pack_weight(w, QuantizerParams(KIND_FP_SIGNED, 2, 1, 4,
                                        jnp.float32(2.0)))
    qp = QuantizerParams(KIND_FP_SIGNED, 2, 1, 4, jnp.float32(2.0))
    x = jnp.asarray(rng.normal(size=(8, 96)).astype(np.float32))
    ops.msfp_quantize(x, qp)
    ops.w4_matmul(x, pw)
    ops.w4a4_matmul(x, pw, qp)
    wc = jnp.asarray(rng.normal(size=(3, 3, 4, 8)).astype(np.float32))
    xc = jnp.asarray(rng.normal(size=(1, 6, 6, 4)).astype(np.float32))
    ops.w4a4_conv2d(xc, _pack_conv(wc), qp)
    assert seen == {"fast_qdq", "fused_matmul", "w4_matmul", "implicit_conv"}
