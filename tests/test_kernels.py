"""Per-kernel allclose sweeps (interpret mode) vs the pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels.ops as ops
from repro.core.qmodule import pack_weight
from repro.kernels import ref
from repro.quant.fakequant import (KIND_FP_SIGNED, KIND_FP_UNSIGNED,
                                   QuantizerParams)


@pytest.fixture(autouse=True)
def force_interpret():
    old = ops.FORCE
    ops.FORCE = "interpret"
    yield
    ops.FORCE = old


QDQ_CASES = [(KIND_FP_SIGNED, 2, 1), (KIND_FP_SIGNED, 1, 2),
             (KIND_FP_SIGNED, 3, 0), (KIND_FP_SIGNED, 0, 3),
             (KIND_FP_UNSIGNED, 2, 2), (KIND_FP_UNSIGNED, 3, 1),
             (KIND_FP_UNSIGNED, 1, 3)]
SHAPES = [(8, 32), (100, 300), (1, 128), (257, 511), (4, 7, 64)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("kind,e,m", QDQ_CASES)
@pytest.mark.parametrize("shape", SHAPES, ids=str)
def test_msfp_qdq_kernel_matches_ref(kind, e, m, shape, rng):
    qp = QuantizerParams(kind, e, m, 4, jnp.float32(2.3),
                         jnp.float32(-0.15 if kind == KIND_FP_UNSIGNED else 0.0))
    x = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    out = ops.msfp_quantize(x, qp)
    want = ref.ref_msfp_qdq(x, qp)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-6)


@pytest.mark.parametrize("dtype", DTYPES, ids=str)
def test_msfp_qdq_kernel_dtypes(dtype, rng):
    qp = QuantizerParams(KIND_FP_SIGNED, 2, 1, 4, jnp.float32(1.7))
    x = jnp.asarray(rng.normal(size=(64, 128)).astype(np.float32)).astype(dtype)
    out = ops.msfp_quantize(x, qp)
    want = ref.ref_msfp_qdq(x, qp)
    assert out.dtype == dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=1e-2)


@pytest.mark.parametrize("m,k,n", [(7, 96, 64), (128, 256, 128), (1, 64, 32),
                                   (33, 130, 66)])
@pytest.mark.parametrize("fmt", [(2, 1), (1, 2), (3, 0)], ids=str)
def test_w4_matmul_kernel_matches_ref(m, k, n, fmt, rng):
    e, mm = fmt
    qp = QuantizerParams(KIND_FP_SIGNED, e, mm, 4, jnp.float32(2.5))
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    pw = pack_weight(w, qp)
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32)).astype(jnp.bfloat16)
    out = ops.w4_matmul(x, pw)
    want = ref.ref_w4_matmul(x, pw, jnp.bfloat16)
    assert out.shape == (m, n)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=2e-1, rtol=2e-2)


def test_w4_matmul_3d_input(rng):
    qp = QuantizerParams(KIND_FP_SIGNED, 2, 1, 4, jnp.float32(1.0))
    w = jnp.asarray(rng.normal(size=(32, 48)).astype(np.float32))
    pw = pack_weight(w, qp)
    x = jnp.asarray(rng.normal(size=(2, 5, 32)).astype(np.float32))
    out = ops.w4_matmul(x, pw)
    assert out.shape == (2, 5, 48)


@pytest.mark.parametrize("shape", [(16, 64), (3, 5, 8, 128), (1, 1, 2, 64)],
                         ids=str)
def test_kv4_roundtrip_and_ref_match(shape, rng):
    t = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    packed, scale = ops.kv4_encode(t)
    back = ops.kv4_decode(packed, scale, jnp.float32)
    pr, sr = ref.ref_kv4_encode(t.reshape(-1, shape[-1]))
    assert bool(jnp.all(packed.reshape(-1, shape[-1] // 2) == pr))
    np.testing.assert_allclose(
        np.asarray(back),
        np.asarray(ref.ref_kv4_decode(pr, sr, jnp.float32)).reshape(shape),
        atol=1e-6)
    # E2M1 with per-head scale: bounded relative error
    rel = float(jnp.max(jnp.abs(back - t)) / jnp.max(jnp.abs(t)))
    assert rel < 0.25


def test_kv4_zero_row():
    t = jnp.zeros((4, 64))
    packed, scale = ops.kv4_encode(t)
    back = ops.kv4_decode(packed, scale, jnp.float32)
    np.testing.assert_allclose(np.asarray(back), 0.0, atol=1e-6)
