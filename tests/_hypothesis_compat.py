"""Drop-in subset of hypothesis for environments without it installed.

The real library is used when importable. The fallback reimplements just
what this suite needs — ``@given`` over ``integers`` / ``floats`` /
``booleans`` / ``lists`` / ``sampled_from`` strategies plus ``@settings`` —
as a deterministic seeded sweep (seeded per test name, so failures
reproduce). Property tests keep running everywhere; shrinking and the
example database are hypothesis-only luxuries.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only where hypothesis exists
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies  # noqa: F401
except ImportError:
    import random
    import zlib

    _DEFAULT_MAX_EXAMPLES = 20

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng: random.Random):
            return self._draw(rng)

    class strategies:  # noqa: N801 - mirrors the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda r: bool(r.getrandbits(1)))

        @staticmethod
        def floats(min_value, max_value, allow_nan=False, allow_infinity=False):
            def draw(r):
                # Hit the endpoints early — they are the classic edge cases.
                roll = r.random()
                if roll < 0.05:
                    return float(min_value)
                if roll < 0.10:
                    return float(max_value)
                return r.uniform(min_value, max_value)

            return _Strategy(draw)

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            return _Strategy(lambda r: [elements.example(r)
                                        for _ in range(r.randint(min_size,
                                                                 max_size))])

        @staticmethod
        def sampled_from(choices):
            seq = list(choices)
            return _Strategy(lambda r: r.choice(seq))

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(*arg_strategies, **kw_strategies):
        def deco(fn):
            # NOTE: no functools.wraps — the wrapper must present a
            # zero-argument signature or pytest tries to resolve the
            # strategy parameters as fixtures.
            def wrapper():
                rng = random.Random(zlib.crc32(fn.__name__.encode()))
                n = getattr(wrapper, "_max_examples", _DEFAULT_MAX_EXAMPLES)
                for _ in range(n):
                    drawn = [s.example(rng) for s in arg_strategies]
                    drawn_kw = {k: s.example(rng)
                                for k, s in kw_strategies.items()}
                    fn(*drawn, **drawn_kw)

            wrapper.__name__ = fn.__name__
            wrapper.__module__ = fn.__module__
            wrapper.__doc__ = fn.__doc__
            wrapper._max_examples = getattr(fn, "_max_examples",
                                            _DEFAULT_MAX_EXAMPLES)
            return wrapper

        return deco
