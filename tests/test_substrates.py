"""Optimizer / checkpoint / trainer (fault tolerance) / data substrates."""
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import CheckpointManager
from repro.data.synthetic import gaussian_bump_images, zipf_tokens
from repro.optim.adam import (AdamConfig, EMA, adam_init, adam_update,
                              global_norm, lr_at)
from repro.train.trainer import Trainer, TrainerConfig

KEY = jax.random.PRNGKey(0)


def test_adam_converges_quadratic():
    p = {"w": jnp.ones((4,)) * 5.0}
    cfg = AdamConfig(lr=0.3, clip_norm=None)
    st = adam_init(p, cfg)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum((p["w"] - 2.0) ** 2))(p)
        p, st, _ = adam_update(g, st, p, cfg)
    np.testing.assert_allclose(np.asarray(p["w"]), 2.0, atol=1e-2)


def test_adam_clipping_and_bf16_moments():
    p = {"w": jnp.zeros((3,))}
    cfg = AdamConfig(lr=0.1, clip_norm=1.0, moment_dtype=jnp.bfloat16)
    st = adam_init(p, cfg)
    assert st["m"]["w"].dtype == jnp.bfloat16
    g = {"w": jnp.ones((3,)) * 100.0}
    p2, st, m = adam_update(g, st, p, cfg)
    assert float(m["grad_norm"]) > 100
    assert bool(jnp.all(jnp.isfinite(p2["w"])))


def test_lr_schedule_warmup_cosine():
    cfg = AdamConfig(lr=1.0, schedule="linear_warmup_cosine",
                     warmup_steps=10, total_steps=100)
    assert float(lr_at(cfg, jnp.int32(5))) == pytest.approx(0.5)
    assert float(lr_at(cfg, jnp.int32(100))) == pytest.approx(0.0, abs=1e-6)


def test_ema():
    ema = EMA(0.5)
    e = ema.init({"w": jnp.zeros(2)})
    e = ema.update(e, {"w": jnp.ones(2)})
    np.testing.assert_allclose(np.asarray(e["w"]), 0.5)


def test_checkpoint_roundtrip_gc_checksum():
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d, keep=2)
        tree = {"a": jnp.arange(5.0), "blocks": [{"w": jnp.ones(2)}]}
        cm.save(1, tree)
        cm.save(2, tree, {"note": "x"})
        cm.save(3, tree)
        assert cm.steps() == [2, 3]
        s, t2, extra = cm.restore()
        assert s == 3
        assert jax.tree.structure(tree) == jax.tree.structure(t2)
        # corrupt -> checksum failure
        import numpy as _np
        path = os.path.join(d, "step_0000000002", "arrays.npz")
        data = dict(_np.load(path))
        data["a0"] = data["a0"] + 1
        _np.savez(path, **data)
        with pytest.raises(IOError):
            cm.restore(2)


def test_checkpoint_async_and_atomic():
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d, keep=5)
        cm.save_async(7, {"x": jnp.ones(3)})
        cm.wait()
        assert cm.steps() == [7]
        assert not any(p.endswith(".tmp") for p in os.listdir(d))


def test_trainer_recovers_from_injected_fault():
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d, keep=5)
        hits = {"n": 0}

        def fault(step):
            if step == 5 and hits["n"] == 0:
                hits["n"] += 1
                raise RuntimeError("injected device failure")

        def step_fn(state, batch):
            return {"x": state["x"] + batch}, {"loss": float(state["x"])}

        tr = Trainer(TrainerConfig(max_steps=10, ckpt_every=2), cm, step_fn,
                     fault_hook=fault)
        final, hist = tr.run({"x": jnp.zeros(())}, iter(lambda: 1.0, None))
        assert tr.restarts == 1
        assert float(final["x"]) == 10.0  # bit-exact replay


def test_trainer_exceeds_max_restarts():
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d, keep=5)

        def fault(step):
            raise RuntimeError("permanently broken host")

        tr = Trainer(TrainerConfig(max_steps=5, max_restarts=2), cm,
                     lambda s, b: (s, {}), fault_hook=fault)
        with pytest.raises(RuntimeError, match="max_restarts"):
            tr.run({"x": jnp.zeros(())}, iter(lambda: 1.0, None))


@pytest.mark.slow
def test_trainer_straggler_detection():
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d, keep=2)

        # injected stall is sized vs wall time of the fast steps so the
        # test stays robust when the host itself is loaded
        def step_fn(state, batch):
            if batch > 0.5:  # one slow step
                time.sleep(2.0)
            else:
                time.sleep(0.01)
            return {"x": state["x"] + 1}, {}

        data = iter([0.0] * 6 + [1.0] + [0.0] * 3)
        tr = Trainer(TrainerConfig(max_steps=10, ckpt_every=100,
                                   straggler_factor=3.0), cm, step_fn)
        _, hist = tr.run({"x": jnp.zeros(())}, data)
        assert 7 in tr.straggler_steps()


def test_trainer_preemption_stop_saves():
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d, keep=2)
        tr = Trainer(TrainerConfig(max_steps=100, ckpt_every=1000), cm,
                     lambda s, b: ({"x": s["x"] + 1}, {}))

        orig_next = {"n": 0}

        def data():
            while True:
                orig_next["n"] += 1
                if orig_next["n"] == 4:
                    tr.request_stop()  # simulated SIGTERM
                yield 1.0

        final, hist = tr.run({"x": jnp.zeros(())}, data())
        assert len(hist) <= 5
        assert cm.latest_step() == len(hist)


@pytest.mark.slow
def test_synthetic_data_shapes_and_determinism():
    img = gaussian_bump_images(KEY, 4, 16)
    assert img.shape == (4, 16, 16, 3)
    assert float(img.max()) <= 1.0 and float(img.min()) >= -1.0
    t1 = zipf_tokens(KEY, 2, 32, 100)
    t2 = zipf_tokens(KEY, 2, 32, 100)
    assert bool(jnp.all(t1 == t2))  # deterministic in key
    assert int(t1.max()) < 100
    # copy structure: every 4th token (from idx 4) repeats t-3
    a = np.asarray(t1)
    idx = np.arange(32)
    mask = (idx % 4 == 0) & (idx >= 3)
    assert np.all(a[:, mask] == np.roll(a, 3, axis=1)[:, mask])
