"""Core package: MSFP plan, TALoRA routing/merging, DFA, W4 packing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

import repro.core as core
from repro.common.tree import flatten_paths, unflatten_paths
from repro.quant import CalibrationDB, QuantizerParams, KIND_FP_SIGNED, \
    KIND_FP_UNSIGNED, fp_qdq


def _fake_db(rng):
    db = CalibrationDB()
    x = rng.normal(size=20000).astype(np.float32)
    db.record("mlp/down", x / (1 + np.exp(-x)))   # SiLU-fed -> AAL
    db.record("attn/q", x)                        # symmetric -> NAL
    return db


@pytest.mark.slow
def test_plan_modes_and_classification(rng):
    db = _fake_db(rng)
    weights = {"mlp/down/w": rng.normal(size=(32, 16)).astype(np.float32),
               "attn/q/w": rng.normal(size=(16, 16)).astype(np.float32)}
    plan = core.build_plan(weights, db, bits_w=4, bits_a=4, mode="msfp")
    assert plan.sites["mlp/down"].is_aal and not plan.sites["attn/q"].is_aal
    assert plan.sites["mlp/down"].qp.kind == KIND_FP_UNSIGNED
    assert plan.sites["attn/q"].qp.kind == KIND_FP_SIGNED
    # signed-only mode never emits unsigned
    plan_s = core.build_plan(weights, db, mode="signed")
    assert plan_s.n_unsigned() == 0
    # INT mode
    plan_i = core.build_plan(weights, db, mode="int")
    assert all(s.qp.kind == 2 for s in plan_i.sites.values())


@pytest.mark.slow
def test_mixed_io_bits(rng):
    db = _fake_db(rng)
    weights = {"mlp/down/w": rng.normal(size=(8, 8)).astype(np.float32),
               "attn/q/w": rng.normal(size=(8, 8)).astype(np.float32)}
    plan = core.build_mixed_plan(weights, db, bits_w=4, bits_a=4,
                                 io_sites={"attn/q/w", "attn/q"}, io_bits=8)
    assert plan.sites["attn/q/w"].qp.bits == 8
    assert plan.sites["mlp/down/w"].qp.bits == 4


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_router_hard_one_hot(t):
    cfg = core.TALoRAConfig(hub_size=3, rank=2, t_emb_dim=16, router_hidden=8)
    router = core.init_router(jax.random.PRNGKey(0), 5, cfg)
    sel = core.route(router, jnp.float32(t), [f"l{i}" for i in range(5)], cfg)
    for v in sel.values():
        a = np.asarray(v)
        assert np.isclose(a.sum(), 1.0) and np.isclose(a.max(), 1.0)


def test_lora_merge_equals_branch(rng):
    """merged (W + A B) forward == base + lora_delta branch."""
    cfg = core.TALoRAConfig(hub_size=2, rank=4, alpha=8.0)
    key = jax.random.PRNGKey(1)
    w = jnp.asarray(rng.normal(size=(12, 10)).astype(np.float32))
    hubs = core.init_lora_hub(key, {"lin/w": (12, 10)}, cfg)
    hubs["lin/w"]["B"] = jax.random.normal(key, (2, 4, 10)) * 0.3
    sel = jnp.asarray([0.0, 1.0])
    x = jnp.asarray(rng.normal(size=(5, 12)).astype(np.float32))
    branch = core.lora_apply(x, w, hubs["lin/w"], sel, cfg)
    merged_tree = core.merge_into_tree({"lin": {"w": w}}, hubs,
                                       {"lin/w": sel}, cfg)
    np.testing.assert_allclose(np.asarray(x @ merged_tree["lin"]["w"]),
                               np.asarray(branch), atol=1e-4)


def test_conv_lora_merge_shape(rng):
    cfg = core.TALoRAConfig(hub_size=2, rank=3)
    w = jnp.asarray(rng.normal(size=(3, 3, 4, 8)).astype(np.float32))
    dims = core.lora_target_dims_from_weights({"conv/w": w})
    assert dims["conv/w"] == (36, 8)
    hubs = core.init_lora_hub(jax.random.PRNGKey(0), dims, cfg)
    out = core.merge_into_tree({"conv": {"w": w}}, hubs,
                               {"conv/w": jnp.asarray([1.0, 0.0])}, cfg)
    assert out["conv"]["w"].shape == w.shape


def test_dfa_weighting():
    alphas = jnp.linspace(0.99, 0.9999, 50)
    abar = jnp.cumprod(alphas)
    g = core.denoising_factor(alphas, abar)
    assert g.shape == (50,) and bool(jnp.all(g > 0))
    eps1 = jnp.ones((4, 8))
    eps2 = jnp.zeros((4, 8))
    gt = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    assert float(core.dfa_loss(eps1, eps2, gt)) == pytest.approx(2.5)
    assert float(core.plain_loss(eps1, eps2)) == pytest.approx(1.0)


@pytest.mark.slow
@settings(max_examples=25, deadline=None)
@given(e=st.integers(0, 3), m=st.integers(0, 3), signed=st.booleans(),
       rows=st.integers(1, 9), cols=st.sampled_from([2, 4, 8, 16]))
def test_pack_roundtrip_equals_fakequant(e, m, signed, rows, cols):
    if e + m != (3 if signed else 4):  # 4-bit formats only
        return
    rng = np.random.default_rng(e * 100 + m * 10 + rows)
    kind = KIND_FP_SIGNED if signed else KIND_FP_UNSIGNED
    qp = QuantizerParams(kind, e, m, 4, jnp.float32(1.9),
                         jnp.float32(-0.1 if not signed else 0.0))
    w = rng.normal(size=(rows, cols)).astype(np.float32)
    if not signed:
        w = np.abs(w) - 0.1
    pw = core.pack_weight(jnp.asarray(w), qp)
    deq = np.asarray(core.dequant_weight(pw, jnp.float32))
    want = np.asarray(fp_qdq(jnp.asarray(w), qp.fmt, qp.maxval, qp.zero_point))
    np.testing.assert_allclose(deq, want, atol=1e-5)


def test_quantize_param_tree_and_tree_roundtrip(rng):
    tree = {"a": {"w": jnp.asarray(rng.normal(size=(8, 6)).astype(np.float32)),
                  "b": jnp.zeros(6)},
            "blocks": [{"w": jnp.ones((4, 4))}, {"w": jnp.zeros((4, 4))}]}
    flat = flatten_paths(tree)
    assert "blocks/#1/w" in flat
    back = unflatten_paths(flat)
    assert isinstance(back["blocks"], list)
    np.testing.assert_allclose(np.asarray(back["blocks"][0]["w"]), 1.0)
