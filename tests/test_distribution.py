"""Sharding rules + dry-run machinery on a subprocess multi-device mesh.

The test process holds 1 CPU device; these tests exec short scripts with
``--xla_force_host_platform_device_count=8`` to get a real (4, 2) mesh, and
assert lower+compile works with the production sharding rules — a scaled
replica of the 512-chip dry-run.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(body: str, timeout=420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_param_shardings_rules_unit():
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.launch.sharding import param_spec

    class FakeMesh:
        axis_names = ("data", "model")
        axis_sizes = (4, 2)

    m = FakeMesh()
    assert param_spec("embed", (1024, 64), m) == P("model", None)
    assert param_spec("blocks/#0/attn/wq/w", (8, 64, 64), m) == \
        P(None, "data", "model")
    assert param_spec("blocks/#0/mlp/down/w", (8, 128, 64), m) == \
        P(None, "model", "data")
    assert param_spec("blocks/#0/moe/w_gate", (4, 64, 32), m) == \
        P("model", "data", None)
    # indivisible dims drop axes
    assert param_spec("lm_head/w", (63, 101), m) == P(None, None)
    # norms replicate
    assert param_spec("final_norm/g", (64,), m) == P()


@pytest.mark.slow
def test_train_step_compiles_sharded_8dev():
    out = run_py("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding
        from repro.configs.registry import get_config
        from repro.launch.sharding import param_shardings, data_spec
        from repro.launch.steps import (make_train_step, abstract_params,
                                        abstract_opt, input_specs)
        from repro.optim.adam import AdamConfig
        from repro.launch.mesh import compat_make_mesh, mesh_scope
        mesh = compat_make_mesh((4, 2), ("data", "model"))
        cfg = get_config("qwen1.5-0.5b", smoke=True)
        acfg = AdamConfig()
        with mesh_scope(mesh):
            ap = abstract_params(cfg)
            ao = abstract_opt(ap, acfg)
            ps = param_shardings(ap, mesh)
            os_ = param_shardings(ao, mesh)
            tokens = jax.ShapeDtypeStruct((8, 16), jnp.int32)
            bs = {"tokens": NamedSharding(mesh, data_spec((8, 16), mesh))}
            step = make_train_step(cfg, acfg)
            co = jax.jit(step, in_shardings=(ps, os_, bs),
                         out_shardings=(ps, os_, None)) \\
                .lower(ap, ao, {"tokens": tokens}).compile()
            ca = co.cost_analysis()
            ca = ca[0] if isinstance(ca, list) else ca  # old JAX: list of dicts
            print("FLOPS", ca.get("flops", -1) > 0)
            print("OK")
    """)
    assert "OK" in out and "FLOPS True" in out


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["zamba2-2.7b", "kimi-k2-1t-a32b"])
def test_decode_step_compiles_sharded_8dev(arch):
    out = run_py(f"""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.registry import get_config
        from repro.configs.shapes import ShapeSpec
        from repro.launch.sharding import (param_shardings, cache_shardings,
                                           data_spec)
        from repro.launch.steps import (abstract_params, input_specs,
                                        make_decode_fn, quantize_abstract)
        from repro.launch.mesh import compat_make_mesh, mesh_scope
        mesh = compat_make_mesh((4, 2), ("data", "model"))
        cfg = get_config("{arch}", smoke=True)
        shape = ShapeSpec("d", 32, 8, "decode")
        with mesh_scope(mesh):
            ap = quantize_abstract(abstract_params(cfg))
            ps = param_shardings(ap, mesh)
            specs = input_specs(cfg, shape)
            cs = cache_shardings(specs["caches"], mesh)
            ts = NamedSharding(mesh, data_spec((8, 1), mesh))
            co = jax.jit(make_decode_fn(cfg),
                         in_shardings=(ps, cs, ts, NamedSharding(mesh, P())),
                         out_shardings=(None, cs)) \\
                .lower(ap, specs["caches"], specs["token"],
                       specs["pos"]).compile()
            ca = co.cost_analysis()
            ca = ca[0] if isinstance(ca, list) else ca
            print("OK", ca.get("flops", 0) > 0)
    """)
    assert "OK True" in out


@pytest.mark.slow
def test_checkpoint_restore_onto_different_mesh():
    """Elasticity: save sharded on (4,2), restore onto (2,4)."""
    out = run_py("""
        import tempfile, jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint.ckpt import CheckpointManager
        from repro.launch.mesh import compat_make_mesh
        m1 = compat_make_mesh((4, 2), ("data", "model"))
        m2 = compat_make_mesh((2, 4), ("data", "model"))
        tree = {"w": jnp.arange(64.0).reshape(8, 8)}
        sh1 = {"w": NamedSharding(m1, P("data", "model"))}
        sh2 = {"w": NamedSharding(m2, P("data", "model"))}
        placed = jax.device_put(tree, sh1)
        with tempfile.TemporaryDirectory() as d:
            cm = CheckpointManager(d)
            cm.save(1, placed)
            _, back, _ = cm.restore(1, shardings=sh2)
            assert back["w"].sharding == sh2["w"]
            np.testing.assert_allclose(np.asarray(back["w"]),
                                       np.asarray(tree["w"]))
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_moe_expert_parallel_matches_global():
    """shard_map EP dispatch == global-sort dispatch (no-drop capacity)."""
    out = run_py("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.nn.moe import MoEConfig, moe_init, moe_apply, moe_apply_ep
        from repro.launch.mesh import compat_make_mesh, mesh_scope
        mesh = compat_make_mesh((4, 2), ("data", "model"))
        cfg = MoEConfig(d_model=32, d_ff=16, n_experts=4, top_k=2,
                        n_shared=1, capacity_factor=8.0)
        key = jax.random.PRNGKey(0)
        p = moe_init(key, cfg, jnp.float32)
        x = jax.random.normal(key, (8, 6, 32))
        with mesh_scope(mesh):
            xg = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
            y_g = jax.jit(lambda p, x: moe_apply(p, x, cfg))(p, xg)
            y_e = jax.jit(lambda p, x: moe_apply_ep(p, x, cfg))(p, xg)
            assert float(jnp.abs(y_g - y_e).max()) < 1e-4
            g = jax.jit(jax.grad(
                lambda p: jnp.sum(moe_apply_ep(p, xg, cfg) ** 2)))(p)
            assert all(bool(jnp.isfinite(l).all())
                       for l in jax.tree.leaves(g))
        print("OK")
    """)
    assert "OK" in out


def test_dryrun_collective_parser():
    from repro.launch.dryrun import parse_collectives
    hlo = """
      %ar = f32[16,1024]{1,0} all-reduce(%x), replica_groups=...
      %ag.1 = bf16[8,512]{1,0} all-gather(%y), dimensions={0}
      %a2a = (bf16[4,4]{1,0}, bf16[4,4]{1,0}) all-to-all(%a, %b)
      %cp = u8[100]{0} collective-permute-start(%z)
    """
    r = parse_collectives(hlo)
    assert r["count_by_op"] == {"all-reduce": 1, "all-gather": 1,
                                "all-to-all": 1, "collective-permute": 1}
    assert r["bytes_by_op"]["all-reduce"] == 2 * 16 * 1024 * 4  # 2x payload
    assert r["bytes_by_op"]["all-gather"] == 8 * 512 * 2
    assert r["bytes_by_op"]["all-to-all"] == 2 * 16 * 2
    assert r["bytes_by_op"]["collective-permute"] == 100
