"""Traffic subsystem: trace format, generators, SLO metrics, scenarios,
and the engine's deadline/priority/prefetch/callback extensions.

Engine-level tests drive a stub ``apply_fn`` (the packed-path numerics
are covered by test_serving); what matters here is scheduling behavior,
determinism, and the metrics contract.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.tree import flatten_paths
from repro.configs.diffusion_presets import tiny_ddim
from repro.core import talora
from repro.diffusion.schedule import make_schedule
from repro.serving import (DiffusionServingEngine, VirtualClock, WeightBank,
                           default_serving_plan)
from repro.serving.traffic import (OPEN_LOOP, SLO, ClosedLoopGenerator,
                                   MetricsCollector, RequestMix, TraceRequest,
                                   TraceWriter, build_trace, get_scenario,
                                   list_scenarios, load_trace,
                                   open_loop_trace, run_scenario, save_trace,
                                   submit_trace, validate_trace)
from repro.serving.traffic.metrics import _Event, percentile
from repro.serving.traffic.scenarios import resolve_trace_path

KEY = jax.random.PRNGKey(0)
T = 40
GOLDEN = "tests/data/golden_trace.jsonl"


def _single_segment_bank():
    params = {"l0": {"w": jnp.ones((4, 4))}}
    plan = default_serving_plan(flatten_paths(params))
    return WeightBank(params, plan, {}, None, None, T)


def _stub_engine(max_batch=3, **kw):
    sched = make_schedule("linear", T)
    return DiffusionServingEngine(
        tiny_ddim(4), sched, _single_segment_bank(), max_batch=max_batch,
        apply_fn=lambda params, x, tb, y, ctx: 0.1 * x, **kw)


def _multi_segment_bank(max_cached=8):
    key = jax.random.PRNGKey(1)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    params = {"l0": {"w": jax.random.normal(k1, (8, 8))},
              "l1": {"w": jax.random.normal(k2, (8, 6))}}
    weights = dict(flatten_paths(params))
    plan = default_serving_plan(weights)
    tcfg = talora.TALoRAConfig(hub_size=2, rank=2, t_emb_dim=16,
                               router_hidden=8)
    hubs = talora.init_lora_hub(k3, talora.lora_target_dims_from_weights(
        weights), tcfg)
    for name in hubs:
        hubs[name]["B"] = jax.random.normal(k4, hubs[name]["B"].shape) * 0.1
    router = talora.init_router(k4, len(weights), tcfg)
    return WeightBank(params, plan, hubs, router, tcfg, T,
                      max_cached=max_cached)


# ---------------------------------------------------------------------------
# Trace format: round-trip, validation, capture.
# ---------------------------------------------------------------------------


def test_trace_roundtrip(tmp_path):
    mix = RequestMix(samplers=("ddim", "plms"), steps=3, steps_jitter=1,
                     deadline_s=(5.0, None), priorities=(1, 0), seed0=50)
    reqs = open_loop_trace("poisson", 7, seed=9, mix=mix, rate=30.0)
    path = str(tmp_path / "t.jsonl")
    save_trace(path, reqs, meta={"note": "roundtrip"})
    loaded, header = load_trace(path)
    assert loaded == reqs
    assert header["meta"] == {"note": "roundtrip"}
    assert header["version"] == 2
    # rids assigned by arrival order, arrivals ascending
    assert [tr.rid for tr in loaded] == list(range(7))
    arr = [tr.arrival for tr in loaded]
    assert arr == sorted(arr)


def test_trace_validation_rejects_malformed(tmp_path):
    ok = TraceRequest(arrival=0.5, steps=2)
    with pytest.raises(ValueError, match="sampler"):
        validate_trace([dataclasses.replace(ok, sampler="euler")])
    with pytest.raises(ValueError, match="steps"):
        validate_trace([dataclasses.replace(ok, steps=0)])
    with pytest.raises(ValueError, match="deadline"):
        validate_trace([dataclasses.replace(ok, deadline=0.5)])
    with pytest.raises(ValueError, match="class"):
        validate_trace([dataclasses.replace(ok, guidance_scale=2.0)])
    with pytest.raises(ValueError, match="arrival"):
        validate_trace([dataclasses.replace(ok, arrival=-1.0)])
    # header checks: wrong version / wrong format / unknown field
    p = tmp_path / "bad.jsonl"
    p.write_text(json.dumps({"format": "repro.traffic.trace",
                             "version": 99}) + "\n")
    with pytest.raises(ValueError, match="version"):
        load_trace(str(p))
    p.write_text(json.dumps({"format": "something-else", "version": 1})
                 + "\n")
    with pytest.raises(ValueError, match="not a"):
        load_trace(str(p))
    p.write_text(json.dumps({"format": "repro.traffic.trace", "version": 1})
                 + "\n" + json.dumps({"arrival": 0.1, "bogus": 1}) + "\n")
    with pytest.raises(ValueError, match="bogus"):
        load_trace(str(p))


def test_trace_load_fills_rids_without_colliding(tmp_path):
    p = tmp_path / "mixed.jsonl"
    p.write_text(json.dumps({"format": "repro.traffic.trace", "version": 1})
                 + "\n" + json.dumps({"arrival": 0.0, "steps": 1}) + "\n"
                 + json.dumps({"arrival": 1.0, "steps": 1, "rid": 0}) + "\n")
    loaded, _ = load_trace(str(p))
    rids = [tr.rid for tr in loaded]
    assert len(set(rids)) == 2   # filled rid skips the explicit 0
    with pytest.raises(ValueError, match="duplicate rids"):
        validate_trace([TraceRequest(arrival=0.0, rid=1),
                        TraceRequest(arrival=1.0, rid=1)])


def test_trace_writer_captures_submissions(tmp_path):
    path = str(tmp_path / "cap.jsonl")
    eng = _stub_engine(clock=VirtualClock())
    writer = TraceWriter(path, meta={"src": "test"}).attach(eng)
    reqs = open_loop_trace("poisson", 4, seed=3,
                           mix=RequestMix(steps=1, priorities=(2, 0)))
    submit_trace(eng, reqs)
    eng.run()
    writer.close()
    captured, header = load_trace(path)
    assert header["meta"] == {"src": "test"}
    assert len(captured) == 4
    assert [c.arrival for c in captured] == [r.arrival for r in reqs]
    assert [c.priority for c in captured] == [r.priority for r in reqs]
    assert [c.steps for c in captured] == [r.steps for r in reqs]


# ---------------------------------------------------------------------------
# Generators: seed determinism, schema invariants.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", sorted(OPEN_LOOP))
def test_open_loop_generator_seed_determinism(kind):
    a = open_loop_trace(kind, 16, seed=5)
    b = open_loop_trace(kind, 16, seed=5)
    c = open_loop_trace(kind, 16, seed=6)
    assert a == b
    assert a != c
    arr = [tr.arrival for tr in a]
    assert len(arr) == 16 and arr == sorted(arr)
    assert all(np.isfinite(t) and t >= 0 for t in arr)


def test_mix_cycles_are_index_deterministic():
    mix = RequestMix(samplers=("ddim", "plms", "dpm_solver2"), steps=2,
                     steps_jitter=2, deadline_s=(1.0, None),
                     priorities=(3, 1), seed0=7)
    reqs = open_loop_trace("poisson", 6, seed=0, mix=mix)
    assert [r.sampler for r in reqs] == ["ddim", "plms", "dpm_solver2"] * 2
    assert [r.steps for r in reqs] == [2, 3, 4] * 2
    assert [r.priority for r in reqs] == [3, 1] * 3
    assert [r.seed for r in reqs] == [7 + i for i in range(6)]
    for i, r in enumerate(reqs):
        if i % 2 == 0:
            assert r.deadline == pytest.approx(r.arrival + 1.0)
        else:
            assert r.deadline is None


def test_closed_loop_reissues_on_completion_and_is_deterministic(tmp_path):
    def once(capture=None):
        eng = _stub_engine(clock=VirtualClock())
        writer = TraceWriter(capture).attach(eng) if capture else None
        gen = ClosedLoopGenerator(n_users=2, requests_per_user=3,
                                  think_mean_s=0.5,
                                  mix=RequestMix(steps=1, steps_jitter=1),
                                  seed=7)
        issued = gen.drive(eng)
        if writer is not None:
            writer.close()
        outs = {rid: (rs.n_evals, np.asarray(rs.x0).tobytes())
                for rid, rs in eng.results.items()}
        return issued, outs

    cap = str(tmp_path / "closed.jsonl")
    i1, o1 = once(capture=cap)
    i2, o2 = once()
    assert i1 == i2 and o1 == o2
    assert len(i1) == 6 and len(o1) == 6
    # two initial requests (no parent), four re-issued on completion with
    # think-time links pointing at a finished request of the same user
    roots = [tr for tr in i1 if tr.parent is None]
    links = [tr for tr in i1 if tr.parent is not None]
    assert len(roots) == 2 and len(links) == 4
    by_rid = {tr.rid: tr for tr in i1}
    for tr in links:
        assert tr.think_s > 0
        assert by_rid[tr.parent].user == tr.user
        assert tr.arrival > by_rid[tr.parent].arrival
    # the captured trace keeps the think-time links (user/parent/think_s)
    captured, _ = load_trace(cap)
    assert sorted(captured, key=lambda t: t.rid) == sorted(
        i1, key=lambda t: t.rid)


# ---------------------------------------------------------------------------
# Scheduler edge cases: deadline expiry, priority, empty groups.
# ---------------------------------------------------------------------------


def test_deadline_expired_admission_refused():
    clock = [0.0]
    eng = _stub_engine(now_fn=lambda: clock[0])
    expired_cb = []
    eng.on_expire.append(lambda rs: expired_cb.append(rs.req.rid))
    eng.submit(steps=1, arrival=0.0, deadline=1.0)
    eng.submit(steps=1, arrival=0.0)
    clock[0] = 2.0   # past rid 0's deadline before any admission
    res = eng.run()
    assert res[0].expired and res[0].n_evals == 0 and res[0].x0 is None
    assert not res[1].expired and res[1].n_evals == 1
    assert expired_cb == [0]
    s = eng.stats()
    assert s["expired"] == 1 and s["requests"] == 1


def test_all_pending_expired_tick_is_safe():
    """An admission wave that expires every due request must not reach
    group selection with an empty in-flight set."""
    clock = [10.0]
    eng = _stub_engine(now_fn=lambda: clock[0])
    ticks = []
    eng.on_tick_end.append(lambda e: ticks.append(e.tick_count))
    for i in range(3):
        eng.submit(steps=1, arrival=0.0, deadline=1.0 + i)
    res = eng.run()
    assert len(res) == 3 and all(rs.expired for rs in res.values())
    assert eng.n_expired == 3 and eng.n_finished == 0
    assert ticks, "on_tick_end must fire even on empty ticks"


def test_priority_admission_beats_fifo_under_contention():
    clock = [0.0]
    eng = _stub_engine(max_batch=1, now_fn=lambda: clock[0])
    eng.submit(steps=1, arrival=0.0, priority=0)
    eng.submit(steps=1, arrival=0.0, priority=5)
    res = eng.run()
    assert list(res) == [1, 0]   # high priority retires first
    assert res[1].admitted_at <= res[0].admitted_at
    # equal priority falls back to (arrival, rid) FIFO
    eng2 = _stub_engine(max_batch=1)
    for _ in range(3):
        eng2.submit(steps=1)
    assert list(eng2.run()) == [0, 1, 2]


# ---------------------------------------------------------------------------
# Metrics: percentile helper, collector windows/summary/SLO.
# ---------------------------------------------------------------------------


def test_percentile_nearest_rank():
    assert percentile([], 95) == 0.0
    vals = sorted(float(v) for v in range(1, 101))
    assert percentile(vals, 0) == 1.0
    assert percentile(vals, 50) == 51.0   # nearest rank over 0..99 indices
    assert percentile(vals, 99) == 99.0
    assert percentile(vals, 100) == 100.0


def test_metrics_collector_summary_windows_and_slo():
    clock = [0.5]
    eng = _stub_engine(max_batch=2, now_fn=lambda: clock[0])
    col = MetricsCollector(window_s=1.0).attach(eng)
    eng.submit(steps=2, arrival=0.0, deadline=5.0)
    eng.submit(steps=2, arrival=0.0, deadline=1.0)
    eng.submit(steps=2, arrival=1.5, deadline=1.8)
    eng.tick()     # admits 0+1 at t=0.5, before rid 1's deadline
    clock[0] = 2.0  # ... which passes mid-flight (miss, not expiry); rid 2
    res = eng.run()  # is due + past deadline at its admission -> expired
    assert res[2].expired
    s = col.summary()
    assert s["requests"] == 2 and s["expired"] == 1
    # rid 0 met its 5.0 deadline; rid 1 finished at 2.0 > 1.0; rid 2 expired
    assert s["deadline_misses"] == 2
    assert s["goodput_frac"] == pytest.approx(1 / 3)
    # finished at 2.0, anchored at max(submitted_at=0.5, arrival=0.0)
    assert s["p95_s"] == pytest.approx(1.5)
    rows = col.windows()
    assert len(rows) >= 2
    assert rows[-1]["expired"] == 1 or rows[-2]["expired"] == 1
    assert sum(r["throughput_rps"] for r in rows) == pytest.approx(2.0)
    # SLO verdicts cut both ways
    assert col.evaluate(SLO(p95_s=3.0, goodput_min=0.2))["passed"]
    bad = col.evaluate(SLO(p95_s=1.0, goodput_min=0.9))
    assert not bad["passed"]
    assert not bad["checks"]["p95_s"]["ok"]
    assert not bad["checks"]["goodput_frac"]["ok"]


def test_percentile_edge_cases():
    # single sample: every percentile is that sample
    for p in (0, 50, 95, 100):
        assert percentile([3.25], p) == 3.25
    # two samples: the midpoint index rounds half-even (nearest rank:
    # p50 of two samples is the lower one)
    assert percentile([1.0, 2.0], 50) == 1.0
    assert percentile([1.0, 2.0], 51) == 2.0
    # out-of-range p clamps instead of wrapping around the list
    assert percentile([1.0, 2.0, 3.0], 150) == 3.0
    assert percentile([1.0, 2.0, 3.0], -50) == 1.0


def test_metrics_windows_edge_cases():
    # no events, no ticks: no windows at all
    assert MetricsCollector().windows() == []

    # single sample finishing at t=0: exactly one window, all stats sane
    col = MetricsCollector(window_s=1.0)
    col.events.append(_Event(arrival=0.0, finished=0.0, latency=0.0,
                             met_deadline=True, expired=False))
    rows = col.windows()
    assert len(rows) == 1
    assert rows[0]["throughput_rps"] == 1.0 and rows[0]["p95_s"] == 0.0

    # an event finishing exactly ON a window boundary belongs to the
    # window it opens ([i*w, (i+1)*w) half-open), including widths where
    # t/w floats just under an integer (0.3 // 0.1 == 2.0)
    for w, t in ((1.0, 2.0), (0.1, 0.3), (0.25, 0.75)):
        col = MetricsCollector(window_s=w)
        col.events.append(_Event(arrival=0.0, finished=t, latency=t,
                                 met_deadline=True, expired=False))
        rows = col.windows()
        assert len(rows) == round(t / w) + 1, (w, t)
        assert rows[-1]["throughput_rps"] == pytest.approx(1.0 / w)
        assert all(r["throughput_rps"] == 0.0 for r in rows[:-1])

    # an empty middle window still emits a zero row, and the cache-hit
    # delta spans it instead of being dropped
    col = MetricsCollector(window_s=1.0)
    col.events.append(_Event(arrival=0.0, finished=0.5, latency=0.5,
                             met_deadline=True, expired=False))
    col.events.append(_Event(arrival=0.0, finished=2.5, latency=2.5,
                             met_deadline=True, expired=False))
    col.ticks.append((0.5, 0, 1, 2, 0))     # hits=2
    col.ticks.append((2.5, 0, 1, 6, 2))     # +4 hits, +2 misses later
    rows = col.windows()
    assert len(rows) == 3
    assert rows[1]["throughput_rps"] == 0.0 and rows[1]["queue_depth"] == 0.0
    assert "cache_hit_rate" not in rows[1]
    assert rows[0]["cache_hit_rate"] == pytest.approx(1.0)
    assert rows[2]["cache_hit_rate"] == pytest.approx(4 / 6)

    # all-expired window: zero throughput, expiries counted, no latencies
    col = MetricsCollector(window_s=1.0)
    col.events.append(_Event(arrival=0.0, finished=0.2, latency=None,
                             met_deadline=False, expired=True))
    rows = col.windows()
    assert rows[0]["expired"] == 1 and rows[0]["throughput_rps"] == 0.0
    assert rows[0]["p95_s"] == 0.0
    assert col.summary()["requests"] == 0


def test_metrics_tick_series_records_queue_depth():
    eng = _stub_engine(max_batch=1, clock=VirtualClock())
    col = MetricsCollector().attach(eng)
    for i in range(3):
        eng.submit(steps=2, arrival=0.0)   # 2 steps: in-flight across ticks
    eng.run()
    assert col.ticks
    assert col.summary()["peak_queue_depth"] >= 1
    assert col.summary()["mean_inflight"] > 0


# ---------------------------------------------------------------------------
# Weight-bank prefetch.
# ---------------------------------------------------------------------------


def test_prefetch_builds_next_segment_and_counts_hits():
    sched = make_schedule("linear", T)

    def run(prefetch):
        bank = _multi_segment_bank()
        eng = DiffusionServingEngine(
            tiny_ddim(4), sched, bank, max_batch=2,
            apply_fn=lambda p, x, tb, y, ctx: 0.1 * x, prefetch=prefetch)
        eng.submit(steps=8, seed=0)
        eng.submit(steps=8, seed=1)
        res = eng.run()
        return bank, {r: np.asarray(rs.x0).tobytes()
                      for r, rs in res.items()}

    bank_p, out_p = run(True)
    bank_n, out_n = run(False)
    assert bank_p.n_segments >= 2, "toy router should fragment the schedule"
    assert bank_p.prefetches >= 1 and bank_p.prefetch_hits >= 1
    assert bank_p.misses < bank_n.misses   # crossings found warm
    assert bank_n.prefetches == 0 and bank_n.prefetch_hits == 0
    assert out_p == out_n                  # prefetch never changes outputs
    d = bank_p.describe()
    assert d["prefetch_hits"] == bank_p.prefetch_hits


def test_prefetch_respects_lru_cap():
    bank = _multi_segment_bank(max_cached=1)
    assert bank.n_segments >= 2
    bank.prefetch(0)
    bank.prefetch(1)   # evicts prefetched 0
    assert bank.evictions == 1
    bank.params_for_segment(1)
    assert bank.prefetch_hits == 1
    bank.params_for_segment(0)             # rebuilt: plain miss, not a hit
    assert (bank.hits, bank.misses) == (1, 1)


# ---------------------------------------------------------------------------
# Scenarios + golden trace replay.
# ---------------------------------------------------------------------------


def test_scenario_registry_contents():
    names = list_scenarios()
    for required in ("steady", "burst", "diurnal", "closed_loop",
                     "deadline_mix", "golden"):
        assert required in names
    with pytest.raises(KeyError, match="unknown scenario"):
        get_scenario("nope")
    # open-loop scenario traces are deterministic in seed
    scn = get_scenario("steady")
    assert build_trace(scn, seed=1) == build_trace(scn, seed=1)
    assert build_trace(scn, seed=1) != build_trace(scn, seed=2)
    # deadline_mix carries tiered deadlines and priorities
    reqs = build_trace(get_scenario("deadline_mix"), seed=0)
    assert any(r.deadline is not None for r in reqs)
    assert any(r.deadline is None for r in reqs)
    assert len({r.priority for r in reqs}) > 1
    with pytest.raises(ValueError, match="closed"):
        build_trace(get_scenario("closed_loop"))


def test_run_scenario_summary_contract():
    scn = get_scenario("deadline_mix")
    scn = dataclasses.replace(
        scn, n_requests=5,
        mix=dataclasses.replace(scn.mix, steps=1, steps_jitter=0))
    eng = _stub_engine(max_batch=2, clock=VirtualClock())
    summary = run_scenario(scn, eng, seed=0)
    assert summary["scenario"] == "deadline_mix"
    assert summary["requests"] + summary["expired"] == 5
    assert "slo" in summary and "checks" in summary["slo"]
    assert "goodput_frac" in summary["slo"]["checks"]


def test_golden_trace_is_valid_and_replays_deterministically():
    reqs, header = load_trace(resolve_trace_path(GOLDEN))
    assert header["version"] == 1
    assert len(reqs) >= 4
    assert {r.sampler for r in reqs} == {"ddim", "plms", "dpm_solver2"}
    assert any(r.deadline is not None for r in reqs)

    def replay():
        eng = _stub_engine(max_batch=2, clock=VirtualClock())
        submit_trace(eng, reqs)
        res = eng.run()
        return {rid: (rs.n_evals, np.asarray(rs.x0).tobytes())
                for rid, rs in res.items()}

    r1, r2 = replay(), replay()
    assert r1 == r2
    assert sorted(r1) == [tr.rid for tr in reqs]
    # per-request step counts follow the trace (dpm_solver2 runs 2 evals
    # per step pair + final; ddim/plms one per step)
    evals = {rid: n for rid, (n, _) in r1.items()}
    for tr in reqs:
        if tr.sampler == "ddim" or tr.sampler == "plms":
            assert evals[tr.rid] == tr.steps
        else:
            assert evals[tr.rid] >= tr.steps


def test_golden_scenario_binds_the_checked_in_trace():
    scn = get_scenario("golden")
    reqs = build_trace(scn)
    direct, _ = load_trace(resolve_trace_path(GOLDEN))
    assert reqs == direct
