import os
import sys

# Tests run on the single real CPU device (smoke configs). Multi-device
# sharding tests spawn subprocesses with XLA_FLAGS (see test_dryrun_small).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
