"""Multi-model serving gateway: registry validation, routing, hook
fan-in, determinism, LM adapter, and trace v2 back-compat.

Gateway-hosted engines here are mostly stubs (``apply_fn`` short-circuits
the UNet) — the packed-path numerics live in test_serving, and the
full-stack gateway digest checks live in CI via ``launch.serve_gateway``.
What this suite pins is the routing/identity layer: gid assignment,
``rs.model``/``rs.gid`` annotations, per-bank counter reconciliation,
deterministic two-model replay, and v1 traces loading unchanged.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.tree import flatten_paths
from repro.configs.diffusion_presets import tiny_ddim
from repro.configs.registry import list_models
from repro.diffusion.schedule import make_schedule
from repro.launch.serve_diffusion import outcome_digest
from repro.models.lm import LMConfig, lm_init
from repro.serving import (DiffusionServingEngine, VirtualClock, WeightBank,
                           default_serving_plan)
from repro.serving.gateway import (FAMILIES, DecodeState, LMServingEngine,
                                   ModelEntry, ModelRegistry, ServingGateway,
                                   default_entries, default_registry)
from repro.serving.traffic import (MetricsCollector, RequestMix, TraceWriter,
                                   get_scenario, list_scenarios, load_trace,
                                   open_loop_trace, run_scenario, save_trace,
                                   submit_trace)
from repro.serving.traffic.scenarios import build_trace, resolve_trace_path
from repro.serving.traffic.sim import SimClock

T = 40
GOLDEN = "tests/data/golden_trace.jsonl"


def _bank():
    params = {"l0": {"w": jnp.ones((4, 4))}}
    plan = default_serving_plan(flatten_paths(params))
    return WeightBank(params, plan, {}, None, None, T)


def _stub_engine(max_batch=3, scale=0.1, **kw):
    sched = make_schedule("linear", T)
    return DiffusionServingEngine(
        tiny_ddim(4), sched, _bank(), max_batch=max_batch,
        apply_fn=lambda params, x, tb, y, ctx, s=scale: s * x, **kw)


def _two_model_gateway(clock=None, **eng_kw):
    """Both default registry names hosted on stub engines (distinct
    apply scales so cross-routing would change outputs)."""
    gw = ServingGateway(clock=clock)
    entries = {e.name: e for e in default_entries()}
    kw = dict(eng_kw)
    if clock is not None:
        kw["clock"] = clock
    gw.add_model(entries["tiny-ddim"],
                 _stub_engine(max_batch=2, scale=0.1, **kw))
    gw.add_model(entries["smollm-135m"],
                 _stub_engine(max_batch=2, scale=0.3, **kw))
    return gw


# ---------------------------------------------------------------------------
# Registry validation.
# ---------------------------------------------------------------------------


def test_model_entry_validation():
    ok = ModelEntry(name="tiny-ddim", family="diffusion", config="tiny-ddim")
    ok.validate()
    with pytest.raises(ValueError, match="family"):
        ModelEntry(name="x", family="vision", config="tiny-ddim").validate()
    with pytest.raises(ValueError, match="preset"):
        ModelEntry(name="x", family="diffusion", config="nope").validate()
    with pytest.raises(ValueError, match="arch"):
        ModelEntry(name="x", family="lm", config="nope").validate()
    with pytest.raises(ValueError, match="name"):
        ModelEntry(name="", family="diffusion", config="tiny-ddim").validate()
    with pytest.raises(ValueError, match="bank_cap"):
        ModelEntry(name="x", family="diffusion", config="tiny-ddim",
                   bank_cap=0).validate()
    assert set(FAMILIES) == {"diffusion", "lm"}


def test_model_registry_register_resolve_list():
    reg = default_registry()
    assert reg.list() == ["smollm-135m", "tiny-ddim"]
    assert "tiny-ddim" in reg and len(reg) == 2
    e = reg.resolve("smollm-135m")
    assert e.family == "lm" and e.config in list_models()
    with pytest.raises(ValueError, match="already registered"):
        reg.register(e)
    with pytest.raises(KeyError, match="unknown model"):
        reg.resolve("missing")
    for entry in default_entries():
        entry.validate()


def test_configs_registry_exposes_models():
    models = list_models()
    assert models == sorted(models)
    assert "smollm-135m" in models


# ---------------------------------------------------------------------------
# Routing + gid identity.
# ---------------------------------------------------------------------------


def test_gateway_routes_by_model_and_assigns_gids():
    gw = _two_model_gateway(clock=VirtualClock())
    assert gw.routes_models
    assert gw.list_models() == ["tiny-ddim", "smollm-135m"]
    g0 = gw.submit(model="tiny-ddim", steps=1, seed=0)
    g1 = gw.submit(model="smollm-135m", steps=1, seed=1)
    g2 = gw.submit(steps=1, seed=1)            # None -> default (first added)
    assert (g0, g1, g2) == (0, 1, 2)
    assert gw.route[g1][0] == "smollm-135m"
    assert gw.route[g2][0] == "tiny-ddim"
    # engine-local rids overlap across engines; gids never do
    assert gw.route[g0][1] == gw.route[g1][1] == 0
    with pytest.raises(KeyError, match="unknown model"):
        gw.submit(model="missing", steps=1)
    res = gw.run()
    assert set(res) == {0, 1, 2}
    for gid, rs in res.items():
        assert rs.gid == gid
        assert rs.model == gw.route[gid][0]
    # distinct apply scales prove requests ran on their routed engine
    x2 = gw.pop_result(g2).x0
    assert not np.allclose(np.asarray(gw.results[g1].x0)[..., 0, 0, 0],
                           np.asarray(x2)[..., 0, 0, 0])


def test_gateway_rejects_duplicate_and_busy_engines():
    gw = ServingGateway()
    entry = default_entries()[0]
    gw.add_model(entry, _stub_engine())
    with pytest.raises(ValueError, match="already hosted"):
        gw.add_model(entry, _stub_engine())
    busy = _stub_engine()
    busy.submit(steps=1)
    with pytest.raises(ValueError, match="already has requests"):
        gw.add_model(default_entries()[1], busy)
    with pytest.raises(RuntimeError, match="no models"):
        ServingGateway().submit(steps=1)


def test_gateway_single_model_is_behavior_identical():
    """Hosting one engine behind the gateway must not change outcomes:
    same trace, same virtual clock -> same digest as the bare engine."""
    mix = RequestMix(samplers=("ddim", "plms"), steps=2, steps_jitter=1,
                     priorities=(1, 0))
    reqs = open_loop_trace("poisson", 6, seed=4, mix=mix, rate=30.0)

    eng = _stub_engine(max_batch=2, clock=VirtualClock())
    submit_trace(eng, reqs)
    direct = outcome_digest(eng.run())

    clock = VirtualClock()
    gw = ServingGateway(clock=clock)
    gw.add_model(default_entries()[0],
                 _stub_engine(max_batch=2, clock=clock))
    submit_trace(gw, reqs)
    via_gateway = outcome_digest(gw.run())
    assert via_gateway == direct


def test_gateway_two_model_replay_is_deterministic():
    mix = RequestMix(samplers=("ddim",), steps=2, steps_jitter=1,
                     models=("tiny-ddim", "smollm-135m"))
    reqs = open_loop_trace("poisson", 8, seed=7, mix=mix, rate=40.0)

    def once():
        gw = _two_model_gateway(clock=VirtualClock())
        submit_trace(gw, reqs)
        res = gw.run()
        for name in gw.list_models():
            bank = gw.engine(name).bank
            assert (bank.builds + bank.build_failures
                    == bank.misses + bank.prefetches), name
        return outcome_digest(res), gw.stats()

    d1, s1 = once()
    d2, s2 = once()
    assert d1 == d2
    assert s1["aggregate"]["requests"] == 8
    # both models actually served traffic, goodput reported per model
    for name in ("tiny-ddim", "smollm-135m"):
        assert s1["per_model"][name]["engine"]["requests"] == 4
        assert s1["per_model"][name]["summary"]["goodput_frac"] == \
            s2["per_model"][name]["summary"]["goodput_frac"]


def test_gateway_shared_collector_and_scenarios():
    assert {"mixed_model", "per_model_slo"} <= set(list_scenarios())
    scn = get_scenario("mixed_model")
    scn = dataclasses.replace(
        scn, n_requests=4,
        mix=dataclasses.replace(scn.mix, steps=1, steps_jitter=0))
    gw = _two_model_gateway(clock=VirtualClock())
    collector = MetricsCollector()
    summary = run_scenario(scn, gw, seed=0, collector=collector)
    assert summary["requests"] == 4
    assert summary["scenario"] == "mixed_model"
    # the shared collector saw completions from both engines
    assert len(collector.events) == 4


def test_per_model_slo_scenario_deadlines_follow_models():
    trace = build_trace(get_scenario("per_model_slo"), seed=0, n=6)
    for tr in trace:
        if tr.model == "tiny-ddim":
            assert tr.deadline is not None
        else:
            assert tr.model == "smollm-135m" and tr.deadline is None


def test_gateway_pop_result_prunes_all_bookkeeping():
    """Regression: pop_result used to drop only ``results``, leaking the
    gid route entry, the model's rid->gid map, and the engine-local
    result for every request a long-lived gateway ever served."""
    gw = _two_model_gateway(clock=VirtualClock())
    gids = [gw.submit(model=m, steps=1, seed=i)
            for i, m in enumerate(("tiny-ddim", "smollm-135m") * 2)]
    res = gw.run()
    assert len(res) == 4
    for g in gids:
        rs = gw.pop_result(g)
        assert rs.gid == g
    assert gw.results == {} and gw.route == {}
    for name in gw.list_models():
        assert gw._models[name].gid_of == {}
        assert gw.engine(name).results == {}
    with pytest.raises(KeyError):
        gw.pop_result(gids[0])


def test_gateway_under_shared_sim_clock():
    """One SimClock across both engines: time advances for each engine's
    compute on a single axis, and the run still drains deterministically."""
    sim = SimClock(tick_base_s=0.01, sample_s=0.005)
    gw = ServingGateway(now_fn=sim.now, max_idle_sleep=0.0)
    entries = {e.name: e for e in default_entries()}
    e1 = _stub_engine(max_batch=2, now_fn=sim.now, max_idle_sleep=0.0)
    e2 = _stub_engine(max_batch=2, scale=0.3, now_fn=sim.now,
                      max_idle_sleep=0.0)
    sim.attach(e1)
    sim.attach(e2)
    gw.add_model(entries["tiny-ddim"], e1)
    gw.add_model(entries["smollm-135m"], e2)
    mix = RequestMix(steps=1, steps_jitter=0,
                     models=("tiny-ddim", "smollm-135m"))
    submit_trace(gw, open_loop_trace("poisson", 4, seed=3, mix=mix,
                                     rate=50.0))
    res = gw.run()
    assert len(res) == 4
    assert sim.now() > 0.0
    assert all(rs.finished_at <= sim.now() for rs in res.values())


# ---------------------------------------------------------------------------
# LM engine adapter.
# ---------------------------------------------------------------------------


def _tiny_lm():
    cfg = LMConfig(name="tiny-test-lm", n_layers=1, d_model=16, n_heads=2,
                   n_kv=2, d_ff=32, vocab=32, dtype=jnp.float32)
    params = lm_init(jax.random.PRNGKey(0), cfg)
    bank = WeightBank(params, None, {}, None, None, 1, max_cached=1,
                      build_fn=lambda p: p)
    return cfg, bank


def test_lm_engine_serves_and_reconciles():
    cfg, bank = _tiny_lm()
    eng = LMServingEngine(cfg, bank, max_batch=2, prompt_len=3)
    r0 = eng.submit(steps=4, seed=1)
    r1 = eng.submit(steps=2, seed=2)
    res = eng.run()
    assert set(res) == {r0, r1}
    out0 = res[r0].x0
    assert out0.shape == (4,) and out0.dtype == np.int32
    assert res[r1].x0.shape == (2,)
    assert res[r0].n_evals == 4 and res[r1].n_evals == 2
    assert (bank.builds + bank.build_failures
            == bank.misses + bank.prefetches)
    s = eng.stats()
    assert s["requests"] == 2 and s["buckets"] == [1]
    assert s["padded_samples"] == 0
    assert "bank_builds" in s


def test_lm_engine_deterministic_and_deadline_expiry():
    cfg, bank = _tiny_lm()

    def decode(seed):
        eng = LMServingEngine(cfg, bank, max_batch=1,
                              clock=VirtualClock())
        rid = eng.submit(steps=3, seed=seed)
        return eng.run()[rid].x0.tolist()

    assert decode(5) == decode(5)
    assert decode(5) != decode(6)   # seed-derived prompt differs

    eng = LMServingEngine(cfg, bank, max_batch=1, clock=VirtualClock())
    rid = eng.submit(steps=2, seed=0, arrival=0.0, deadline=-1.0)
    res = eng.run()
    assert res[rid].expired and res[rid].x0 is None


def test_decode_state_steps_left_counts_prefill():
    cfg, _ = _tiny_lm()
    dec = DecodeState(cfg, seed=0, gen_len=3, prompt_len=2)
    assert dec.kind == "lm"
    assert dec.steps_left == 5      # prompt not yet prefetched into cache
    assert not dec.done


# ---------------------------------------------------------------------------
# Trace v2 back-compat (satellite: v1 loads + round-trips; mixed-model
# capture round-trips).
# ---------------------------------------------------------------------------


def test_v1_golden_trace_loads_with_default_model_and_roundtrips(tmp_path):
    reqs, header = load_trace(resolve_trace_path(GOLDEN))
    assert header["version"] == 1
    assert all(tr.model is None for tr in reqs)
    out = str(tmp_path / "resaved.jsonl")
    save_trace(out, reqs)
    again, header2 = load_trace(out)
    assert header2["version"] == 2
    assert again == reqs
    # v1 requests have no model field, so their encoded lines are
    # identical before and after the version bump
    v1_lines = open(resolve_trace_path(GOLDEN)).read().splitlines()[1:]
    v2_lines = open(out).read().splitlines()[1:]
    assert sorted(json.loads(ln)["seed"] for ln in v1_lines) == \
        sorted(json.loads(ln)["seed"] for ln in v2_lines)


def test_v1_header_without_model_field_accepted(tmp_path):
    p = tmp_path / "v1.jsonl"
    p.write_text(
        json.dumps({"format": "repro.traffic.trace", "version": 1,
                    "meta": {}}) + "\n"
        + json.dumps({"arrival": 0.1, "steps": 2}) + "\n")
    reqs, header = load_trace(str(p))
    assert header["version"] == 1
    assert reqs[0].model is None and reqs[0].steps == 2


def test_trace_rejects_bad_model_field():
    from repro.serving.traffic import validate_trace
    from repro.serving.traffic.trace import TraceRequest
    with pytest.raises(ValueError, match="model"):
        validate_trace([TraceRequest(arrival=0.0, steps=1, model="")])


def test_mixed_model_capture_roundtrips(tmp_path):
    mix = RequestMix(steps=1, steps_jitter=0,
                     models=("tiny-ddim", "smollm-135m"))
    reqs = open_loop_trace("poisson", 6, seed=11, mix=mix, rate=40.0)
    path = str(tmp_path / "cap.jsonl")

    gw = _two_model_gateway(clock=VirtualClock())
    writer = TraceWriter(path, meta={"src": "gw"}).attach(gw)
    submit_trace(gw, reqs)
    gw.run()
    writer.close()

    captured, header = load_trace(path)
    assert header["version"] == 2
    assert len(captured) == 6
    # gateway-wide gids, not per-engine rids, land in the capture —
    # unique, and routing survives the round-trip
    assert sorted(tr.rid for tr in captured) == list(range(6))
    assert [tr.model for tr in captured] == [tr.model for tr in reqs]
    assert [tr.seed for tr in captured] == [tr.seed for tr in reqs]

    gw2 = _two_model_gateway(clock=VirtualClock())
    submit_trace(gw2, captured)
    res = gw2.run()
    assert len(res) == 6
    by_model = {}
    for gid, rs in res.items():
        by_model.setdefault(rs.model, 0)
        by_model[rs.model] += 1
    assert by_model == {"tiny-ddim": 3, "smollm-135m": 3}
