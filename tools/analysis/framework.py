"""repolint core: AST rule framework, allowlists, config, baseline.

The repo's determinism and performance guarantees rest on invariants
that used to live only in reviewers' heads (engine-clock discipline for
the golden-replay digest, span emission outside ``bank._lock``, runtime
operands in benchmarks, the layer DAG). ``repolint`` machine-checks them
per PR: each invariant is a :class:`Rule` with an AST visitor, a
severity, and a scope; the CLI (``python -m tools.analysis``) runs them
over the tree and gates CI.

Suppression has three levels, strictest first:

  * per-line — ``# repolint: disable=<rule>[,<rule>...]`` on the
    flagged line (or a standalone comment on the line directly above);
    use for a single sanctioned exception and say *why* next to it.
  * per-file — ``# repolint: disable-file=<rule>`` anywhere in the
    file; use when a whole module is out of a rule's jurisdiction.
  * baseline — ``tools/analysis/repolint.toml`` ``[baseline]`` entries
    (``"rule:path:line"``); the committed ledger of accepted debt. The
    test suite asserts the baseline matches ``--all-files`` output
    *exactly* — a fixed violation must leave the baseline, a new one
    must not silently join it.

The config file also declares per-rule severity overrides, per-rule
path scopes, and the import-layer DAG (see ``rules.ImportLayeringRule``).
No third-party parser: Python 3.10 has no ``tomllib``, so
:func:`parse_toml_subset` reads the small TOML subset the config uses
(sections, scalar values, string arrays).
"""
from __future__ import annotations

import ast
import dataclasses
import os
import re
import subprocess


# ---------------------------------------------------------------------------
# Violations
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, order=True)
class Violation:
    path: str                   # repo-relative, posix separators
    line: int
    col: int
    rule: str
    message: str
    severity: str = "error"     # "error" | "warning"

    @property
    def key(self) -> str:
        """Baseline identity — stable across message rewording."""
        return f"{self.rule}:{self.path}:{self.line}"

    def format(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: "
                f"[{self.severity}] {self.rule}: {self.message}")


# ---------------------------------------------------------------------------
# Minimal TOML-subset parser (no tomllib on 3.10)
# ---------------------------------------------------------------------------


def _strip_comment(line: str) -> str:
    out = []
    in_str = None
    for ch in line:
        if in_str:
            out.append(ch)
            if ch == in_str:
                in_str = None
        elif ch in ("'", '"'):
            in_str = ch
            out.append(ch)
        elif ch == "#":
            break
        else:
            out.append(ch)
    return "".join(out)


def _parse_scalar(tok: str):
    tok = tok.strip()
    if tok.startswith('"') and tok.endswith('"') and len(tok) >= 2:
        return tok[1:-1]
    if tok.startswith("'") and tok.endswith("'") and len(tok) >= 2:
        return tok[1:-1]
    if tok in ("true", "false"):
        return tok == "true"
    try:
        return int(tok)
    except ValueError:
        pass
    try:
        return float(tok)
    except ValueError:
        raise ValueError(f"unparseable TOML value: {tok!r}")


def _parse_array(tok: str) -> list:
    body = tok.strip()[1:-1]
    items, cur, in_str, depth = [], [], None, 0
    for ch in body:
        if in_str:
            cur.append(ch)
            if ch == in_str:
                in_str = None
        elif ch in ("'", '"'):
            in_str = ch
            cur.append(ch)
        elif ch == "[":
            depth += 1
            cur.append(ch)
        elif ch == "]":
            depth -= 1
            cur.append(ch)
        elif ch == "," and depth == 0:
            items.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if "".join(cur).strip():
        items.append("".join(cur))
    return [_parse_scalar(i) for i in items if i.strip()]


def parse_toml_subset(text: str) -> dict:
    """Parse the config's TOML subset: ``[section]`` tables, bare or
    quoted keys, string/int/float/bool scalars, and (possibly multiline)
    arrays of scalars. Raises ``ValueError`` on anything it can't read —
    a half-understood lint config must fail loudly, not lint loosely."""
    root: dict = {}
    section = root
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        line = _strip_comment(lines[i]).strip()
        i += 1
        if not line:
            continue
        if line.startswith("[") and line.endswith("]"):
            name = line[1:-1].strip().strip('"').strip("'")
            section = root.setdefault(name, {})
            continue
        if "=" not in line:
            raise ValueError(f"unparseable TOML line: {line!r}")
        key, _, val = line.partition("=")
        key = key.strip().strip('"').strip("'")
        val = val.strip()
        if val.startswith("["):
            # accumulate until brackets balance outside strings
            while True:
                depth, in_str = 0, None
                for ch in val:
                    if in_str:
                        if ch == in_str:
                            in_str = None
                    elif ch in ("'", '"'):
                        in_str = ch
                    elif ch == "[":
                        depth += 1
                    elif ch == "]":
                        depth -= 1
                if depth == 0:
                    break
                if i >= len(lines):
                    raise ValueError(f"unterminated array for key {key!r}")
                val += " " + _strip_comment(lines[i]).strip()
                i += 1
            section[key] = _parse_array(val)
        else:
            section[key] = _parse_scalar(val)
    return root


# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------

CONFIG_PATH = os.path.join("tools", "analysis", "repolint.toml")


class Config:
    """Parsed repolint.toml: severities, scopes, layer DAG, baseline."""

    def __init__(self, data: dict | None = None):
        data = data or {}
        self.severities: dict = dict(data.get("rules", {}))
        self.scopes: dict = {k: list(v)
                             for k, v in data.get("scopes", {}).items()}
        self.layers: dict = {k: list(v)
                             for k, v in data.get("layers", {}).items()}
        base = data.get("baseline", {})
        self.baseline: list[str] = [str(e) for e in base.get("entries", [])]
        run = data.get("run", {})
        self.include: list[str] = list(run.get("include",
                                               ["src", "tests", "benchmarks",
                                                "tools", "examples"]))
        self.exclude: list[str] = list(run.get("exclude", []))

    def severity_for(self, rule) -> str:
        return self.severities.get(rule.name, rule.severity)

    def scope_for(self, rule) -> list[str]:
        return self.scopes.get(rule.name, list(rule.default_scope))


def load_config(root: str) -> Config:
    path = os.path.join(root, CONFIG_PATH)
    if not os.path.exists(path):
        return Config()
    with open(path) as f:
        return Config(parse_toml_subset(f.read()))


# ---------------------------------------------------------------------------
# Rules
# ---------------------------------------------------------------------------


class Rule:
    """One machine-checked invariant.

    Subclasses set ``name``/``severity``/``description``/``why`` and a
    ``default_scope`` of path prefixes (overridable per-config), and
    implement :meth:`check` over a parsed module.
    """

    name: str = ""
    severity: str = "error"
    description: str = ""
    why: str = ""                       # the postmortem / PR this encodes
    default_scope: tuple = ()           # path prefixes; () = everywhere

    def applies_to(self, path: str, config: Config) -> bool:
        scope = config.scope_for(self)
        if not scope:
            return True
        return any(path == s or path.startswith(s) for s in scope)

    def check(self, tree: ast.AST, src: str, path: str,
              config: Config) -> list[Violation]:
        raise NotImplementedError

    def violation(self, path: str, node: ast.AST, message: str,
                  config: Config) -> Violation:
        return Violation(path=path, line=getattr(node, "lineno", 1),
                         col=getattr(node, "col_offset", 0), rule=self.name,
                         message=message,
                         severity=config.severity_for(self))


_REGISTRY: dict[str, Rule] = {}


def register(cls):
    """Class decorator: instantiate + add to the rule registry."""
    inst = cls()
    assert inst.name and inst.name not in _REGISTRY, inst.name
    _REGISTRY[inst.name] = inst
    return cls


def all_rules() -> list[Rule]:
    import tools.analysis.rules  # noqa: F401  — registers on import
    return [_REGISTRY[k] for k in sorted(_REGISTRY)]


def get_rule(name: str) -> Rule:
    import tools.analysis.rules  # noqa: F401
    return _REGISTRY[name]


# ---------------------------------------------------------------------------
# Suppression comments
# ---------------------------------------------------------------------------

_DISABLE_RE = re.compile(
    r"#\s*repolint:\s*disable(?P<file>-file)?\s*=\s*"
    r"(?P<rules>[A-Za-z0-9_\-]+(?:\s*,\s*[A-Za-z0-9_\-]+)*)")


def scan_disables(src: str) -> tuple[dict, set]:
    """Returns (line -> set(rule), file_disabled_rules).

    A trailing disable covers its own line. A *standalone* disable
    comment (a line that is only a comment) covers the next code line,
    carrying through any comment/blank lines in between — so a
    multi-line justification block above the flagged statement works.
    """
    per_line: dict[int, set] = {}
    per_file: set = set()
    pending: set = set()
    for i, line in enumerate(src.splitlines(), start=1):
        stripped = line.strip()
        m = _DISABLE_RE.search(line)
        if m:
            rules = {r.strip() for r in m.group("rules").split(",")}
            if m.group("file"):
                per_file |= rules
                continue
            per_line.setdefault(i, set()).update(rules)
            if stripped.startswith("#"):
                pending |= rules
                continue
        if pending and stripped and not stripped.startswith("#"):
            per_line.setdefault(i, set()).update(pending)
            pending = set()
    return per_line, per_file


# ---------------------------------------------------------------------------
# Running
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LintResult:
    violations: list
    suppressed: int = 0          # dropped by inline/file disables
    files: int = 0


def lint_source(src: str, path: str, config: Config | None = None,
                rules: list[Rule] | None = None) -> LintResult:
    """Lint one module's source. ``path`` decides which rules apply."""
    config = config or Config()
    rules = rules if rules is not None else all_rules()
    path = path.replace(os.sep, "/")
    try:
        tree = ast.parse(src)
    except SyntaxError as e:
        return LintResult([Violation(path=path, line=e.lineno or 1,
                                     col=e.offset or 0, rule="parse-error",
                                     message=f"file does not parse: {e.msg}")],
                          files=1)
    per_line, per_file = scan_disables(src)
    out, suppressed = [], 0
    for rule in rules:
        if config.severity_for(rule) == "off":
            continue
        if not rule.applies_to(path, config):
            continue
        for v in rule.check(tree, src, path, config):
            if v.rule in per_file or v.rule in per_line.get(v.line, ()):
                suppressed += 1
            else:
                out.append(v)
    return LintResult(sorted(out), suppressed=suppressed, files=1)


def lint_file(path: str, root: str, config: Config,
              rules: list[Rule] | None = None) -> LintResult:
    rel = os.path.relpath(path, root).replace(os.sep, "/")
    with open(path, encoding="utf-8") as f:
        src = f.read()
    return lint_source(src, rel, config, rules)


def collect_files(root: str, config: Config) -> list[str]:
    """Every lintable .py under the configured include roots."""
    out = []
    for inc in config.include:
        base = os.path.join(root, inc)
        if not os.path.isdir(base):
            continue
        for dirpath, dirnames, filenames in os.walk(base):
            dirnames[:] = sorted(d for d in dirnames
                                 if not d.startswith(".")
                                 and d != "__pycache__")
            rel_dir = os.path.relpath(dirpath, root).replace(os.sep, "/")
            if any(rel_dir == e.rstrip("/") or
                   rel_dir.startswith(e.rstrip("/") + "/")
                   for e in config.exclude):
                continue
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    out.append(os.path.join(dirpath, fn))
    return out


def changed_files(root: str, base: str = "HEAD") -> list[str]:
    """Modified + staged + untracked .py files (the pre-push set)."""
    names: set[str] = set()
    for args in (["git", "diff", "--name-only", base],
                 ["git", "diff", "--name-only", "--cached"],
                 ["git", "ls-files", "--others", "--exclude-standard"]):
        try:
            res = subprocess.run(args, cwd=root, capture_output=True,
                                 text=True, check=True)
        except (subprocess.CalledProcessError, OSError) as e:
            raise RuntimeError(f"--changed needs git ({e})") from e
        names.update(n for n in res.stdout.splitlines() if n)
    out = []
    for n in sorted(names):
        if not n.endswith(".py"):
            continue
        full = os.path.join(root, n)
        if os.path.exists(full):
            out.append(full)
    return out


def run_files(files: list[str], root: str, config: Config,
              rules: list[Rule] | None = None) -> LintResult:
    violations, suppressed = [], 0
    for f in files:
        r = lint_file(f, root, config, rules)
        violations.extend(r.violations)
        suppressed += r.suppressed
    return LintResult(sorted(violations), suppressed=suppressed,
                      files=len(files))


def baseline_split(result: LintResult, config: Config
                   ) -> tuple[list, list, list[str]]:
    """(new_violations, baselined, stale_entries).

    A baseline entry is ``"rule:path:line"``; stale entries (baselined
    debt that no longer fires) fail the run too — the ledger must track
    reality in both directions.
    """
    entries = set(config.baseline)
    new, baselined = [], []
    seen: set[str] = set()
    for v in result.violations:
        if v.key in entries:
            baselined.append(v)
            seen.add(v.key)
        else:
            new.append(v)
    stale = sorted(entries - seen)
    return new, baselined, stale
