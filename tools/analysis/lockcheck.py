"""Dynamic lock-order race detector for the serving stack.

The static ``lock-discipline`` rule catches *lexical* span/callback
calls under ``with self._lock:``; this module catches what grep can't —
lock-order inversions that only materialize at runtime across call
chains (bank thread holds ``bank._lock`` wanting ``tracer._lock`` while
the engine thread holds ``tracer._lock`` wanting ``bank._lock``).

Usage: a :class:`LockMonitor` is itself the ``lock_factory`` seam that
``WeightBank``, ``SpanTracer``, ``MetricsRegistry`` and
``KernelProfiler`` expose::

    mon = serving_discipline(LockMonitor())
    obs  = Observability(lock_factory=mon)
    bank = WeightBank(..., lock_factory=mon)
    ...   # run the churn workload
    mon.assert_clean()

Every lock it hands out records, per thread, the stack of names
currently held. On each acquire it:

  * adds outer->inner edges to a global order graph and DFS-checks for a
    cycle (the classic AB/BA deadlock precondition — flagged even if the
    interleaving that would deadlock never fired in this run);
  * checks the edge against the *forbidden pairs* declared with
    :meth:`LockMonitor.forbid` (e.g. "never acquire a tracer lock while
    holding the bank lock" — the PR 7 span-outside-lock invariant);
  * flags re-acquisition of the same (non-reentrant) lock object, which
    with a real ``threading.Lock`` is a guaranteed self-deadlock.

Violations are recorded (with both thread names and the acquiring
stack), never raised inline — the workload runs to completion and
``assert_clean()`` reports everything at once.
"""
from __future__ import annotations

import threading
import traceback


class LockOrderError(AssertionError):
    """Raised by assert_clean() when the monitor recorded violations."""


class LockOrderViolation:
    __slots__ = ("kind", "outer", "inner", "thread", "reason", "stack")

    def __init__(self, kind, outer, inner, thread, reason, stack):
        self.kind = kind        # "cycle" | "forbidden" | "self-deadlock"
        self.outer = outer
        self.inner = inner
        self.thread = thread
        self.reason = reason
        self.stack = stack

    def format(self) -> str:
        head = (f"[{self.kind}] {self.outer} -> {self.inner} "
                f"(thread {self.thread}): {self.reason}")
        if self.stack:
            head += "\n  acquired at:\n" + "".join(
                "    " + ln for ln in self.stack)
        return head


class InstrumentedLock:
    """Drop-in ``threading.Lock`` that reports acquires/releases to its
    monitor. Multiple locks may share a name (e.g. every ``Counter`` of
    one metric family) — ordering is tracked by *name*, deadlock-on-self
    by object identity."""

    def __init__(self, monitor: "LockMonitor", name: str):
        self._monitor = monitor
        self.name = name
        self._lock = threading.Lock()

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        if not self._monitor._before_acquire(self):
            # same-thread re-acquire: a real threading.Lock would hang
            # forever here — fail the test loudly instead of deadlocking
            raise LockOrderError(
                f"self-deadlock: {self.name} re-acquired by the thread "
                "already holding it")
        got = (self._lock.acquire(blocking, timeout) if timeout != -1
               else self._lock.acquire(blocking))
        if got:
            self._monitor._on_acquired(self)
        return got

    def release(self) -> None:
        self._monitor._on_release(self)
        self._lock.release()

    def locked(self) -> bool:
        return self._lock.locked()

    def __enter__(self) -> bool:
        return self.acquire()

    def __exit__(self, *exc) -> None:
        self.release()


class LockMonitor:
    """Factory + global order graph for instrumented locks.

    The monitor object is callable so it plugs straight into the
    ``lock_factory=`` constructor seams: ``WeightBank(...,
    lock_factory=mon)`` / ``Observability(lock_factory=mon)``.
    """

    def __init__(self, capture_stacks: bool = True):
        self.capture_stacks = capture_stacks
        self._meta = threading.Lock()   # guards graph/violations/counts
        self._tls = threading.local()
        # edge graph: outer name -> {inner name: (thread, stack)}
        self._edges: dict[str, dict] = {}
        self._forbidden: list[tuple] = []   # (outer_pfx, inner_pfx, reason)
        self._violations: list[LockOrderViolation] = []
        self._acquires: dict[str, int] = {}
        self._max_held = 0

    # -- factory seam --------------------------------------------------------

    def lock(self, name: str) -> InstrumentedLock:
        return InstrumentedLock(self, name)

    __call__ = lock

    # -- policy --------------------------------------------------------------

    def forbid(self, outer_prefix: str, inner_prefix: str,
               reason: str) -> "LockMonitor":
        """Declare that no lock named ``inner_prefix*`` may ever be
        acquired while a ``outer_prefix*`` lock is held. Empty
        ``inner_prefix`` means *any* lock (outer is a leaf)."""
        self._forbidden.append((outer_prefix, inner_prefix, reason))
        return self

    # -- hot path ------------------------------------------------------------

    def _held(self) -> list:
        held = getattr(self._tls, "held", None)
        if held is None:
            held = self._tls.held = []
        return held

    def _stack(self):
        if not self.capture_stacks:
            return ()
        # drop the 3 innermost frames (this, _on_acquired, acquire)
        return tuple(traceback.format_stack()[:-3][-6:])

    def _before_acquire(self, lock: InstrumentedLock) -> bool:
        """Record edges; False means same-thread re-acquire (the caller
        raises instead of hanging on the real lock)."""
        held = self._held()
        tname = threading.current_thread().name
        if any(h is lock for h in held):
            with self._meta:
                self._violations.append(LockOrderViolation(
                    "self-deadlock", lock.name, lock.name, tname,
                    "re-acquiring a non-reentrant lock already held by "
                    "this thread", self._stack()))
            return False
        for outer in held:
            if outer.name == lock.name:
                continue  # same-name siblings carry no order information
            self._record_edge(outer.name, lock.name, tname)
        return True

    def _on_acquired(self, lock: InstrumentedLock) -> None:
        held = self._held()
        held.append(lock)
        with self._meta:
            self._acquires[lock.name] = self._acquires.get(lock.name, 0) + 1
            if len(held) > self._max_held:
                self._max_held = len(held)

    def _on_release(self, lock: InstrumentedLock) -> None:
        held = self._held()
        for i in range(len(held) - 1, -1, -1):
            if held[i] is lock:
                del held[i]
                return

    def _record_edge(self, outer: str, inner: str, tname: str) -> None:
        with self._meta:
            for o_pfx, i_pfx, reason in self._forbidden:
                if outer.startswith(o_pfx) and inner.startswith(i_pfx):
                    self._violations.append(LockOrderViolation(
                        "forbidden", outer, inner, tname, reason,
                        self._stack()))
            inners = self._edges.setdefault(outer, {})
            if inner in inners:
                return  # known edge: already checked for cycles
            inners[inner] = (tname, self._stack())
            cycle = self._find_path(inner, outer)
            if cycle:
                other_thread = self._edges[cycle[0]][cycle[1]][0]
                self._violations.append(LockOrderViolation(
                    "cycle", outer, inner, tname,
                    "lock-order cycle: this thread takes "
                    f"{outer} -> {inner}, but the reverse path "
                    f"{' -> '.join(cycle)} was taken (first by thread "
                    f"{other_thread}) — AB/BA deadlock precondition",
                    self._stack()))

    def _find_path(self, start: str, goal: str):
        """DFS path start -> goal in the edge graph (caller holds _meta)."""
        stack = [(start, [start])]
        seen = set()
        while stack:
            node, path = stack.pop()
            if node == goal:
                return path
            if node in seen:
                continue
            seen.add(node)
            for nxt in self._edges.get(node, ()):
                stack.append((nxt, path + [nxt]))
        return None

    # -- read side -----------------------------------------------------------

    def edges(self) -> set:
        with self._meta:
            return {(o, i) for o, inners in self._edges.items()
                    for i in inners}

    def acquire_counts(self) -> dict:
        with self._meta:
            return dict(self._acquires)

    def violations(self) -> list:
        with self._meta:
            return list(self._violations)

    def report(self) -> str:
        with self._meta:
            n_edges = sum(len(inners) for inners in self._edges.values())
            lines = [f"lockcheck: {sum(self._acquires.values())} acquires "
                     f"across {len(self._acquires)} locks, "
                     f"{n_edges} order edges, max nesting "
                     f"{self._max_held}, {len(self._violations)} "
                     "violation(s)"]
        for v in self.violations():
            lines.append(v.format())
        return "\n".join(lines)

    def assert_clean(self) -> None:
        vs = self.violations()
        if vs:
            raise LockOrderError(self.report())


def serving_discipline(mon: LockMonitor) -> LockMonitor:
    """The repo's lock-order policy for the bank + obs population.

    Encodes the PR 7 invariants the static lock-discipline rule checks
    lexically, as runtime law:

      * spans/metrics/profiler updates happen strictly *after* releasing
        ``bank._lock`` — the bank lock may never be outer to an obs lock;
      * the tracer buffer lock and the kernel-profiler counts lock are
        leaves: nothing is acquired under them;
      * the metrics registry lock may create instruments but never calls
        back into the tracer or the bank.
    """
    mon.forbid("bank._lock", "tracer",
               "span emission while holding the bank lock (spans must be "
               "emitted after release — PR 7 invariant)")
    mon.forbid("bank._lock", "metrics",
               "registry/instrument update while holding the bank lock")
    mon.forbid("bank._lock", "kernel_profiler",
               "profiler callback while holding the bank lock")
    mon.forbid("tracer._lock", "",
               "the tracer buffer lock is a leaf — no lock may be "
               "acquired while holding it")
    mon.forbid("kernel_profiler._lock", "",
               "the profiler counts lock is a leaf")
    mon.forbid("metrics._lock", "tracer",
               "registry ops must not emit spans under the registry lock")
    mon.forbid("metrics._lock", "bank._lock",
               "the registry must never call back into the bank")
    return mon
