"""repolint — the repo's static-analysis subsystem.

Run ``python -m tools.analysis --all-files`` (CI) or ``--changed``
(pre-push). See ``framework`` for the rule/config/baseline machinery,
``rules`` for the rule set, ``lockcheck`` for the dynamic lock-order
race detector, and ``README.md`` for the rule catalog.
"""
from tools.analysis.framework import (Config, LintResult, Rule, Violation,
                                      all_rules, baseline_split, get_rule,
                                      lint_source, load_config, register)

__all__ = ["Config", "LintResult", "Rule", "Violation", "all_rules",
           "baseline_split", "get_rule", "lint_source", "load_config",
           "register"]
