"""The repolint rule set — each rule encodes one repo invariant.

See ``tools/analysis/README.md`` for the catalog with the incident /
design decision behind each rule. Rules register themselves via
``@register``; scopes below are defaults and can be overridden in
``repolint.toml [scopes]``.
"""
from __future__ import annotations

import ast

from tools.analysis.framework import Config, Rule, Violation, register


# ---------------------------------------------------------------------------
# shared AST helpers
# ---------------------------------------------------------------------------


def dotted(node) -> str | None:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _names_loaded(node) -> set:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def _walk_with_ancestors(tree):
    """Yields (node, ancestors) — ancestors outermost-first."""
    stack: list = []

    def rec(node):
        yield node, tuple(stack)
        stack.append(node)
        for child in ast.iter_child_nodes(node):
            yield from rec(child)
        stack.pop()

    yield from rec(tree)


_SCOPE_NODES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda,
                ast.ClassDef)


def _shallow_walk(node):
    """ast.walk that does not descend into nested function/class scopes."""
    todo = list(ast.iter_child_nodes(node))
    while todo:
        n = todo.pop(0)
        yield n
        if not isinstance(n, _SCOPE_NODES):
            todo.extend(ast.iter_child_nodes(n))


def _fn_params(fn) -> list[str]:
    a = fn.args
    names = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return names


# ---------------------------------------------------------------------------
# clock-discipline
# ---------------------------------------------------------------------------


@register
class ClockDisciplineRule(Rule):
    name = "clock-discipline"
    severity = "error"
    description = ("No ad-hoc wall-clock reads in the serving/launch stack "
                   "outside clock classes.")
    why = ("Scheduling decisions must run on the engine clock so the "
           "golden-replay digest is reproducible under VirtualClock; "
           "diagnostics go through repro.common.clock.wall_clock(). A stray "
           "time.time() silently forks the time base.")
    default_scope = ("src/repro/serving/", "src/repro/launch/")

    BANNED_ALWAYS = {
        "time.time", "time.time_ns", "time.perf_counter",
        "time.perf_counter_ns", "time.process_time", "time.process_time_ns",
        "time.monotonic_ns",
    }
    # wall clock only when called with no args (tz-aware now(tz) is still a
    # wall read, but the argless form is the one that shows up in practice)
    BANNED_ARGLESS = {
        "datetime.now", "datetime.utcnow",
        "datetime.datetime.now", "datetime.datetime.utcnow",
        "datetime.date.today", "date.today",
    }

    def check(self, tree, src, path, config):
        out = []
        clock_depth = 0

        def rec(node):
            nonlocal clock_depth
            is_clock_cls = (isinstance(node, ast.ClassDef)
                            and "Clock" in node.name)
            if is_clock_cls:
                clock_depth += 1
            if isinstance(node, ast.Call) and clock_depth == 0:
                chain = dotted(node.func)
                if chain in self.BANNED_ALWAYS:
                    out.append(self.violation(
                        path, node,
                        f"{chain}() reads an ad-hoc wall clock; use the "
                        "engine clock for scheduling time or "
                        "repro.common.clock.wall_clock() for diagnostics",
                        config))
                elif (chain in self.BANNED_ARGLESS and not node.args
                      and not node.keywords):
                    out.append(self.violation(
                        path, node,
                        f"argless {chain}() is a wall-clock read; route "
                        "through the engine clock or wall_clock()", config))
            for child in ast.iter_child_nodes(node):
                rec(child)
            if is_clock_cls:
                clock_depth -= 1

        rec(tree)
        return out


# ---------------------------------------------------------------------------
# lock-discipline
# ---------------------------------------------------------------------------


@register
class LockDisciplineRule(Rule):
    name = "lock-discipline"
    severity = "error"
    description = ("No tracer/obs/callback/engine-hook calls lexically "
                   "inside a `with self._lock:` block.")
    why = ("Span emission or user callbacks under bank/obs locks is how the "
           "original bank deadlock family happened: the callee takes its "
           "own lock (tracer buffer, registry) and the order inverts under "
           "churn. Emit after releasing; defer via executor.submit.")
    default_scope = ("src/repro/serving/weight_bank.py",
                     "src/repro/serving/obs/")

    FLAGGED_SEGMENTS = {"tracer", "obs", "_obs", "callbacks"}
    FLAGGED_NAMES = {"cb", "callback", "hook"}

    def check(self, tree, src, path, config):
        out = []

        def is_lock_item(item) -> bool:
            expr = item.context_expr
            chain = dotted(expr)
            return bool(chain) and (chain == "_lock"
                                    or chain.endswith("._lock"))

        def rec(node, depth):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)) and depth > 0:
                # nested def/lambda bodies run later (executor.submit etc.),
                # not while the lock is held — legal deferral pattern
                return
            if isinstance(node, ast.With):
                d = depth + 1 if any(is_lock_item(i)
                                     for i in node.items) else depth
                for item in node.items:
                    rec(item, depth)
                for st in node.body:
                    rec(st, d)
                return
            if isinstance(node, ast.Call) and depth > 0:
                chain = dotted(node.func)
                segs = chain.split(".") if chain else []
                bad = (any(s in self.FLAGGED_SEGMENTS for s in segs)
                       or (isinstance(node.func, ast.Name)
                           and node.func.id in self.FLAGGED_NAMES)
                       or any(s.startswith("on_") for s in segs[1:]))
                if bad:
                    out.append(self.violation(
                        path, node,
                        f"call to '{chain or node.func.__class__.__name__}' "
                        "while holding a _lock; emit spans / run callbacks "
                        "after releasing the lock", config))
            for child in ast.iter_child_nodes(node):
                rec(child, depth)

        rec(tree, 0)
        return out


# ---------------------------------------------------------------------------
# import-layering
# ---------------------------------------------------------------------------


@register
class ImportLayeringRule(Rule):
    name = "import-layering"
    severity = "error"
    description = ("repro.* imports must follow the layer DAG declared in "
                   "repolint.toml [layers].")
    why = ("kernels/ importing serving/ (or core/ importing launch/) "
           "creates cycles that break partial reuse (e.g. using the "
           "quantizers without the serving stack) and make obs a hidden "
           "kernel dependency.")
    default_scope = ("src/repro/",)

    @staticmethod
    def _layer_of_path(path: str) -> str | None:
        if not path.startswith("src/repro/"):
            return None
        parts = path[len("src/repro/"):].split("/")
        if len(parts) == 1:
            return None  # top-level module (e.g. version.py): unlayered
        layer = parts[0]
        if layer == "serving" and len(parts) > 2 and parts[1] in ("obs",
                                                                  "traffic",
                                                                  "gateway",
                                                                  "fleet"):
            return f"serving.{parts[1]}"
        return layer

    @staticmethod
    def _layer_of_module(mod: str) -> str | None:
        parts = mod.split(".")
        if len(parts) < 2 or parts[0] != "repro":
            return None
        layer = parts[1]
        if layer == "serving" and len(parts) > 2 and parts[2] in ("obs",
                                                                  "traffic",
                                                                  "gateway",
                                                                  "fleet"):
            return f"serving.{parts[2]}"
        return layer

    def check(self, tree, src, path, config):
        src_layer = self._layer_of_path(path)
        if src_layer is None or src_layer not in config.layers:
            return []
        allowed = set(config.layers[src_layer])
        out = []
        for node in ast.walk(tree):
            mods = []
            if isinstance(node, ast.Import):
                mods = [a.name for a in node.names]
            elif isinstance(node, ast.ImportFrom) and node.level == 0:
                mods = [node.module] if node.module else []
            for mod in mods:
                tgt = self._layer_of_module(mod)
                if tgt is None or tgt == src_layer:
                    continue
                # a sub-layer may import its own parent package only if
                # declared; the parent importing a declared sub-layer is
                # handled by the DAG entries themselves
                if "*" in allowed or tgt in allowed:
                    continue
                out.append(self.violation(
                    path, node,
                    f"layer '{src_layer}' may not import layer '{tgt}' "
                    f"(module {mod}); allowed: "
                    f"{sorted(allowed) or 'nothing'} — see repolint.toml "
                    "[layers]", config))
        return out


# ---------------------------------------------------------------------------
# tracer-purity
# ---------------------------------------------------------------------------


@register
class TracerPurityRule(Rule):
    name = "tracer-purity"
    severity = "error"
    description = ("No float()/int()/bool()/.item()/np.asarray on "
                   "ref-derived values in Pallas kernel bodies or "
                   "BlockSpec index maps.")
    why = ("Concretizing a traced value inside a kernel body or index map "
           "raises TracerConversionError at trace time — or worse, "
           "silently bakes in a compile-time constant. Host-side int() on "
           "static shapes (conv padding) is fine and stays unflagged.")
    default_scope = ("src/repro/kernels/",)

    CONCRETIZERS = {"float", "int", "bool", "complex"}
    NP_CONCRETIZERS = {"np.asarray", "np.array", "numpy.asarray",
                       "numpy.array"}
    ATTR_CONCRETIZERS = {"item", "tolist"}

    def _flag_concretizers(self, body_nodes, tainted, path, config, out,
                           require_taint=True):
        for node in body_nodes:
            if not isinstance(node, ast.Call):
                continue
            chain = dotted(node.func)
            arg_names = set()
            for a in list(node.args) + [k.value for k in node.keywords]:
                arg_names |= _names_loaded(a)
            hit = None
            if (isinstance(node.func, ast.Name)
                    and node.func.id in self.CONCRETIZERS):
                hit = f"{node.func.id}()"
            elif chain in self.NP_CONCRETIZERS:
                hit = f"{chain}()"
            elif (isinstance(node.func, ast.Attribute)
                    and node.func.attr in self.ATTR_CONCRETIZERS):
                hit = f".{node.func.attr}()"
                arg_names |= _names_loaded(node.func.value)
            if hit is None:
                continue
            if require_taint and not (arg_names & tainted):
                continue
            out.append(self.violation(
                path, node,
                f"{hit} on a traced value inside a "
                + ("kernel body" if require_taint else "BlockSpec index map")
                + " concretizes it at trace time; keep index/compute math "
                "symbolic (jnp ops, pl.program_id)", config))

    @staticmethod
    def _taint(fn) -> set:
        tainted = {p for p in _fn_params(fn) if p.endswith("_ref")}
        for _ in range(3):  # small fixpoint: chains like a = x_ref[...]; b = a
            before = len(tainted)
            for st in ast.walk(fn):
                tgt_names: list[str] = []
                val = None
                if isinstance(st, ast.Assign):
                    val = st.value
                    for t in st.targets:
                        if isinstance(t, ast.Name):
                            tgt_names.append(t.id)
                        elif isinstance(t, (ast.Tuple, ast.List)):
                            tgt_names += [e.id for e in t.elts
                                          if isinstance(e, ast.Name)]
                elif isinstance(st, ast.AugAssign) and isinstance(
                        st.target, ast.Name):
                    val, tgt_names = st.value, [st.target.id]
                elif isinstance(st, ast.AnnAssign) and st.value is not None \
                        and isinstance(st.target, ast.Name):
                    val, tgt_names = st.value, [st.target.id]
                elif isinstance(st, ast.For) and isinstance(st.target,
                                                            ast.Name):
                    val, tgt_names = st.iter, [st.target.id]
                if val is not None and (_names_loaded(val) & tainted):
                    tainted.update(tgt_names)
            if len(tainted) == before:
                break
        return tainted

    def check(self, tree, src, path, config):
        out = []
        # kernel bodies: any function with a *_ref parameter
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and any(p.endswith("_ref") for p in _fn_params(node)):
                tainted = self._taint(node)
                self._flag_concretizers(ast.walk(node), tainted, path,
                                        config, out, require_taint=True)
        # BlockSpec index maps: everything in a lambda passed to BlockSpec
        # derives from grid indices — concretizers are flagged untainted
        for node in ast.walk(tree):
            if isinstance(node, ast.Call):
                chain = dotted(node.func)
                if not chain or not chain.split(".")[-1] == "BlockSpec":
                    continue
                for a in list(node.args) + [k.value for k in node.keywords]:
                    if isinstance(a, ast.Lambda):
                        self._flag_concretizers(ast.walk(a.body), set(),
                                                path, config, out,
                                                require_taint=False)
        return out


# ---------------------------------------------------------------------------
# bench-operand
# ---------------------------------------------------------------------------


@register
class BenchOperandRule(Rule):
    name = "bench-operand"
    severity = "error"
    description = ("Benchmark arrays must be runtime operands of jitted "
                   "callables, never closed over.")
    why = ("XLA constant-folds closed-over arrays: the 'kernel' bench then "
           "times a memcpy of a precomputed result. This exact footgun "
           "invalidated early matmul numbers (PR 6 postmortem); every "
           "bench now passes arrays as arguments.")
    default_scope = ("benchmarks/",)

    ARRAY_PREFIXES = ("jnp.", "np.", "numpy.", "jax.numpy.", "jax.random.")
    ARRAY_FUNCS = {"pack_weight"}
    JIT_CHAINS = {"jax.jit", "jit"}

    @staticmethod
    def _root_chain(func):
        """Like dotted(), but drills through call chaining so
        ``jnp.ones(...).astype(...)`` roots at ``jnp.ones``."""
        node, parts = func, []
        while True:
            if isinstance(node, ast.Attribute):
                parts.append(node.attr)
                node = node.value
            elif isinstance(node, ast.Call):
                parts = []          # root is whatever the inner call is
                node = node.func
            elif isinstance(node, ast.Name):
                parts.append(node.id)
                return ".".join(reversed(parts))
            else:
                return None

    def _collect_arrays(self, scope_node, inherited: set) -> set:
        arrays = set(inherited)
        for _ in range(2):  # catch w2 = w.astype(...) after w = jnp.ones(...)
            for st in _shallow_walk(scope_node):
                if not isinstance(st, ast.Assign) \
                        or not isinstance(st.value, ast.Call):
                    continue
                chain = self._root_chain(st.value.func)
                if not chain:
                    continue
                base = chain.split(".")[0]
                is_arr = (chain.startswith(self.ARRAY_PREFIXES)
                          or chain in self.ARRAY_FUNCS
                          or base in arrays)
                if not is_arr:
                    continue
                for t in st.targets:
                    if isinstance(t, ast.Name):
                        arrays.add(t.id)
                    elif isinstance(t, (ast.Tuple, ast.List)):
                        arrays.update(e.id for e in t.elts
                                      if isinstance(e, ast.Name))
        return arrays

    @staticmethod
    def _free_names(fn) -> set:
        """Loads in a function/lambda body not bound locally."""
        bound = set(_fn_params(fn))
        body = fn.body if isinstance(fn.body, list) else [fn.body]
        loads = set()
        for st in body:
            for n in ast.walk(st):
                if isinstance(n, ast.Name):
                    if isinstance(n.ctx, ast.Store):
                        bound.add(n.id)
                    else:
                        loads.add(n.id)
                elif isinstance(n, (ast.Import, ast.ImportFrom)):
                    for al in n.names:
                        bound.add(al.asname or al.name.split(".")[0])
        return loads - bound

    def _jit_targets(self, scope_node):
        """(report_node, fn_node_or_name) for each jit site in scope."""
        local_defs = {st.name: st for st in _shallow_walk(scope_node)
                      if isinstance(st, ast.FunctionDef)}
        for st in _shallow_walk(scope_node):
            if isinstance(st, ast.Call) and dotted(st.func) in self.JIT_CHAINS:
                tgt = st.args[0] if st.args else None
                if isinstance(tgt, ast.Lambda):
                    yield st, tgt
                elif isinstance(tgt, ast.Name) and tgt.id in local_defs:
                    yield st, local_defs[tgt.id]
        for name, fn in local_defs.items():
            for dec in fn.decorator_list:
                chain = dotted(dec) or dotted(getattr(dec, "func", None))
                if chain in self.JIT_CHAINS:
                    yield fn, fn

    def _scan_scope(self, scope_node, inherited, path, config, out):
        arrays = self._collect_arrays(scope_node, inherited)
        for report_node, fn in self._jit_targets(scope_node):
            closed = sorted(self._free_names(fn) & arrays)
            if closed:
                out.append(self.violation(
                    path, report_node,
                    f"jitted callable closes over array(s) {closed}; XLA "
                    "constant-folds them — pass as runtime operands "
                    "instead", config))
        for st in _shallow_walk(scope_node):
            if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._scan_scope(st, arrays, path, config, out)

    def check(self, tree, src, path, config):
        out: list[Violation] = []
        self._scan_scope(tree, set(), path, config, out)
        return out


# ---------------------------------------------------------------------------
# seeded-rng
# ---------------------------------------------------------------------------


@register
class SeededRngRule(Rule):
    name = "seeded-rng"
    severity = "error"
    description = ("No global np.random.* / random.* state in src/; use "
                   "np.random.default_rng(seed) (or jax.random keys).")
    why = ("Global RNG state makes runs order-dependent: importing a module "
           "that draws from np.random shifts every later draw, and two "
           "tests sharing the global stream can't reproduce in isolation.")
    default_scope = ("src/",)

    NP_ALLOWED = {"default_rng", "Generator", "SeedSequence", "PCG64",
                  "MT19937", "Philox", "bit_generator"}
    STDLIB_ALLOWED = {"Random", "SystemRandom"}

    def check(self, tree, src, path, config):
        out = []
        imports_stdlib_random = any(
            isinstance(n, ast.Import)
            and any(a.name == "random" and (a.asname or a.name) == "random"
                    for a in n.names)
            for n in ast.walk(tree))
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            chain = dotted(node.func)
            if not chain:
                continue
            parts = chain.split(".")
            if len(parts) >= 3 and parts[0] in ("np", "numpy") \
                    and parts[1] == "random" \
                    and parts[2] not in self.NP_ALLOWED:
                out.append(self.violation(
                    path, node,
                    f"{chain}() draws from the global numpy RNG; thread an "
                    "np.random.default_rng(seed) generator through instead",
                    config))
            elif imports_stdlib_random and len(parts) == 2 \
                    and parts[0] == "random" \
                    and parts[1] not in self.STDLIB_ALLOWED:
                out.append(self.violation(
                    path, node,
                    f"{chain}() uses the global stdlib RNG; use a seeded "
                    "random.Random(seed) instance", config))
        return out


# ---------------------------------------------------------------------------
# no-silent-fallback
# ---------------------------------------------------------------------------


@register
class NoSilentFallbackRule(Rule):
    name = "no-silent-fallback"
    severity = "error"
    description = ("Every ops branch routing off Pallas (_ref.* / "
                   "xla_serve.*) must go through _dispatch (which counts "
                   "it) or raise.")
    why = ("A silent fallback hides route regressions: the suite stays "
           "green while serving quietly runs the reference path at 10x "
           "cost. _dispatch increments the per-route counter and feeds the "
           "profiler, so a fallback is always visible in metrics.")
    default_scope = ("src/repro/kernels/ops.py",)

    FALLBACK_BASES = {"_ref", "xla_serve"}

    def check(self, tree, src, path, config):
        out = []
        for node, ancestors in _walk_with_ancestors(tree):
            if not isinstance(node, ast.Call):
                continue
            chain = dotted(node.func)
            if not chain or chain.split(".")[0] not in self.FALLBACK_BASES:
                continue
            routed = any(
                isinstance(a, ast.Call)
                and (dotted(a.func) or "").split(".")[-1] == "_dispatch"
                for a in ancestors)
            raised = any(isinstance(a, ast.Raise) for a in ancestors)
            if not routed and not raised:
                out.append(self.violation(
                    path, node,
                    f"off-Pallas call {chain}() bypasses _dispatch — wrap "
                    "it in the dispatch thunk so the fallback is counted, "
                    "or raise", config))
        return out
