"""repolint CLI.

    python -m tools.analysis --all-files            # CI gate
    python -m tools.analysis --changed              # pre-push loop
    python -m tools.analysis --changed --base main
    python -m tools.analysis --list-rules
    python -m tools.analysis --all-files --write-baseline

Exit status: 0 — clean (every finding baselined or suppressed, no stale
baseline entries); 1 — unbaselined violations and/or stale baseline
entries; 2 — usage error. Stale entries fail only ``--all-files`` runs:
a partial ``--changed`` run can't tell "fixed" from "not scanned".
"""
from __future__ import annotations

import argparse
import json
import os
import re
import sys
import time

from tools.analysis.framework import (CONFIG_PATH, all_rules, baseline_split,
                                      changed_files, collect_files,
                                      lint_file, load_config, run_files)


def _find_root(start: str) -> str:
    cur = os.path.abspath(start)
    while True:
        if os.path.isdir(os.path.join(cur, ".git")) or \
                os.path.exists(os.path.join(cur, CONFIG_PATH)):
            return cur
        parent = os.path.dirname(cur)
        if parent == cur:
            return os.path.abspath(start)
        cur = parent


def _write_baseline(root: str, keys: list[str]) -> str:
    """Rewrite the ``entries`` array under ``[baseline]`` in repolint.toml.

    Textual splice, not a re-serialize: everything outside the entries
    array (severities, scopes, layers, comments) is preserved verbatim.
    Hand-written justification comments *inside* the array are replaced —
    re-add them when re-baselining.
    """
    path = os.path.join(root, CONFIG_PATH)
    block = "entries = [\n" + "".join(f'    "{k}",\n' for k in keys) + "]"
    if not os.path.exists(path):
        text = "[baseline]\n" + block + "\n"
        with open(path, "w") as f:
            f.write(text)
        return path
    with open(path) as f:
        text = f.read()
    m = re.search(r"entries\s*=\s*\[", text)
    if m:
        i, depth, in_str = m.end(), 1, None
        while i < len(text) and depth:
            ch = text[i]
            if in_str:
                if ch == in_str:
                    in_str = None
            elif ch in ("'", '"'):
                in_str = ch
            elif ch == "[":
                depth += 1
            elif ch == "]":
                depth -= 1
            i += 1
        text = text[:m.start()] + block + text[i:]
    elif "[baseline]" in text:
        text = text.replace("[baseline]", "[baseline]\n" + block, 1)
    else:
        text = text.rstrip("\n") + "\n\n[baseline]\n" + block + "\n"
    with open(path, "w") as f:
        f.write(text)
    return path


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.analysis",
        description="repolint: AST invariant checks for this repo")
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--all-files", action="store_true",
                      help="lint every configured .py in the repo")
    mode.add_argument("--changed", action="store_true",
                      help="lint modified/staged/untracked .py files")
    mode.add_argument("--list-rules", action="store_true",
                      help="print the rule catalog and exit")
    ap.add_argument("--base", default="HEAD",
                    help="diff base for --changed (default HEAD)")
    ap.add_argument("--root", default=".", help="repo root (default: auto)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report baselined violations too")
    ap.add_argument("--write-baseline", action="store_true",
                    help="rewrite [baseline] entries from this run's "
                         "findings (use with --all-files)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("files", nargs="*",
                    help="explicit files to lint (overrides mode)")
    args = ap.parse_args(argv)

    root = _find_root(args.root)
    config = load_config(root)

    if args.list_rules:
        for rule in all_rules():
            sev = config.severity_for(rule)
            scope = ", ".join(config.scope_for(rule)) or "(everywhere)"
            print(f"{rule.name} [{sev}]  scope: {scope}")
            print(f"    {rule.description}")
        return 0

    t0 = time.perf_counter()
    if args.files:
        files = [os.path.abspath(f) for f in args.files]
    elif args.changed:
        try:
            files = changed_files(root, args.base)
        except RuntimeError as e:
            print(f"repolint: {e}", file=sys.stderr)
            return 2
    elif args.all_files:
        files = collect_files(root, config)
    else:
        ap.print_usage(sys.stderr)
        print("repolint: one of --all-files / --changed / --list-rules / "
              "explicit files is required", file=sys.stderr)
        return 2

    result = run_files(files, root, config)
    new, baselined, stale = baseline_split(result, config)
    if args.no_baseline:
        new, baselined = sorted(new + baselined), []
    # stale entries only fail full runs; a subset scan can't see every site
    check_stale = args.all_files
    wall_s = time.perf_counter() - t0

    if args.write_baseline:
        keys = sorted({v.key for v in new} | {v.key for v in baselined})
        path = _write_baseline(root, keys)
        print(f"repolint: wrote {len(keys)} baseline entries to {path}")
        return 0

    failing = [v for v in new if v.severity == "error"]
    warnings = [v for v in new if v.severity != "error"]
    ok = not failing and not (stale and check_stale)

    if args.format == "json":
        print(json.dumps({
            "ok": ok,
            "files": result.files,
            "wall_s": round(wall_s, 3),
            "violations": [vars(v) for v in new],
            "baselined": [v.key for v in baselined],
            "stale_baseline": stale if check_stale else [],
            "suppressed": result.suppressed,
        }, indent=2))
        return 0 if ok else 1

    for v in new:
        print(v.format())
    if check_stale:
        for key in stale:
            print(f"(baseline) stale entry '{key}': no longer fires — "
                  "remove it from tools/analysis/repolint.toml (or run "
                  "--write-baseline)")
    print(f"repolint: {len(files)} files in {wall_s:.2f}s — "
          f"{len(failing)} error(s), {len(warnings)} warning(s), "
          f"{len(baselined)} baselined, {result.suppressed} suppressed"
          + (f", {len(stale)} stale baseline entr"
             f"{'y' if len(stale) == 1 else 'ies'}"
             if check_stale and stale else ""))
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
